"""Overload A/B: offered load vs completion latency, with and without the
progress engine's priority lanes + per-peer credit windows.

The scenario the layered runtime exists for: a continuous stream of gather
requests saturates a hot shard (bulk key-frames in, bulk RETURN data out)
while the control plane concurrently tree-publishes fresh code
(benchmarks/propagate.py's multicast) through the same congested PEs.
Under the old single-lane FIFO runtime a PUBLISH hop queues behind every
bulk frame that arrived before it, so code distribution latency grows
linearly with data backlog; with **lanes** on, control frames drain first
at every hop, and with a **credit window** the client cannot flood a slow
shard's receive queue in the first place (excess sends queue locally,
``TrafficStats.credit_stalls`` counts them).

Both arms run the *same* bounded progress engine (``poll_budget`` frames
processed per poll — an overloaded PE never drains its backlog in one
tick), so the A/B isolates scheduling policy, not engine throughput:

  ``baseline``  lanes off, credits off — the pre-layering FIFO drain.
  ``flow``      lanes on, per-peer credit window on.

Latency unit: deterministic scheduler ticks (one service round: admit ->
flush -> poll every PE -> retire), the same clock for both arms.  Every
run is oracle-checked — gather rows bit-identical to numpy take, every
server's counter incremented by the broadcast TSI exactly once — before
any number is reported.

``python -m benchmarks.overload --ab --json BENCH_overload.json`` records
the committed trajectory (guarded by benchmarks/check_regression.py);
``--tiny`` is the CI fast-lane smoke.
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster, make_tsi
from repro.runtime.embed_service import EmbedShardService

from .hw_model import PROFILES

TSI_VALUE = 7
MAX_TICKS = 200_000


def hot_batches(
    vocab: int,
    rows_per_shard: int,
    n_requests: int,
    n_keys: int,
    seed: int,
    hot_frac: float = 0.8,
) -> list[np.ndarray]:
    """Ragged key batches skewed onto shard 0: ``hot_frac`` of requests
    draw every key from the hot shard's row range, the rest uniformly —
    the hot-key distribution that actually overloads one PE."""
    rng = np.random.default_rng(seed)
    batches = []
    for _ in range(n_requests):
        n = int(rng.integers(1, n_keys + 1))
        hi = rows_per_shard if rng.random() < hot_frac else vocab
        batches.append(rng.integers(0, hi, n).astype(np.int32))
    return batches


def overload_run(
    n_servers: int,
    offered: int,
    *,
    lanes: bool,
    credit_window: int,
    poll_budget: int,
    profile: str = "thor_bf2",
    n_keys: int = 8,
    dim: int = 16,
    vocab_per_shard: int = 64,
    max_slots: int = 64,
    publish_tick: int = 3,
    seed: int = 0,
) -> dict:
    """One arm: ``offered`` gather requests against a hot shard, with a
    TSI tree-publish injected at ``publish_tick``.  Returns per-arm
    latency/backlog/wire accounting (all latencies in scheduler ticks)."""
    vocab = vocab_per_shard * n_servers
    cl = Cluster(n_servers=n_servers, wire=profile)
    svc = EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=n_keys, max_slots=max_slots, seed=seed
    )
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, np.int32))
    cl.toolchain.publish(make_tsi())
    batches = hot_batches(
        vocab, svc.rows_per_shard, offered, n_keys, seed + 1
    )
    want = svc.oracle(batches)
    # warm the gather path (code movement + the common pad buckets) before
    # measuring, so both arms start from the same steady state
    svc.gather(batches[: min(16, offered)], batching=True)

    cl.set_batching(True)
    svc.batching = True
    cl.set_flow(lanes=lanes, credit_window=credit_window, poll_budget=poll_budget)
    cl.fabric.stats.reset()

    rids = [svc.submit(b) for b in batches]
    done_tick: dict[int, int] = {}
    n_done0 = len(svc.finished)
    tick = 0
    hop_done = None
    max_backlog = 0
    max_sender_queue = 0
    while svc.queue or svc.active or hop_done is None:
        tick += 1
        if tick == publish_tick:
            cl.client.publish_ifunc("tsi", np.array([TSI_VALUE], np.int32))
        svc.tick()
        for req in svc.finished[n_done0 + len(done_tick):]:
            done_tick[req.rid] = tick
        if hop_done is None and tick >= publish_tick and all(
            int(pe.region("counter")[0]) == TSI_VALUE for pe in cl.servers
        ):
            hop_done = tick
        max_backlog = max(
            max_backlog,
            max(
                len(pe.endpoint.inbox) + pe.progress.pending()
                for pe in cl.servers
            ),
        )
        max_sender_queue = max(
            max_sender_queue, cl.client.wire.queued_credit_frames()
        )
        if tick > MAX_TICKS:
            raise TimeoutError(f"overload run did not settle in {MAX_TICKS} ticks")
    # oracle: every gather bit-identical, every counter incremented exactly once
    finished = {r.rid: r for r in svc.finished[n_done0:]}
    for rid, w in zip(rids, want):
        assert np.array_equal(finished[rid].rows, w), "gather diverged from oracle"
    counters = [int(pe.region("counter")[0]) for pe in cl.servers]
    assert counters == [TSI_VALUE] * n_servers, counters
    lat = np.array([done_tick[r] for r in rids], np.int64)
    st = cl.fabric.stats
    return {
        "hop_ticks": hop_done - publish_tick,
        "req_mean_ticks": round(float(lat.mean()), 2),
        "req_p95_ticks": int(np.percentile(lat, 95)),
        "req_max_ticks": int(lat.max()),
        "total_ticks": tick,
        "max_receiver_backlog": max_backlog,
        "max_sender_queue": max_sender_queue,
        "credit_stalls": st.credit_stalls,
        "puts": st.puts,
        "wire_bytes": st.put_bytes + st.get_bytes + st.region_put_bytes,
        "modeled_us": round(st.modeled_us, 3),
    }


def overload_ab(
    n_servers: int = 16,
    offered_loads: tuple[int, ...] = (64, 256),
    poll_budget: int = 8,
    credit_window: int = 8,
    profile: str = "thor_bf2",
    seed: int = 0,
) -> dict:
    """The A/B sweep: each offered load runs the baseline (single-lane
    FIFO, no credits) and the flow arm (lanes + credit window) on fresh
    but identically-seeded clusters."""
    sweep = []
    for offered in offered_loads:
        arms = {}
        for label, lanes, window in (
            ("baseline", False, 0),
            ("flow", True, credit_window),
        ):
            arms[label] = overload_run(
                n_servers,
                offered,
                lanes=lanes,
                credit_window=window,
                poll_budget=poll_budget,
                profile=profile,
                seed=seed,
            )
        sweep.append({"offered": offered, **arms})
    top = sweep[-1]
    base, flow = top["baseline"], top["flow"]
    return {
        "config": {
            "n_servers": n_servers,
            "offered_loads": list(offered_loads),
            "poll_budget": poll_budget,
            "credit_window": credit_window,
            "profile": profile,
        },
        "sweep": sweep,
        # the headline: control-plane latency under peak data overload
        "hop_ticks_baseline": base["hop_ticks"],
        "hop_ticks_flow": flow["hop_ticks"],
        "hop_latency_improvement_pct": round(
            100 * (1 - flow["hop_ticks"] / max(base["hop_ticks"], 1)), 2
        ),
        # credits keep the hot shard's receive backlog bounded; the excess
        # waits at the sender (counted as credit stalls)
        "receiver_backlog_ratio": round(
            base["max_receiver_backlog"] / max(flow["max_receiver_backlog"], 1), 2
        ),
        "flow_credit_stalls": flow["credit_stalls"],
        "oracle_checked": True,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true",
                    help="baseline vs lanes+credits sweep (the only mode)")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--servers", type=int, default=16)
    ap.add_argument("--loads", type=int, nargs="+", default=None,
                    help="offered-load sweep points (requests per burst)")
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--profile", default="thor_bf2", choices=PROFILES)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test size (4 servers, one small load)")
    ap.add_argument(
        "--trace",
        metavar="PATH",
        help="capture the hot-shard request stream (default runtime) to a "
             "replayable JSONL trace",
    )
    args = ap.parse_args()

    if args.trace:
        from repro.analysis import capture, replay_stats, save_trace

        n_servers = 4 if args.tiny else args.servers
        offered = 32 if args.tiny else 64
        vocab = 64 * n_servers
        cl = Cluster(n_servers=n_servers, wire=args.profile)
        svc = EmbedShardService(cl, vocab=vocab, dim=16, n_keys=8)
        batches = hot_batches(vocab, svc.rows_per_shard, offered, 8, seed=1)
        want = svc.oracle(batches)
        svc.gather(batches[:16], batching=False)  # warm off-trace
        with capture(
            cl, meta={"workload": "overload", "profile": args.profile}
        ) as rec:
            rep = svc.gather(batches, batching=False)
        for got, w in zip(rep.results, want):
            assert np.array_equal(got, w), "trace run diverged from oracle"
        st, _ = replay_stats(rec)
        assert st.as_dict() == cl.fabric.stats.as_dict(), "replay != live"
        n = save_trace(rec, args.trace)
        print(f"captured {n} events -> {args.trace} (replay verified)")

    out = overload_ab(
        n_servers=4 if args.tiny else args.servers,
        offered_loads=tuple(args.loads) if args.loads else (
            (32,) if args.tiny else (64, 256)
        ),
        poll_budget=args.budget,
        credit_window=args.window,
        profile=args.profile,
    )
    if not args.tiny:
        # acceptance floor: under peak overload, lanes+credits must cut the
        # control-plane hop latency and the flow arm must actually have
        # exercised the credit window (at tiny sizes it merely has to be
        # correct)
        assert out["hop_latency_improvement_pct"] > 0.0, out
        assert out["flow_credit_stalls"] > 0, out
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
