"""Trace-driven knob autotuning: capture → replay → tune → live A/B proof.

Closes the loop over the runtime's grown configuration space (batching,
data-plane thresholds, credit windows, poll budgets, lanes, propagation
fanout): for each calibrated hardware profile × workload cell,

  1. run the workload once under the hand-tuned default runtime with a
     :class:`repro.analysis.TraceRecorder` attached and prove *replay
     fidelity* — ``replay_stats`` over the captured event stream must
     reproduce the live fabric's ``TrafficStats`` bit-identically;
  2. save the trace to JSONL, reload it from disk, and coordinate-descend
     the knob grid against the :class:`repro.analysis.ReplayModel`
     (``autotune``) — the tuned :class:`FlowProfile` is derived from the
     file alone;
  3. A/B the tuned profile against the default *live*, loading the tuned
     knobs back through ``Cluster.set_flow(profile=<path>)``, with every
     arm verified against the numpy oracle before any number is reported.

The headline metrics are the **minimum** improvement across all cells —
tuned must beat the hand-tuned default on every profile × workload pair,
on both the replay estimate and the live run, or the guard in
``benchmarks/check_regression.py`` fails.  ``python -m benchmarks.autotune
--ab --json BENCH_autotune.json`` records the trajectory; ``--tiny`` is
the CI fast-lane smoke (thor_xeon only, small sizes).
"""

from __future__ import annotations

import os

import numpy as np

from repro.analysis import (
    FlowProfile,
    autotune,
    capture,
    load_trace,
    replay_stats,
    save_trace,
)
from repro.core import Cluster, PointerChaseApp, chase_ref
from repro.runtime.embed_service import EmbedShardService, ragged_batches

#: Default (profile, workload) sizes of the committed BENCH_autotune.json.
FULL = {
    "dapc": dict(n_servers=8, depth=64, n_chases=256, n_entries=1 << 14),
    "gather": dict(
        n_servers=8, n_requests=256, n_keys=8, dim=32, vocab=4096, max_slots=64
    ),
}
#: Fast-lane smoke sizes (seconds, not minutes).
TINY = {
    "dapc": dict(n_servers=4, depth=16, n_chases=32, n_entries=1 << 10),
    "gather": dict(
        n_servers=4, n_requests=32, n_keys=4, dim=8, vocab=512, max_slots=16
    ),
}


def _dapc_workload(profile: str, sizes: dict, seed: int):
    cl = Cluster(n_servers=sizes["n_servers"], wire=profile)
    app = PointerChaseApp(
        cl, n_entries=sizes["n_entries"], max_slots=sizes["n_chases"], seed=seed
    )
    rng = np.random.default_rng(seed + 1)
    starts = rng.integers(0, sizes["n_entries"], sizes["n_chases"]).astype(np.int32)
    depth = sizes["depth"]
    expect = np.array([chase_ref(app.table, s, depth) for s in starts], np.int32)

    def warm() -> None:
        app.dapc(starts, depth)
        app.dapc(starts, depth, batching=True)

    def run(batching: bool = False, dataplane=None):
        rep = app.dapc(starts, depth, batching=batching, dataplane=dataplane)
        assert np.array_equal(rep.results, expect), "dapc diverged from oracle"
        return rep

    return cl, warm, run


def _gather_workload(profile: str, sizes: dict, seed: int):
    cl = Cluster(n_servers=sizes["n_servers"], wire=profile)
    svc = EmbedShardService(
        cl,
        vocab=sizes["vocab"],
        dim=sizes["dim"],
        n_keys=sizes["n_keys"],
        max_slots=sizes["max_slots"],
        seed=seed,
    )
    batches = ragged_batches(
        sizes["vocab"], sizes["n_requests"], sizes["n_keys"], seed + 1
    )
    want = svc.oracle(batches)

    def warm() -> None:
        svc.gather(batches[: min(32, len(batches))], batching=False)
        svc.gather(batches, batching=True)

    def run(batching: bool = False, dataplane=None):
        rep = svc.gather(batches, batching=batching, dataplane=dataplane)
        for got, wanted in zip(rep.results, want):
            assert np.array_equal(got, wanted), "gather diverged from oracle"
        return rep

    return cl, warm, run


WORKLOADS = {"dapc": _dapc_workload, "gather": _gather_workload}


def tune_cell(
    workload: str,
    profile: str,
    sizes: dict,
    seed: int = 0,
    trace_dir: str | None = "traces",
) -> dict:
    """One capture → replay-fidelity → tune → live-A/B cell."""
    cl, warm, run = WORKLOADS[workload](profile, sizes, seed)
    warm()  # code caches + pad-bucket compiles on both sides of the A/B

    # -- 1. capture the default (per-message, framed) arm
    with capture(cl, meta={"workload": workload, "profile": profile, **sizes}) as rec:
        live_default = run()

    # replay fidelity: the event stream alone must reproduce the live
    # run's aggregate counters bit-identically (floats included)
    st, _ = replay_stats(rec)
    live = cl.fabric.stats.as_dict()
    assert st.as_dict() == live, "trace replay diverged from live TrafficStats"

    # -- 2. tune from the serialized artifact, not the in-memory recorder
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        trace_path = os.path.join(trace_dir, f"autotune_{profile}_{workload}.jsonl")
        save_trace(rec, trace_path)
        trace = load_trace(trace_path)
    else:
        trace_path = None
        trace = rec
    report = autotune(trace, seed=seed)
    tuned = report.profile

    # -- 3. live A/B: install the tuned knobs through the disk loader
    live_default2 = run()  # fresh default arm without the tracer attached
    assert live_default2.modeled_us == live_default.modeled_us, (
        "capture is not zero-cost: modeled_us changed with the tracer attached"
    )
    if trace_dir:
        profile_path = os.path.join(
            trace_dir, f"flowprofile_{profile}_{workload}.json"
        )
        tuned.save(profile_path)
        cl.set_flow(profile=profile_path)  # flow knobs persist across runs
        loaded = FlowProfile.load(profile_path)
        assert loaded == tuned, "FlowProfile did not round-trip through disk"
    else:
        tuned.apply(cl)
    # the apps pin batching/data plane per call (and restore after), so the
    # tuned arm passes those two explicitly; lanes/credit/poll/propagation
    # stay installed from the profile
    live_tuned = run(batching=tuned.batching, dataplane=tuned.dataplane())

    live_impr = 100.0 * (1.0 - live_tuned.modeled_us / live_default.modeled_us)
    row = {
        "workload": workload,
        "profile": profile,
        "trace_events": len(rec),
        "trace_path": trace_path,
        "tuned_profile": tuned.as_dict(),
        "knob_order": list(report.knob_order),
        "history": list(report.history),
        "replay": {
            "default_us": round(report.default_us, 3),
            "tuned_us": round(report.tuned_us, 3),
            "improvement_pct": round(report.improvement_pct, 2),
            "evaluations": report.evaluations,
            "passes": report.passes,
        },
        "live": {
            "default_us": round(live_default.modeled_us, 3),
            "tuned_us": round(live_tuned.modeled_us, 3),
            "improvement_pct": round(live_impr, 2),
        },
        "replay_fidelity": True,
        "oracle_checked": True,
    }
    return row


def autotune_ab(
    profiles: tuple[str, ...] = ("thor_xeon", "thor_bf2"),
    workloads: tuple[str, ...] = ("dapc", "gather"),
    sizes: dict | None = None,
    seed: int = 0,
    trace_dir: str | None = "traces",
) -> dict:
    """The full matrix: every profile × workload cell, headline = worst cell."""
    sizes = sizes or FULL
    cells = []
    for profile in profiles:
        for workload in workloads:
            cells.append(tune_cell(workload, profile, sizes[workload], seed, trace_dir))
    return {
        "config": {
            "profiles": list(profiles),
            "workloads": list(workloads),
            "sizes": {w: dict(sizes[w]) for w in workloads},
            "seed": seed,
        },
        "cells": cells,
        "min_replay_improvement_pct": min(
            c["replay"]["improvement_pct"] for c in cells
        ),
        "min_live_improvement_pct": min(c["live"]["improvement_pct"] for c in cells),
        "oracle_checked": all(c["oracle_checked"] for c in cells),
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true", help="profile×workload A/B matrix")
    ap.add_argument("--tiny", action="store_true", help="fast-lane smoke sizes")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--trace-dir",
        default="traces",
        help="directory for trace/profile artifacts ('' disables disk round-trip)",
    )
    args = ap.parse_args()

    out = autotune_ab(
        profiles=("thor_xeon",) if args.tiny else ("thor_xeon", "thor_bf2"),
        sizes=TINY if args.tiny else FULL,
        seed=args.seed,
        trace_dir=args.trace_dir or None,
    )
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
