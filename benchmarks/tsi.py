"""Target-Side Increment (TSI) benchmark — paper Tables I-VI.

Measures, for Active Message / uncached bitcode ifunc / cached bitcode
ifunc (and binary ifuncs, Sec. V-A last paragraph):

  * wire bytes of each frame kind (exact — this is what the caching
    protocol is about),
  * lookup+execution time on the target (measured in-process),
  * one-time JIT compilation cost (measured; LLVM ORC-JIT's analogue is
    jax.export deserialize + jit compile),
  * transmission time (modeled with the paper-calibrated wire profiles),
  * end-to-end latency + message rate per profile, with the paper's
    speedup ratios recomputed on our numbers.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import Cluster, FrameKind, make_tsi
from repro.core.frame import Frame

from .hw_model import PAPER, PROFILES, wire


@dataclass
class TsiRow:
    mode: str
    wire_bytes_uncached: int
    wire_bytes_cached: int
    lookup_exec_us: float
    jit_ms: float | None
    trans_us: dict[str, float] = field(default_factory=dict)
    total_us: dict[str, float] = field(default_factory=dict)
    rate_msg_s: dict[str, float] = field(default_factory=dict)


def _measure_lookup_exec(cluster: Cluster, send, n: int = 300) -> float:
    """Target-side handling time per message (poll+install-hit+invoke)."""
    server = cluster.servers[0]
    send()  # warm: first message installs + JITs
    server.poll()
    for _ in range(10):
        send()
    server.poll()
    t0 = time.perf_counter()
    for _ in range(n):
        send()
    server.poll()
    return (time.perf_counter() - t0) / n * 1e6


def run_tsi(n: int = 300) -> dict:
    rows: list[TsiRow] = []

    def fresh_cluster() -> Cluster:
        cl = Cluster(n_servers=1, wire="ideal")
        cl.servers[0].register_region("counter", np.zeros(1, np.int32))
        cl.toolchain.publish(make_tsi())
        cl.toolchain.publish(make_tsi(targets=("cpu-bf2",), kind=FrameKind.BINARY, name="tsi_bin"))

        def am_handler(pe, payload):
            pe.region("counter")[0] += np.frombuffer(payload, np.int32)[0]

        cl.servers[0].am_table["tsi"] = am_handler
        return cl

    payload = np.ones(1, np.int32)

    # ---------------- frame sizes (exact)
    cl = fresh_cluster()
    tsi = cl.toolchain.lookup("tsi")
    frame = tsi.make_frame(payload.tobytes())
    am_frame = Frame(kind=FrameKind.ACTIVE_MESSAGE, name="tsi", payload=payload.tobytes())
    tsi_bin = cl.toolchain.lookup("tsi_bin")
    bin_frame = tsi_bin.make_frame(payload.tobytes())
    sizes = {
        "am": (am_frame.cached_nbytes, am_frame.cached_nbytes),
        "bitcode": (frame.full_nbytes, frame.cached_nbytes),
        "binary": (bin_frame.full_nbytes, bin_frame.cached_nbytes),
    }

    # ---------------- measured target-side times
    cl = fresh_cluster()
    am_us = _measure_lookup_exec(
        cl, lambda: cl.client.send_am("server0", "tsi", payload), n
    )
    cl = fresh_cluster()
    cached_us = _measure_lookup_exec(
        cl, lambda: cl.client.send_ifunc("server0", "tsi", payload), n
    )
    jit_ms = cl.servers[0].stats.jit_ms_total  # one install happened

    # uncached: the Three-Chains registry is forgotten each message (full
    # frames travel, the install path runs), but the digest-keyed JIT
    # artifact survives — matching the paper's observation that ORC-JIT's
    # internal caching makes re-JIT of already-seen code free (Sec. V-A).
    cl = fresh_cluster()
    server = cl.servers[0]
    cl.client.send_ifunc("server0", "tsi", payload)
    server.poll()
    t_unc = []
    for _ in range(60):
        server.target_cache.forget_names()
        cl.client.sender_cache._seen.clear()
        t0 = time.perf_counter()
        cl.client.send_ifunc("server0", "tsi", payload)
        server.poll()
        t_unc.append(time.perf_counter() - t0)
    uncached_us = float(np.mean(t_unc) * 1e6)

    stages = {"am": am_us, "bitcode_cached": cached_us, "bitcode_uncached": uncached_us}

    # ---------------- assemble per-profile tables
    for mode in ("am", "bitcode", "binary"):
        unc_b, cach_b = sizes[mode]
        row = TsiRow(
            mode=mode,
            wire_bytes_uncached=unc_b,
            wire_bytes_cached=cach_b,
            lookup_exec_us=cached_us if mode != "am" else am_us,
            jit_ms=jit_ms if mode == "bitcode" else None,
        )
        for p in PROFILES:
            w = wire(p)
            row.trans_us[p] = w.latency_us(cach_b)
            row.total_us[p] = w.latency_us(cach_b) + row.lookup_exec_us
            row.rate_msg_s[p] = 1e6 / (w.inverse_throughput_us(cach_b))
        rows.append(row)

    # uncached bitcode as its own pseudo-row
    unc = TsiRow(
        mode="bitcode_uncached",
        wire_bytes_uncached=sizes["bitcode"][0],
        wire_bytes_cached=sizes["bitcode"][0],
        lookup_exec_us=uncached_us,
        jit_ms=jit_ms,
    )
    for p in PROFILES:
        w = wire(p)
        b = sizes["bitcode"][0]
        unc.trans_us[p] = w.latency_us(b)
        unc.total_us[p] = w.latency_us(b) + uncached_us
        unc.rate_msg_s[p] = 1e6 / w.inverse_throughput_us(b)
    rows.append(unc)

    # ---------------- claim ratios (paper: Tables IV-VI)
    claims = {}
    get = lambda m: next(r for r in rows if r.mode == m)
    for p in PROFILES:
        cached = get("bitcode")
        uncached = get("bitcode_uncached")
        am = get("am")
        # Latency claims are computed in the paper's regime — transmission-
        # dominated, with sub-us target handling (their Lookup+Exec is
        # 0.01-0.10 us).  Our measured in-process handling (~100 us of jax
        # dispatch on this 1-core container) is reported separately in
        # rows[].lookup_exec_us and deliberately kept OUT of the ratio: it
        # is a runtime artifact that exists identically on both sides of
        # every comparison and would otherwise mask the byte-count effect
        # the paper's caching argument is about.
        claims[p] = {
            "uncached_vs_cached_latency_pct": 100 * (uncached.trans_us[p] / cached.trans_us[p] - 1),
            "cached_vs_uncached_rate_pct": 100 * (cached.rate_msg_s[p] / uncached.rate_msg_s[p] - 1),
            "cached_vs_am_latency_pct": 100 * (cached.trans_us[p] / am.trans_us[p] - 1),
            "cached_vs_am_rate_pct": 100 * (cached.rate_msg_s[p] / am.rate_msg_s[p] - 1),
            "measured_uncached_vs_cached_total_pct": 100
            * (uncached.total_us[p] / cached.total_us[p] - 1),
            "paper_uncached_vs_cached_latency_pct": 100
            * (PAPER[p]["uncached_lat_us"] / PAPER[p]["cached_lat_us"] - 1),
            "paper_cached_vs_uncached_rate_pct": 100
            * (PAPER[p]["cached_rate"] / PAPER[p]["uncached_rate"] - 1),
            "paper_cached_vs_am_rate_pct": 100
            * (PAPER[p]["cached_rate"] / PAPER[p]["am_rate"] - 1),
        }

    return {
        "rows": [r.__dict__ for r in rows],
        "stages_us": stages,
        "jit_ms": jit_ms,
        "claims": claims,
    }


def main() -> None:
    import json

    out = run_tsi()
    print(json.dumps(out, indent=1, default=float))


if __name__ == "__main__":
    main()
