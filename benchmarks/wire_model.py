"""Protocol-crossover microbenchmark: where does rendezvous start to win?

Sweeps RETURN payload sizes per calibrated wire profile through the three
modeled delivery costs (see repro.core.dataplane):

  framed eager   alpha + (hdr + n)/beta + n/COPY_BUS   (bounce copy out of
                 the receive buffer — the cost real NICs pay for
                 unexpected eager messages)
  zerocopy       alpha + (n + 4)/beta                  (lands in place)
  rendezvous     alpha + (hdr + 16)/beta + 2*alpha + n/beta

and emits the eager->rendezvous crossover point per profile, validating
the default thresholds: ``DEFAULT_EAGER_MAX`` must sit well below every
profile's crossover (payloads that small should never pay the rendezvous
round trip) and ``DEFAULT_RNDV_MIN`` within the band the calibrated
profiles span (tens of KB — the same order as UCX's default).

``python -m benchmarks.wire_model --json BENCH_wire_model.json``
"""

from __future__ import annotations

from repro.core.dataplane import (
    DEFAULT_EAGER_MAX,
    DEFAULT_RNDV_MIN,
    eager_rndv_crossover,
    framed_us,
    rendezvous_us,
    zerocopy_us,
)
from repro.core.transport import WIRE_PROFILES

SWEEP = [64, 256, 1024, 4096, 16384, 32768, 65536, 262144, 1048576]
CALIBRATED = ("ookami", "thor_bf2", "thor_xeon")


def sweep_profile(name: str) -> dict:
    wire = WIRE_PROFILES[name]
    rows = []
    for n in SWEEP:
        rows.append(
            {
                "payload_bytes": n,
                "framed_us": round(framed_us(wire, n), 3),
                "zerocopy_us": round(zerocopy_us(wire, n), 3),
                "rendezvous_us": round(rendezvous_us(wire, n), 3),
            }
        )
    crossover = eager_rndv_crossover(wire)
    return {
        "profile": name,
        "alpha_us": wire.alpha_us,
        "beta_Bus": wire.beta_Bus,
        "sweep": rows,
        "eager_rndv_crossover_bytes": crossover,
    }


def validate(results: list[dict]) -> dict:
    """The threshold-validation claims the CI lane asserts on."""
    crossovers = {r["profile"]: r["eager_rndv_crossover_bytes"] for r in results}
    lo, hi = min(crossovers.values()), max(crossovers.values())
    return {
        "crossovers": crossovers,
        "default_eager_max": DEFAULT_EAGER_MAX,
        "default_rndv_min": DEFAULT_RNDV_MIN,
        # eager_max far below any crossover: small payloads never pay 2*alpha
        "eager_max_below_all_crossovers": DEFAULT_EAGER_MAX < lo,
        # rndv_min inside the calibrated band (order-of-magnitude check:
        # within [lo/4, hi*4] of the profiles' crossovers)
        "rndv_min_within_band": lo / 4 <= DEFAULT_RNDV_MIN <= hi * 4,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    args = ap.parse_args()

    results = [sweep_profile(p) for p in CALIBRATED]
    out = {"profiles": results, "validation": validate(results)}
    assert out["validation"]["eager_max_below_all_crossovers"], out["validation"]
    assert out["validation"]["rndv_min_within_band"], out["validation"]
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
