"""Multi-tenant isolation A/B: per-tenant QoS vs a free-for-all fabric.

The serving tier's claim (runtime/tenancy.py): one hot tenant flooding the
shared embedding-shard substrate must not take the background tenants'
tail latency with it.  Three arms, identical workload schedule:

  ``solo``       each tenant alone on its own cluster, unthrottled — the
                 per-tenant baseline its shared-arm latency is judged
                 against.
  ``shared``     every tenant on one cluster, no QoS classes — the
                 failure mode: background p95 collapses behind the hot
                 tenant's backlog.
  ``qos``        every tenant on one cluster under TenantRouter QoS —
                 the hot tenant is confined to a CQ-slot quota + credit
                 budget and shed at its queue limit; background tenants
                 ride the express lane.

Isolation holds when, in the ``qos`` arm, every background tenant's p95
stays within ~1.2x of its solo baseline while the hot tenant's p95
degrades >=3x against *its* solo baseline (the throttle is real) — and
shedding is exactly-once: a shed request never produces rows, an accepted
one produces exactly one result, bit-identical to the numpy oracle.

Latency unit: deterministic scheduler ticks (submit tick -> retire tick
on the service's clock), the same clock in every arm.

``python -m benchmarks.tenancy --ab --json BENCH_tenancy.json`` records
the committed trajectory (guarded by benchmarks/check_regression.py);
``--tiny`` is the CI fast-lane smoke.
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster
from repro.runtime.embed_service import EmbedShardService
from repro.runtime.tenancy import TenantClass, TenantRouter

from .hw_model import PROFILES

MAX_TICKS = 200_000


def make_schedule(
    tenants: "list[tuple[str, int]]",
    vocab: int,
    n_keys: int,
    duration: int,
    seed: int,
) -> "list[list[tuple[str, np.ndarray]]]":
    """Per-tick submission plan: ``rate`` uniform-random key batches per
    tenant per tick, pre-drawn so every arm replays the identical offered
    load (the solo arms replay just their tenant's slice)."""
    rng = np.random.default_rng(seed)
    plan: list[list[tuple[str, np.ndarray]]] = []
    for _ in range(duration):
        tick_plan: list[tuple[str, np.ndarray]] = []
        for name, rate in tenants:
            for _ in range(rate):
                n = int(rng.integers(1, n_keys + 1))
                tick_plan.append(
                    (name, rng.integers(0, vocab, n).astype(np.int32))
                )
        plan.append(tick_plan)
    return plan


def run_arm(
    classes: "list[TenantClass]",
    plan: "list[list[tuple[str, np.ndarray]]]",
    *,
    n_servers: int,
    profile: str,
    n_keys: int,
    dim: int,
    vocab_per_shard: int,
    max_slots: int,
    poll_budget: int,
    credit_window: int,
    seed: int,
) -> dict:
    """Replay one schedule against one cluster/QoS configuration; returns
    the router's per-tenant report plus the arm's shed-accuracy oracle."""
    vocab = vocab_per_shard * n_servers
    cl = Cluster(n_servers=n_servers, wire=profile)
    svc = EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=n_keys, max_slots=max_slots, seed=seed
    )
    names = {c.name for c in classes}
    # warm the gather path (code movement + pad buckets) before measuring
    svc.gather([b for tick in plan[:2] for t, b in tick if t in names] or
               [np.arange(1, n_keys + 1, dtype=np.int32)], batching=True)
    cl.set_batching(True)
    svc.batching = True
    cl.set_flow(lanes=True, credit_window=credit_window, poll_budget=poll_budget)
    router = TenantRouter(svc, classes)

    expected: dict[int, np.ndarray] = {}
    done = []
    for tick_plan in plan:
        for tenant, keys in tick_plan:
            if tenant not in names:
                continue
            rid = router.submit(tenant, keys)
            if rid is not None:
                expected[rid] = svc.table[keys]
        done += router.tick()
    ticks = len(plan)
    while svc.queue or svc.active:
        done += router.tick()
        ticks += 1
        if ticks > MAX_TICKS:
            raise TimeoutError(f"arm did not drain in {MAX_TICKS} ticks")

    # oracle 1: every accepted request retired exactly once, bit-identical
    served = [r for r in done if r.rid in expected]
    rids = [r.rid for r in served]
    exactly_once = len(rids) == len(set(rids)) == len(expected)
    for req in served:
        assert not req.degraded, f"rid={req.rid} degraded on a lossless fabric"
        assert np.array_equal(req.rows, expected[req.rid]), (
            f"rid={req.rid} diverged from oracle"
        )
    # oracle 2: a shed request never entered the fabric, so accepted+shed
    # must account for every submission attempt
    attempts = sum(1 for tp in plan for t, _ in tp if t in names)
    shed = sum(st.shed for st in router.stats.values())
    assert len(expected) + shed == attempts, "shed/accepted accounting broken"
    return {
        "tenants": router.report(),
        "drain_ticks": ticks,
        "shed_total": shed,
        "shed_exactly_once": exactly_once,
        "credit_stalls": cl.fabric.stats.credit_stalls,
        "tenant_stalls": dict(cl.fabric.stats.tenant_stalls),
    }


def tenancy_ab(
    n_servers: int = 8,
    duration: int = 40,
    hot_rate: int = 8,
    n_bg: int = 3,
    bg_rate: int = 1,
    hot_slot_quota: int = 2,
    hot_queue_limit: int = 10,
    hot_credit_budget: int = 1,
    poll_budget: int = 32,
    credit_window: int = 8,
    max_slots: int = 32,
    n_keys: int = 8,
    dim: int = 16,
    vocab_per_shard: int = 64,
    profile: str = "thor_bf2",
    seed: int = 0,
) -> dict:
    """The A/B: solo baselines, the unprotected shared arm, and the QoS
    arm, all replaying one pre-drawn schedule."""
    vocab = vocab_per_shard * n_servers
    tenants = [("hot", hot_rate)] + [(f"bg{i}", bg_rate) for i in range(n_bg)]
    plan = make_schedule(tenants, vocab, n_keys, duration, seed + 1)
    kw = dict(
        n_servers=n_servers, profile=profile, n_keys=n_keys, dim=dim,
        vocab_per_shard=vocab_per_shard, max_slots=max_slots,
        poll_budget=poll_budget, credit_window=credit_window, seed=seed,
    )
    qos_classes = [
        TenantClass(
            "hot",
            slot_quota=hot_slot_quota,
            queue_limit=hot_queue_limit,
            credit_budget=hot_credit_budget,
        )
    ] + [TenantClass(f"bg{i}", express=True) for i in range(n_bg)]
    free_classes = [TenantClass(name) for name, _ in tenants]

    solo = {
        name: run_arm([TenantClass(name)], plan, **kw) for name, _ in tenants
    }
    shared = run_arm(free_classes, plan, **kw)
    qos = run_arm(qos_classes, plan, **kw)

    def p95(arm: dict, name: str) -> float:
        return max(arm["tenants"][name]["p95_ticks"], 1.0)

    bg_names = [f"bg{i}" for i in range(n_bg)]
    bg_ratio_qos = max(
        p95(qos, n) / p95(solo[n], n) for n in bg_names
    )
    bg_ratio_shared = max(
        p95(shared, n) / p95(solo[n], n) for n in bg_names
    )
    hot_ratio = p95(qos, "hot") / p95(solo["hot"], "hot")
    shed_ok = all(a["shed_exactly_once"] for a in [shared, qos, *solo.values()])
    return {
        "config": {
            "n_servers": n_servers,
            "duration": duration,
            "hot_rate": hot_rate,
            "n_bg": n_bg,
            "bg_rate": bg_rate,
            "hot_slot_quota": hot_slot_quota,
            "hot_queue_limit": hot_queue_limit,
            "hot_credit_budget": hot_credit_budget,
            "poll_budget": poll_budget,
            "credit_window": credit_window,
            "max_slots": max_slots,
            "profile": profile,
        },
        "solo": solo,
        "shared": shared,
        "qos": qos,
        # the headline triple: QoS keeps the background flat (<=1.2x solo)
        # by throttling the hot tenant (>=3x its solo), where the
        # unprotected shared arm lets the hot backlog crush everyone
        "bg_p95_ratio": round(bg_ratio_qos, 2),
        "bg_p95_ratio_unprotected": round(bg_ratio_shared, 2),
        "hot_p95_ratio": round(hot_ratio, 2),
        "shed_total": qos["shed_total"],
        "shed_accuracy": 1.0 if shed_ok else 0.0,
        "hot_credit_stalls": qos["tenant_stalls"].get("hot", 0),
        "oracle_checked": True,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true",
                    help="solo / shared / qos isolation sweep (the only mode)")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--duration", type=int, default=40)
    ap.add_argument("--hot-rate", type=int, default=8)
    ap.add_argument("--profile", default="thor_bf2", choices=PROFILES)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test size (4 servers, short schedule)")
    args = ap.parse_args()

    out = tenancy_ab(
        n_servers=4 if args.tiny else args.servers,
        duration=10 if args.tiny else args.duration,
        hot_rate=4 if args.tiny else args.hot_rate,
        n_bg=1 if args.tiny else 3,
        profile=args.profile,
    )
    if not args.tiny:
        # acceptance floor: the QoS arm must actually isolate — background
        # within 1.2x of solo, hot visibly throttled, shedding exactly-once
        # (at tiny sizes the run merely has to be correct)
        assert out["bg_p95_ratio"] <= 1.2, out["bg_p95_ratio"]
        assert out["hot_p95_ratio"] >= 3.0, out["hot_p95_ratio"]
    assert out["shed_accuracy"] == 1.0
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
