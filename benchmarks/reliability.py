"""Reliability A/B: what exactly-once delivery costs, and what loss costs.

Four arms run the same gather burst on identically-seeded clusters:

  ``base``   reliability OFF, loss 0 — the pre-PR 6 runtime, bit-for-bit.
  ``rel0``   reliability ON, loss 0 — the pure protocol overhead: seq/ack
             words ride inside the existing 64-byte header (zero wire
             bytes), so the only cost is standalone delayed-ACK frames.
  ``rel1``   reliability ON, 1% seeded Bernoulli frame loss.
  ``rel5``   reliability ON, 5% loss.

The headline numbers:

* ``ack_overhead_pct`` — wire-byte overhead of the reliability machinery
  at zero loss (rel0 vs base).  The acceptance bound is <= 2%: piggybacked
  acks are free, so only trailing standalone ACK frames count.
* ``recovery_p95_ticks_*`` — per-request completion latency (deterministic
  scheduler ticks) under loss: how long retransmit timers + the seq gate
  take to turn a lossy wire back into exactly-once completion.
* ``goodput_*`` — completed requests per tick under loss, vs lossless.

Every arm is oracle-checked (rows bit-identical to numpy take) before any
number is reported; the lossy arms additionally assert that loss really
happened and that recovery really ran (retransmits > 0).

``python -m benchmarks.reliability --ab --json BENCH_reliability.json``
records the committed trajectory (guarded by
benchmarks/check_regression.py); ``--tiny`` is the CI fast-lane smoke.
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster, ReliabilityConfig
from repro.runtime.embed_service import EmbedShardService, ragged_batches

from .hw_model import PROFILES

MAX_TICKS = 500_000


def reliability_run(
    n_servers: int,
    offered: int,
    *,
    reliability: bool,
    loss_rate: float,
    profile: str = "thor_bf2",
    n_keys: int = 8,
    dim: int = 16,
    vocab_per_shard: int = 64,
    max_slots: int = 64,
    seed: int = 0,
) -> dict:
    """One arm: ``offered`` gather requests, oracle-checked, with per-
    request completion latency in scheduler ticks and full wire/recovery
    accounting."""
    vocab = vocab_per_shard * n_servers
    cl = Cluster(n_servers=n_servers, wire=profile)
    svc = EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=n_keys, max_slots=max_slots, seed=seed
    )
    batches = ragged_batches(vocab, offered, n_keys, seed + 1)
    want = svc.oracle(batches)
    # warm the gather path (code movement, pad buckets) losslessly so every
    # arm measures steady-state protocol cost, not first-contact code cost
    svc.gather(batches[: min(16, offered)])

    if reliability:
        cl.set_reliability(ReliabilityConfig.on())
    if loss_rate:
        cl.fabric.set_loss(loss_rate, seed=seed + 2)
    cl.fabric.stats.reset()

    rids = [svc.submit(b) for b in batches]
    n_done0 = len(svc.finished)
    done_tick: dict[int, int] = {}
    tick = 0
    while svc.queue or svc.active:
        tick += 1
        svc.tick()
        for req in svc.finished[n_done0 + len(done_tick):]:
            done_tick[req.rid] = tick
        if tick > MAX_TICKS:
            raise TimeoutError(f"arm did not settle in {MAX_TICKS} ticks")

    finished = {r.rid: r for r in svc.finished[n_done0:]}
    for rid, w in zip(rids, want):
        assert not finished[rid].degraded, "no owner died: must not degrade"
        assert np.array_equal(finished[rid].rows, w), "gather diverged from oracle"
    if loss_rate:
        assert cl.fabric.stats.frames_lost > 0, "loss arm saw no loss"

    st = cl.fabric.stats
    lat = np.array([done_tick[r] for r in rids], np.int64)
    pes = cl.pes()
    return {
        "total_ticks": tick,
        "req_mean_ticks": round(float(lat.mean()), 2),
        "req_p95_ticks": int(np.percentile(lat, 95)),
        "req_max_ticks": int(lat.max()),
        "goodput_req_per_tick": round(offered / tick, 3),
        "puts": st.puts,
        "wire_bytes": st.put_bytes + st.get_bytes + st.region_put_bytes,
        "frames_lost": st.frames_lost,
        "lost_bytes": st.lost_bytes,
        "retransmits": sum(pe.stats.retransmits for pe in pes),
        "acks_sent": sum(pe.stats.acks_sent for pe in pes),
        "dup_frames_dropped": sum(pe.stats.dup_frames_dropped for pe in pes),
        "frames_held_ooo": sum(pe.stats.frames_held_ooo for pe in pes),
        "modeled_us": round(st.modeled_us, 3),
    }


def reliability_ab(
    n_servers: int = 8,
    offered: int = 128,
    loss_rates: tuple[float, ...] = (0.01, 0.05),
    profile: str = "thor_bf2",
    seed: int = 0,
) -> dict:
    """The A/B: base (reliability off) vs rel0 (on, lossless) isolates the
    ACK overhead; relN arms add seeded loss and measure recovery."""
    arms = {
        "base": reliability_run(
            n_servers, offered, reliability=False, loss_rate=0.0,
            profile=profile, seed=seed,
        ),
        "rel0": reliability_run(
            n_servers, offered, reliability=True, loss_rate=0.0,
            profile=profile, seed=seed,
        ),
    }
    for rate in loss_rates:
        arms[f"rel{int(rate * 100)}"] = reliability_run(
            n_servers, offered, reliability=True, loss_rate=rate,
            profile=profile, seed=seed,
        )
    base, rel0 = arms["base"], arms["rel0"]
    lossy = {k: v for k, v in arms.items() if k not in ("base", "rel0")}
    out = {
        "config": {
            "n_servers": n_servers,
            "offered": offered,
            "loss_rates": list(loss_rates),
            "profile": profile,
            "reliability": ReliabilityConfig.on().__dict__,
        },
        "arms": arms,
        # headline: exactly-once protocol cost at zero loss (wire bytes)
        "ack_overhead_pct": round(
            100 * (rel0["wire_bytes"] - base["wire_bytes"])
            / max(base["wire_bytes"], 1), 3
        ),
        "oracle_checked": True,
    }
    for name, arm in lossy.items():
        out[f"recovery_p95_ticks_{name}"] = arm["req_p95_ticks"]
        out[f"goodput_{name}"] = arm["goodput_req_per_tick"]
        out[f"retransmits_{name}"] = arm["retransmits"]
    out["goodput_rel0"] = rel0["goodput_req_per_tick"]
    return out


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true",
                    help="base vs reliability vs loss sweep (the only mode)")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--offered", type=int, default=128)
    ap.add_argument("--loss", type=float, nargs="+", default=None,
                    help="loss-rate sweep points (fractions)")
    ap.add_argument("--profile", default="thor_bf2", choices=PROFILES)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test size (2 servers, small burst)")
    args = ap.parse_args()

    out = reliability_ab(
        n_servers=2 if args.tiny else args.servers,
        offered=16 if args.tiny else args.offered,
        loss_rates=tuple(args.loss) if args.loss else (
            (0.05,) if args.tiny else (0.01, 0.05)
        ),
        profile=args.profile,
    )
    if not args.tiny:
        # acceptance: piggybacked acks keep the zero-loss wire overhead
        # inside 2%, and the lossy arms must actually have recovered
        # (retransmits ran, every row still oracle-identical)
        assert out["ack_overhead_pct"] <= 2.0, out
        assert all(
            out[k] > 0 for k in out if k.startswith("retransmits_")
        ), out
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
