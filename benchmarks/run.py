"""Benchmark driver: one module per paper table/figure.

  tsi          Tables I-III (overhead breakdown) + IV-VI (latency/rate)
  dapc         Figs 5-8 (depth sweep) + Figs 9-12 (server scaling)
  dapc_tensor  the compiled-SPMD rendering of the same experiment
  roofline     summary of the dry-run artifact table (if present)

Writes artifacts/bench.json and prints a compact CSV per benchmark.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts"


def _section(name: str) -> None:
    print(f"\n===== {name} " + "=" * max(0, 60 - len(name)))


def bench_tsi() -> dict:
    from .tsi import run_tsi

    out = run_tsi()
    _section("TSI (Tables I-VI)")
    print("mode,uncached_B,cached_B,lookup_exec_us,jit_ms")
    for r in out["rows"]:
        print(
            f"{r['mode']},{r['wire_bytes_uncached']},{r['wire_bytes_cached']},"
            f"{r['lookup_exec_us']:.3f},{r['jit_ms'] if r['jit_ms'] else ''}"
        )
    print("profile,metric,ours_pct,paper_pct")
    for p, c in out["claims"].items():
        print(
            f"{p},uncached_vs_cached_latency,{c['uncached_vs_cached_latency_pct']:.1f},"
            f"{c['paper_uncached_vs_cached_latency_pct']:.1f}"
        )
        print(
            f"{p},cached_vs_uncached_rate,{c['cached_vs_uncached_rate_pct']:.1f},"
            f"{c['paper_cached_vs_uncached_rate_pct']:.1f}"
        )
        print(
            f"{p},cached_vs_am_rate,{c['cached_vs_am_rate_pct']:.1f},"
            f"{c['paper_cached_vs_am_rate_pct']:.1f}"
        )
    return out


def bench_dapc(fast: bool = False) -> dict:
    from .dapc import claims, depth_sweep, scaling_sweep

    depths = (1, 4, 16, 64, 256) if fast else (1, 4, 16, 64, 256, 1024)
    servers = (2, 4, 8, 16) if fast else (2, 4, 8, 16, 32)
    d = depth_sweep(depths=depths)
    s = scaling_sweep(servers=servers, depth=depths[-1])
    _section("DAPC depth sweep (Figs 5-8)")
    print("depth,mode,chase_rate_modeled,wire_bytes,puts,gets")
    for r in d:
        print(
            f"{r['depth']},{r['mode']},{r['chase_rate_modeled']:.0f},"
            f"{r['wire_bytes']},{r['puts']},{r['gets']}"
        )
    _section("DAPC scaling (Figs 9-12)")
    print("servers,mode,chase_rate_modeled")
    for r in s:
        print(f"{r['servers']},{r['mode']},{r['chase_rate_modeled']:.0f}")
    cl = claims(d)
    _section("DAPC claims (paper: DAPC beats GBPC by 20-75%)")
    for k, v in cl.items():
        print(f"{k},{v:.1f}%")
    return {"depth_sweep": d, "scaling": s, "claims": cl}


def bench_dapc_batched(fast: bool = False) -> dict:
    from .dapc import batch_sweep, batched_ab

    n_chases = 64 if fast else 256
    ab = batched_ab(n_chases=n_chases)
    rows = batch_sweep(n_chases_list=(16, 64) if fast else (16, 64, 256))
    _section("DAPC batched runtime (per-message vs coalesced/vmapped)")
    print("n_chases,batching,puts,invokes,coalesced_frames,modeled_wire_s")
    for r in rows:
        print(
            f"{r['n_chases']},{int(r['batching'])},{r['puts']},{r['invokes']},"
            f"{r['coalesced_frames']},{r['modeled_wire_s']:.6f}"
        )
    print(
        f"A/B @ {ab['config']['n_chases']} chases, depth {ab['config']['depth']}, "
        f"{ab['config']['n_servers']} servers, {ab['config']['profile']}: "
        f"{ab['dispatch_ratio']}x fewer dispatches, "
        f"{ab['modeled_us_reduction_pct']}% lower modeled wire time"
    )
    out = {"ab": ab, "batch_sweep": rows}
    bench_path = Path(__file__).resolve().parent.parent / "BENCH_dapc.json"
    bench_path.write_text(json.dumps(ab, indent=1, default=float) + "\n")
    print(f"wrote {bench_path}")
    return out


def bench_gather(fast: bool = False) -> dict:
    from .gather import gather_ab

    ab = gather_ab(n_requests=64 if fast else 256)
    _section("X-RDMA Gather (embedding-shard service vs GET-per-row)")
    print("path,network_ops,invokes,coalesced_frames,wire_bytes,modeled_us")
    for label in ("get_per_row", "per_message", "batched", "zerocopy", "rendezvous"):
        r = ab[label]
        print(
            f"{label},{r['network_ops']},{r['invokes']},{r['coalesced_frames']},"
            f"{r['wire_bytes']},{r['modeled_us']}"
        )
    print(
        f"A/B @ {ab['config']['n_requests']} requests, "
        f"{ab['config']['n_servers']} shards, {ab['config']['profile']}: "
        f"{ab['batched_vs_get_ops_ratio']}x fewer network ops, "
        f"{ab['batched_vs_get_modeled_pct']}% lower modeled wire time vs GET, "
        f"zerocopy wire bytes {ab['zerocopy_vs_get_bytes_ratio']}x the GET floor"
    )
    bench_path = Path(__file__).resolve().parent.parent / "BENCH_gather.json"
    bench_path.write_text(json.dumps(ab, indent=1, default=float) + "\n")
    print(f"wrote {bench_path}")
    return ab


def bench_dapc_tensor() -> dict:
    # needs >1 device: run in a subprocess with 8 host platform devices
    import subprocess

    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import json; from benchmarks.dapc_tensor import run;"
        "print(json.dumps(run(), default=float))"
    )
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=Path(__file__).resolve().parent.parent, timeout=600,
    )
    out = json.loads(r.stdout.strip().splitlines()[-1]) if r.returncode == 0 else {
        "error": r.stderr[-800:]
    }
    _section("DAPC tensor-scale (compiled SPMD, 8 devices)")
    print(json.dumps(out, indent=1, default=float))
    return out


def bench_embed_ablation() -> dict:
    import subprocess

    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import json; from benchmarks.embed_ablation import run;"
        "print(json.dumps(run(), default=float))"
    )
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=Path(__file__).resolve().parent.parent, timeout=600,
    )
    out = json.loads(r.stdout.strip().splitlines()[-1]) if r.returncode == 0 else {
        "error": r.stderr[-800:]
    }
    _section("Embedding ablation: c2d vs gather vs auto (8 devices)")
    print(json.dumps(out, indent=1, default=float))
    return out


def bench_roofline() -> dict:
    rows = []
    path = ART / "dryrun.jsonl"
    if not path.exists():
        _section("Roofline (no dry-run artifact yet — run repro.launch.dryrun --all)")
        return {}
    for line in path.read_text().splitlines():
        r = json.loads(line)
        if r.get("status") == "ok":
            rows.append(r)
    _section("Roofline summary (from dry-run artifacts)")
    print("arch,shape,mesh,dominant,t_compute_s,t_memory_s,t_collective_s,mfu_bound,fits_hbm")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['dominant']},"
            f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},{r['t_collective_s']:.4f},"
            f"{r['mfu_bound']:.3f},{r['fits_hbm']}"
        )
    return {"cells": len(rows)}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        choices=[
            "tsi", "dapc", "dapc_batched", "gather", "dapc_tensor",
            "embed_ablation", "roofline",
        ],
    )
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    ART.mkdir(exist_ok=True)
    t0 = time.time()
    out: dict = {}
    todo = [args.only] if args.only else [
        "tsi", "dapc", "dapc_batched", "gather", "dapc_tensor",
        "embed_ablation", "roofline",
    ]
    for name in todo:
        out[name] = {
            "tsi": bench_tsi,
            "dapc": lambda: bench_dapc(args.fast),
            "dapc_batched": lambda: bench_dapc_batched(args.fast),
            "gather": lambda: bench_gather(args.fast),
            "dapc_tensor": bench_dapc_tensor,
            "embed_ablation": bench_embed_ablation,
            "roofline": bench_roofline,
        }[name]()
    (ART / "bench.json").write_text(json.dumps(out, indent=1, default=float))
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s -> {ART/'bench.json'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
