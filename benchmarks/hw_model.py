"""Wire models calibrated from the paper's own measurements.

The container has no RDMA NIC, so transmission times are *modeled* with
the two-point fits from Tables I-VI (see repro.core.transport.WIRE_PROFILES
for the calibration arithmetic); everything CPU-bound — JIT ms, lookup,
execution, byte counts — is *measured* in-process.  Claim validation is on
ratios (cached/uncached, DAPC/GBPC, ifunc/AM), which are hardware-portable.
"""

from __future__ import annotations

from repro.core.transport import WIRE_PROFILES, WireModel

PROFILES = ("ookami", "thor_bf2", "thor_xeon")

# Paper-reported reference numbers for claim validation (Tables I-VI).
PAPER = {
    "ookami": {
        "am_lat_us": 2.58, "cached_lat_us": 2.67, "uncached_lat_us": 5.12,
        "am_rate": 1_320_000, "cached_rate": 1_669_000, "uncached_rate": 405_300,
        "jit_ms": 6.59,
    },
    "thor_bf2": {
        "am_lat_us": 1.88, "cached_lat_us": 1.87, "uncached_lat_us": 3.49,
        "am_rate": 974_000, "cached_rate": 1_311_000, "uncached_rate": 417_300,
        "jit_ms": 4.50,
    },
    "thor_xeon": {
        "am_lat_us": 1.56, "cached_lat_us": 1.53, "uncached_lat_us": 3.59,
        "am_rate": 6_754_000, "cached_rate": 7_302_000, "uncached_rate": 2_037_000,
        "jit_ms": 0.83,
    },
}


def wire(profile: str) -> WireModel:
    return WIRE_PROFILES[profile]
