"""Distributed Adaptive Pointer Chasing — paper Figs. 5-12.

Depth sweep (Figs 5-8): chase rate vs depth for the four modes —
  * ``get``     GBPC: one-sided READ per hop, client does all the work
  * ``am``      Active Messages (handlers pre-deployed)
  * ``bitcode`` X-RDMA Chaser ifunc, fat-bitcode, cached after 1st contact
  * ``binary``  X-RDMA Chaser ifunc, binary representation

Scaling sweep (Figs 9-12): chase rate vs number of servers at fixed depth.

Rate accounting: the simulated fabric counts every PUT/GET byte exactly
and integrates the calibrated wire model (modeled_tput_us accumulates
inverse-throughput; GETs are round-trips and do not pipeline — matching
the paper's observation that the GET line is flat and low).  Chase rate =
n_chases / (modeled wire time + measured target-side compute time).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Cluster, PointerChaseApp, chase_ref

from .hw_model import PROFILES


def run_one(
    n_servers: int,
    depth: int,
    mode: str,
    profile: str,
    n_entries: int = 1 << 14,
    n_chases: int = 16,
    seed: int = 0,
) -> dict:
    cl = Cluster(n_servers=n_servers, wire=profile)
    app = PointerChaseApp(cl, n_entries=n_entries, max_slots=n_chases, seed=seed)
    rng = np.random.default_rng(seed + 1)
    starts = rng.integers(0, n_entries, n_chases).astype(np.int32)

    t0 = time.perf_counter()
    if mode == "get":
        rep = app.gbpc(starts, depth)
    else:
        rep = app.dapc(starts, depth, mode=mode)
        if mode in ("bitcode", "binary"):
            # steady state: first run paid the code movement; run again with
            # caches warm (the regime Figs 5-12 measure)
            t0 = time.perf_counter()
            rep = app.dapc(starts, depth, mode=mode)
    wall_s = time.perf_counter() - t0

    # verify every result against the numpy oracle
    expect = np.array([chase_ref(app.table, s, depth) for s in starts], np.int32)
    assert np.array_equal(rep.results, expect), (mode, depth, n_servers)

    modeled_s = rep.modeled_us / 1e6
    total_s = modeled_s + wall_s
    return {
        "mode": mode,
        "servers": n_servers,
        "depth": depth,
        "profile": profile,
        "puts": rep.puts,
        "gets": rep.gets,
        "wire_bytes": rep.put_bytes + rep.get_bytes,
        "modeled_wire_s": modeled_s,
        "measured_compute_s": wall_s,
        "chase_rate_modeled": n_chases / max(modeled_s, 1e-12),
        "chase_rate_total": n_chases / total_s,
    }


def depth_sweep(
    n_servers: int = 8,
    depths: tuple[int, ...] = (1, 4, 16, 64, 256, 1024),
    profile: str = "thor_bf2",
    n_chases: int = 16,
) -> list[dict]:
    rows = []
    for depth in depths:
        for mode in ("get", "am", "bitcode", "binary"):
            rows.append(run_one(n_servers, depth, mode, profile, n_chases=n_chases))
    return rows


def scaling_sweep(
    depth: int = 1024,
    servers: tuple[int, ...] = (2, 4, 8, 16, 32),
    profile: str = "thor_bf2",
    n_chases: int = 16,
) -> list[dict]:
    rows = []
    for n in servers:
        for mode in ("get", "am", "bitcode"):
            rows.append(run_one(n, depth, mode, profile, n_chases=n_chases))
    return rows


def claims(rows: list[dict]) -> dict:
    """DAPC-vs-GBPC speedups by depth (paper: 20-75%, growing with depth)."""
    out = {}
    by = {}
    for r in rows:
        by.setdefault((r["depth"], r["servers"]), {})[r["mode"]] = r
    for (depth, srv), modes in sorted(by.items()):
        if "get" in modes and "bitcode" in modes:
            sp = (
                modes["bitcode"]["chase_rate_modeled"]
                / modes["get"]["chase_rate_modeled"]
                - 1
            )
            out[f"depth{depth}_srv{srv}_bitcode_vs_get_pct"] = 100 * sp
    return out


def main() -> None:
    import json

    d = depth_sweep()
    s = scaling_sweep()
    print(json.dumps({"depth_sweep": d, "scaling": s, "claims": claims(d)}, indent=1))


if __name__ == "__main__":
    main()
