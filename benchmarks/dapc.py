"""Distributed Adaptive Pointer Chasing — paper Figs. 5-12.

Depth sweep (Figs 5-8): chase rate vs depth for the four modes —
  * ``get``     GBPC: one-sided READ per hop, client does all the work
  * ``am``      Active Messages (handlers pre-deployed)
  * ``bitcode`` X-RDMA Chaser ifunc, fat-bitcode, cached after 1st contact
  * ``binary``  X-RDMA Chaser ifunc, binary representation

Scaling sweep (Figs 9-12): chase rate vs number of servers at fixed depth.

Rate accounting: the simulated fabric counts every PUT/GET byte exactly
and integrates the calibrated wire model (modeled_tput_us accumulates
inverse-throughput; GETs are round-trips and do not pipeline — matching
the paper's observation that the GET line is flat and low).  Chase rate =
n_chases / (modeled wire time + measured target-side compute time).

Batched A/B (``batched_ab`` / ``--ab``): the message-rate regime the
batched runtime targets — N concurrent chases, per-message baseline vs the
coalesced/vmapped path, reporting XLA dispatches (``PEStats.invokes``),
coalesced frame counts, and modeled wire time.  ``python -m benchmarks.dapc
--ab --json BENCH_dapc.json`` records the trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Cluster, DataPlaneConfig, PointerChaseApp, chase_ref

from .hw_model import PROFILES


def run_one(
    n_servers: int,
    depth: int,
    mode: str,
    profile: str,
    n_entries: int = 1 << 14,
    n_chases: int = 16,
    seed: int = 0,
    batching: bool = False,
) -> dict:
    cl = Cluster(n_servers=n_servers, wire=profile)
    app = PointerChaseApp(cl, n_entries=n_entries, max_slots=n_chases, seed=seed)
    rng = np.random.default_rng(seed + 1)
    starts = rng.integers(0, n_entries, n_chases).astype(np.int32)

    t0 = time.perf_counter()
    if mode == "get":
        rep = app.gbpc(starts, depth)
    else:
        rep = app.dapc(starts, depth, mode=mode, batching=batching)
        if mode in ("bitcode", "binary"):
            # steady state: first run paid the code movement (and, batched,
            # the per-bucket vmap compiles); run again with caches warm (the
            # regime Figs 5-12 measure)
            t0 = time.perf_counter()
            rep = app.dapc(starts, depth, mode=mode, batching=batching)
    wall_s = time.perf_counter() - t0

    # verify every result against the numpy oracle
    expect = np.array([chase_ref(app.table, s, depth) for s in starts], np.int32)
    assert np.array_equal(rep.results, expect), (mode, depth, n_servers)

    modeled_s = rep.modeled_us / 1e6
    total_s = modeled_s + wall_s
    return {
        "mode": mode,
        "servers": n_servers,
        "depth": depth,
        "profile": profile,
        "batching": batching,
        "n_chases": n_chases,
        "puts": rep.puts,
        "gets": rep.gets,
        "invokes": rep.invokes,
        "coalesced_frames": rep.coalesced_frames,
        "coalesced_payloads": rep.coalesced_payloads,
        "wire_bytes": rep.put_bytes + rep.get_bytes,
        "modeled_wire_s": modeled_s,
        "measured_compute_s": wall_s,
        "chase_rate_modeled": n_chases / max(modeled_s, 1e-12),
        "chase_rate_total": n_chases / total_s,
    }


def depth_sweep(
    n_servers: int = 8,
    depths: tuple[int, ...] = (1, 4, 16, 64, 256, 1024),
    profile: str = "thor_bf2",
    n_chases: int = 16,
) -> list[dict]:
    rows = []
    for depth in depths:
        for mode in ("get", "am", "bitcode", "binary"):
            rows.append(run_one(n_servers, depth, mode, profile, n_chases=n_chases))
    return rows


def scaling_sweep(
    depth: int = 1024,
    servers: tuple[int, ...] = (2, 4, 8, 16, 32),
    profile: str = "thor_bf2",
    n_chases: int = 16,
) -> list[dict]:
    rows = []
    for n in servers:
        for mode in ("get", "am", "bitcode"):
            rows.append(run_one(n, depth, mode, profile, n_chases=n_chases))
    return rows


def batched_ab(
    n_servers: int = 8,
    depth: int = 64,
    n_chases: int = 256,
    profile: str = "thor_xeon",
    n_entries: int = 1 << 14,
    mode: str = "bitcode",
    seed: int = 0,
) -> dict:
    """Per-message vs batched runtime on ONE cluster, results oracle-checked.

    One shared cluster/table so the comparison is exact: same starts, same
    shards, caches warm on both sides of the A/B.
    """
    cl = Cluster(n_servers=n_servers, wire=profile)
    app = PointerChaseApp(cl, n_entries=n_entries, max_slots=n_chases, seed=seed)
    rng = np.random.default_rng(seed + 1)
    starts = rng.integers(0, n_entries, n_chases).astype(np.int32)
    expect = np.array([chase_ref(app.table, s, depth) for s in starts], np.int32)

    app.dapc(starts, depth, mode=mode)  # warm code caches + compiles
    app.dapc(starts, depth, mode=mode, batching=True)  # warm batched buckets

    sides = {}
    arms = (
        ("per_message", dict(batching=False)),
        ("batched", dict(batching=True)),
        # data-plane A/B on the batched runtime: the chase RETURN is 8
        # payload bytes, so eager_max=0 forces every RETURN one-sided and
        # rndv_min=0 forces descriptor+GET — the two off-threshold corners
        # the decision table exists to avoid (see benchmarks/wire_model.py)
        ("zerocopy", dict(batching=True, dataplane=DataPlaneConfig.zero_copy(eager_max=0))),
        ("rendezvous", dict(batching=True, dataplane=DataPlaneConfig.rendezvous(rndv_min=0))),
    )
    for label, kwargs in arms:
        t0 = time.perf_counter()
        rep = app.dapc(starts, depth, mode=mode, **kwargs)
        wall_s = time.perf_counter() - t0
        assert np.array_equal(rep.results, expect), f"{label} diverged from oracle"
        sides[label] = {
            "puts": rep.puts,
            "gets": rep.gets,
            "region_puts": rep.region_puts,
            "invokes": rep.invokes,
            "coalesced_frames": rep.coalesced_frames,
            "coalesced_payloads": rep.coalesced_payloads,
            "wire_bytes": rep.wire_bytes,
            "wire_bytes_by_kind": rep.wire_bytes_by_kind,
            "modeled_us": round(rep.modeled_us, 3),
            "measured_compute_s": round(wall_s, 4),
        }
    base, bat = sides["per_message"], sides["batched"]
    return {
        "config": {
            "n_servers": n_servers,
            "depth": depth,
            "n_chases": n_chases,
            "profile": profile,
            "mode": mode,
            "n_entries": n_entries,
        },
        **sides,
        "dispatch_ratio": round(base["invokes"] / max(bat["invokes"], 1), 2),
        "modeled_us_reduction_pct": round(
            100 * (1 - bat["modeled_us"] / base["modeled_us"]), 2
        ),
        "oracle_checked": True,
    }


def batch_sweep(
    n_chases_list: tuple[int, ...] = (16, 64, 256),
    depth: int = 64,
    n_servers: int = 8,
    profile: str = "thor_xeon",
) -> list[dict]:
    """How amortization grows with the batch dimension (concurrent chases)."""
    rows = []
    for n in n_chases_list:
        for batching in (False, True):
            rows.append(
                run_one(
                    n_servers,
                    depth,
                    "bitcode",
                    profile,
                    n_chases=n,
                    batching=batching,
                )
            )
    return rows


def claims(rows: list[dict]) -> dict:
    """DAPC-vs-GBPC speedups by depth (paper: 20-75%, growing with depth)."""
    out = {}
    by = {}
    for r in rows:
        by.setdefault((r["depth"], r["servers"]), {})[r["mode"]] = r
    for (depth, srv), modes in sorted(by.items()):
        if "get" in modes and "bitcode" in modes:
            sp = (
                modes["bitcode"]["chase_rate_modeled"]
                / modes["get"]["chase_rate_modeled"]
                - 1
            )
            out[f"depth{depth}_srv{srv}_bitcode_vs_get_pct"] = 100 * sp
    return out


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true", help="batched-vs-per-message A/B only")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--chases", type=int, default=256)
    ap.add_argument("--depth", type=int, default=64)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--profile", default="thor_xeon", choices=PROFILES)
    ap.add_argument(
        "--trace",
        metavar="PATH",
        help="capture the A/B run's default arm to a replayable JSONL trace",
    )
    args = ap.parse_args()

    if args.trace:
        from repro.analysis import capture, replay_stats, save_trace
        from repro.core import Cluster, PointerChaseApp, chase_ref

        cl = Cluster(n_servers=args.servers, wire=args.profile)
        app = PointerChaseApp(cl, n_entries=1 << 14, max_slots=args.chases)
        rng = np.random.default_rng(1)
        starts = rng.integers(0, 1 << 14, args.chases).astype(np.int32)
        app.dapc(starts, args.depth)  # warm: code movement happens off-trace
        with capture(
            cl, meta={"workload": "dapc", "profile": args.profile}
        ) as rec:
            rep = app.dapc(starts, args.depth)
        expect = np.array(
            [chase_ref(app.table, s, args.depth) for s in starts], np.int32
        )
        assert np.array_equal(rep.results, expect), "trace run diverged from oracle"
        st, _ = replay_stats(rec)
        assert st.as_dict() == cl.fabric.stats.as_dict(), "replay != live"
        n = save_trace(rec, args.trace)
        print(f"captured {n} events -> {args.trace} (replay verified)")

    ab = batched_ab(
        n_servers=args.servers,
        depth=args.depth,
        n_chases=args.chases,
        profile=args.profile,
    )
    if args.ab:
        out = ab
    else:
        # one configuration end to end: the flags apply to every section
        d = depth_sweep(n_servers=args.servers, profile=args.profile)
        out = {
            "depth_sweep": d,
            "scaling": scaling_sweep(profile=args.profile),
            "batch_sweep": batch_sweep(
                depth=args.depth, n_servers=args.servers, profile=args.profile
            ),
            "claims": claims(d),
            "batched_ab": ab,
        }
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
