"""Recursive code propagation A/B — flat push vs tree multicast vs warm tree.

The paper's signature claim (Sec. I) is that injected code "can recursively
propagate itself to other remote machines"; this benchmark measures what
that buys over the point-to-point distribution every pre-propagation
workload paid:

  ``flat``   the client pushes the ifunc (code + payload) to each of the N
             servers itself: N client dispatches, N code frames serialized
             through one NIC.
  ``tree``   ``xrdma_bcast``: the client publishes to its spanning-tree
             children only (ceil(log2(N+1)) sends for the binomial
             default); every PE that installs the code re-publishes one
             level down.  Same N code deliveries in total — the win is the
             root's dispatch count and the *parallel completion time*.
  ``warm``   the same tree again with every cache warm: hops are
             digest-only frames, zero code bytes move.

Accounting: ``modeled_us`` per arm is the LogP-style multicast completion
time (:func:`repro.core.propagate.tree_completion_us` — sender serializes
successive child frames ``o_us`` apart, each hop pays ``alpha_us``,
subtrees proceed in parallel); ``modeled_serial_us`` is the fabric's
serial wire-latency sum, reported for honesty — the tree never wins that
one (same code bytes plus hop headers), which is exactly why the
completion model exists.  Every arm is oracle-checked: the broadcast TSI
payload must have incremented every server's counter exactly once per
multicast.

``python -m benchmarks.propagate --ab --json BENCH_propagate.json``
records the committed trajectory; ``--tiny`` is the CI fast-lane smoke.
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster, PropagationConfig, make_tsi
from repro.sharding.collectives import (
    PropagateReport,
    xrdma_bcast,
    xrdma_flat_push,
)

from .hw_model import PROFILES


def _fresh_cluster(n_servers: int, profile: str) -> Cluster:
    cl = Cluster(n_servers=n_servers, wire=profile)
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, np.int32))
    cl.toolchain.publish(make_tsi())
    return cl


def _check_counters(cl: Cluster, want: int) -> None:
    got = [int(pe.region("counter")[0]) for pe in cl.servers]
    assert got == [want] * cl.n_servers, (got, want)


def _arm_dict(rep: PropagateReport) -> dict:
    return {
        "client_sends": rep.client_sends,
        "client_code_sends": rep.client_code_sends,
        "publishes": rep.publishes,
        "hop_frames": rep.hop_frames,
        "covered": rep.covered,
        "n_targets": rep.n_targets,
        "wire_bytes": rep.wire_bytes,
        "wire_bytes_by_kind": rep.wire_bytes_by_kind,
        "modeled_us": round(rep.modeled_completion_us, 3),
        "modeled_serial_us": round(rep.modeled_us, 3),
    }


def propagate_ab(
    n_servers: int = 16,
    profile: str = "thor_bf2",
    topology: str = "binomial",
    k: int = 2,
    ttl: int = 16,
    value: int = 7,
) -> dict:
    """Flat-push vs tree vs warm-tree multicast of one TSI (code+payload).

    Fresh clusters for the two cold arms so both pay first-contact code
    movement; the warm arm reruns the tree cluster with every cache hot.
    """
    cfg = PropagationConfig(topology=topology, k=k, ttl=ttl)
    payload = np.array([value], np.int32)

    cl_flat = _fresh_cluster(n_servers, profile)
    flat = xrdma_flat_push(cl_flat, "tsi", payload)
    _check_counters(cl_flat, value)

    cl_tree = _fresh_cluster(n_servers, profile)
    tree = xrdma_bcast(cl_tree, "tsi", payload, config=cfg)
    _check_counters(cl_tree, value)

    warm = xrdma_bcast(cl_tree, "tsi", payload, config=cfg)
    _check_counters(cl_tree, 2 * value)

    assert flat.covered == flat.n_targets == n_servers
    assert tree.covered == tree.n_targets == n_servers
    assert warm.covered == n_servers

    return {
        "config": {
            "n_servers": n_servers,
            "profile": profile,
            "topology": topology,
            "k": k,
            "ttl": ttl,
        },
        "flat": _arm_dict(flat),
        "tree": _arm_dict(tree),
        "warm": _arm_dict(warm),
        "client_dispatch_ratio": round(
            flat.client_sends / max(tree.client_sends, 1), 2
        ),
        "modeled_us_reduction_pct": round(
            100 * (1 - tree.modeled_completion_us / flat.modeled_completion_us),
            2,
        ),
        "warm_modeled_us_reduction_pct": round(
            100 * (1 - warm.modeled_completion_us / flat.modeled_completion_us),
            2,
        ),
        "warm_code_bytes": warm.wire_bytes_by_kind.get("code", 0),
        "oracle_checked": True,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true", help="flat/tree/warm A/B (the only mode)")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--servers", type=int, default=16)
    ap.add_argument("--profile", default="thor_bf2", choices=PROFILES)
    ap.add_argument("--topology", default="binomial", choices=("binomial", "kary"))
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--tiny", action="store_true", help="smoke-test size (4 servers)")
    ap.add_argument(
        "--trace",
        metavar="PATH",
        help="capture a cold tree multicast to a replayable JSONL trace",
    )
    args = ap.parse_args()

    if args.trace:
        from repro.analysis import capture, replay_stats, save_trace

        cfg = PropagationConfig(topology=args.topology, k=args.k)
        cl = _fresh_cluster(4 if args.tiny else args.servers, args.profile)
        with capture(
            cl, meta={"workload": "propagate", "profile": args.profile}
        ) as rec:
            rep = xrdma_bcast(cl, "tsi", np.array([7], np.int32), config=cfg)
        _check_counters(cl, 7)  # oracle: every counter bumped exactly once
        assert rep.covered == rep.n_targets
        st, _ = replay_stats(rec)
        assert st.as_dict() == cl.fabric.stats.as_dict(), "replay != live"
        n = save_trace(rec, args.trace)
        print(f"captured {n} events -> {args.trace} (replay verified)")

    out = propagate_ab(
        n_servers=4 if args.tiny else args.servers,
        profile=args.profile,
        topology=args.topology,
        k=args.k,
    )
    if not args.tiny:
        # acceptance floor: the tree must beat flat push on both headline
        # metrics at >= 16 PEs (at 4 it merely has to be correct)
        assert out["client_dispatch_ratio"] >= 3.0, out["client_dispatch_ratio"]
        assert out["modeled_us_reduction_pct"] > 0.0, out["modeled_us_reduction_pct"]
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
