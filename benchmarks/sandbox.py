"""Safe code injection A/B — what the install-time verifier + runtime
sandbox cost, and what they contain.

The paper's headline capability (remotely injected ifuncs that recursively
propagate themselves) is exactly the thing a shared fabric cannot extend
on trust; core/verify.py adds an install-time verifier and a runtime
resource sandbox.  Three arms, identical benign workload (one cold tree
publish of the TSI counter ifunc, then ``warm_rounds`` warm re-publishes
riding digest-only hops):

  ``off``      sandbox disabled (the default config) — the pre-sandbox
               runtime, bit-for-bit: zero verifications, zero stamps,
               zero refusals anywhere.
  ``on``       sandbox enabled: each PE pays exactly **one** cold
               verification per digest; every warm hop resolves through
               the capability-stamp cache, so the warm path re-verifies
               **nothing** (``verify_overhead_pct`` is deterministically
               0.0 — the headline guarded metric).
  ``hostile``  sandbox enabled with a ttl ceiling, benign direct sends
               interleaved with a rogue self-propagating ifunc that
               re-mints a deeper publish budget than the ceiling admits:
               the re-mint must be refused loudly, the digest banished
               cluster-wide (uninstalled + sender caches forgotten +
               refused on sight thereafter), and the benign counters must
               come out oracle-exact — ``hostile_contained`` is 1.0 or
               the run fails.

``python -m benchmarks.sandbox --ab --json BENCH_sandbox.json`` records
the committed trajectory (guarded by benchmarks/check_regression.py);
``--tiny`` is the CI fast-lane smoke.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    A_PUBLISH,
    ACTION_WIDTH,
    Cluster,
    IFunc,
    SandboxConfig,
    SandboxViolation,
    make_tsi,
)

from .hw_model import PROFILES

I32 = np.int32
TARGETS = ("cpu-host", "cpu-bf2")  # two triples keep toolchain builds cheap


def make_reminter() -> IFunc:
    """A rogue gossiper: structurally a ring gossiper, but each arrival
    re-publishes itself granting ttl 9 — re-minting a deeper propagation
    budget than its capability stamp holds."""

    def entry(
        payload: jax.Array, log: jax.Array, meta: jax.Array
    ) -> "tuple[jax.Array, jax.Array]":
        me, n = meta[0], meta[1]
        nxt = jnp.where(me + 1 >= n, 0, me + 1)
        row = jnp.zeros(ACTION_WIDTH, I32)
        row = row.at[0].set(A_PUBLISH).at[1].set(nxt).at[2].set(3)
        row = row.at[3].set(9).at[5].set(payload[1])  # p0 = granted ttl 9
        return log + 1, row

    return IFunc.build(
        name="reminter",
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((2,), I32),
            jax.ShapeDtypeStruct((2,), I32),
        ),
        deps=("region:gossip_log", "cap:gossip_meta"),
        abi="propagate",
        targets=TARGETS,
    )


def _fresh_cluster(
    n_servers: int, profile: str, *, gossip: bool = False
) -> Cluster:
    cl = Cluster(n_servers=n_servers, wire=profile)
    for i, pe in enumerate(cl.pes()):
        if pe is not cl.client:
            pe.register_region("counter", np.zeros(1, I32))
        if gossip:
            pe.register_region("gossip_log", np.zeros(2, I32))
            pe.register_cap("gossip_meta", np.array([i, n_servers + 1], I32))
    cl.toolchain.publish(make_tsi())
    return cl


def _counters(cl: Cluster) -> "list[int]":
    return [int(pe.region("counter")[0]) for pe in cl.servers]


def _verifier_totals(cl: Cluster) -> "dict[str, float]":
    return {
        "verifies": sum(pe.verifier.verifies for pe in cl.pes()),
        "stamp_hits": sum(pe.verifier.stamp_hits for pe in cl.pes()),
        "verify_ms": sum(pe.verifier.verify_ms_total for pe in cl.pes()),
    }


def run_publish_arm(
    n_servers: int,
    profile: str,
    warm_rounds: int,
    value: int,
    sandbox: "SandboxConfig | None",
) -> dict:
    """One cold tree publish + ``warm_rounds`` warm re-publishes; returns
    the arm's verifier ledger split at the cold/warm boundary."""
    cl = _fresh_cluster(n_servers, profile)
    if sandbox is not None:
        cl.set_sandbox(sandbox)
    payload = np.array([value], I32)

    cl.client.publish_ifunc("tsi", payload)
    cl.drain()
    assert _counters(cl) == [value] * n_servers, "cold publish oracle"
    cold = _verifier_totals(cl)

    for _ in range(warm_rounds):
        cl.client.publish_ifunc("tsi", payload)
        cl.drain()
    want = (1 + warm_rounds) * value
    assert _counters(cl) == [want] * n_servers, "warm publish oracle"
    after = _verifier_totals(cl)

    warm_hops = warm_rounds * n_servers  # digest-only deliveries
    warm_verifies = after["verifies"] - cold["verifies"]
    enabled = sandbox is not None and sandbox.enabled
    if enabled:
        # exactly one cold verification per server (client stamps at mint)
        assert all(pe.verifier.verifies == 1 for pe in cl.servers)
    else:
        assert after["verifies"] == 0 and after["stamp_hits"] == 0
        assert cl.refusals() == {}
    return {
        "cold_verifies": cold["verifies"],
        "cold_verify_ms_mean": round(
            cold["verify_ms"] / max(cold["verifies"], 1), 4
        ),
        "warm_hops": warm_hops,
        "warm_verifies": int(warm_verifies),
        "warm_stamp_hits": int(after["stamp_hits"] - cold["stamp_hits"]),
        "refusals": cl.refusals(),
    }


def run_hostile_arm(
    n_servers: int, profile: str, benign_rounds: int, value: int
) -> dict:
    """Benign direct sends sharing a sandboxed fabric with a ttl re-minter:
    the hostile digest must be refused + banished with the benign counters
    oracle-exact.  Returns the containment scorecard."""
    reminter = make_reminter()
    cl = _fresh_cluster(n_servers, profile, gossip=True)
    cl.toolchain.publish(reminter)
    cl.set_sandbox(SandboxConfig.on(max_publish_ttl=4))
    payload = np.array([value], I32)

    # benign first half: direct sends, verified once per server then warm
    for _ in range(benign_rounds):
        for i in range(n_servers):
            cl.client.send_ifunc(f"server{i}", "tsi", payload)
        cl.drain()

    # the attack: reminter grants ttl 9 against a stamp ceiling of 4
    refused = False
    cl.client.send_ifunc("server0", "reminter", np.array([1, value], I32))
    try:
        cl.servers[0].poll()
    except SandboxViolation as e:
        refused = "ttl 9" in str(e)
    cl.drain()

    hexd = reminter.digest.hex()
    banished = all(
        hexd in pe.verifier.quarantined
        and not pe.target_cache.has_name("reminter")
        for pe in cl.pes()
    )
    # the refused publish never travelled one hop
    no_spread = all(
        pe.region("gossip_log").tolist() == [0, 0] for pe in cl.servers[1:]
    )
    # refused on sight thereafter: the banished digest cannot re-enter
    resend_refused = False
    cl.client.send_ifunc("server1", "reminter", np.array([1, value], I32))
    try:
        cl.servers[1].poll()
    except SandboxViolation as e:
        resend_refused = "quarantined" in str(e)
    cl.drain()

    # benign second half: the other tenant's traffic is unaffected
    for i in range(n_servers):
        cl.client.send_ifunc(f"server{i}", "tsi", payload)
    cl.drain()
    want = (benign_rounds + 1) * value
    benign_exact = _counters(cl) == [want] * n_servers

    roll = cl.refusals()
    contained = all(
        (refused, banished, no_spread, resend_refused, benign_exact)
    ) and roll.get("verify_ttl", 0) >= 1
    return {
        "refused_at_mint": refused,
        "banished_cluster_wide": banished,
        "zero_spread": no_spread,
        "refused_on_sight": resend_refused,
        "benign_oracle_exact": benign_exact,
        "refusals": roll,
        "contained": 1.0 if contained else 0.0,
    }


def sandbox_ab(
    n_servers: int = 16,
    warm_rounds: int = 8,
    benign_rounds: int = 3,
    value: int = 5,
    profile: str = "thor_bf2",
) -> dict:
    """The A/B: the disabled baseline, the enabled arm's cold-once/warm-free
    verification ledger, and the hostile containment scorecard."""
    off = run_publish_arm(n_servers, profile, warm_rounds, value, None)
    on = run_publish_arm(
        n_servers, profile, warm_rounds, value, SandboxConfig.on()
    )
    hostile = run_hostile_arm(n_servers, profile, benign_rounds, value)

    overhead = 100.0 * on["warm_verifies"] / max(on["warm_hops"], 1)
    return {
        "config": {
            "n_servers": n_servers,
            "warm_rounds": warm_rounds,
            "benign_rounds": benign_rounds,
            "profile": profile,
        },
        "off": off,
        "on": on,
        "hostile": hostile,
        # the headline pair: a warm tree re-verifies nothing (the stamp
        # cache eats every digest-only hop) and hostility is contained
        "verify_overhead_pct": round(overhead, 2),
        "hostile_contained": hostile["contained"],
        "cold_verify_ms_mean": on["cold_verify_ms_mean"],
        "warm_verifies": on["warm_verifies"],
        "oracle_checked": True,
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true",
                    help="off / on / hostile sweep (the only mode)")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--servers", type=int, default=16)
    ap.add_argument("--warm-rounds", type=int, default=8)
    ap.add_argument("--profile", default="thor_bf2", choices=PROFILES)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-test size (4 servers, 2 warm rounds)")
    args = ap.parse_args()

    out = sandbox_ab(
        n_servers=4 if args.tiny else args.servers,
        warm_rounds=2 if args.tiny else args.warm_rounds,
        benign_rounds=1 if args.tiny else 3,
        profile=args.profile,
    )
    # acceptance floor at every size: the warm path must be free and the
    # hostile scenario contained — both are binary, not statistical
    assert out["verify_overhead_pct"] == 0.0, out["verify_overhead_pct"]
    assert out["hostile_contained"] == 1.0, out["hostile"]
    if not args.tiny:
        assert out["on"]["cold_verifies"] >= args.servers
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
