"""X-RDMA Gather A/B: embedding-shard service vs GET-per-row baseline.

The serving-shaped workload (DOLMA's data-object disaggregation): N
concurrent gather requests, each a batch of up to K row ids against a
row-sharded (V, D) table.  Five paths on ONE cluster so the comparison
is exact (same table, same requests, caches warm):

  * ``get``          move-data-to-compute: one one-sided GET round trip
                     per row; zero target-side code.
  * ``xrdma``        the Gatherer ifunc, per-message runtime.
  * ``xrdma+batch``  the same over PR 1's batched runtime: coalesced
                     key-frames, one XLA dispatch per (PE, tick), partial
                     RETURNs folded in one masked-scan dispatch (the
                     ``framed`` data plane).
  * ``zerocopy``     batched, with partial RETURNs written one-sidedly
                     into the requester's registered completion slab +
                     doorbell — no RETURN frames, no requester dispatch.
  * ``rendezvous``   batched, with partial RETURNs shipped as 16-byte
                     descriptors the requester GETs against.

Every path is verified bit-identical to the numpy take oracle before any
number is reported.  ``python -m benchmarks.gather --ab --json
BENCH_gather.json`` records the trajectory.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Cluster, DataPlaneConfig
from repro.runtime.embed_service import EmbedShardService, ragged_batches

from .hw_model import PROFILES


def gather_ab(
    n_servers: int = 8,
    n_requests: int = 256,
    n_keys: int = 8,
    dim: int = 32,
    vocab: int = 4096,
    max_slots: int = 64,
    profile: str = "thor_xeon",
    seed: int = 0,
) -> dict:
    """GET-per-row vs per-message vs batched X-RDMA on one warm cluster."""
    cl = Cluster(n_servers=n_servers, wire=profile)
    svc = EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=n_keys, max_slots=max_slots, seed=seed
    )
    batches = ragged_batches(vocab, n_requests, n_keys, seed + 1)
    want = svc.oracle(batches)

    # steady state: first contact pays code movement; a full batched pass
    # pre-compiles every pad bucket this request mix will hit
    svc.gather(batches[: min(32, n_requests)], batching=False)
    svc.gather(batches, batching=True)

    sides = {}
    runs = (
        ("get_per_row", lambda: svc.gather_get(batches)),
        ("per_message", lambda: svc.gather(batches, batching=False)),
        ("batched", lambda: svc.gather(batches, batching=True)),
        # the data-plane A/B rides the batched runtime: same coalesced
        # key-frames, different RETURN protocol
        (
            "zerocopy",
            lambda: svc.gather(
                batches, batching=True, dataplane=DataPlaneConfig.zero_copy()
            ),
        ),
        (
            "rendezvous",
            lambda: svc.gather(
                batches,
                batching=True,
                # RETURN payloads here are ~(3+K+K*D)*4 bytes; pin the
                # threshold below that so every partial goes descriptor+GET
                dataplane=DataPlaneConfig.rendezvous(rndv_min=256),
            ),
        ),
    )
    for label, run in runs:
        t0 = time.perf_counter()
        rep = run()
        wall_s = time.perf_counter() - t0
        for got, w in zip(rep.results, want):
            assert np.array_equal(got, w), f"{label} diverged from oracle"
        sides[label] = {
            "puts": rep.puts,
            "gets": rep.gets,
            "region_puts": rep.region_puts,
            "network_ops": rep.network_ops,
            "invokes": rep.invokes,
            "coalesced_frames": rep.coalesced_frames,
            "coalesced_payloads": rep.coalesced_payloads,
            "wire_bytes": rep.wire_bytes,
            "wire_bytes_by_kind": rep.wire_bytes_by_kind,
            "modeled_us": round(rep.modeled_us, 3),
            "measured_compute_s": round(wall_s, 4),
        }
    get, bat = sides["get_per_row"], sides["batched"]
    per, zc = sides["per_message"], sides["zerocopy"]
    n_rows = int(sum(len(b) for b in batches))
    return {
        "config": {
            "n_servers": n_servers,
            "n_requests": n_requests,
            "n_keys": n_keys,
            "dim": dim,
            "vocab": vocab,
            "max_slots": max_slots,
            "profile": profile,
            "n_rows": n_rows,
        },
        **sides,
        # batching amortization vs the per-message X-RDMA path
        "dispatch_ratio": round(per["invokes"] / max(bat["invokes"], 1), 2),
        # the acceptance comparison: batched X-RDMA vs GET-per-row
        "batched_vs_get_ops_ratio": round(
            get["network_ops"] / max(bat["network_ops"], 1), 2
        ),
        "batched_vs_get_modeled_pct": round(
            100 * (1 - bat["modeled_us"] / get["modeled_us"]), 2
        ),
        # the data-plane acceptance: zero-copy kills the framing tax —
        # wire bytes fall toward the GET baseline's pure-row floor while
        # keeping the network-op and dispatch advantages
        "zerocopy_vs_get_bytes_ratio": round(
            zc["wire_bytes"] / max(get["wire_bytes"], 1), 2
        ),
        "zerocopy_vs_batched_modeled_pct": round(
            100 * (1 - zc["modeled_us"] / bat["modeled_us"]), 2
        ),
        "oracle_checked": True,
    }


def slot_sweep(
    slots_list: tuple[int, ...] = (8, 32, 128),
    n_requests: int = 256,
    n_servers: int = 8,
    profile: str = "thor_xeon",
) -> list[dict]:
    """How overlap depth (completion-queue slots) shapes the amortization."""
    rows = []
    for slots in slots_list:
        ab = gather_ab(
            n_servers=n_servers,
            n_requests=n_requests,
            max_slots=slots,
            profile=profile,
        )
        rows.append(
            {
                "max_slots": slots,
                "batched_modeled_us": ab["batched"]["modeled_us"],
                "batched_invokes": ab["batched"]["invokes"],
                "batched_network_ops": ab["batched"]["network_ops"],
                "get_modeled_us": ab["get_per_row"]["modeled_us"],
            }
        )
    return rows


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true", help="A/B comparison only")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--keys", type=int, default=8)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--servers", type=int, default=8)
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--profile", default="thor_xeon", choices=PROFILES)
    ap.add_argument(
        "--trace",
        metavar="PATH",
        help="capture the A/B run's default arm to a replayable JSONL trace",
    )
    args = ap.parse_args()

    if args.trace:
        from repro.analysis import capture, replay_stats, save_trace

        cl = Cluster(n_servers=args.servers, wire=args.profile)
        svc = EmbedShardService(
            cl, vocab=4096, dim=args.dim, n_keys=args.keys, max_slots=args.slots
        )
        batches = ragged_batches(4096, args.requests, args.keys, 1)
        want = svc.oracle(batches)
        svc.gather(batches[:32], batching=False)  # warm off-trace
        with capture(
            cl, meta={"workload": "gather", "profile": args.profile}
        ) as rec:
            rep = svc.gather(batches, batching=False)
        for got, w in zip(rep.results, want):
            assert np.array_equal(got, w), "trace run diverged from oracle"
        st, _ = replay_stats(rec)
        assert st.as_dict() == cl.fabric.stats.as_dict(), "replay != live"
        n = save_trace(rec, args.trace)
        print(f"captured {n} events -> {args.trace} (replay verified)")

    ab = gather_ab(
        n_servers=args.servers,
        n_requests=args.requests,
        n_keys=args.keys,
        dim=args.dim,
        max_slots=args.slots,
        profile=args.profile,
    )
    if args.ab:
        out = ab
    else:
        out = {"ab": ab, "slot_sweep": slot_sweep(profile=args.profile)}
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
