"""Embedding-mode ablation: the paper's GET-vs-compute-to-data argument
measured inside the compiled LM.

Three ways to look up a token in a vocab-sharded table (models/embedding):
  c2d     ship indices, psum D-vectors back (the Chaser)
  gather  replicate the table first (GBPC)
  auto    whatever GSPMD picks for a plain take

Reports collective bytes per mode from the loop-corrected HLO analysis of
a small LM forward on 8 devices — the tensor-scale restatement of paper
Tables IV-VI: steady-state bytes on the wire decide everything.
"""

from __future__ import annotations


def run(vocab: int = 32_768, d_model: int = 256, batch: int = 8, seq: int = 128) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.analysis.hlo import analyze_hlo
    from repro.models.embedding import embed_c2d, embed_gather, embed_auto

    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    table_sh = NamedSharding(mesh, P("model", None))
    ids_sh = NamedSharding(mesh, P(None, None))
    sds = jax.ShapeDtypeStruct
    table = sds((vocab, d_model), jnp.bfloat16)
    ids = sds((batch, seq), jnp.int32)

    fns = {
        "c2d": lambda t, i: embed_c2d(t, i, mesh, batch_axes=()),
        "gather": lambda t, i: embed_gather(t, i, mesh),
        "auto": lambda t, i: embed_auto(t, i),
    }
    out: dict = {
        "devices": n_dev, "vocab": vocab, "d_model": d_model,
        "tokens": batch * seq,
        "table_bytes": vocab * d_model * 2,
    }
    for name, fn in fns.items():
        c = jax.jit(fn, in_shardings=(table_sh, ids_sh)).lower(table, ids).compile()
        hc = analyze_hlo(c.as_text())
        out[name] = {
            "collective_bytes_per_dev": hc.collective_bytes,
            "by_kind": {k: round(v) for k, v in hc.collective_by_kind.items()},
            "bytes_per_token": round(hc.collective_bytes / (batch * seq), 1),
        }
    return out


def main() -> None:
    import json

    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
