"""DAPC at tensor scale: the compiled SPMD pointer chase (DESIGN.md §2).

Compares the collective bytes of the compute-to-data chase
(sharding/compute_to_data.dapc_shard_map — indices travel) against the
GET-style baseline (gbpc_reference — the table is gathered), using the
same loop-aware HLO analysis as the dry-run.  This is the paper's Fig 5-8
argument re-run inside the compiler: bytes-on-the-wire per hop is the
whole story, and here the byte counts come from the partitioned HLO.

Also validates both against the numpy oracle on the host device count.
"""

from __future__ import annotations

import numpy as np


def run(n_entries: int = 1 << 22, batch: int = 256, depth: int = 64) -> dict:
    """Defaults reflect the paper's regime: the table (16 MiB of int32 here,
    GBs in production) dwarfs the chase traffic, so moving indices
    (4 B x depth x batch) beats moving the table by orders of magnitude.
    The crossover is exactly depth x batch x 4 = table_bytes — the
    tensor-scale restatement of the paper's Fig 5-8 argument."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.hlo import analyze_hlo
    from repro.sharding.compute_to_data import (
        chase_oracle,
        dapc_shard_map,
        gbpc_reference,
    )

    from jax.sharding import NamedSharding, PartitionSpec as P

    n_dev = jax.device_count()
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    rng = np.random.default_rng(0)
    perm = rng.permutation(n_entries)
    table = np.empty(n_entries, np.int32)
    table[perm] = np.roll(perm, -1)
    starts = rng.integers(0, n_entries, batch).astype(np.int32)

    t_j, s_j = jnp.asarray(table), jnp.asarray(starts)
    want = chase_oracle(table, starts, depth)
    # the table LIVES sharded over the mesh — both contenders start there
    # (the GET baseline then has to move it; the c2d chase moves indices)
    in_sh = (NamedSharding(mesh, P("model")), NamedSharding(mesh, P()))

    out: dict = {"devices": n_dev, "entries": n_entries, "batch": batch, "depth": depth}
    for name, fn in (
        ("dapc_c2d", lambda t, s: dapc_shard_map(t, s, depth, mesh)),
        ("gbpc_get", lambda t, s: gbpc_reference(t, s, depth, mesh)),
    ):
        c = jax.jit(fn, in_shardings=in_sh).lower(t_j, s_j).compile()
        got = np.asarray(c(t_j, s_j))
        assert np.array_equal(got, want), name
        hc = analyze_hlo(c.as_text())
        out[name] = {
            "collective_bytes_per_dev": hc.collective_bytes,
            "by_kind": {k: round(v) for k, v in hc.collective_by_kind.items()},
            "bytes_per_hop_per_chase": hc.collective_bytes / (depth * batch),
        }
    if out["dapc_c2d"]["collective_bytes_per_dev"] > 0:
        out["gbpc_over_dapc_bytes"] = (
            out["gbpc_get"]["collective_bytes_per_dev"]
            / max(out["dapc_c2d"]["collective_bytes_per_dev"], 1)
        )
    return out


def main() -> None:
    import json

    print(json.dumps(run(), indent=1, default=float))


if __name__ == "__main__":
    main()
