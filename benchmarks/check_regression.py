"""Perf-regression guard: fail the full CI lane if a freshly produced
BENCH_*.json regresses >10% below the values committed at HEAD.

Committed baselines are read from git (``git show HEAD:<file>``) so the
fresh files the benchmark steps just (over)wrote in the worktree are never
compared against themselves.  A fresh/committed config mismatch (different
sweep sizes) skips that file loudly instead of comparing apples to pears.

Guarded metrics — "higher is better" unless marked ``<``:

  BENCH_dapc.json       dispatch_ratio, modeled_us_reduction_pct
  BENCH_gather.json     dispatch_ratio, batched_vs_get_ops_ratio,
                        batched_vs_get_modeled_pct,
                        zerocopy_vs_batched_modeled_pct,
                        zerocopy_vs_get_bytes_ratio (<)
  BENCH_propagate.json  client_dispatch_ratio, modeled_us_reduction_pct,
                        warm_modeled_us_reduction_pct, warm_code_bytes (<)
  BENCH_overload.json   hop_latency_improvement_pct, receiver_backlog_ratio,
                        hop_ticks_flow (<)
  BENCH_reliability.json  ack_overhead_pct (<), recovery_p95_ticks_rel5 (<),
                        goodput_rel5
  BENCH_tenancy.json    bg_p95_ratio (<), hot_p95_ratio, shed_accuracy
  BENCH_sandbox.json    verify_overhead_pct (<), hostile_contained
  BENCH_autotune.json   min_replay_improvement_pct, min_live_improvement_pct
  BENCH_placement.json  min_pushdown_wire_reduction_pct,
                        optimizer_agrees_with_oracle_cells

``python -m benchmarks.check_regression`` (run from the repo root after
regenerating the BENCH files); exits non-zero on any regression.
"""

from __future__ import annotations

import json
import subprocess
import sys

TOLERANCE = 0.10  # >10% below (or above, for lower-is-better) committed fails

#: file -> [(metric, higher_is_better)]
GUARDS = {
    "BENCH_dapc.json": [
        ("dispatch_ratio", True),
        ("modeled_us_reduction_pct", True),
    ],
    "BENCH_gather.json": [
        ("dispatch_ratio", True),
        ("batched_vs_get_ops_ratio", True),
        ("batched_vs_get_modeled_pct", True),
        ("zerocopy_vs_batched_modeled_pct", True),
        ("zerocopy_vs_get_bytes_ratio", False),
    ],
    "BENCH_propagate.json": [
        ("client_dispatch_ratio", True),
        ("modeled_us_reduction_pct", True),
        ("warm_modeled_us_reduction_pct", True),
        ("warm_code_bytes", False),  # a warm tree must ship zero code bytes
    ],
    "BENCH_overload.json": [
        ("hop_latency_improvement_pct", True),
        ("receiver_backlog_ratio", True),
        # control-plane latency under overload must not creep back up
        ("hop_ticks_flow", False),
    ],
    "BENCH_reliability.json": [
        # exactly-once must stay (nearly) free at zero loss ...
        ("ack_overhead_pct", False),
        # ... and recovery under 5% loss must stay fast and productive
        ("recovery_p95_ticks_rel5", False),
        ("goodput_rel5", True),
    ],
    "BENCH_tenancy.json": [
        # background tenants must stay pinned to their solo baseline ...
        ("bg_p95_ratio", False),
        # ... because the hot tenant is genuinely throttled ...
        ("hot_p95_ratio", True),
        # ... and shedding stays exactly-once (1.0 or bust)
        ("shed_accuracy", True),
    ],
    "BENCH_sandbox.json": [
        # a warm tree must stay verification-free (0.0 or bust) ...
        ("verify_overhead_pct", False),
        # ... while every hostile scenario stays contained (1.0 or bust)
        ("hostile_contained", True),
    ],
    "BENCH_autotune.json": [
        # the tuner must keep beating the hand-tuned default on every
        # profile x workload cell — on the replay estimate AND live
        ("min_replay_improvement_pct", True),
        ("min_live_improvement_pct", True),
    ],
    "BENCH_placement.json": [
        # pushdown must keep cutting wire payload ~ the selectivity
        # factor at the lowest selectivity ...
        ("min_pushdown_wire_reduction_pct", True),
        # ... and the cost model must keep matching the exhaustive A/B
        # winner in every {servers} x {selectivity} cell (1.0 or bust)
        ("optimizer_agrees_with_oracle_cells", True),
    ],
}


def committed(path: str) -> dict | None:
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"], capture_output=True, check=True
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, FileNotFoundError, json.JSONDecodeError):
        return None


def check_file(path: str) -> list[str]:
    failures: list[str] = []
    base = committed(path)
    if base is None:
        print(f"[guard] {path}: no committed baseline at HEAD — skipping")
        return failures
    try:
        with open(path) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: fresh file unreadable ({e})"]
    if fresh.get("config") != base.get("config"):
        print(
            f"[guard] {path}: fresh config {fresh.get('config')} != committed "
            f"{base.get('config')} — skipping (not comparable)"
        )
        return failures
    if not fresh.get("oracle_checked"):
        return [f"{path}: fresh run is not oracle_checked"]
    for metric, higher_better in GUARDS[path]:
        if metric not in base:
            print(f"[guard] {path}: {metric} not in committed baseline — skipping")
            continue
        b, f = float(base[metric]), float(fresh.get(metric, float("nan")))
        # widen the band away from the baseline by |b|*TOLERANCE so the
        # check keeps its direction for negative committed values
        if higher_better:
            ok = f >= b - abs(b) * TOLERANCE
            rel = "below"
        else:
            ok = f <= b + abs(b) * TOLERANCE
            rel = "above"
        status = "ok" if ok else "REGRESSED"
        print(f"[guard] {path}: {metric} fresh={f:g} committed={b:g} -> {status}")
        if not ok:
            failures.append(
                f"{path}: {metric} {f:g} is >{TOLERANCE:.0%} {rel} committed {b:g}"
            )
    return failures


def main() -> int:
    failures: list[str] = []
    for path in GUARDS:
        failures.extend(check_file(path))
    if failures:
        print("\nPERF REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print("[guard] all perf metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
