"""Heterogeneous placement A/B: DPU predicate pushdown vs pull-to-client.

The paper's core trade, priced end to end: a row-sharded table served by
PEs whose *advertised capability vectors* differ (BlueField-2 DPU wire
arithmetic vs Xeon host arithmetic, calibrated ``thor_bf2`` /
``thor_xeon`` profiles), and a filter whose survivors are a tunable
fraction of each scanned window.  Two placements on ONE warm cluster per
cell, both oracle-checked before any number is reported:

  * ``pushdown``  ship the Filter ifunc next to the shard once; each
                  request is a 5-word frame out, a *ragged* survivor
                  RETURN back — wire payload scales with selectivity.
  * ``pull``      one range GET of the whole window per request; the
                  client evaluates the predicate after the operand
                  crossed the wire.

The A/B oracle scores each arm with the fabric's hetero-priced
``modeled_us`` plus the analytic per-message CPU overheads and the
memory-bandwidth scan term the wire model doesn't meter (both known
exactly: the run's message counts are deterministic).  The
:class:`~repro.sharding.placement.PlacementOptimizer` must pick the same
winner in every cell from the capability registry alone — including the
hardware-sensitive flip: at selectivity 0.75 the DPU-served cell refuses
pushdown (fat per-message ``o_us``) while the Xeon-served cell still
pushes down.

``python -m benchmarks.placement --ab --json BENCH_placement.json``
records the trajectory; ``--tiny`` is the CI fast-lane smoke.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Cluster
from repro.runtime.embed_service import FilterShardService
from repro.sharding.placement import PlacementOptimizer

#: server-platform cells: default Cluster serving is DPU-homed (cpu-bf2
#: shards behind thor_bf2 wire arithmetic); the contrast cell homes the
#: same shards on host Xeons (thor_xeon, cheap o_us, fat GET path)
SERVER_CELLS = (("dpu", "cpu-bf2"), ("xeon", "cpu-host"))


def _scored(rep, arm: str, caps: dict, n: int, operand_bytes: int) -> float:
    """Full per-arm cost: measured hetero wire time + the analytic
    per-message overheads and scan bandwidth the fabric doesn't meter.
    Message counts are deterministic: n request PUTs by the client and n
    ragged RETURN PUTs by the servers (pushdown), n range GETs (pull)."""
    client, server = caps["client"], caps["server0"]
    if arm == "pushdown":
        return (
            rep.modeled_us
            + n * (client.o_us + server.o_us)
            + n * operand_bytes / server.scan_Bus
        )
    return rep.modeled_us + n * operand_bytes / client.scan_Bus


def placement_ab(
    n_servers: int = 4,
    n_requests: int = 96,
    window: int = 24,
    dim: int = 96,
    vocab: int = 4096,
    max_slots: int = 64,
    seed: int = 0,
    selectivities: tuple = (0.05, 0.25, 0.75),
    strict: bool = True,
) -> dict:
    """The full placement matrix: {DPU, Xeon} servers x selectivity sweep."""
    operand_bytes = window * dim * 4
    cells = []
    for kind, triple in SERVER_CELLS:
        cl = Cluster(
            n_servers=n_servers,
            wire="thor_xeon",
            server_triple=triple,
            hetero_wire=True,
        )
        svc = FilterShardService(
            cl, vocab=vocab, dim=dim, window=window, max_slots=max_slots, seed=seed
        )
        opt = PlacementOptimizer(cl)
        caps = cl.capabilities()
        los = svc.windows(n_requests, seed=seed + 1)
        # steady state: first contact pays code movement + XLA compiles
        svc.filter(los[: min(8, n_requests)], 0.0, placement="pushdown")
        for sel in selectivities:
            thresh = svc.thresh_for_selectivity(sel)
            want = svc.oracle_filter(los, thresh)
            arms = {}
            for arm in ("pushdown", "pull"):
                t0 = time.perf_counter()
                rep = svc.filter(los, thresh, placement=arm)
                wall_s = time.perf_counter() - t0
                for got, w in zip(rep.results, want):
                    assert np.array_equal(got, w), (
                        f"{kind}/{sel}/{arm} diverged from oracle"
                    )
                arms[arm] = {
                    "puts": rep.puts,
                    "gets": rep.gets,
                    "wire_bytes": rep.wire_bytes,
                    "modeled_us": round(rep.modeled_us, 3),
                    "scored_us": round(
                        _scored(rep, arm, caps, n_requests, operand_bytes), 3
                    ),
                    "measured_compute_s": round(wall_s, 4),
                    "_rep": rep,
                }
            # wire *payload* bytes: strip the fixed frame overheads (the
            # pushdown run is exactly n request + n ragged RETURN frames)
            push, pull = arms["pushdown"], arms["pull"]
            assert push["_rep"].puts == 2 * n_requests, "unexpected frame count"
            payload_push = (
                push["_rep"].put_bytes
                - n_requests * (72 + len(svc.op_name))
                - n_requests * (72 + len(svc.return_name))
            )
            payload_pull = pull["_rep"].get_bytes
            for a in arms.values():
                del a["_rep"]
            ab_winner = (
                "pushdown" if push["scored_us"] < pull["scored_us"] else "pull"
            )
            decision = svc.plan_with(opt, los)
            again = svc.plan_with(opt, los)
            assert decision == again, "placement decision not deterministic"
            cells.append(
                {
                    "servers": kind,
                    "server_triple": triple,
                    "selectivity": sel,
                    "thresh": float(thresh),
                    **arms,
                    "payload_bytes_pushdown": int(payload_push),
                    "payload_bytes_pull": int(payload_pull),
                    "payload_ratio": round(payload_push / payload_pull, 4),
                    "ab_winner": ab_winner,
                    "optimizer": decision.as_dict(),
                    "optimizer_agrees": decision.choice == ab_winner,
                }
            )

    agree = sum(c["optimizer_agrees"] for c in cells) / len(cells)
    low_sel = [c for c in cells if c["selectivity"] == min(selectivities)]
    worst_low_ratio = max(c["payload_ratio"] for c in low_sel)
    winners = {(c["servers"], c["selectivity"]): c["ab_winner"] for c in cells}
    out = {
        "config": {
            "n_servers": n_servers,
            "n_requests": n_requests,
            "window": window,
            "dim": dim,
            "vocab": vocab,
            "selectivities": list(selectivities),
            "cells": len(cells),
        },
        "cells": cells,
        # guard metrics: pushdown's payload shrink at the lowest
        # selectivity (worst cell), and optimizer/oracle agreement
        "min_pushdown_wire_reduction_pct": round(100 * (1 - worst_low_ratio), 2),
        "optimizer_agrees_with_oracle_cells": round(agree, 4),
        "hardware_sensitive_flip": (
            winners.get(("dpu", 0.75)) == "pull"
            and winners.get(("xeon", 0.75)) == "pushdown"
        ),
        "oracle_checked": True,
    }
    if strict:
        assert worst_low_ratio <= 0.15, (
            f"pushdown payload ratio {worst_low_ratio} exceeds 0.15 at "
            f"selectivity {min(selectivities)}"
        )
        assert agree == 1.0, "optimizer disagreed with the exhaustive A/B"
    return out


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ab", action="store_true", help="A/B matrix (the default)")
    ap.add_argument("--tiny", action="store_true", help="CI fast-lane smoke")
    ap.add_argument("--json", metavar="PATH", help="write the result dict to PATH")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--window", type=int, default=24)
    ap.add_argument("--dim", type=int, default=96)
    ap.add_argument("--servers", type=int, default=4)
    args = ap.parse_args()

    if args.tiny:
        out = placement_ab(
            n_servers=2,
            n_requests=8,
            window=8,
            dim=16,
            vocab=256,
            selectivities=(0.05, 0.75),
            # tiny operands sit below every crossover: only the oracle
            # identity and the plumbing are asserted in the fast lane
            strict=False,
        )
    else:
        out = placement_ab(
            n_servers=args.servers,
            n_requests=args.requests,
            window=args.window,
            dim=args.dim,
        )
    text = json.dumps(out, indent=1, default=float)
    print(text)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
