"""Trace capture/replay fidelity suite (PR 9 tentpole hardening).

Three contracts pinned here:

* **Round trip** — capture → serialize (JSONL) → parse → replay reproduces
  the live run's ``TrafficStats`` bit-identically (float accumulators
  included) and the trace-visible ``PEStats`` subset as exact deltas,
  property-tested across seeds/depths/batching.
* **Typed errors** — a truncated, garbage, or schema-incompatible trace
  raises :class:`TraceError` and nothing else: no ``KeyError``, no
  ``json.JSONDecodeError`` escapes ``load_trace``/``parse_trace``.
* **Zero overhead when off** — with no recorder attached the runtime
  buffers no events and produces byte-identical results/stats to a
  captured run (capture is observation, never perturbation).
"""

import json

import numpy as np
import pytest

from repro.analysis import (
    Trace,
    TraceError,
    TraceRecorder,
    capture,
    load_trace,
    replay_stats,
    save_trace,
)
from repro.analysis.trace import SCHEMA, dump_trace, parse_trace, pe_stats_subset
from repro.core import Cluster, PointerChaseApp, chase_ref

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

I32 = np.int32


def _run_captured(seed: int, depth: int, batching: bool):
    """One small dapc run under capture; returns (cluster, recorder,
    live TrafficStats dict, per-PE stat deltas)."""
    cl = Cluster(n_servers=2, wire="thor_xeon")
    app = PointerChaseApp(cl, n_entries=128, max_slots=8, seed=seed)
    rng = np.random.default_rng(seed + 1)
    starts = rng.integers(0, 128, 6).astype(I32)
    app.dapc(starts, depth)  # warm: code movement happens off-trace
    before = {pe.name: pe_stats_subset(pe.stats) for pe in cl.pes()}
    with capture(cl, meta={"seed": seed}) as rec:
        rep = app.dapc(starts, depth, batching=batching)
    want = np.array([chase_ref(app.table, s, depth) for s in starts], I32)
    np.testing.assert_array_equal(rep.results, want)
    deltas = {}
    for pe in cl.pes():
        after = pe_stats_subset(pe.stats)
        deltas[pe.name] = {k: after[k] - before[pe.name][k] for k in after}
    return cl, rec, cl.fabric.stats.as_dict(), deltas


# ------------------------------------------------------------- round trip
@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    depth=st.sampled_from([1, 4, 16]),
    batching=st.sampled_from([False, True]),
)
def test_roundtrip_reproduces_live_counters(seed, depth, batching):
    """capture → JSONL → parse → replay == the live run, bit-identical."""
    cl, rec, live, deltas = _run_captured(seed % 97, depth, batching)
    lines = []

    class _Sink:
        def write(self, s):
            lines.append(s)

    dump_trace(rec, _Sink())
    tr = parse_trace("".join(lines).splitlines())
    assert len(tr) == len(rec)
    st_, pes = replay_stats(tr)
    assert st_.as_dict() == live
    # float accumulators must match exactly, not just to repr precision
    assert st_.modeled_us == cl.fabric.stats.modeled_us
    assert st_.modeled_tput_us == cl.fabric.stats.modeled_tput_us
    # per-PE deltas: everything the trace saw equals what the PEs counted
    for name, counted in pes.items():
        assert counted == deltas[name], name


@settings(max_examples=20, deadline=None)
@given(
    events=st.lists(
        st.sampled_from(
            [
                {"k": "put", "src": "a", "dst": "b", "n": 100, "p": 1},
                {"k": "put", "src": "a", "dst": "b", "n": 64, "p": 3,
                 "by": {"payload": 24}, "hop": 1, "tn": "t0"},
                {"k": "rput", "src": "a", "dst": "c", "n": 256, "w": 4},
                {"k": "get", "src": "c", "dst": "a", "n": 128},
                {"k": "send", "src": "a", "dst": "b", "n": 90, "p": 1,
                 "kind": 1, "name": "f", "pb": 8, "cb": 0, "cached": True},
                {"k": "stall", "src": "a", "dst": "b", "tn": "t1", "budget": True},
                {"k": "retx", "src": "b", "dst": "a", "seq": 3, "n": 72},
                {"k": "ack", "src": "b", "dst": "a", "ack": 5},
                {"k": "poll", "src": "b", "tick": 2, "p": 3},
                {"k": "frame", "src": "a", "dst": "b", "p": 2, "done": True},
                {"k": "ret", "src": "b", "dst": "a", "name": "r", "n": 40,
                 "zc": 44, "cached": True, "proto": "zerocopy"},
                {"k": "cq_alloc", "src": "a", "slot": 0, "epoch": 1},
                {"k": "cq_free", "src": "a", "slot": 0},
            ]
        ),
        min_size=0,
        max_size=24,
    )
)
def test_synthetic_stream_roundtrip(events):
    """Any valid event stream survives serialize → parse unchanged, and
    replays to the same counters before and after the trip."""
    rec = TraceRecorder("thor_bf2", meta={"synthetic": True})
    for ev in events:
        ev = dict(ev)
        ev.pop("k2", None)
        k = ev.pop("k")
        rec.emit(k, **ev)
    lines = []

    class _Sink:
        def write(self, s):
            lines.append(s)

    dump_trace(rec, _Sink())
    tr = parse_trace("".join(lines).splitlines())
    assert tr.events == Trace.from_recorder(rec).events
    assert tr.wire_name == "thor_bf2"
    a, pa = replay_stats(rec)
    b, pb = replay_stats(tr)
    assert a.as_dict() == b.as_dict()
    assert pa == pb


def test_save_load_file_roundtrip(tmp_path):
    _, rec, live, _ = _run_captured(3, 4, True)
    path = str(tmp_path / "run.jsonl")
    n = save_trace(rec, path)
    assert n == len(rec)
    tr = load_trace(path)
    assert tr.header["meta"] == {"seed": 3}
    st_, _ = replay_stats(tr)
    assert st_.as_dict() == live


# ----------------------------------------------------------- typed errors
def _write(tmp_path, text: str) -> str:
    p = tmp_path / "t.jsonl"
    p.write_text(text)
    return str(p)


def test_empty_file_raises_trace_error(tmp_path):
    with pytest.raises(TraceError, match="no header"):
        load_trace(_write(tmp_path, ""))


def test_missing_file_raises_trace_error(tmp_path):
    with pytest.raises(TraceError, match="cannot read"):
        load_trace(str(tmp_path / "absent.jsonl"))


def test_garbage_json_raises_trace_error(tmp_path):
    header = json.dumps({"schema": SCHEMA, "wire": "ideal", "events": 1})
    with pytest.raises(TraceError, match="invalid JSON"):
        load_trace(_write(tmp_path, header + "\n{not json@@@\n"))


def test_wrong_schema_raises_trace_error(tmp_path):
    bad = json.dumps({"schema": "xrdma-trace/999", "events": 0})
    with pytest.raises(TraceError, match="not a xrdma-trace/1"):
        load_trace(_write(tmp_path, bad + "\n"))


def test_non_object_header_raises_trace_error(tmp_path):
    with pytest.raises(TraceError, match="not a xrdma-trace/1"):
        load_trace(_write(tmp_path, "[1,2,3]\n"))


def test_unknown_kind_raises_trace_error(tmp_path):
    header = json.dumps({"schema": SCHEMA, "wire": "ideal", "events": 1})
    ev = json.dumps({"k": "warp", "i": 0, "src": "a"})
    with pytest.raises(TraceError, match="unknown event kind"):
        load_trace(_write(tmp_path, header + "\n" + ev + "\n"))


def test_missing_field_raises_trace_error(tmp_path):
    header = json.dumps({"schema": SCHEMA, "wire": "ideal", "events": 1})
    ev = json.dumps({"k": "put", "i": 0, "src": "a", "dst": "b", "p": 1})  # no n
    with pytest.raises(TraceError, match="field 'n'"):
        load_trace(_write(tmp_path, header + "\n" + ev + "\n"))


def test_mistyped_field_raises_trace_error(tmp_path):
    header = json.dumps({"schema": SCHEMA, "wire": "ideal", "events": 1})
    # bool is an int subclass in Python; the validator must still refuse it
    ev = json.dumps({"k": "put", "i": 0, "src": "a", "dst": "b", "n": True, "p": 1})
    with pytest.raises(TraceError, match="field 'n'"):
        load_trace(_write(tmp_path, header + "\n" + ev + "\n"))


def test_truncated_trace_raises_trace_error(tmp_path):
    """A file cut mid-stream (header promises more events) is detected."""
    _, rec, _, _ = _run_captured(0, 4, False)
    full = []

    class _Sink:
        def write(self, s):
            full.append(s)

    dump_trace(rec, _Sink())
    lines = "".join(full).splitlines()
    truncated = "\n".join(lines[: len(lines) // 2]) + "\n"
    with pytest.raises(TraceError, match="truncated"):
        load_trace(_write(tmp_path, truncated))


def test_event_not_object_raises_trace_error(tmp_path):
    header = json.dumps({"schema": SCHEMA, "wire": "ideal", "events": 1})
    with pytest.raises(TraceError, match="not an object"):
        load_trace(_write(tmp_path, header + "\n[1,2]\n"))


@settings(max_examples=30, deadline=None)
@given(blob=st.binary(min_size=0, max_size=80))
def test_fuzzed_garbage_never_escapes_typed_error(blob):
    """Arbitrary bytes either parse as a valid trace or raise TraceError —
    never KeyError / JSONDecodeError."""
    import os
    import tempfile

    fd, path = tempfile.mkstemp(suffix=".jsonl")
    try:
        with os.fdopen(fd, "wb") as fp:
            fp.write(blob)
        try:
            load_trace(path)
        except TraceError:
            pass
    finally:
        os.unlink(path)


# ------------------------------------------------- zero overhead when off
def test_no_tracer_attached_by_default():
    cl = Cluster(n_servers=2, wire="ideal")
    assert cl.fabric.tracer is None
    app = PointerChaseApp(cl, n_entries=64, max_slots=4, seed=0)
    starts = np.array([1, 2, 3], I32)
    app.dapc(starts, 4)
    # nothing anywhere buffers events when detached
    assert cl.fabric.tracer is None


def test_capture_detaches_on_exit_and_freezes_recorder():
    cl = Cluster(n_servers=2, wire="ideal")
    app = PointerChaseApp(cl, n_entries=64, max_slots=4, seed=0)
    starts = np.array([1, 2, 3], I32)
    app.dapc(starts, 4)
    with capture(cl) as rec:
        app.dapc(starts, 4)
    n = len(rec)
    assert n > 0
    assert cl.fabric.tracer is None
    app.dapc(starts, 4)  # post-capture run must not grow the recorder
    assert len(rec) == n


def test_capture_nesting_restores_previous_recorder():
    cl = Cluster(n_servers=2, wire="ideal")
    app = PointerChaseApp(cl, n_entries=64, max_slots=4, seed=0)
    starts = np.array([1, 2], I32)
    app.dapc(starts, 2)
    with capture(cl) as outer:
        with capture(cl) as inner:
            app.dapc(starts, 2)
        assert cl.fabric.tracer is outer
    assert len(inner) > 0
    assert len(outer) == 0
    assert cl.fabric.tracer is None


def test_capture_is_observation_not_perturbation():
    """Identical seeds with and without the tracer attached produce
    byte-identical results and TrafficStats — capture changes nothing."""

    def run(with_capture: bool):
        cl = Cluster(n_servers=2, wire="thor_bf2")
        app = PointerChaseApp(cl, n_entries=128, max_slots=8, seed=5)
        rng = np.random.default_rng(6)
        starts = rng.integers(0, 128, 6).astype(I32)
        app.dapc(starts, 8)
        if with_capture:
            with capture(cl) as rec:
                rep = app.dapc(starts, 8, batching=True)
            assert len(rec) > 0
        else:
            rep = app.dapc(starts, 8, batching=True)
        return rep.results, cl.fabric.stats.as_dict()

    res_off, stats_off = run(False)
    res_on, stats_on = run(True)
    np.testing.assert_array_equal(res_off, res_on)
    assert stats_off == stats_on
