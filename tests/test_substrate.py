"""Substrate tests: optimizer, data pipeline, checkpoint store, monitors,
chunked-computation equivalences (deliverable (c))."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, TokenPipeline
from repro.checkpoint import CheckpointStore, latest_step, restore_state, save_state
from repro.optim import AdamW, cosine_schedule
from repro.runtime.monitor import HeartbeatMonitor, StepTimer, StragglerPolicy


# ------------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_wd_skip_and_clip():
    opt = AdamW(lr=1e-2, weight_decay=1.0, clip_norm=1.0)
    params = {"w": jnp.ones(4), "ln_gain": jnp.ones(4)}
    state = opt.init(params)
    zeros = {k: jnp.zeros(4) for k in params}
    p2, state, m = opt.update(zeros, state, params)
    # zero grads: only weight decay moves 'w'; 'ln_gain' is exempt
    assert float(jnp.abs(p2["ln_gain"] - 1).max()) < 1e-6
    assert float(p2["w"][0]) < 1.0
    big = {k: jnp.full(4, 1e6) for k in params}
    _, _, m = opt.update(big, state, params)
    assert float(m["grad_norm"]) > 1e6  # reported unclipped


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, floor=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-5)


# -------------------------------------------------------------------- data
def test_pipeline_deterministic_and_disjoint():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab=100, n_shards=2, shard_id=0)
    p0 = TokenPipeline(cfg)
    p0b = TokenPipeline(cfg)
    b1 = p0.batch_at(7)
    b2 = p0b.batch_at(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # restart-safe
    p1 = TokenPipeline(DataConfig(seq_len=32, global_batch=4, vocab=100, n_shards=2, shard_id=1))
    assert not np.array_equal(b1["tokens"], p1.batch_at(7)["tokens"])
    # labels are next-token shifted
    assert b1["tokens"].shape == (2, 32)  # local batch = global/2


def test_pipeline_memmap(tmp_path):
    from repro.data.pipeline import synthetic_corpus

    path = synthetic_corpus(tmp_path / "corpus.bin", n_tokens=10_000, vocab=97)
    cfg = DataConfig(
        seq_len=16, global_batch=2, vocab=97, source="memmap", path=str(path)
    )
    pipe = TokenPipeline(cfg)
    b = pipe.batch_at(0)
    assert b["tokens"].max() < 97
    b5 = pipe.batch_at(5)
    assert np.array_equal(b5["tokens"], TokenPipeline(cfg).batch_at(5)["tokens"])


def test_pipeline_prefetch_thread():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab=50)
    pipe = TokenPipeline(cfg).start(step=3)
    want = pipe.batch_at(3)
    got = next(pipe)
    pipe.stop()
    assert np.array_equal(want["tokens"], got["tokens"])


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_bf16(tmp_path):
    state = {
        "params": {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5},
        "step": jnp.int32(7),
    }
    save_state(tmp_path, state, step=7)
    like = jax.eval_shape(lambda: state)
    got, step = restore_state(tmp_path, like)
    assert step == 7
    assert got["params"]["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"], np.float32),
        np.asarray(state["params"]["w"], np.float32),
    )


def test_checkpoint_torn_write_ignored(tmp_path):
    state = {"w": jnp.ones(3)}
    save_state(tmp_path, state, step=5)
    # torn: directory without _COMMIT
    torn = tmp_path / "step_000000009"
    torn.mkdir()
    (torn / "MANIFEST.json").write_text("{}")
    assert latest_step(tmp_path) == 5


def test_checkpoint_corruption_detected(tmp_path):
    state = {"w": jnp.arange(8.0)}
    d = save_state(tmp_path, state, step=1)
    # flip bytes in the one saved leaf
    npy = next(p for p in d.iterdir() if p.suffix == ".npy")
    raw = bytearray(npy.read_bytes())
    raw[-4] ^= 0xFF
    npy.write_bytes(bytes(raw))
    like = jax.eval_shape(lambda: state)
    with pytest.raises(IOError):
        restore_state(tmp_path, like)


def test_checkpoint_async_and_retention(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (10, 20, 30):
        store.save_async({"w": jnp.full(4, float(s))}, s)
        store.wait()
    steps = sorted(
        int(p.name.split("_")[1]) for p in tmp_path.iterdir() if p.name.startswith("step_")
    )
    assert steps == [20, 30]
    got, step = restore_state(tmp_path, jax.eval_shape(lambda: {"w": jnp.zeros(4)}))
    assert step == 30 and float(got["w"][0]) == 30.0


# ----------------------------------------------------------------- monitor
def test_heartbeat_death_detection():
    mon = HeartbeatMonitor(interval_s=1.0, max_misses=3)
    mon.beat("a", now=0.0)
    mon.beat("b", now=0.0)
    assert mon.check(now=2.0) == set()
    mon.beat("a", now=2.0)
    assert mon.check(now=4.0) == {"b"}
    assert mon.check(now=5.0) == set()  # not newly dead twice
    mon.beat("b", now=6.0)  # resurrection clears
    assert "b" not in mon.dead


def test_straggler_policy():
    t = StepTimer(StragglerPolicy(factor=1.5, patience=3, ewma=1.0))
    for step in range(5):
        for h in ("h0", "h1", "h2"):
            t.record(h, 1.0)
        t.record("slow", 2.0)
        out = t.stragglers()
        if step < 2:
            assert out == set()
    assert "slow" in out


# ------------------------------------------------- chunked == unchunked
def test_scan_chunked_remat_equivalence():
    from repro.models.common import scan_chunked_remat

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    xs = jnp.arange(64.0)
    c_ref, ys_ref = jax.lax.scan(step, jnp.float32(0), xs)
    c_got, ys_got = scan_chunked_remat(step, jnp.float32(0), xs, chunk=8)
    np.testing.assert_allclose(np.asarray(ys_ref), np.asarray(ys_got), rtol=1e-6)

    def loss_plain(x0):
        _, ys = jax.lax.scan(step, x0, xs)
        return jnp.sum(ys**2)

    def loss_chunked(x0):
        _, ys = scan_chunked_remat(step, x0, xs, chunk=8)
        return jnp.sum(ys**2)

    g1 = jax.grad(loss_plain)(jnp.float32(1.0))
    g2 = jax.grad(loss_chunked)(jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_attend_chunked_equivalence():
    from repro.models.attention import attend, attend_chunked

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    pos = jnp.arange(64)
    a = attend(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=17, cap=20.0)
    b = attend_chunked(
        q, k, v, q_pos=pos, k_pos=pos, chunk=16, causal=True, window=17, cap=20.0
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


def test_chunked_xent_equivalence():
    from repro.configs import get_config
    from repro.models import zoo
    from repro.models.zoo import ShapeSpec, build_params, make_batch

    cfg = get_config("gemma2-2b", smoke=True)  # softcap + tied head
    params, _ = build_params(cfg, 0)
    batch = make_batch(cfg, ShapeSpec("t", 2 * zoo.LOSS_CHUNK, 2, "train"), 3)
    h, _, _ = zoo.forward(cfg, params, batch, return_hidden=True)
    chunked = zoo._chunked_xent(cfg, params, h, batch["labels"], batch["mask"])
    from repro.models.common import cross_entropy

    logits = zoo._head(cfg, params, h)
    plain = cross_entropy(logits, batch["labels"], cfg.vocab, batch["mask"])
    np.testing.assert_allclose(float(chunked), float(plain), rtol=2e-3)


def test_microbatch_equivalence():
    """microbatch=2 must produce (numerically close) identical updates."""
    from repro.configs import get_config
    from repro.models.zoo import ShapeSpec, build_params, make_batch, make_train_step

    cfg = get_config("yi-9b", smoke=True)
    params, _ = build_params(cfg, 0)
    opt = AdamW(lr=1e-3)
    batch = make_batch(cfg, ShapeSpec("t", 32, 4, "train"), 5)

    def run(c):
        state = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
        state, m = jax.jit(make_train_step(c, opt))(state, batch)
        return state, m

    s1, m1 = run(cfg)
    s2, m2 = run(cfg.replace(microbatch=2))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-3)
    for k in s1["params"]:
        np.testing.assert_allclose(
            np.asarray(s1["params"][k], np.float32),
            np.asarray(s2["params"][k], np.float32),
            atol=5e-3,
        )
