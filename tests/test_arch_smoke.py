"""Per-assigned-architecture smoke tests: reduced same-family configs run
one forward/train step + a prefill->decode handoff on CPU, asserting
output shapes and finite values (deliverable (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.zoo import (
    ShapeSpec,
    build_params,
    make_batch,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.optim import AdamW

# whole-module slow marker: one train step per assigned architecture is
# minutes of XLA compiles — full CI lane only
pytestmark = pytest.mark.slow

TRAIN = ShapeSpec("t", 64, 2, "train")
PREFILL = ShapeSpec("p", 32, 2, "prefill")


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        params, axes = build_params(cfg, 0)
        out[arch] = (cfg, params, axes)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, built):
    cfg, params, _ = built[arch]
    opt = AdamW(lr=1e-3)
    state = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
    batch = make_batch(cfg, TRAIN, seed=1)
    state, m = jax.jit(make_train_step(cfg, opt))(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) > 0
    assert int(state["step"]) == 1
    # params actually changed
    delta = sum(
        float(jnp.abs(state["params"][k] - params[k]).sum()) for k in params
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, built):
    cfg, params, _ = built[arch]
    batch = make_batch(cfg, PREFILL, seed=2)
    logits, cache = jax.jit(make_prefill_step(cfg))(params, batch)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    lg, cache = serve(params, cache, tok, jnp.int32(PREFILL.seq_len))
    assert lg.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_configs_match_assignment(arch):
    """Full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168, vocab=65536),
        "phi3.5-moe-42b-a6.6b": dict(
            n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
            vocab=32064, n_experts=16, topk=2,
        ),
        "granite-moe-1b-a400m": dict(
            n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
            vocab=49155, n_experts=32, topk=8,
        ),
        "internvl2-26b": dict(
            n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
            vocab=92553,
        ),
        "starcoder2-15b": dict(
            n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
            vocab=49152,
        ),
        "qwen2.5-14b": dict(
            n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
            vocab=152064, qkv_bias=True,
        ),
        "yi-9b": dict(
            n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
            vocab=64000,
        ),
        "gemma2-2b": dict(
            n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
            vocab=256000,
        ),
        "hymba-1.5b": dict(
            n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
            vocab=32001, ssm_state=16,
        ),
        "seamless-m4t-medium": dict(
            n_layers=12, enc_layers=12, d_model=1024, n_heads=16,
            n_kv_heads=16, d_ff=4096, vocab=256206,
        ),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_full_param_counts_sane():
    """Full-config parameter counts land near the advertised sizes."""
    import math

    expect_b = {
        "rwkv6-1.6b": (1.3, 2.2),
        "granite-moe-1b-a400m": (0.9, 1.6),
        "gemma2-2b": (2.0, 3.4),
        "hymba-1.5b": (1.2, 2.2),
        "yi-9b": (8.0, 10.0),
        "starcoder2-15b": (14.0, 17.0),
        "qwen2.5-14b": (13.0, 16.5),
        "internvl2-26b": (18.0, 27.0),  # LLM backbone only (ViT is stubbed)
        "phi3.5-moe-42b-a6.6b": (40.0, 45.0),
        "seamless-m4t-medium": (0.5, 1.3),
    }
    for arch, (lo, hi) in expect_b.items():
        cfg = get_config(arch)
        params = jax.eval_shape(lambda c=cfg: build_params(c, abstract=True)[0])
        n = sum(math.prod(p.shape) for p in params.values()) / 1e9
        assert lo <= n <= hi, (arch, n)
