"""Distributed-optimization collectives: hierarchical reduction order and
int8 error-feedback compression (numerics + convergence property)."""

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.sharding.collectives import (
    compressed_psum_with_feedback,
    dequantize_int8,
    quantize_int8,
)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, 64), jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # error bounded by one quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9
    assert q.dtype == jnp.int8


def test_compressed_psum_error_feedback_unbiased():
    """Across steps, error feedback keeps the accumulated compressed sum
    close to the exact sum (the EF-SGD guarantee)."""
    n_ranks, dim, steps = 4, 256, 50
    rng = np.random.default_rng(0)
    grads = rng.normal(0, 1, (steps, n_ranks, dim)).astype(np.float32)

    def one_round(gs, errs):
        # emulate the psum across ranks: quantize each rank's (g + err)
        sent, new_errs, scales = [], [], []
        for r in range(n_ranks):
            g = gs[r] + errs[r]
            q, s = quantize_int8(jnp.asarray(g))
            sent.append(np.asarray(q, np.int32))
            scales.append(float(s))
            new_errs.append(g - np.asarray(dequantize_int8(q, s)))
        smax = max(scales)
        total = np.sum(np.stack(sent), axis=0).astype(np.float32) * smax
        return total, new_errs

    errs = [np.zeros(dim, np.float32) for _ in range(n_ranks)]
    acc_compressed = np.zeros(dim, np.float32)
    acc_exact = np.zeros(dim, np.float32)
    for t in range(steps):
        total, errs = one_round(grads[t], errs)
        acc_compressed += total
        acc_exact += grads[t].sum(0)
    # accumulated drift stays small relative to the signal
    rel = np.abs(acc_compressed - acc_exact).max() / (np.abs(acc_exact).max() + 1e-9)
    assert rel < 0.25  # conservative-scale quantizer; EF bounds the drift


def test_compressed_psum_shard_map():
    """The shard_map form: 8 ranks psum int8 payloads; result approximates
    the f32 psum and wire bytes are 1/4."""
    import subprocess, sys, json, os
    from pathlib import Path

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.sharding.collectives import compressed_psum_with_feedback
mesh = jax.make_mesh((8,), ("pod",))
rng = np.random.default_rng(1)
g = jnp.asarray(rng.normal(0, 1, (8, 128)), jnp.float32)  # one row per rank
err = jnp.zeros((8, 128), jnp.float32)

def body(g_l, e_l):
    out, new_e = compressed_psum_with_feedback(g_l[0], e_l[0], "pod")
    return out[None], new_e[None]

out, new_err = jax.jit(shard_map(body, mesh=mesh,
    in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod"))))(g, err)
exact = np.asarray(jnp.sum(g, 0))
got = np.asarray(out[0])
rel = float(np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9))
print("REL::" + json.dumps(rel))
"""
    root = Path(__file__).resolve().parent.parent
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=root, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    rel = json.loads([l for l in r.stdout.splitlines() if l.startswith("REL::")][-1][5:])
    assert rel < 0.05
