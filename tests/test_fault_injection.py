"""Fault-injection schedules against the transport/runtime boundary.

PR 1's error-containment contract: a bad frame (or a bad payload inside a
drained batch) must never wedge ``PE.poll`` and must never take healthy
frames down with it — every healthy frame/group still retires, then the
first error surfaces loudly.  These tests drive that contract under the
schedules a real fabric produces: dropped, duplicated, and reordered
frames, and mid-batch corruption.

The injection point is the endpoint inbox (the receive buffer a one-sided
PUT lands in): dropping/duplicating/reordering entries there is exactly a
lossy/racy wire without faking anything above the transport.
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    CorruptFrame,
    ProtocolError,
    make_tsi,
)
from repro.core.frame import MAGIC
from repro.runtime.embed_service import EmbedShardService

I32 = np.int32


def tsi_pair():
    from repro.core.ifunc import PE, Toolchain
    from repro.core.transport import Fabric

    fabric = Fabric("ideal")
    tc = Toolchain()
    names = ["server0", "client"]
    server = PE("server0", fabric, triple="cpu-bf2", toolchain=tc, peers=names)
    client = PE("client", fabric, triple="cpu-host", toolchain=tc, peers=names)
    server.register_region("counter", np.zeros(1, I32))
    client.register_source(make_tsi())
    return fabric, client, server


class TestDrop:
    def test_dropped_frame_loses_only_itself(self):
        """Drop the middle of three in-flight TSIs: the other two retire,
        poll returns cleanly (loss is detected by idleness, not a wedge)."""
        fabric, client, server = tsi_pair()
        for v in (10, 20, 30):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        inbox = server.endpoint.inbox
        assert len(inbox) == 3
        del inbox[1]  # the wire ate frame #2
        assert server.poll() == 2
        assert server.region("counter")[0] == 40

    def test_dropped_gather_frame_detected_not_hung(self):
        """A dropped key-frame means one request can never complete: the
        service must raise TimeoutError (idle detection), not spin, and
        the un-dropped requests must already have completed."""
        cl = Cluster(n_servers=2, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        svc.gather([np.array([1], I32)])  # warm code caches
        rids = [svc.submit(np.array([k], I32)) for k in (3, 40, 7)]
        svc._admit()
        # eat the key-frame parked at server1 (owner of key 40)
        assert len(cl.servers[1].endpoint.inbox) == 1
        cl.servers[1].endpoint.inbox.clear()
        with pytest.raises(TimeoutError):
            svc.run()
        done = {r.rid for r in svc.finished}
        assert rids[0] in done and rids[2] in done and rids[1] not in done


class TestDuplicate:
    def test_duplicated_frame_is_at_least_once(self):
        """The fabric re-delivering a frame must not error or stall —
        one-sided PUT semantics are at-least-once; the payload re-runs."""
        fabric, client, server = tsi_pair()
        client.send_ifunc("server0", "tsi", np.array([5], I32))
        inbox = server.endpoint.inbox
        inbox.append(bytearray(inbox[0]))  # duplicate delivery
        assert server.poll() == 2
        assert server.region("counter")[0] == 10

    def test_duplicated_gather_return_is_idempotent_on_rows(self):
        """A duplicated partial RETURN ORs position bits already set and
        scatters the SAME rows to the SAME positions — exactly idempotent,
        results bit-identical.  (The early-completion variant of this
        schedule is test_gather.py::test_duplicate_partial_return_cannot_
        complete_early.)"""
        cl = Cluster(n_servers=2, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        keys = np.array([3, 40], I32)  # spans both shards
        svc.gather([keys])  # warm
        fut = cl.client.submit("server0", "gatherer", svc._pad(keys), svc.cq,
                               expected=len(keys))
        # let the servers resolve; duplicate whatever lands at the client
        for _ in range(4):
            for pe in cl.pes():
                pe.poll()
            inbox = cl.client.endpoint.inbox
            for buf in list(inbox):
                inbox.append(bytearray(buf))
        cl.run_until(fut.done)
        np.testing.assert_array_equal(fut.result()[: len(keys)], svc.table[keys])


class TestReorder:
    def test_reordered_frames_commute(self):
        """TSI is commutative and gather RETURNs are slot/position-addressed:
        any delivery order of steady-state (code-cached) frames produces
        the same state."""
        fabric, client, server = tsi_pair()
        client.send_ifunc("server0", "tsi", np.array([100], I32))
        server.poll()  # code installed; everything later is payload-only
        for v in (1, 2, 3, 4):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        server.endpoint.inbox.rotate(2)  # adversarial reordering
        server.poll()
        assert server.region("counter")[0] == 110

    def test_code_frame_reordered_behind_its_payloads_is_loud(self):
        """The one reordering the protocol cannot absorb: a truncated
        frame arriving before the code it refers to.  The receiver must
        refuse loudly (ProtocolError) — and still retire the code frame
        and every later payload (error containment, batched path)."""
        fabric, client, server = tsi_pair()
        server.batching = True
        for v in (1, 2, 3):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        server.endpoint.inbox.rotate(1)  # code frame now arrives last
        with pytest.raises(ProtocolError, match="stale sender cache"):
            server.poll()
        # the code-carrying frame (v=1) and the frame behind it (v=2)
        # both retired; only the too-early truncated v=3 was refused
        assert server.region("counter")[0] == 3

    def test_reordered_gather_returns_match_oracle(self):
        cl = Cluster(n_servers=4, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        rng = np.random.default_rng(0)
        batches = [rng.integers(0, 64, 4).astype(I32) for _ in range(6)]
        svc.gather(batches)  # warm
        futs = []
        for keys in batches:
            f = cl.client.submit(f"server{svc.owner(keys[0])}", "gatherer",
                                 svc._pad(keys), svc.cq, expected=len(keys))
            f.meta = keys
            futs.append(f)
        rounds = 0
        while not all(f.done() for f in futs):
            for pe in cl.pes():
                pe.endpoint.inbox.rotate(1)  # shuffle every queue, every round
                pe.poll()
            rounds += 1
            assert rounds < 100
        for f in futs:
            np.testing.assert_array_equal(f.result()[: len(f.meta)],
                                          svc.table[f.meta])


class TestCorruption:
    def test_corrupt_frame_mid_batch_contained(self):
        """Batched poll: [healthy, corrupt, healthy] — both healthy frames
        retire, THEN the corruption surfaces as a ProtocolError."""
        fabric, client, server = tsi_pair()
        server.batching = True
        client.send_ifunc("server0", "tsi", np.array([7], I32))
        client.send_ifunc("server0", "tsi", np.array([2], I32))
        client.send_ifunc("server0", "tsi", np.array([4], I32))
        inbox = server.endpoint.inbox
        mid = inbox[1]
        mid[mid.index(MAGIC)] ^= 0xFF  # smash the payload sentinel
        with pytest.raises(ProtocolError):
            server.poll()
        assert server.region("counter")[0] == 11  # 7 + 4 ran

    def test_corrupt_batch_subheader_contained(self):
        """A coalesced frame whose batch sub-header disagrees with its
        payload section is rejected without discarding its batch-mates."""
        fabric, client, server = tsi_pair()
        client.batching = server.batching = True
        for v in (1, 2, 3):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        client.flush()
        client.send_ifunc("server0", "tsi", np.array([10], I32))
        client.flush()
        inbox = server.endpoint.inbox
        assert len(inbox) == 2
        # inflate the coalesced frame's payload count field
        hdr_end = inbox[0].index(b"tsi") + 3
        inbox[0][hdr_end] = 200  # count u32 LSB: 3 -> 200
        with pytest.raises(ProtocolError):
            server.poll()
        assert server.region("counter")[0] == 10  # the healthy single ran

    def test_garbage_delivery_then_healthy_traffic(self):
        """Pure garbage on the wire: the per-message poll surfaces it and
        the NEXT poll retires the healthy traffic behind it."""
        fabric, client, server = tsi_pair()
        fabric.put("client", "server0", b"\xde\xad\xbe\xef" * 16)
        client.send_ifunc("server0", "tsi", np.array([9], I32))
        with pytest.raises(CorruptFrame):
            server.poll()
        assert server.poll() == 1
        assert server.region("counter")[0] == 9

    def test_garbage_in_batched_poll_contained(self):
        """Batched poll: garbage plus two healthy frames — both retire in
        the same poll, then the error is re-raised."""
        fabric, client, server = tsi_pair()
        server.batching = True
        client.send_ifunc("server0", "tsi", np.array([3], I32))
        fabric.put("client", "server0", b"\x00" * 80)
        client.send_ifunc("server0", "tsi", np.array([6], I32))
        with pytest.raises(ProtocolError):
            server.poll()
        assert server.region("counter")[0] == 9