"""Fault-tolerance integration: the TrainDriver's restart path replays
deterministically, and the sender-cache invalidation story holds on the
simulated fabric after a PE restart."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamW
from repro.runtime import TrainDriver


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("gemma2-2b", smoke=True).replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        d_ff=64, vocab=128, window=8, embed_mult=1.0,
    )


def _driver(cfg, tmp, **kw):
    return TrainDriver(
        cfg,
        ckpt_dir=tmp,
        opt=AdamW(lr=1e-3),
        data=DataConfig(seq_len=32, global_batch=2, vocab=cfg.vocab),
        ckpt_every=5,
        **kw,
    )


@pytest.mark.slow
def test_restart_replays_identically(tiny_cfg, tmp_path):
    clean = _driver(tiny_cfg, tmp_path / "a").run(12)
    faulty = _driver(tiny_cfg, tmp_path / "b").run(12, fail_at_step=8)
    assert faulty.restarts == 1
    assert faulty.restored_steps == [5]
    # steps 5..7 run twice in the faulty run; the final losses (i.e. the
    # trajectory by step index) must match the clean run bit-for-bit-ish
    # because state restored from ckpt(5) + deterministic pipeline replay
    clean_by_step = clean.losses
    faulty_tail = faulty.losses[-7:]  # steps 5..11 after restore
    np.testing.assert_allclose(clean_by_step[5:12], faulty_tail, rtol=1e-5)


@pytest.mark.slow
def test_resume_from_disk(tiny_cfg, tmp_path):
    d1 = _driver(tiny_cfg, tmp_path / "c")
    r1 = d1.run(10)
    # a brand-new driver process resumes from the step-10 checkpoint
    d2 = _driver(tiny_cfg, tmp_path / "c")
    r2 = d2.run(15)
    assert r2.steps_run == 5  # only 10->15
    # and diverging-loss protection works
    assert all(np.isfinite(r2.losses))


def test_restarted_pe_invalidates_sender_cache():
    """Paper Sec III-D corner: a restarted PE lost its code cache; senders
    holding stale cache entries would ship truncated frames that the PE
    cannot decode.  ``Cluster.restart_server`` now invalidates every
    sender's entries itself (ISSUE 4 regression fix), so the first send
    after a restart re-pays the full code frame and just works."""
    from repro.core import Cluster, make_tsi

    cl = Cluster(n_servers=1, wire="ideal")
    cl.servers[0].register_region("counter", np.zeros(1, np.int32))
    cl.toolchain.publish(make_tsi())
    cl.client.send_ifunc("server0", "tsi", np.ones(1, np.int32))
    cl.drain()
    # server dies and restarts: fresh caches, no regions — and every
    # sender's cache rows for it dropped by restart_server
    cl.kill_server(0)
    pe = cl.restart_server(0)
    pe.register_region("counter", np.zeros(1, np.int32))
    code0 = cl.client.stats.code_sends
    cl.client.send_ifunc("server0", "tsi", np.ones(1, np.int32))
    pe.poll()  # full frame travelled: installs and runs, no refusal
    assert pe.region("counter")[0] == 1
    assert cl.client.stats.code_sends == code0 + 1


def test_stale_sender_cache_still_refused_loudly():
    """The loud-refusal path behind the restart fix is still exercised
    when staleness arises outside Cluster.restart_server (e.g. an operator
    swapping a process under the same endpoint name): a truncated frame
    for unknown code raises, and manual invalidation recovers."""
    from repro.core import Cluster, ProtocolError, make_tsi
    from repro.core.ifunc import PE

    cl = Cluster(n_servers=1, wire="ideal")
    cl.servers[0].register_region("counter", np.zeros(1, np.int32))
    cl.toolchain.publish(make_tsi())
    cl.client.send_ifunc("server0", "tsi", np.ones(1, np.int32))
    cl.drain()
    # a fresh process takes over the endpoint WITHOUT the cluster's
    # restart path running — senders keep their stale cache rows
    cl.fabric.kill("server0")
    pe = PE("server0", cl.fabric, triple="cpu-bf2", toolchain=cl.toolchain,
            peers=cl.servers[0].peers)
    cl.servers[0] = pe
    pe.register_region("counter", np.zeros(1, np.int32))
    cl.client.send_ifunc("server0", "tsi", np.ones(1, np.int32))
    with pytest.raises(ProtocolError):
        pe.poll()
    cl.client.sender_cache.invalidate_endpoint("server0")
    cl.client.send_ifunc("server0", "tsi", np.ones(1, np.int32))
    pe.poll()
    assert pe.region("counter")[0] == 1
