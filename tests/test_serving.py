"""Continuous-batching scheduler: admission, lockstep decode, correctness
against single-request decoding."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.models.zoo import build_params
from repro.runtime.serving import ServeScheduler


@pytest.fixture(scope="module")
def served():
    cfg = get_config("yi-9b", smoke=True)
    params, _ = build_params(cfg, 0)
    return cfg, params


def _reference_decode(cfg, params, prompt, max_new, t_max=64):
    """Single request through its own scheduler = the reference stream."""
    s = ServeScheduler(cfg, params, slots=1, t_max=t_max)
    s.submit(prompt, max_new)
    (req,) = s.run()
    return req.out


def test_more_requests_than_slots(served):
    cfg, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 12)).astype(np.int32)
               for _ in range(5)]
    sched = ServeScheduler(cfg, params, slots=2, t_max=64)
    rids = [sched.submit(p, max_new=6) for p in prompts]
    done = sched.run()
    assert sorted(r.rid for r in done) == rids
    assert all(len(r.out) == 6 for r in done)
    # every request's stream matches its isolated decode (continuous
    # batching must not leak state across slots)
    for r in done:
        want = _reference_decode(cfg, params, prompts[r.rid], 6)
        assert r.out == want, (r.rid, r.out, want)


def test_max_new_one_returns_exactly_one_token(served):
    """Regression: the prefill token already satisfies ``max_new=1``, so
    the scheduler must retire the request before the decode step — it
    used to decode (and return) a second token."""
    cfg, params = served
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    sched = ServeScheduler(cfg, params, slots=2, t_max=64)
    sched.submit(prompt, max_new=1)
    (req,) = sched.run()
    assert len(req.out) == 1, req.out
    assert req.out == _reference_decode(cfg, params, prompt, 1)


def test_max_new_never_overshot(served):
    """No request — any ``max_new``, mixed in one batch — may ever exceed
    its token budget."""
    cfg, params = served
    rng = np.random.default_rng(3)
    sched = ServeScheduler(cfg, params, slots=2, t_max=64)
    budgets = [1, 2, 5]
    prompts = [rng.integers(0, cfg.vocab, 4).astype(np.int32) for _ in budgets]
    for p, m in zip(prompts, budgets):
        sched.submit(p, max_new=m)
    done = sched.run()
    assert sorted(len(r.out) for r in done) == sorted(budgets)
    for r in done:
        assert r.out == _reference_decode(cfg, params, prompts[r.rid], budgets[r.rid])


def test_late_arrivals_join_running_batch(served):
    cfg, params = served
    rng = np.random.default_rng(1)
    sched = ServeScheduler(cfg, params, slots=2, t_max=64)
    p0 = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    sched.submit(p0, max_new=8)
    for _ in range(3):
        sched.tick()  # first request mid-flight
    p1 = rng.integers(0, cfg.vocab, 5).astype(np.int32)
    sched.submit(p1, max_new=4)
    done = sched.run()
    assert len(done) == 2
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].out == _reference_decode(cfg, params, p1, 4)
    assert by_rid[0].out == _reference_decode(cfg, params, p0, 8)
