"""The loop-aware HLO analyzer against programs with known costs."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import analyze_hlo
from repro.analysis.roofline import roofline


def _compiled_text(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    n_layers, m = 6, 128

    def scanned(x, w):
        def body(h, w_l):
            return jnp.tanh(h @ w_l), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((n_layers, m, m), jnp.float32)
    hc = analyze_hlo(_compiled_text(scanned, x, w))
    want = 2.0 * m * m * m * n_layers
    assert hc.while_trip_counts and max(hc.while_trip_counts) == n_layers
    assert want * 0.99 <= hc.dot_flops <= want * 1.01


def test_unrolled_matches_scanned_flops():
    m = 64

    def unrolled(x, w):
        for i in range(4):
            x = x @ w[i]
        return x

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((4, m, m), jnp.float32)
    hc = analyze_hlo(_compiled_text(unrolled, x, w))
    assert hc.dot_flops == pytest.approx(4 * 2 * m**3, rel=0.01)


def test_grad_flops_roughly_triple():
    m = 128

    def loss(x, w):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    w = jax.ShapeDtypeStruct((m, m), jnp.float32)
    fwd = analyze_hlo(_compiled_text(loss, x, w)).dot_flops
    bwd = analyze_hlo(_compiled_text(jax.grad(loss, argnums=1), x, w)).dot_flops
    assert 1.8 * fwd <= bwd <= 3.2 * fwd  # dL/dw + recompute terms


def test_collective_bytes_counted(tmp_path):
    # hand-written module exercising the collective parser
    hlo = """
HloModule test

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups={}, to_apply=%add
  ROOT %out = f32[1024]{0} add(%ar, %p)
}
"""
    # computation %add is missing but the parser only needs the entry
    hc = analyze_hlo(hlo)
    assert hc.collective_bytes == 4096
    assert hc.collective_by_kind == {"all-reduce": 4096.0}


def test_roofline_terms_and_dominance():
    from repro.analysis.hlo import HloCost

    cost = HloCost(
        flops=197e12,  # exactly 1s of compute
        bytes_accessed=819e9 * 0.5,
        bytes_major=819e9 * 0.5,  # 0.5s of HBM
        collective_bytes=100e9 * 2,  # 2s all-reduce at ring factor 2 => 4s
        collective_by_kind={"all-reduce": 100e9 * 2},
    )
    rep = roofline("a", "s", "m", 4, cost, model_flops=197e12 * 4)
    assert rep.t_compute == pytest.approx(1.0)
    assert rep.t_memory == pytest.approx(0.5)
    assert rep.t_collective == pytest.approx(4.0)
    assert rep.dominant == "collective"
    assert rep.useful_ratio == pytest.approx(1.0)
    assert rep.mfu_bound == pytest.approx(0.25)


def test_bytes_major_below_pessimistic():
    def f(x, w):
        h = jnp.tanh(x @ w)
        return jnp.sum(h * h + 3.0)

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    hc = analyze_hlo(_compiled_text(f, x, w))
    assert 0 < hc.bytes_major <= hc.bytes_accessed
