"""Fuzz-hardening of the wire-ingest surfaces (satellite of the sandbox PR).

Contract: hostile bytes fed to any parser a remote peer can reach —
``peek_header`` / ``unpack`` / ``unpack_hop`` / ``uvarint_decode`` /
``unpack_payloads`` / ``unpack_rndv`` / ``FatBitcode.from_bytes`` — must
either succeed on genuinely well-formed input or raise the
:class:`ProtocolError` family (:class:`CorruptFrame`), **never** leak an
``IndexError`` / ``struct.error`` / ``UnicodeDecodeError`` /
``AssertionError`` out of the parsing layer.  ``peek_header`` may also
return ``None`` (more bytes pending) and ``delivery_complete`` ``False``
— those are flow-control signals, not errors.

test_core_frame.py already property-tests round-trips and single-byte
tampering; this module drives *structured* hostility: truncation at every
prefix length of a valid buffer, forged length fields that point past the
end, and undecodable text sections.
"""

import struct

import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.core.bitcode import FatBitcode
from repro.core.frame import (
    CorruptFrame,
    Frame,
    FrameKind,
    HopHeader,
    ProtocolError,
    delivery_complete,
    pack_hop,
    pack_payloads,
    peek_header,
    unpack,
    unpack_hop,
    unpack_payloads,
    unpack_rndv,
    uvarint_decode,
)

FORBIDDEN = (IndexError, struct.error, UnicodeDecodeError, AssertionError)


def _frame_buf(deps=("abi:update", "region:counter")) -> bytes:
    return Frame(
        kind=FrameKind.BITCODE,
        name="fuzzee",
        payload=b"\x01\x02\x03\x04",
        code=b"C" * 40,
        deps=deps,
        digest=b"\xab" * 32,
    ).pack()


def _fat_buf() -> bytes:
    return FatBitcode(
        slices={"cpu-host": b"H" * 24, "tpu-v5e": b"T" * 56}
    ).to_bytes()


def _ingest_all(buf: bytes) -> None:
    """Feed one buffer to every reachable parser; loud or clean only."""
    for fn in (
        lambda b: peek_header(b),
        lambda b: unpack(b, has_code=True),
        lambda b: unpack(b, has_code=False),
        lambda b: delivery_complete(b, expect_code=True),
        lambda b: unpack_hop(b),
        lambda b: uvarint_decode(b, 0),
        lambda b: unpack_payloads(b),
        lambda b: FatBitcode.from_bytes(b),
    ):
        try:
            fn(buf)
        except ProtocolError:
            pass
        except ValueError as e:  # CorruptFrame is also a ValueError
            assert not isinstance(e, FORBIDDEN), e


# ---------------------------------------------------------------- truncation
class TestTruncation:
    def test_frame_every_prefix_is_loud_or_pending(self):
        buf = _frame_buf()
        for cut in range(len(buf)):
            prefix = buf[:cut]
            assert peek_header(prefix) is None or True  # must not raise junk
            try:
                unpack(prefix, has_code=True)
            except ProtocolError:
                continue
            except FORBIDDEN as e:  # pragma: no cover - the failure mode
                pytest.fail(f"cut={cut}: {type(e).__name__} leaked: {e}")
            pytest.fail(f"cut={cut}: truncated frame parsed silently")

    def test_fat_bitcode_every_prefix_is_loud(self):
        buf = _fat_buf()
        for cut in range(len(buf)):
            try:
                FatBitcode.from_bytes(buf[:cut])
            except CorruptFrame:
                continue
            except FORBIDDEN as e:  # pragma: no cover - the failure mode
                pytest.fail(f"cut={cut}: {type(e).__name__} leaked: {e}")
            pytest.fail(f"cut={cut}: truncated archive parsed silently")

    def test_fat_bitcode_roundtrip_still_exact(self):
        fat = FatBitcode.from_bytes(_fat_buf())
        assert fat.slices == {"cpu-host": b"H" * 24, "tpu-v5e": b"T" * 56}

    def test_hop_every_prefix_is_loud(self):
        buf = pack_hop(HopHeader(ttl=3, root=2, pub_id=9, path=(2, 0), k=0))
        for cut in range(len(buf)):
            with pytest.raises(CorruptFrame):
                unpack_hop(buf[:cut])


# ------------------------------------------------------------- forged fields
class TestForgedLengths:
    def test_fat_bitcode_slice_count_lies(self):
        """A slice count larger than the archive holds must not walk off
        the buffer (the pre-hardening struct.error/IndexError path)."""
        buf = bytearray(_fat_buf())
        struct.pack_into("<H", buf, 4, 0xFFFF)
        with pytest.raises(CorruptFrame, match="truncated slice"):
            FatBitcode.from_bytes(bytes(buf))

    def test_fat_bitcode_blob_length_lies(self):
        buf = bytearray(_fat_buf())
        struct.pack_into("<I", buf, 8, 2**31)  # first slice's blob length
        with pytest.raises(CorruptFrame, match="exceeds archive"):
            FatBitcode.from_bytes(bytes(buf))

    def test_fat_bitcode_triple_not_utf8(self):
        buf = bytearray(_fat_buf())
        buf[12] = 0xFF  # first byte of the first triple's name
        with pytest.raises(CorruptFrame, match="undecodable"):
            FatBitcode.from_bytes(bytes(buf))

    def test_fat_bitcode_bad_magic_is_corrupt_and_value_error(self):
        err = None
        try:
            FatBitcode.from_bytes(b"XXXX" + _fat_buf()[4:])
        except CorruptFrame as e:
            err = e
        assert err is not None and isinstance(err, ValueError)
        assert "not a fat-bitcode archive" in str(err)

    def test_frame_deps_not_utf8(self):
        """Corrupt the DEPS text section of a full frame: unpack must
        refuse loudly, not leak UnicodeDecodeError."""
        frame = Frame(
            kind=FrameKind.BITCODE,
            name="fuzzee",
            payload=b"p",
            code=b"C" * 8,
            deps=("abi:update",),
            digest=b"\xab" * 32,
        )
        buf = bytearray(frame.pack())
        deps_off = len(buf) - 8 - len("abi:update")  # before trailing MAGIC
        buf[deps_off] = 0xFF
        with pytest.raises(CorruptFrame, match="deps"):
            unpack(bytes(buf), has_code=True)

    def test_rndv_wrong_sizes_are_loud(self):
        for n in (0, 1, 8, 15, 17, 32):
            with pytest.raises(CorruptFrame):
                unpack_rndv(b"\x00" * n)

    def test_batch_count_lies(self):
        section = bytearray(pack_payloads([b"ab", b"cd"]))
        section[0] = 0x7F  # claim 127 payloads
        with pytest.raises(CorruptFrame):
            unpack_payloads(bytes(section))


# ----------------------------------------------------------- random hostility
@settings(max_examples=200, deadline=None)
@given(junk=st.binary(min_size=0, max_size=200))
def test_garbage_never_leaks_low_level_errors(junk):
    _ingest_all(junk)


@settings(max_examples=100, deadline=None)
@given(
    pos=st.integers(min_value=0, max_value=10_000),
    val=st.integers(min_value=0, max_value=255),
)
def test_single_byte_corruption_never_leaks(pos, val):
    """Overwrite one byte anywhere in a valid frame, archive, or batch
    section: every ingest either still parses (benign byte) or refuses
    via the ProtocolError family."""
    for base in (_frame_buf(), _fat_buf(), pack_payloads([b"xy", b"zw!"])):
        buf = bytearray(base)
        buf[pos % len(buf)] = val
        _ingest_all(bytes(buf))


@settings(max_examples=100, deadline=None)
@given(
    junk=st.binary(min_size=0, max_size=64),
    hdr=st.binary(min_size=0, max_size=24),
)
def test_valid_magic_with_hostile_tail_never_leaks(junk, hdr):
    """The adversary knows the magics: prefix them to junk so parsing gets
    past the cheap first check into the length-field logic."""
    _ingest_all(b"FBC1" + junk)
    _ingest_all(b"3CHN" + hdr + junk)  # frame header magic (HDR_MAGIC)
