"""X-RDMA Gather: conformance, completion queue, multi-action ABI.

Acceptance surface:
* gather results bit-identical to the numpy take oracle across shard
  counts {1, 4, 8} and both batching modes (ragged key batches included);
* out-of-order RETURN matching by slot — many gathers overlapped in
  flight, partial results from different shards interleaving;
* the batched path amortizes: fewer network ops and lower modeled wire
  time than the GET-per-row baseline at scale.
"""

import numpy as np
import pytest

from repro.core import (
    A_FORWARD,
    A_NOP,
    A_RETURN,
    Cluster,
    make_gather_return,
    make_gatherer,
)
from repro.runtime.embed_service import EmbedShardService, ragged_batches

I32 = np.int32


def make_service(n_servers, vocab=256, dim=16, n_keys=8, max_slots=32, seed=3):
    cl = Cluster(n_servers=n_servers, wire="ideal")
    return EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=n_keys, max_slots=max_slots, seed=seed
    )


# ----------------------------------------------------------- conformance
class TestGatherConformance:
    @pytest.mark.parametrize("batching", [False, True])
    @pytest.mark.parametrize("n_servers", [1, 4, 8])
    def test_bit_identical_to_take_oracle(self, n_servers, batching):
        svc = make_service(n_servers)
        batches = ragged_batches(svc.vocab, 24, svc.n_keys, seed=11)
        rep = svc.gather(batches, batching=batching)
        for got, want in zip(rep.results, svc.oracle(batches)):
            np.testing.assert_array_equal(got, want)

    def test_single_key_and_full_batch(self):
        svc = make_service(4)
        batches = [np.array([5], I32), np.arange(8, dtype=I32) * 31 % svc.vocab]
        rep = svc.gather(batches)
        for got, want in zip(rep.results, svc.oracle(batches)):
            np.testing.assert_array_equal(got, want)

    def test_duplicate_keys_in_one_request(self):
        svc = make_service(4)
        batches = [np.array([7, 7, 200, 7], I32)]
        rep = svc.gather(batches)
        np.testing.assert_array_equal(rep.results[0], svc.table[[7, 7, 200, 7]])

    def test_key_validation(self):
        svc = make_service(4)
        with pytest.raises(ValueError, match="range"):
            svc.submit(np.array([svc.vocab], I32))
        with pytest.raises(ValueError, match="range"):
            svc.submit(np.array([-1], I32))
        with pytest.raises(ValueError, match="keys"):
            svc.submit(np.arange(svc.n_keys + 1, dtype=I32))

    def test_forward_only_on_locality_breaks(self):
        """A request whose keys all live on the first owner costs zero
        FORWARDs; a request spanning m shards costs <= m-1 forward PUTs
        plus m returns (the Chaser contract, serving-shaped)."""
        svc = make_service(4)
        local = np.arange(4, dtype=I32)  # all on server0
        svc.gather([local])  # warm code caches
        rep = svc.gather([local])
        assert sum(pe.stats.forwards for pe in svc.cluster.servers) == 0
        assert rep.puts == 2  # inject + one RETURN


# ------------------------------------------------- completion queue layer
class TestCompletionQueue:
    def test_out_of_order_interleaved_returns(self):
        """Many gathers in flight; every request's keys span every shard,
        so partial RETURNs from 4 servers interleave across 16 slots and
        must land in their own slots."""
        svc = make_service(4, max_slots=16)
        rng = np.random.default_rng(0)
        batches = [
            np.array(
                [s * svc.rows_per_shard + rng.integers(svc.rows_per_shard)
                 for s in range(4)] * 2,
                I32,
            )
            for _ in range(16)
        ]
        rep = svc.gather(batches, batching=True)
        for got, want in zip(rep.results, svc.oracle(batches)):
            np.testing.assert_array_equal(got, want)

    def test_slots_recycle_under_continuous_batching(self):
        """3x more requests than slots: admission waits for retirements,
        everything completes, all slots return to the free list."""
        svc = make_service(4, max_slots=8)
        batches = ragged_batches(svc.vocab, 24, svc.n_keys, seed=5)
        rep = svc.gather(batches)
        assert svc.cq.free_slots == 8
        for got, want in zip(rep.results, svc.oracle(batches)):
            np.testing.assert_array_equal(got, want)

    def test_queue_full_would_block(self):
        """Slot exhaustion is an admission signal, not an exception:
        ``submit`` returns None (would-block), the in-flight submissions
        are untouched, and a freed slot admits again."""
        cl = Cluster(n_servers=1, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=2)
        cl.toolchain.lookup("gatherer")  # artifacts exist
        futs = [
            cl.client.submit("server0", "gatherer", svc._pad(np.array([k], I32)),
                             svc.cq, expected=1)
            for k in (1, 2)
        ]
        assert all(f is not None for f in futs)
        blocked = cl.client.submit("server0", "gatherer",
                                   svc._pad(np.array([3], I32)),
                                   svc.cq, expected=1)
        assert blocked is None
        assert svc.cq.free_slots == 0  # the would-block did not leak a slot
        # the raising contract survives for direct queue users
        with pytest.raises(RuntimeError, match="full"):
            svc.cq._alloc()
        cl.run_until(lambda: all(f.done() for f in futs))
        for f, k in zip(futs, (1, 2)):
            np.testing.assert_array_equal(f.result()[0], svc.table[k])
        retry = cl.client.submit("server0", "gatherer",
                                 svc._pad(np.array([3], I32)),
                                 svc.cq, expected=1)
        assert retry is not None
        cl.run_until(retry.done)
        np.testing.assert_array_equal(retry.result()[0], svc.table[3])

    def test_future_misuse_raises(self):
        cl = Cluster(n_servers=1, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=2)
        fut = cl.client.submit("server0", "gatherer", svc._pad(np.array([3], I32)),
                               svc.cq, expected=1)
        with pytest.raises(RuntimeError, match="incomplete"):
            fut.result()
        cl.run_until(fut.done)
        np.testing.assert_array_equal(fut.result()[0], svc.table[3])
        with pytest.raises(RuntimeError, match="consumed"):
            fut.result()


# ------------------------------------------------------- multi-action ABI
class TestMultiActionABI:
    def test_action_matrix_shape_and_nops(self):
        """The gatherer's traced action matrix: one potential FORWARD row
        per server + one RETURN row; NOP rows where nothing goes."""
        import jax

        S, rows_per, K, D = 4, 16, 4, 2
        gat = make_gatherer(rows_per, S, K, D, targets=("cpu-host",))

        exported = jax.export.deserialize(gat.fat.slices["cpu-host"])
        table = np.arange(rows_per * D, dtype=np.float32).reshape(rows_per, D)
        meta = np.array([0, rows_per, S], I32)
        # hdr [requester=S, slot=0, epoch=7]; keys: one local (server0),
        # one on server2, padding elsewhere
        payload = np.array([S, 0, 7, 3, 2 * rows_per + 1, -1, -1], I32)
        acts = np.asarray(exported.call(payload, table, meta))
        assert acts.shape == (S + 1, 3 + 3 + K + K * D)
        assert acts[0, 0] == A_NOP  # server0 keys were resolved locally
        assert acts[1, 0] == A_NOP
        assert acts[2, 0] == A_FORWARD and acts[2, 1] == 2
        # forwarded hdr carries [requester, slot, epoch] verbatim ...
        np.testing.assert_array_equal(acts[2, 3:6], [S, 0, 7])
        # ... and keys preserve positions: pos 1 carries the remote key
        fwd_keys = acts[2, 6 : 6 + K]
        np.testing.assert_array_equal(fwd_keys, [-1, 2 * rows_per + 1, -1, -1])
        ret = acts[S]
        assert ret[0] == A_RETURN and ret[1] == S  # to the requester
        assert ret[3] == 0 and ret[4] == 7  # slot + epoch echoed
        assert ret[5] == 1  # nres: exactly the local key
        # returned row 0 = table[3], bit-cast
        row0 = ret[6 + K : 6 + K + D].view(np.float32)
        np.testing.assert_array_equal(row0, table[3])

    def test_gather_return_scatters_counts_and_drops_stale(self):
        import jax

        K, D, slots = 4, 2, 3
        gr = make_gather_return(slots, K, D, targets=("cpu-host",))
        exported = jax.export.deserialize(gr.fat.slices["cpu-host"])
        results = np.zeros((slots, 2 + K * D), I32)
        results[1, 1] = 7  # slot 1 is at generation 7
        rows = np.zeros((K, D), np.float32)
        rows[2] = [1.5, -2.5]
        payload = np.concatenate(
            [
                np.array([1, 7, 1], I32),  # slot 1, epoch 7, one result
                np.array([-1, -1, 2, -1], I32),  # only pos 2 valid
                rows.view(I32).reshape(-1),
            ]
        )
        out = np.asarray(exported.call(payload, results))
        assert out[1, 0] == 1 << 2  # position bitmask, not a counter
        assert out[0, 0] == out[2, 0] == 0
        got = out[1, 2:].view(np.float32).reshape(K, D)
        np.testing.assert_array_equal(got[2], rows[2])
        assert not got[[0, 1, 3]].any()
        # re-delivering the same partial is exactly idempotent (OR + same rows)
        out_dup = np.asarray(exported.call(payload, out))
        np.testing.assert_array_equal(out_dup, out)
        # a stale-generation RETURN (epoch 6 != 7) is dropped whole
        stale = payload.copy()
        stale[1] = 6
        out2 = np.asarray(exported.call(stale, out))
        np.testing.assert_array_equal(out2, out)

    def test_duplicate_partial_return_cannot_complete_early(self):
        """The at-least-once hazard inside one generation: the wire
        re-delivers shard A's partial RETURN before shard B's arrives.
        A counter would hit `expected` and complete the future with B's
        rows still zero; the position bitmask must not."""
        cl = Cluster(n_servers=2, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=2)
        keys = np.array([3, 40], I32)  # spans both shards
        svc.gather([keys])  # warm code caches everywhere
        fut = cl.client.submit("server0", "gatherer", svc._pad(keys),
                               svc.cq, expected=len(keys))
        cl.servers[0].poll()  # server0: partial RETURN + FORWARD to server1
        # duplicate server0's partial RETURN before server1 even runs
        inbox = cl.client.endpoint.inbox
        assert len(inbox) == 1
        inbox.append(bytearray(inbox[0]))
        cl.client.poll()
        assert not fut.done()  # 1 distinct position arrived, not 2
        cl.run_until(fut.done)  # server1's partial completes it
        np.testing.assert_array_equal(fut.result()[: len(keys)], svc.table[keys])

    def test_stale_return_after_slot_recycle_is_dropped(self):
        """At-least-once hazard: a RETURN for a *retired* gather drained
        after its slot was recycled must not scatter into (or complete)
        the slot's new owner."""
        cl = Cluster(n_servers=1, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=1)
        ka, kb = np.array([3], I32), np.array([40], I32)
        fut_a = cl.client.submit("server0", "gatherer", svc._pad(ka),
                                 svc.cq, expected=1)
        cl.servers[0].poll()  # RETURN for A lands in the client inbox
        stale = bytes(cl.client.endpoint.inbox[0])  # the wire re-delivers it later
        cl.client.poll()
        np.testing.assert_array_equal(fut_a.result()[0], svc.table[3])
        # slot 0 recycles to request B (epoch bumps)
        fut_b = cl.client.submit("server0", "gatherer", svc._pad(kb),
                                 svc.cq, expected=1)
        cl.client.endpoint.deliver(stale)  # late duplicate of A's RETURN
        cl.client.poll()
        assert not fut_b.done()  # stale epoch dropped: B is NOT spuriously done
        cl.run_until(fut_b.done)
        np.testing.assert_array_equal(fut_b.result()[0], svc.table[40])

    def test_failed_send_does_not_leak_slot(self):
        """A dead destination endpoint must not consume a completion-queue
        slot: the slot frees, the error propagates, and later submits work."""
        from repro.core import EndpointDead

        cl = Cluster(n_servers=2, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=2)
        cl.fabric.kill("server1")
        for _ in range(4):  # more failures than slots: would wedge if leaking
            with pytest.raises(EndpointDead):
                cl.client.submit("server1", "gatherer", svc._pad(np.array([40], I32)),
                                 svc.cq, expected=1)
        assert svc.cq.free_slots == 2
        fut = cl.client.submit("server0", "gatherer", svc._pad(np.array([3], I32)),
                               svc.cq, expected=1)
        cl.run_until(fut.done)
        np.testing.assert_array_equal(fut.result()[0], svc.table[3])

    def test_cancel_recycles_slot_safely(self):
        """cancel() on a lost-frame future frees its slot; the epoch guard
        protects the recycled slot even if the lost gather's RETURN shows
        up afterwards."""
        cl = Cluster(n_servers=1, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=1)
        svc.gather([np.array([1], I32)])  # code caches warm on both sides
        fut = cl.client.submit("server0", "gatherer", svc._pad(np.array([5], I32)),
                               svc.cq, expected=1)
        cl.servers[0].endpoint.inbox.clear()  # the wire ate the key-frame
        fut.cancel()
        fut.cancel()  # idempotent
        assert svc.cq.free_slots == 1
        fut2 = cl.client.submit("server0", "gatherer", svc._pad(np.array([6], I32)),
                                svc.cq, expected=1)
        cl.run_until(fut2.done)
        np.testing.assert_array_equal(fut2.result()[0], svc.table[6])


# --------------------------------------------------------- fat-bitcode
class TestGathererToolchain:
    def test_tpu_slice_carries_pallas_kernel(self):
        """The per-platform toolchain: the gatherer's TPU bitcode slice is
        lowered through the Pallas embed_lookup (Mosaic custom call), the
        CPU slices through the masked-take reference — one op, per-ISA
        bodies, same function."""
        gat = make_gatherer(64, 4, 8, 16)
        fat = gat.fat
        assert "tpu-v5e" in fat.triples() and "cpu-host" in fat.triples()
        tpu = fat.slices["tpu-v5e"]
        assert b"tpu_custom_call" in tpu or b"Mosaic" in tpu
        assert b"tpu_custom_call" not in fat.slices["cpu-host"]

    def test_pallas_gate_falls_back_on_bad_blocking(self):
        """A shard shape the kernel cannot block (v_loc > 512, not a
        multiple of 512) still builds — portable entry in every slice."""
        gat = make_gatherer(600, 2, 4, 8)
        assert b"tpu_custom_call" not in gat.fat.slices["tpu-v5e"]


# ----------------------------------------------------------- amortization
class TestGatherAmortizes:
    def test_batched_beats_get_per_row_at_scale(self):
        """The acceptance numbers: >= 256 concurrent requests, 8 shards,
        thor_xeon — batched X-RDMA must use fewer network dispatches and
        lower modeled wire time than GET-per-row, bit-identically."""
        cl = Cluster(n_servers=8, wire="thor_xeon")
        svc = EmbedShardService(cl, vocab=1024, dim=16, n_keys=8, max_slots=64)
        batches = ragged_batches(svc.vocab, 256, svc.n_keys, seed=1)
        want = svc.oracle(batches)
        svc.gather(batches, batching=True)  # warm code + pad buckets
        get = svc.gather_get(batches)
        bat = svc.gather(batches, batching=True)
        for rep in (get, bat):
            for got, w in zip(rep.results, want):
                np.testing.assert_array_equal(got, w)
        assert bat.network_ops < get.network_ops
        assert bat.invokes < get.gets
        assert bat.modeled_us < get.modeled_us
        assert bat.coalesced_frames > 0
        assert bat.coalesced_payloads > bat.coalesced_frames
