"""Multi-tenant serving-tier contracts: QoS classes, shedding, isolation.

The serving tier (runtime/tenancy.py) maps tenant classes onto three
runtime mechanisms — EXPRESS control-lane drain priority, per-tenant
credit budgets in the wire layer, and per-tenant CQ-slot quotas — and
sheds above the fabric at each tenant's queue limit.  Every test here
checks both the scheduling effect (what the knob buys) and the invariants
that must survive it: shed requests never enter the fabric, accepted
requests complete exactly once and bit-identical to the numpy oracle, and
the per-tenant ledgers (wire occupancy, CQ tags) drain back to zero.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.core import Cluster, make_tsi
from repro.runtime.embed_service import EmbedShardService
from repro.runtime.tenancy import RemoteEmbedClient, TenantClass, TenantRouter

I32 = np.int32


def service(n_servers=2, max_slots=8, n_keys=4, dim=4, vocab_per_shard=16):
    cl = Cluster(n_servers)
    svc = EmbedShardService(
        cl, vocab=vocab_per_shard * n_servers, dim=dim, n_keys=n_keys,
        max_slots=max_slots,
    )
    # warm the gather code path so admission tests measure QoS, not
    # first-contact code movement
    svc.gather([np.arange(1, n_keys + 1, dtype=I32)])
    return cl, svc


def batches(svc, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, svc.vocab, rng.integers(1, svc.n_keys + 1)).astype(I32)
        for _ in range(n)
    ]


# ---------------------------------------------------------------- router
class TestRouter:
    def test_duplicate_class_names_rejected(self):
        _, svc = service()
        with pytest.raises(ValueError):
            TenantRouter(svc, [TenantClass("a"), TenantClass("a")])

    def test_shed_at_queue_limit_is_exactly_once(self):
        """A shed request never enters the fabric: no rid, no slot, no
        frame, no late result — and every accepted request completes
        exactly once, bit-identical to the oracle."""
        cl, svc = service()
        router = TenantRouter(svc, [TenantClass("t", queue_limit=2)])
        keys = batches(svc, 5)
        rids = [router.submit("t", k) for k in keys]
        accepted = [r for r in rids if r is not None]
        assert len(accepted) == 2 and rids[2:] == [None, None, None]
        assert router.stats["t"].shed == 3
        done = []
        while svc.queue or svc.active:
            done += router.tick()
        # exactly the accepted rids completed, exactly once each
        assert sorted(r.rid for r in done) == sorted(accepted)
        for req, k in zip(sorted(done, key=lambda r: r.rid), keys[:2]):
            assert np.array_equal(req.rows, svc.table[k])
        # shedding freed capacity: the tenant may submit again now
        assert router.submit("t", keys[0]) is not None

    def test_outstanding_tracks_completion(self):
        cl, svc = service()
        router = TenantRouter(svc, [TenantClass("t", queue_limit=4)])
        router.submit("t", np.array([1, 2], I32))
        assert router.outstanding("t") == 1
        while svc.queue or svc.active:
            router.tick()
        assert router.outstanding("t") == 0
        assert router.stats["t"].latencies  # tick latency recorded

    def test_unknown_tenant_raises(self):
        _, svc = service()
        router = TenantRouter(svc, [TenantClass("a")])
        with pytest.raises(KeyError):
            router.submit("nobody", np.array([1], I32))


# ------------------------------------------------------------ slot quota
class TestSlotQuota:
    def test_quota_caps_cq_occupancy(self):
        """A tenant with slot_quota=1 never holds more than one CQ slot,
        however deep its backlog — and still completes everything."""
        cl, svc = service(max_slots=8)
        router = TenantRouter(svc, [TenantClass("t", slot_quota=1)])
        keys = batches(svc, 6)
        rids = [router.submit("t", k) for k in keys]
        done = []
        while svc.queue or svc.active:
            done += router.tick()
            assert svc.cq.tag_inflight("t") <= 1
        assert sorted(r.rid for r in done) == rids
        for req in done:
            assert np.array_equal(
                req.rows, svc.table[keys[rids.index(req.rid)]]
            )
        assert svc.cq.tag_inflight("t") == 0  # ledger drained

    def test_quota_block_does_not_head_of_line_block(self):
        """With the hot tenant at quota and more of its requests queued
        *ahead* of a background request, the background request still
        admits this tick — the quota holds back the hot tenant only."""
        cl, svc = service(max_slots=8)
        router = TenantRouter(
            svc, [TenantClass("hot", slot_quota=1), TenantClass("bg")]
        )
        for k in batches(svc, 4, seed=1):
            router.submit("hot", k)
        router.submit("bg", np.array([3, 5], I32))
        svc._admit()
        assert svc.cq.tag_inflight("hot") == 1
        # bg admitted past three quota-held hot requests
        assert any(r.tenant == "bg" for r in svc.active.values())
        assert sum(1 for r in svc.queue if r.tenant == "hot") == 3
        while svc.queue or svc.active:
            router.tick()
        assert router.stats["bg"].served == 1


# ---------------------------------------------------------- credit budget
class TestCreditBudget:
    def _warm_counter_cluster(self):
        cl = Cluster(n_servers=1, wire="ideal")
        cl.servers[0].register_region("counter", np.zeros(1, I32))
        cl.toolchain.publish(make_tsi())
        cl.client.send_ifunc("server0", "tsi", np.array([0], I32))
        cl.drain()  # code installed, sender cache warm
        return cl

    def test_budget_stalls_excess_and_conserves(self):
        """With a budget of 1 payload in flight, back-to-back tenant sends
        queue at the sender (counted per tenant), drain as the receiver
        polls, and the tenant's wire occupancy returns to zero."""
        cl = self._warm_counter_cluster()
        cl.set_tenant_budgets({"t": 1})
        for _ in range(3):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32), tenant="t")
        assert cl.fabric.tenant_outstanding("client", "t") == 1
        assert cl.client.wire.queued_credit_frames(tenant="t") == 2
        assert cl.fabric.stats.tenant_stalls["t"] == 2
        assert cl.client.stats.tenant_stalls["t"] == 2
        cl.drain()
        assert int(cl.servers[0].region("counter")[0]) == 3  # nothing lost
        assert cl.fabric.tenant_outstanding("client", "t") == 0
        assert cl.client.wire.queued_credit_frames() == 0

    def test_budget_lanes_are_per_tenant(self):
        """One tenant at budget must not stall another tenant's sends —
        the wire queues are per (dst, tenant) lanes, not one FIFO."""
        cl = self._warm_counter_cluster()
        cl.set_tenant_budgets({"a": 1})
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32), tenant="a")
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32), tenant="a")
        before = int(cl.fabric.stats.puts)
        cl.client.send_ifunc("server0", "tsi", np.array([10], I32), tenant="b")
        assert int(cl.fabric.stats.puts) == before + 1  # b flowed past a's stall
        cl.drain()
        assert int(cl.servers[0].region("counter")[0]) == 12

    def test_untenanted_traffic_ignores_budgets(self):
        cl = self._warm_counter_cluster()
        cl.set_tenant_budgets({"t": 1})
        for _ in range(4):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.client.wire.queued_credit_frames() == 0
        cl.drain()
        assert int(cl.servers[0].region("counter")[0]) == 4


# ----------------------------------------------------------- express lane
class TestExpressLane:
    def _backlogged(self, express_last=True):
        cl = Cluster(n_servers=1, wire="ideal")
        srv = cl.servers[0]
        srv.register_region("counter", np.zeros(1, I32))
        cl.toolchain.publish(make_tsi())
        cl.client.send_ifunc("server0", "tsi", np.array([0], I32))
        cl.drain()  # warm: later frames are digest-only and resolvable
        for _ in range(3):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.client.send_ifunc(
            "server0", "tsi", np.array([100], I32), express=express_last
        )
        srv.batching = True
        srv.poll_budget = 1  # one payload per poll: order is observable
        return cl, srv

    def test_express_jumps_the_bulk_backlog(self):
        cl, srv = self._backlogged()
        srv.lanes = True
        srv.poll()
        # the express frame was served first despite arriving last...
        assert int(srv.region("counter")[0]) == 100
        cl.drain()
        # ...and nothing was lost or doubled behind it
        assert int(srv.region("counter")[0]) == 103

    def test_express_without_lanes_stays_fifo(self):
        cl, srv = self._backlogged()
        srv.lanes = False
        srv.poll()
        assert int(srv.region("counter")[0]) == 1
        cl.drain()
        assert int(srv.region("counter")[0]) == 103


# ------------------------------------------------------------- quota edges
class TestQuotaEdges:
    def test_zero_quota_fields_mean_unlimited(self):
        """The all-zeros class is documented best-effort: no shedding, no
        slot cap, no budget — a backlog deeper than the CQ still completes
        via admission backpressure alone."""
        cl, svc = service(max_slots=4)
        router = TenantRouter(svc, [TenantClass("t")])
        keys = batches(svc, 10)
        rids = [router.submit("t", k) for k in keys]
        assert None not in rids and router.stats["t"].shed == 0
        done = []
        while svc.queue or svc.active:
            done += router.tick()
        assert sorted(r.rid for r in done) == rids
        assert svc.cq.free_slots == svc.max_slots

    def test_slot_quota_exactly_at_max_slots(self):
        """quota == max_slots is the degenerate cap: global saturation and
        the tenant ledger bind at the same point, and neither leaks."""
        cl, svc = service(max_slots=4)
        router = TenantRouter(
            svc, [TenantClass("t", slot_quota=svc.max_slots)]
        )
        rids = [router.submit("t", k) for k in batches(svc, 7)]
        done = []
        while svc.queue or svc.active:
            done += router.tick()
            assert svc.cq.tag_inflight("t") <= svc.max_slots
        assert sorted(r.rid for r in done) == rids
        assert svc.cq.tag_inflight("t") == 0
        assert svc.cq.free_slots == svc.max_slots

    def test_queue_limit_exactly_at_offered_load(self):
        """Submitting exactly queue_limit requests sheds nothing; the
        (limit+1)-th is the first refusal."""
        cl, svc = service()
        router = TenantRouter(svc, [TenantClass("t", queue_limit=3)])
        keys = batches(svc, 4)
        rids = [router.submit("t", k) for k in keys[:3]]
        assert None not in rids and router.stats["t"].shed == 0
        assert router.submit("t", keys[3]) is None
        assert router.stats["t"].shed == 1
        while svc.queue or svc.active:
            router.tick()
        assert router.stats["t"].served == 3

    def test_quota_held_requests_survive_recovery_sweep(self):
        """The interaction the sandbox PR hardens: requests held on the
        quota aside-list while ``_recover`` degrades a dead-owner future
        must neither be lost, double-admitted, nor leak a slot.  Every
        accepted request retires exactly once (degraded or whole) and the
        CQ ledgers drain to empty."""
        from repro.core import ReliabilityConfig

        cl, svc = service(n_servers=2, max_slots=4, vocab_per_shard=16)
        cl.set_reliability(
            ReliabilityConfig.on(
                rto_ticks=1, retransmit_budget=2, max_misses=2,
                future_deadline=8,
            )
        )
        router = TenantRouter(svc, [TenantClass("hot", slot_quota=1)])
        # every request touches both shards: key < 16 owned by server0,
        # key >= 16 by server1 — so server0's death degrades, not voids
        keys = [np.array([2 + i, 18 + i], I32) for i in range(4)]
        rids = [router.submit("hot", k) for k in keys]
        assert None not in rids
        done = router.tick()  # admits one (quota), holds three aside
        assert len(svc.queue) == 3  # the aside-list requeued, none lost
        cl.kill_server(0)
        ticks = 0
        while svc.queue or svc.active:
            done += router.tick()
            assert svc.cq.tag_inflight("hot") <= 1  # quota held throughout
            ticks += 1
            assert ticks < 10_000
        done += router.tick()
        # exactly-once through the sweep: all four retired, none twice
        assert sorted(r.rid for r in done) == rids
        for req, k in zip(sorted(done, key=lambda r: r.rid), keys):
            if req.degraded:  # admitted after (or across) the death
                # server0's half can never be valid; server1's half may or
                # may not have landed before the recovery sweep fired —
                # but whatever is marked valid must be oracle-exact.
                assert not req.valid.tolist()[0]
                for j, ok in enumerate(req.valid.tolist()):
                    if ok:
                        np.testing.assert_array_equal(
                            req.rows[j], svc.table[k[j]]
                        )
            else:  # completed whole before server0 died
                np.testing.assert_array_equal(req.rows, svc.table[k])
        assert sum(r.degraded for r in done) >= 3
        # no slot leak, no stale tag ledger
        assert svc.cq.free_slots == svc.max_slots
        assert svc.cq.tag_inflight("hot") == 0


# ------------------------------------------------------ remote-embed decode
@pytest.fixture(scope="module")
def served():
    from repro.configs import get_config
    from repro.models.zoo import build_params

    cfg = get_config("yi-9b", smoke=True)
    params, _ = build_params(cfg, 0)
    return cfg, params


class TestRemoteEmbedDecode:
    def test_rows_bit_identical_to_table(self, served):
        _, params = served
        table = np.asarray(params["embed.tok"], np.float32)
        client = RemoteEmbedClient(table, n_servers=2, n_keys=4)
        rng = np.random.default_rng(3)
        ids = rng.integers(0, table.shape[0], (2, 7)).astype(I32)
        got = client.rows(ids)
        assert got.shape == (2, 7, table.shape[1])
        assert np.array_equal(got, table[ids])  # f32 through int32 CQ words

    def test_decode_stream_bit_identical_local_vs_remote(self, served):
        """The end-to-end LM serving scenario: a ServeScheduler whose
        embedding rows arrive via CQ gather futures over the PE fabric
        must emit the same token stream as the local-lookup scheduler —
        bit-for-bit, across continuous batching and ragged admission."""
        from repro.runtime.serving import ServeScheduler

        cfg, params = served
        prompts = [np.arange(1, 6, dtype=I32), np.array([7, 3, 2], I32)]

        local = ServeScheduler(cfg, params, slots=2, t_max=32)
        for p in prompts:
            local.submit(p, 5)
        want = {r.rid: r.out for r in local.run()}

        embed = RemoteEmbedClient(np.asarray(params["embed.tok"], np.float32))
        remote = ServeScheduler(cfg, params, slots=2, t_max=32, embed_client=embed)
        for p in prompts:
            remote.submit(p, 5)
        got = {r.rid: r.out for r in remote.run()}
        assert got == want
        assert embed.gathers > 0  # the rows really travelled the fabric


# ----------------------------------------------------- isolation property
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    quota=st.integers(1, 3),
    limit=st.integers(0, 4),
    budget=st.integers(0, 2),
    n_hot=st.integers(1, 10),
    n_bg=st.integers(1, 5),
    lanes=st.sampled_from([False, True]),
    loss=st.sampled_from([0.0, 0.05]),
)
def test_tenant_isolation_invariants(
    seed, quota, limit, budget, n_hot, n_bg, lanes, loss
):
    """For any QoS configuration (budgets x lanes x loss rate) and any
    interleaved two-tenant workload: accounting is exactly-once (accepted
    + shed == offered; every accepted request retires exactly once, no
    request both shed and served), results are oracle-identical, and
    every per-tenant ledger — wire occupancy, CQ tags, stalled lanes —
    drains back to zero."""
    from repro.core import ReliabilityConfig

    cl, svc = service(n_servers=2, max_slots=4)
    cl.set_flow(lanes=lanes)
    if loss:
        # a lossy fabric needs the reliability layer to stay exactly-once
        cl.set_reliability(ReliabilityConfig.on(retransmit_budget=50))
        cl.fabric.set_loss(loss, seed=seed + 7)
    router = TenantRouter(
        svc,
        [
            TenantClass(
                "hot", slot_quota=quota, queue_limit=limit, credit_budget=budget
            ),
            TenantClass("bg", express=True),
        ],
    )
    rng = np.random.default_rng(seed)
    offered = [("hot", k) for k in batches(svc, n_hot, seed)]
    offered += [("bg", k) for k in batches(svc, n_bg, seed + 1)]
    rng.shuffle(offered)

    expected = {}
    done = []
    for i, (tenant, keys) in enumerate(offered):
        rid = router.submit(tenant, keys)
        if rid is not None:
            expected[rid] = svc.table[keys]
        if i % 3 == 2:  # interleave progress with submission
            done += router.tick()
    ticks = 0
    while svc.queue or svc.active:
        done += router.tick()
        ticks += 1
        assert ticks < 10_000
    done += router.tick()  # final harvest

    # exactly-once: every accepted request retired once, none twice, and
    # accepted + shed accounts for every submission attempt
    rids = sorted(r.rid for r in done)
    assert rids == sorted(expected)
    shed = sum(s.shed for s in router.stats.values())
    assert len(expected) + shed == len(offered)
    for req in done:
        assert not req.degraded
        assert np.array_equal(req.rows, expected[req.rid])
    # ledgers drained: no leaked credits, slots, or stalled frames
    for tenant in ("hot", "bg"):
        assert cl.fabric.tenant_outstanding("client", tenant) == 0
        assert svc.cq.tag_inflight(tenant) == 0
    assert cl.client.wire.queued_credit_frames() == 0
    assert svc.cq.free_slots == svc.max_slots
