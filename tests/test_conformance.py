"""Cross-mode conformance: ONE parametrized oracle check for every DAPC
execution mode (replacing the ad-hoc per-mode checks that used to live in
test_pointer_chase.py).

The contract: ``dapc`` over {bitcode, binary, am} x {batching on, off} x
3 seeds is bit-identical to the numpy ``chase_ref`` oracle, and ``gbpc``
(the RDMA-GET baseline) agrees — same table, same starts, same depths.
One cluster per (mode-independent) seed so every mode/batching cell is
compared on identical state.
"""

import numpy as np
import pytest

from repro.core import Cluster, PointerChaseApp, chase_ref

I32 = np.int32

SEEDS = (0, 1, 2)
DEPTHS = (1, 7, 64)


@pytest.fixture(scope="module", params=SEEDS, ids=lambda s: f"seed{s}")
def seeded_app(request):
    """One 4-server cluster + sharded table per seed, shared by every
    mode/batching cell (conformance must hold on the same state)."""
    seed = request.param
    cluster = Cluster(n_servers=4, wire="ideal")
    app = PointerChaseApp(cluster, n_entries=512, max_slots=16, seed=seed)
    rng = np.random.default_rng(seed + 100)
    starts = rng.integers(0, app.n_entries, 8).astype(I32)
    want = {
        d: np.array([chase_ref(app.table, s, d) for s in starts], I32)
        for d in DEPTHS
    }
    return app, starts, want


@pytest.mark.parametrize("batching", [False, True], ids=["permsg", "batched"])
@pytest.mark.parametrize("mode", ["bitcode", "binary", "am"])
def test_dapc_conformance(seeded_app, mode, batching):
    app, starts, want = seeded_app
    for depth in DEPTHS:
        rep = app.dapc(starts, depth, mode=mode, batching=batching)
        np.testing.assert_array_equal(
            rep.results, want[depth],
            err_msg=f"mode={mode} batching={batching} depth={depth}",
        )


def test_gbpc_agrees(seeded_app):
    app, starts, want = seeded_app
    for depth in DEPTHS:
        rep = app.gbpc(starts, depth)
        np.testing.assert_array_equal(rep.results, want[depth])
