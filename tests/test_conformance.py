"""Cross-mode conformance: ONE parametrized oracle check for every DAPC
execution mode (replacing the ad-hoc per-mode checks that used to live in
test_pointer_chase.py).

The contract: ``dapc`` over {bitcode, binary, am} x {batching on, off} x
3 seeds is bit-identical to the numpy ``chase_ref`` oracle, and ``gbpc``
(the RDMA-GET baseline) agrees — same table, same starts, same depths.
One cluster per (mode-independent) seed so every mode/batching cell is
compared on identical state.

The propagation axis ({flat, tree} x {bitcode, binary} x seeds) runs on
*fresh* clusters per cell: tree code distribution only differs from flat
on cold caches, and the claim is twofold — oracle-identical results AND
strictly fewer client-side code dispatches for the tree.

The loss axis (PR 6) re-runs the whole mode matrix on a lossy fabric
(``Fabric.set_loss(0.05)``) with ``ReliabilityConfig.on()`` installed:
the oracle check must still hold bit-identically — no hangs, no
duplicated/double-applied rows — and in per-message mode the XLA invoke
count must match the lossless run exactly (retransmits never re-invoke:
the seq gate is exactly-once into the exec layer).
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    DataPlaneConfig,
    PointerChaseApp,
    PropagationConfig,
    ReliabilityConfig,
    chase_ref,
)

I32 = np.int32

SEEDS = (0, 1, 2)
DEPTHS = (1, 7, 64)
PROPAGATIONS = {
    "flat": None,
    "tree-binomial": PropagationConfig(),
    "tree-kary2": PropagationConfig(topology="kary", k=2),
}


@pytest.fixture(scope="module", params=SEEDS, ids=lambda s: f"seed{s}")
def seeded_app(request):
    """One 4-server cluster + sharded table per seed, shared by every
    mode/batching cell (conformance must hold on the same state)."""
    seed = request.param
    cluster = Cluster(n_servers=4, wire="ideal")
    app = PointerChaseApp(cluster, n_entries=512, max_slots=16, seed=seed)
    rng = np.random.default_rng(seed + 100)
    starts = rng.integers(0, app.n_entries, 8).astype(I32)
    want = {
        d: np.array([chase_ref(app.table, s, d) for s in starts], I32)
        for d in DEPTHS
    }
    return app, starts, want


@pytest.mark.parametrize("batching", [False, True], ids=["permsg", "batched"])
@pytest.mark.parametrize("mode", ["bitcode", "binary", "am"])
def test_dapc_conformance(seeded_app, mode, batching):
    app, starts, want = seeded_app
    for depth in DEPTHS:
        rep = app.dapc(starts, depth, mode=mode, batching=batching)
        np.testing.assert_array_equal(
            rep.results, want[depth],
            err_msg=f"mode={mode} batching={batching} depth={depth}",
        )


def test_gbpc_agrees(seeded_app):
    app, starts, want = seeded_app
    for depth in DEPTHS:
        rep = app.gbpc(starts, depth)
        np.testing.assert_array_equal(rep.results, want[depth])


@pytest.mark.parametrize("prop", PROPAGATIONS, ids=list(PROPAGATIONS))
@pytest.mark.parametrize("mode", ["bitcode", "binary"])
@pytest.mark.parametrize("seed", SEEDS, ids=lambda s: f"seed{s}")
def test_dapc_propagation_conformance(seed, mode, prop):
    """Tree code distribution is invisible to results (oracle-identical on
    a cold cluster) and strictly cheaper at the client: fewer code-carrying
    dispatches than the flat first-contact push."""
    cluster = Cluster(n_servers=4, wire="ideal")
    app = PointerChaseApp(cluster, n_entries=512, max_slots=16, seed=seed)
    rng = np.random.default_rng(seed + 100)
    starts = rng.integers(0, app.n_entries, 8).astype(I32)
    depth = 16
    want = np.array([chase_ref(app.table, s, depth) for s in starts], I32)
    rep = app.dapc(starts, depth, mode=mode, propagation=PROPAGATIONS[prop])
    np.testing.assert_array_equal(rep.results, want)
    name = {"bitcode": "chaser", "binary": "chaser_bin"}[mode]
    digest = cluster.toolchain.lookup(name).digest.hex()
    # the cluster is fresh, so the client's lifetime send stats == this run
    if prop == "flat":
        # flat: one full frame per server the client contacted first
        assert cluster.client.stats.code_sends >= 3
    else:
        # tree: exactly the root's children carry code from the client
        k_code = PROPAGATIONS[prop].k_code
        from repro.core import tree_children

        n_children = len(tree_children(k_code, 4, 4, 5))
        assert cluster.client.stats.code_sends == n_children
        flat_cost = sum(
            1 for pe in cluster.servers
            if pe.target_cache.lookup_digest(digest) is not None
        )
        assert cluster.client.stats.code_sends < flat_cost  # strictly fewer


# ------------------------------------------------------------- loss axis
LOSS_RATE = 0.05


def _lossy_app(seed: int, loss: float) -> tuple:
    cluster = Cluster(n_servers=4, wire="ideal")
    app = PointerChaseApp(cluster, n_entries=512, max_slots=16, seed=seed)
    cluster.set_reliability(ReliabilityConfig.on())
    cluster.fabric.set_loss(loss, seed=seed + 1)
    rng = np.random.default_rng(seed + 100)
    starts = rng.integers(0, app.n_entries, 8).astype(I32)
    return app, starts


@pytest.mark.parametrize("batching", [False, True], ids=["permsg", "batched"])
@pytest.mark.parametrize("mode", ["bitcode", "binary", "am"])
@pytest.mark.parametrize("seed", SEEDS, ids=lambda s: f"seed{s}")
def test_dapc_conformance_under_loss(seed, mode, batching):
    """Every mode cell survives 5% frame loss bit-identically: recovery is
    invisible to results, and in per-message mode the invoke count equals
    the lossless run's — exactly-once, not at-least-once."""
    depth = 16
    app, starts = _lossy_app(seed, LOSS_RATE)
    want = np.array([chase_ref(app.table, s, depth) for s in starts], I32)
    rep = app.dapc(starts, depth, mode=mode, batching=batching)
    np.testing.assert_array_equal(
        rep.results, want, err_msg=f"mode={mode} batching={batching}"
    )
    assert app.cluster.fabric.stats.frames_lost > 0  # loss really happened
    if not batching:
        ref_app, ref_starts = _lossy_app(seed, 0.0)
        ref = ref_app.dapc(ref_starts, depth, mode=mode, batching=False)
        assert rep.invokes == ref.invokes


# -------------------------------------------------- autotuned-profile axis
#
# PR 9's tuner emits FlowProfiles that Cluster.set_flow installs wholesale.
# The conformance claim: no profile the tuner (or a hand) can express may
# change results — every {mode} x {batching} x {data plane} cell stays
# oracle-identical with a profile installed, including under 5% loss.


@pytest.fixture(scope="module")
def autotuned_profile():
    """A genuinely tuned profile (coordinate descent over a captured
    trace), plus a 'stressed' variant with every flow knob off-default —
    the corners the tuner is allowed to reach."""
    from repro.analysis import autotune, capture

    cluster = Cluster(n_servers=4, wire="thor_xeon")
    app = PointerChaseApp(cluster, n_entries=512, max_slots=16, seed=0)
    rng = np.random.default_rng(100)
    starts = rng.integers(0, 512, 8).astype(I32)
    app.dapc(starts, 16)
    with capture(cluster) as rec:
        app.dapc(starts, 16)
    return autotune(rec, seed=0).profile


def _stressed(profile):
    from dataclasses import replace

    return replace(
        profile, lanes=True, credit_window=8, poll_budget=8, k_code=2
    )


@pytest.mark.parametrize("variant", ["tuned", "stressed"])
@pytest.mark.parametrize(
    "plane",
    ["framed", "zerocopy", "rendezvous"],
    ids=["framed", "zerocopy", "rndv"],
)
@pytest.mark.parametrize("batching", [False, True], ids=["permsg", "batched"])
@pytest.mark.parametrize("mode", ["bitcode", "binary", "am"])
def test_dapc_conformance_under_autotuned_profile(
    autotuned_profile, mode, batching, plane, variant
):
    from dataclasses import replace

    prof = autotuned_profile if variant == "tuned" else _stressed(autotuned_profile)
    prof = replace(
        prof,
        batching=batching,
        zerocopy=plane == "zerocopy",
        eager_max=0 if plane == "zerocopy" else 256,
        rndv_min=0 if plane == "rendezvous" else prof.rndv_min,
    )
    cluster = Cluster(n_servers=4, wire="ideal")
    app = PointerChaseApp(cluster, n_entries=512, max_slots=16, seed=0)
    rng = np.random.default_rng(100)
    starts = rng.integers(0, 512, 8).astype(I32)
    depth = 16
    want = np.array([chase_ref(app.table, s, depth) for s in starts], I32)
    prof.apply(cluster)
    rep = app.dapc(
        starts, depth, mode=mode, batching=prof.batching, dataplane=prof.dataplane()
    )
    np.testing.assert_array_equal(
        rep.results, want,
        err_msg=f"mode={mode} batching={batching} plane={plane} variant={variant}",
    )


@pytest.mark.parametrize("batching", [False, True], ids=["permsg", "batched"])
@pytest.mark.parametrize("mode", ["bitcode", "binary", "am"])
def test_dapc_autotuned_profile_under_loss(autotuned_profile, mode, batching):
    """The stressed profile's flow knobs (lanes, credit window, poll
    budget, k-ary propagation) survive the 5% loss arm bit-identically."""
    depth = 16
    app, starts = _lossy_app(0, LOSS_RATE)
    want = np.array([chase_ref(app.table, s, depth) for s in starts], I32)
    prof = _stressed(autotuned_profile)
    prof.apply(app.cluster)
    rep = app.dapc(
        starts, depth, mode=mode, batching=batching, dataplane=prof.dataplane()
    )
    np.testing.assert_array_equal(
        rep.results, want, err_msg=f"mode={mode} batching={batching}"
    )
    assert app.cluster.fabric.stats.frames_lost > 0  # loss really happened


@pytest.mark.parametrize(
    "plane",
    ["framed", "zerocopy", "rendezvous"],
    ids=["framed", "zerocopy", "rndv"],
)
def test_gather_conformance_under_loss(plane):
    """The gather service across every data-plane protocol at 5% loss:
    oracle-identical rows (lost one-sided RETURN writes are recovered by
    CQ-deadline resubmission, lost frames by retransmit)."""
    from repro.runtime.embed_service import EmbedShardService, ragged_batches

    cl = Cluster(n_servers=4, wire="ideal")
    svc = EmbedShardService(cl, vocab=128, dim=16, n_keys=6, max_slots=8)
    cl.set_reliability(ReliabilityConfig.on())
    cl.fabric.set_loss(LOSS_RATE, seed=17)
    dataplane = {
        "framed": None,
        "zerocopy": DataPlaneConfig.zero_copy(eager_max=0),
        "rendezvous": DataPlaneConfig.rendezvous(rndv_min=1),
    }[plane]
    batches = ragged_batches(128, 16, 6, seed=17)
    rep = svc.gather(batches, dataplane=dataplane)
    for got, want in zip(rep.results, svc.oracle(batches)):
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------- placement axis
#
# PR 10's heterogeneous placement layer routes the same logical filter
# through the pushdown ifunc, the pull GET baseline, or whatever the cost
# model picks.  The conformance claim: placement is invisible to results —
# every {batching} x {data plane} x {placement} cell is oracle-identical,
# including at 5% loss and under the per-tenant sandbox (whose verifier
# must admit the DPU filter entry's ABI: ragged RETURN payloads included).

PLACEMENTS = ("pushdown", "pull", "auto")
PLANES = ("framed", "zerocopy", "rendezvous")


def _filter_cell(loss: float = 0.0, sandbox: bool = False):
    from repro.core import SandboxConfig
    from repro.runtime.embed_service import FilterShardService

    cl = Cluster(n_servers=4, wire="ideal", hetero_wire=True)
    svc = FilterShardService(cl, vocab=512, dim=16, window=8, max_slots=8, seed=5)
    if sandbox:
        cl.set_sandbox(SandboxConfig.on())
    if loss:
        cl.set_reliability(ReliabilityConfig.on())
        cl.fabric.set_loss(loss, seed=11)
    los = svc.windows(12, seed=6)
    th = svc.thresh_for_selectivity(0.4)
    return svc, los, th, svc.oracle_filter(los, th)


@pytest.mark.parametrize("placement", PLACEMENTS)
@pytest.mark.parametrize("plane", PLANES, ids=["framed", "zerocopy", "rndv"])
@pytest.mark.parametrize("batching", [False, True], ids=["permsg", "batched"])
def test_filter_placement_conformance(batching, plane, placement):
    svc, los, th, want = _filter_cell()
    dataplane = {
        "framed": None,
        "zerocopy": DataPlaneConfig.zero_copy(eager_max=0),
        "rendezvous": DataPlaneConfig.rendezvous(rndv_min=1),
    }[plane]
    rep = svc.filter(
        los, th, batching=batching, dataplane=dataplane, placement=placement
    )
    for got, w in zip(rep.results, want):
        np.testing.assert_array_equal(
            got, w,
            err_msg=f"batching={batching} plane={plane} placement={placement}",
        )


@pytest.mark.parametrize("placement", PLACEMENTS)
def test_filter_placement_conformance_under_loss(placement):
    svc, los, th, want = _filter_cell(loss=LOSS_RATE)
    rep = svc.filter(los, th, placement=placement)
    for got, w in zip(rep.results, want):
        np.testing.assert_array_equal(got, w, err_msg=f"placement={placement}")
    if placement == "pushdown":  # the GET path never frames — nothing to lose
        assert svc.cluster.fabric.stats.frames_lost > 0  # loss really happened


@pytest.mark.parametrize("placement", PLACEMENTS)
def test_filter_placement_conformance_under_sandbox(placement):
    """The install-time verifier must admit the filter pair's ABI —
    including the ragged survivor RETURN — and the runtime sandbox must
    not refuse the per-tenant submission path."""
    svc, los, th, want = _filter_cell(sandbox=True)
    rep = svc.filter(los, th, placement=placement)
    for got, w in zip(rep.results, want):
        np.testing.assert_array_equal(got, w, err_msg=f"placement={placement}")
    assert sum(svc.cluster.refusals().values()) == 0, (
        "verifier/sandbox refused the filter ABI"
    )
