"""The runnable examples stay runnable (subprocess smoke).

Entry-point smokes run at ``--tiny`` sizes so the fast CI lane covers
every example; the heavyweight launcher tests carry the ``slow`` marker
(full lane only — see pytest.ini / .github/workflows/ci.yml).
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))


def _run(args, timeout=900):
    r = subprocess.run(
        [sys.executable, *args], capture_output=True, text=True, env=ENV,
        cwd=ROOT, timeout=timeout,
    )
    assert r.returncode == 0, (args, r.stderr[-2500:])
    return r.stdout


def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "(want 12)" in out and "counter on server0 = 12" in out
    assert "counter on server1 = 42" in out  # recursive spawn worked
    assert "results verified" in out


def test_xrdma_pointer_chase_example():
    out = _run(["examples/xrdma_pointer_chase.py", "--tiny"])
    assert "verified" in out
    assert "Pallas chase kernel resolved" in out


def test_dpu_preprocessing_example():
    out = _run(["examples/dpu_preprocessing.py", "--tiny"])
    assert "data moved 0 B" in out  # stats verified in-process before print


def test_xrdma_embed_service_example():
    out = _run(["examples/xrdma_embed_service.py", "--tiny"])
    assert "bit-identical to the numpy take oracle" in out
    assert "gather_shard_map over" in out and "verified" in out


def test_xrdma_propagate_example():
    out = _run(["examples/xrdma_propagate.py", "--tiny"])
    assert "tree multicast verified" in out
    assert "verified against numpy sum" in out
    assert "gossip verified" in out


@pytest.mark.slow
def test_serve_launcher():
    out = _run([
        "-m", "repro.launch.serve", "--arch", "gemma2-2b", "--batch", "2",
        "--prompt-len", "16", "--gen", "4",
    ])
    assert '"generated": 4' in out


@pytest.mark.slow
def test_train_launcher_tiny(tmp_path):
    # fresh ckpt dir: the driver auto-resumes from any committed checkpoint
    # it finds (that's the FT feature), so the test must not share one
    out = _run([
        "-m", "repro.launch.train", "--arch", "rwkv6-1.6b", "--steps", "4",
        "--seq-len", "64", "--global-batch", "2", "--ckpt-every", "2",
        "--ckpt-dir", str(tmp_path / "ckpt"),
    ])
    assert '"steps": 4' in out


@pytest.mark.slow
def test_dryrun_single_cell_smokes():
    """The dry-run entry point works end to end for one cheap cell (the
    full 80-cell matrix runs out of band; see artifacts/dryrun.jsonl)."""
    out = _run([
        "-m", "repro.launch.dryrun", "--arch", "granite-moe-1b-a400m",
        "--shape", "decode_32k", "--mesh", "single",
    ], timeout=1700)
    assert '"status": "ok"' in out
    assert '"devices": 256' in out
