"""Layered-runtime contracts: the ifunc re-export guarantee and the
cross-layer import hygiene of the `core/pe` package.

Two things a refactor must never silently break:

* every name historically importable from ``repro.core.ifunc`` (the
  pre-split god-object) keeps importing from there — downstream code and
  older notebooks depend on that surface;
* no module outside ``repro.core.pe`` imports a private ``_``-prefixed
  symbol from a layer module (enforced by walking every AST in src/,
  tests/, and benchmarks/), and the layers themselves only share their
  public surface with each other — the facade composes layers, nothing
  reaches around it.
"""

import ast
import importlib
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
CORE_PACKAGE = REPO / "src" / "repro" / "core"
PE_PACKAGE = CORE_PACKAGE / "pe"
LAYER_MODULES = ("source", "wire", "codecache", "exec", "progress", "cq", "pe")


def _py_files():
    for d in SCAN_DIRS:
        root = REPO / d
        if root.exists():
            yield from sorted(root.rglob("*.py"))


def _pe_imports(tree: ast.AST, in_package: bool):
    """Yield (module, imported_name) for every from-import that resolves
    into the repro.core.pe package."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ImportFrom):
            continue
        mod = node.module or ""
        absolute = mod.startswith("repro.core.pe")
        relative = in_package and node.level >= 1 and (
            mod.split(".")[0] in LAYER_MODULES or mod == ""
        )
        if absolute or relative:
            for alias in node.names:
                yield mod, alias.name


class TestIfuncReexports:
    def test_canonical_imports_still_work(self):
        from repro.core.ifunc import (  # noqa: F401
            PE,
            CompletionQueue,
            GatherFuture,
            IFunc,
        )

    def test_full_historical_surface(self):
        """Everything the pre-split module exported by name resolves."""
        mod = importlib.import_module("repro.core.ifunc")
        for name in (
            "ACTION_WIDTH", "A_DONE", "A_FORWARD", "A_RETURN", "A_SPAWN",
            "A_NOP", "A_PUBLISH", "CompletionQueue", "GatherFuture",
            "IFunc", "ISAMismatch", "PE", "PEStats", "ProtocolError",
            "RNDV_STAGING_DEPTH", "Toolchain",
        ):
            assert hasattr(mod, name), f"repro.core.ifunc lost {name!r}"

    def test_facade_is_thin(self):
        """The god-object stays dead: the facade module holds re-exports
        only (no class/function definitions) and stays small."""
        path = REPO / "src" / "repro" / "core" / "ifunc.py"
        text = path.read_text()
        assert len(text.splitlines()) < 200
        tree = ast.parse(text)
        defs = [
            n for n in tree.body
            if isinstance(n, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        assert not defs, f"ifunc.py regrew definitions: {[d.name for d in defs]}"

    def test_layer_modules_import_independently(self):
        for layer in LAYER_MODULES:
            importlib.import_module(f"repro.core.pe.{layer}")


class TestImportHygiene:
    def test_no_private_imports_from_layers_outside_package(self):
        """No module outside core/pe/ may import a ``_``-prefixed symbol
        from any layer module — the layers' private surface is internal."""
        offenders = []
        for path in _py_files():
            if PE_PACKAGE in path.parents:
                continue
            tree = ast.parse(path.read_text())
            for mod, name in _pe_imports(tree, in_package=False):
                if name.startswith("_"):
                    offenders.append(f"{path}: from {mod} import {name}")
        assert not offenders, "\n".join(offenders)

    def test_no_private_imports_between_layers(self):
        """Within core/pe/, layers compose through public names only: a
        layer importing another layer's ``_``-prefixed symbol couples to
        its internals and defeats the layering."""
        offenders = []
        for path in sorted(PE_PACKAGE.glob("*.py")):
            tree = ast.parse(path.read_text())
            for mod, name in _pe_imports(tree, in_package=True):
                if name.startswith("_"):
                    offenders.append(f"{path.name}: from {mod} import {name}")
        assert not offenders, "\n".join(offenders)

    def test_core_never_imports_runtime_or_launch(self):
        """``repro.core`` is the bottom of the stack: no core module may
        import from ``repro.runtime`` or ``repro.launch`` — not even a
        deferred (function-level) import, which is how the inversion last
        crept in (the failure detector reaching up for the heartbeat
        monitor).  The walk covers every statement in every core module,
        absolute and relative spellings alike."""
        offenders = []
        for path in sorted(CORE_PACKAGE.rglob("*.py")):
            # the package this file's relative imports resolve against
            pkg = ["repro", "core", *path.relative_to(CORE_PACKAGE).parts[:-1]]
            tree = ast.parse(path.read_text())
            for node in ast.walk(tree):
                if isinstance(node, ast.Import):
                    targets = [alias.name for alias in node.names]
                elif isinstance(node, ast.ImportFrom):
                    mod = node.module or ""
                    if node.level:
                        base = pkg[: len(pkg) - (node.level - 1)]
                        targets = [".".join([*base, *mod.split(".")]).rstrip(".")]
                    else:
                        targets = [mod]
                else:
                    continue
                for target in targets:
                    parts = target.split(".")
                    if parts[:1] == ["repro"] and parts[1:2] and parts[1] in (
                        "runtime", "launch"
                    ):
                        offenders.append(f"{path.relative_to(REPO)}: {target}")
        assert not offenders, (
            "repro.core must not depend on repro.runtime/repro.launch:\n"
            + "\n".join(offenders)
        )

    def test_layers_do_not_import_the_facade(self):
        """The facade composes the layers; a layer importing `.pe` back
        (outside annotations) would be a dependency cycle.  TYPE_CHECKING
        imports are fine — this walks only runtime imports."""
        for path in sorted(PE_PACKAGE.glob("*.py")):
            if path.name in ("pe.py", "__init__.py"):
                continue
            tree = ast.parse(path.read_text())
            runtime_imports = []
            for node in ast.walk(tree):
                if isinstance(node, ast.If):
                    # skip `if TYPE_CHECKING:` bodies
                    t = node.test
                    if isinstance(t, ast.Name) and t.id == "TYPE_CHECKING":
                        for sub in ast.walk(node):
                            sub._skip = True  # type: ignore[attr-defined]
            for node in ast.walk(tree):
                if getattr(node, "_skip", False):
                    continue
                if isinstance(node, ast.ImportFrom) and (node.module or "") in (
                    "pe", "repro.core.pe.pe"
                ):
                    runtime_imports.append(ast.dump(node))
            assert not runtime_imports, f"{path.name} imports the facade at runtime"
