"""Data-plane protocol selection: framed / zero-copy / rendezvous RETURNs.

Acceptance surface:
* the three protocols are oracle-identical across the ``eager_max`` /
  ``rndv_min`` thresholds, including payloads exactly AT each threshold
  (the boundary is part of the contract: ``> eager_max`` goes one-sided,
  ``>= rndv_min`` goes rendezvous);
* one-sided slab writes honor doorbell (or/add) and generation-guard
  semantics — a stale write for a retired slot is refused at the 'NIC';
* fault injection: a killed requester means the doorbell is never set and
  ``cancel()`` releases the slab slot; duplicated rendezvous descriptors
  stay idempotent;
* registered regions survive non-C-contiguous arrays (transposed views
  materialize contiguously at registration, like pinning a copy buffer);
* ``TrafficStats.wire_bytes_by_kind`` reports the framing tax directly.
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    DataPlaneConfig,
    EndpointDead,
    Fabric,
    PointerChaseApp,
    RegionWrite,
    chase_ref,
)
from repro.core.frame import pack_payloads, unpack_payloads
from repro.runtime.embed_service import EmbedShardService, ragged_batches

I32 = np.int32


def make_service(n_servers, vocab=64, dim=4, n_keys=4, max_slots=8, seed=3):
    cl = Cluster(n_servers=n_servers, wire="ideal")
    return EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=n_keys, max_slots=max_slots, seed=seed
    )


# ----------------------------------------------------- registered regions
class TestRegionContiguity:
    def test_transposed_view_registers_and_roundtrips(self):
        """A non-C-contiguous registered array (transposed view) must still
        byte-address correctly: registration materializes it contiguously."""
        fab = Fabric("ideal")
        ep = fab.connect("pe")
        base = np.arange(12, dtype=I32).reshape(3, 4)
        view = base.T  # (4, 3), not C-contiguous
        assert not view.flags.c_contiguous
        ep.register_region("t", view)
        assert ep.regions["t"].flags.c_contiguous
        # reads follow the view's logical (row-major) order, not base's
        want = np.ascontiguousarray(view).tobytes()
        assert ep.read_region("t", 0, len(want)) == want
        # writes round-trip through the same addressing
        ep.write_region("t", 4, b"\xff\xff\xff\xff")
        got = np.frombuffer(ep.read_region("t", 0, len(want)), I32)
        assert got[1] == -1
        np.testing.assert_array_equal(
            np.delete(got, 1), np.delete(np.ascontiguousarray(view).reshape(-1), 1)
        )

    def test_strided_slice_registers(self):
        fab = Fabric("ideal")
        ep = fab.connect("pe")
        arr = np.arange(20, dtype=I32)[::2]  # strided, not contiguous
        ep.register_region("s", arr)
        assert ep.read_region("s", 0, 8) == arr[:2].tobytes()


# ------------------------------------------------------- one-sided writes
class TestPutRegion:
    def setup_method(self):
        self.fab = Fabric("thor_xeon")
        self.ep = self.fab.connect("dst")
        self.ep.register_region("slab", np.zeros(8, I32))

    def test_write_plus_doorbell_or_and_add(self):
        self.fab.put_region(
            "src", "dst", "slab", 4, np.array([7], I32).tobytes(),
            doorbell=(0, 1 << 3, "or"),
        )
        self.fab.put_region(
            "src", "dst", "slab", 8, np.array([9], I32).tobytes(),
            doorbell=(0, 1 << 3, "or"),  # re-delivery: OR is idempotent
        )
        self.fab.put_region("src", "dst", "slab", 12, b"", doorbell=(28, 2, "add"))
        slab = self.ep.regions["slab"]
        assert slab[0] == 1 << 3 and slab[1] == 7 and slab[2] == 9
        assert slab[7] == 2
        assert self.fab.stats.region_puts == 3
        # data + one 4-byte doorbell word per op
        assert self.fab.stats.region_put_bytes == (4 + 4) + (4 + 4) + (0 + 4)
        assert self.fab.stats.wire_bytes_by_kind["region"] == 20

    def test_guard_refuses_stale_generation(self):
        self.ep.regions["slab"][1] = 5  # current generation
        t = self.fab.put_region(
            "src", "dst", "slab", 8, np.array([42], I32).tobytes(),
            doorbell=(0, 1, "or"), guard=(4, 4),  # expects retired gen 4
        )
        assert t > 0  # the bytes still crossed the wire
        slab = self.ep.regions["slab"]
        assert slab[2] == 0 and slab[0] == 0  # neither data nor doorbell applied
        assert self.fab.stats.region_guard_drops == 1
        # a live-generation write on the same chain applies
        self.fab.put_region(
            "src", "dst", "slab", 8, np.array([42], I32).tobytes(),
            doorbell=(0, 1, "or"), guard=(4, 5),
        )
        assert slab[2] == 42 and slab[0] == 1

    def test_batched_chain_is_one_wire_op(self):
        writes = [
            RegionWrite("slab", 4 * i, np.array([i], I32).tobytes()) for i in range(1, 4)
        ]
        self.fab.put_region_multi("src", "dst", writes)
        assert self.fab.stats.region_puts == 1
        wire = self.fab.wire
        # one alpha for the chain, o_us per extra segment
        assert self.fab.stats.modeled_us == pytest.approx(
            wire.latency_us(12) + 2 * wire.o_us
        )

    def test_dead_endpoint_raises(self):
        self.fab.kill("dst")
        with pytest.raises(EndpointDead):
            self.fab.put_region("src", "dst", "slab", 0, b"\x00" * 4)


# ------------------------------------------------- protocol boundaries
class TestProtocolBoundaries:
    """The RETURN payload here is (3 + K + K*D)*4 bytes; thresholds are
    pinned exactly at/around it to exercise both sides of each boundary."""

    RET_NBYTES = (3 + 4 + 4 * 4) * 4  # K=4, D=4 -> 92

    def _run(self, dataplane, batching=True, seed=11):
        svc = make_service(2)
        batches = ragged_batches(svc.vocab, 12, svc.n_keys, seed=seed)
        svc.gather(batches)  # warm code caches (selection needs cache-warm peers)
        rep = svc.gather(batches, batching=batching, dataplane=dataplane)
        for got, want in zip(rep.results, svc.oracle(batches)):
            np.testing.assert_array_equal(got, want)
        return svc, rep

    def test_payload_exactly_at_eager_max_stays_framed(self):
        svc, rep = self._run(DataPlaneConfig.zero_copy(eager_max=self.RET_NBYTES))
        assert sum(pe.stats.zerocopy_returns for pe in svc.cluster.pes()) == 0
        assert rep.region_puts == 0

    def test_payload_one_below_eager_max_goes_zerocopy(self):
        svc, rep = self._run(DataPlaneConfig.zero_copy(eager_max=self.RET_NBYTES - 1))
        assert sum(pe.stats.zerocopy_returns for pe in svc.cluster.pes()) > 0
        assert rep.region_puts > 0

    def test_payload_exactly_at_rndv_min_goes_rendezvous(self):
        svc, rep = self._run(DataPlaneConfig.rendezvous(rndv_min=self.RET_NBYTES))
        assert sum(pe.stats.rndv_returns for pe in svc.cluster.pes()) > 0
        assert rep.gets > 0  # descriptors were pulled against

    def test_payload_one_above_rndv_min_stays_framed(self):
        svc, rep = self._run(DataPlaneConfig.rendezvous(rndv_min=self.RET_NBYTES + 1))
        assert sum(pe.stats.rndv_returns for pe in svc.cluster.pes()) == 0
        assert rep.gets == 0

    @pytest.mark.parametrize("batching", [False, True])
    @pytest.mark.parametrize(
        "dataplane",
        [
            DataPlaneConfig.framed(),
            DataPlaneConfig.zero_copy(eager_max=0),
            DataPlaneConfig.rendezvous(rndv_min=0),
        ],
        ids=["framed", "zerocopy", "rendezvous"],
    )
    @pytest.mark.parametrize("seed", [7, 23])
    def test_oracle_identical_across_protocols(self, dataplane, batching, seed):
        self._run(dataplane, batching=batching, seed=seed)

    def test_first_contact_never_selects_rendezvous(self):
        """A rendezvous descriptor cannot carry code: against a cold peer
        the RETURN must go framed (code travels and installs), and only
        later RETURNs ride the descriptor."""
        svc = make_service(1)
        cl = svc.cluster
        cl.set_dataplane(DataPlaneConfig.rendezvous(rndv_min=0))
        try:
            fut = cl.client.submit(
                "server0", "gatherer", svc._pad(np.array([3], I32)), svc.cq, expected=1
            )
            cl.run_until(fut.done)
            np.testing.assert_array_equal(fut.result()[0], svc.table[3])
        finally:
            cl.set_dataplane(None)
        srv = cl.servers[0]
        assert srv.stats.returns == 1 and srv.stats.rndv_returns == 0

    def test_chase_protocols_match_oracle(self):
        cl = Cluster(n_servers=2, wire="ideal")
        app = PointerChaseApp(cl, n_entries=128, max_slots=8, seed=5)
        starts = np.arange(6, dtype=I32) * 17 % 128
        want = np.array([chase_ref(app.table, s, 19) for s in starts], I32)
        app.dapc(starts, 19)  # warm
        for dp in (
            None,
            DataPlaneConfig.zero_copy(eager_max=0),
            DataPlaneConfig.rendezvous(rndv_min=0),
        ):
            rep = app.dapc(starts, 19, batching=True, dataplane=dp)
            np.testing.assert_array_equal(rep.results, want)


# --------------------------------------------------------- fault injection
class TestDataPlaneFaults:
    def test_killed_requester_doorbell_never_set_cancel_releases_slot(self):
        """Kill the requester mid-gather under zero-copy: the server's slab
        write fails loudly (contained in the batched poll), no doorbell is
        ever set, and cancel() releases the slab slot for reuse."""
        svc = make_service(1, max_slots=2)
        cl = svc.cluster
        svc.gather([np.array([1], I32)])  # warm code caches
        cl.set_dataplane(DataPlaneConfig.zero_copy(eager_max=0))
        try:
            fut = cl.client.submit(
                "server0", "gatherer", svc._pad(np.array([5], I32)), svc.cq, expected=1
            )
            cl.fabric.kill("client")
            srv = cl.servers[0]
            srv.batching = True
            with pytest.raises(EndpointDead):
                srv.poll()  # gatherer runs; the one-sided RETURN hits a corpse
            assert svc.cq._count(fut.slot) == 0  # doorbell never set
            assert not fut.done()
            fut.cancel()
            assert svc.cq.free_slots == 2
        finally:
            cl.set_dataplane(None)

    def test_stale_zerocopy_write_refused_by_guard(self):
        """A zero-copy RETURN for a retired generation must not corrupt the
        slot's next owner: the guard drops it at the fabric."""
        svc = make_service(1, max_slots=1)
        cl = svc.cluster
        cl.set_dataplane(DataPlaneConfig.zero_copy(eager_max=0))
        try:
            fut_a = cl.client.submit(
                "server0", "gatherer", svc._pad(np.array([3], I32)), svc.cq, expected=1
            )
            old_epoch = int(svc.cq.pe.region(svc.cq.region)[fut_a.slot, 1])
            cl.run_until(fut_a.done)
            np.testing.assert_array_equal(fut_a.result()[0], svc.table[3])
            # slot recycles to request B (epoch bumps)
            fut_b = cl.client.submit(
                "server0", "gatherer", svc._pad(np.array([40], I32)), svc.cq, expected=1
            )
            # replay A's RETURN as a raw stale slab write (old generation)
            gr = cl.toolchain.lookup("gather_return")
            K, D = svc.n_keys, svc.dim
            pay = np.zeros(3 + K + K * D, I32)
            pay[0], pay[1], pay[2] = fut_a.slot, old_epoch, 1
            pay[3:3 + K] = [0, -1, -1, -1]
            drops0 = cl.fabric.stats.region_guard_drops
            cl.fabric.put_region_multi("server0", "client", gr.slab.plan(pay))
            assert cl.fabric.stats.region_guard_drops > drops0
            assert not fut_b.done()  # stale write neither scattered nor completed B
            cl.run_until(fut_b.done)
            np.testing.assert_array_equal(fut_b.result()[0], svc.table[40])
        finally:
            cl.set_dataplane(None)

    def test_evicted_rndv_staging_is_loud_but_contained(self):
        """A descriptor whose staging region is gone (ring eviction / source
        restart) must raise ProtocolError without taking healthy frames in
        the same batched poll down with it."""
        from repro.core import ProtocolError
        from repro.core.frame import rndv_region

        svc = make_service(1, max_slots=2)
        cl = svc.cluster
        svc.gather([np.array([1], I32)])  # warm
        cl.set_dataplane(DataPlaneConfig.rendezvous(rndv_min=0))
        try:
            fut_a = cl.client.submit(
                "server0", "gatherer", svc._pad(np.array([3], I32)), svc.cq, expected=1
            )
            fut_b = cl.client.submit(
                "server0", "gatherer", svc._pad(np.array([5], I32)), svc.cq, expected=1
            )
            cl.servers[0].poll()  # two descriptors now parked at the client
            # evict A's staging region (token 0) before the client pulls
            cl.servers[0].endpoint.unregister_region(rndv_region("server0", 0))
            cl.client.batching = True
            with pytest.raises(ProtocolError, match="staging region"):
                cl.client.poll()
            assert fut_b.done()  # the healthy descriptor still retired
            np.testing.assert_array_equal(fut_b.result()[0], svc.table[5])
            assert not fut_a.done()
            fut_a.cancel()
        finally:
            cl.client.batching = False
            cl.set_dataplane(None)

    def test_duplicated_rndv_descriptor_is_idempotent(self):
        """The wire re-delivering a rendezvous descriptor re-pulls the same
        staged payload — the position-bitmask fold stays exactly idempotent."""
        svc = make_service(2)
        cl = svc.cluster
        keys = np.array([3, 40], I32)  # spans both shards
        svc.gather([keys])  # warm
        cl.set_dataplane(DataPlaneConfig.rendezvous(rndv_min=0))
        try:
            fut = cl.client.submit(
                "server0", "gatherer", svc._pad(keys), svc.cq, expected=len(keys)
            )
            for _ in range(4):
                for pe in cl.pes():
                    pe.poll()
                inbox = cl.client.endpoint.inbox
                for buf in list(inbox):
                    inbox.append(bytearray(buf))
            cl.run_until(fut.done)
            np.testing.assert_array_equal(fut.result()[: len(keys)], svc.table[keys])
        finally:
            cl.set_dataplane(None)


# --------------------------------------------------------- byte accounting
class TestWireBytesByKind:
    def test_framing_tax_reported_directly(self):
        """header + payload + code + region must tile the wire exactly, and
        the zero-copy plane must move the row bytes from ``payload`` (inside
        frames) to ``region`` (one-sided)."""
        svc = make_service(4)
        batches = ragged_batches(svc.vocab, 16, svc.n_keys, seed=2)
        svc.gather(batches)  # warm
        framed = svc.gather(batches, batching=True)
        k = framed.wire_bytes_by_kind
        assert k["region"] == 0 and k["code"] == 0  # steady state, all framed
        assert k["header"] + k["payload"] == framed.wire_bytes
        zc = svc.gather(
            batches, batching=True, dataplane=DataPlaneConfig.zero_copy(eager_max=0)
        )
        kz = zc.wire_bytes_by_kind
        assert kz["region"] == zc.region_put_bytes > 0
        assert sum(kz.values()) == zc.wire_bytes
        assert kz["payload"] < k["payload"]  # the framing tax left the frames

    def test_code_bytes_attributed_on_first_contact(self):
        svc = make_service(1)
        rep = svc.gather([np.array([1], I32)])  # cold: code travels
        assert rep.wire_bytes_by_kind["code"] > 0

    def test_get_baseline_is_pure_region_bytes(self):
        svc = make_service(2)
        rep = svc.gather_get([np.array([1, 40], I32)])
        assert rep.wire_bytes_by_kind["region"] == rep.get_bytes == rep.wire_bytes


# ------------------------------------------------------- varint batch wire
class TestVarintBatchFormat:
    def test_uniform_subheader_is_smaller_than_fixed(self):
        """The varint sub-header undercuts the 8-byte fixed (count, item)
        pair it replaced for every realistic burst."""
        payloads = [bytes([i]) * 44 for i in range(16)]
        section = pack_payloads(payloads)
        overhead = len(section) - sum(len(p) for p in payloads)
        assert overhead < 8
        assert unpack_payloads(section) == payloads

    def test_ragged_offset_table_roundtrips(self):
        payloads = [b"", b"a", b"bc" * 100, bytes(300)]
        assert unpack_payloads(pack_payloads(payloads)) == payloads

    def test_large_uniform_roundtrips(self):
        payloads = [bytes(556)] * 300  # multi-byte varints on both fields
        section = pack_payloads(payloads)
        assert unpack_payloads(section) == payloads
