"""Frame layout + truncation protocol unit tests (paper Figs. 2/3, Sec. III-D)."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.core.frame import (
    MAGIC,
    MAGIC_LEN,
    Frame,
    FrameKind,
    delivery_complete,
    peek_header,
    unpack,
)


def mk_frame(payload=b"\x01\x02", code=b"C" * 100, deps=("abi:pure", "region:x")):
    return Frame(
        kind=FrameKind.BITCODE,
        name="foo",
        payload=payload,
        code=code,
        deps=deps,
        digest=b"\xaa" * 32,
        seq=7,
    )


class TestPackUnpack:
    def test_roundtrip_full(self):
        f = mk_frame()
        g = unpack(f.pack(), has_code=True)
        assert (g.name, g.payload, g.code, g.deps) == (f.name, f.payload, f.code, f.deps)
        assert g.digest == f.digest and g.seq == f.seq and g.kind == f.kind

    def test_roundtrip_truncated(self):
        f = mk_frame()
        wire = f.wire_bytes(cached=True)
        assert len(wire) == f.cached_nbytes
        g = unpack(wire, has_code=False)
        assert g.payload == f.payload and g.code == b""

    def test_truncation_is_prefix(self):
        """The cached send is a shorter PUT of the SAME buffer (Sec. III-D:
        'the ifunc message is never modified')."""
        f = mk_frame()
        assert f.pack()[: f.cached_nbytes] == f.wire_bytes(cached=True)

    def test_sentinels_present(self):
        f = mk_frame()
        buf = f.pack()
        assert buf[f.cached_nbytes - MAGIC_LEN : f.cached_nbytes] == MAGIC
        assert buf[-MAGIC_LEN:] == MAGIC

    def test_code_bytes_dominate_uncached(self):
        f = mk_frame(code=b"C" * 5159)
        assert f.full_nbytes - f.cached_nbytes == 5159 + len("abi:pure\nregion:x") + MAGIC_LEN


class TestDelivery:
    def test_partial_header_incomplete(self):
        f = mk_frame()
        assert peek_header(f.pack()[:10]) is None
        assert not delivery_complete(f.pack()[:10], expect_code=True)

    def test_partial_payload_incomplete(self):
        f = mk_frame(payload=b"\x00" * 64)
        buf = f.pack()
        assert not delivery_complete(buf[: f.cached_nbytes - 1], expect_code=False)
        assert delivery_complete(buf[: f.cached_nbytes], expect_code=False)

    def test_full_delivery_detection(self):
        f = mk_frame()
        buf = f.pack()
        assert not delivery_complete(buf[:-1], expect_code=True)
        assert delivery_complete(buf, expect_code=True)

    def test_corrupt_magic_raises(self):
        f = mk_frame()
        buf = bytearray(f.pack())
        buf[0] ^= 0xFF
        with pytest.raises(ValueError, match="header magic"):
            peek_header(buf)


@settings(max_examples=50, deadline=None)
@given(
    payload=st.binary(max_size=512),
    code=st.binary(max_size=2048),
    deps=st.lists(st.sampled_from(["abi:xrdma", "region:t", "cap:m", "returns:r"]), max_size=4),
    seq=st.integers(min_value=0, max_value=2**63 - 1),
)
def test_frame_roundtrip_property(payload, code, deps, seq):
    f = Frame(
        kind=FrameKind.BITCODE,
        name="prop",
        payload=payload,
        code=code,
        deps=tuple(dict.fromkeys(deps)),
        digest=np.random.default_rng(0).bytes(32),
        seq=seq,
    )
    g = unpack(f.pack(), has_code=True)
    assert g.payload == payload and g.code == code and g.seq == seq
    assert g.deps == tuple(dict.fromkeys(deps))
    # truncated view always parses as payload-only
    h = unpack(f.wire_bytes(cached=True), has_code=False)
    assert h.payload == payload
