"""Frame layout + truncation protocol unit tests (paper Figs. 2/3, Sec. III-D)."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.core.frame import (
    HOP_FIXED_NBYTES,
    MAGIC,
    MAGIC_LEN,
    RNDV_DESC_NBYTES,
    CorruptFrame,
    Frame,
    FrameKind,
    HopHeader,
    ProtocolError,
    coalesce,
    delivery_complete,
    pack_hop,
    pack_rndv,
    peek_header,
    split_hop,
    split_payloads,
    unpack,
    unpack_hop,
    unpack_rndv,
)


def mk_frame(payload=b"\x01\x02", code=b"C" * 100, deps=("abi:pure", "region:x")):
    return Frame(
        kind=FrameKind.BITCODE,
        name="foo",
        payload=payload,
        code=code,
        deps=deps,
        digest=b"\xaa" * 32,
        seq=7,
    )


class TestPackUnpack:
    def test_roundtrip_full(self):
        f = mk_frame()
        g = unpack(f.pack(), has_code=True)
        assert (g.name, g.payload, g.code, g.deps) == (f.name, f.payload, f.code, f.deps)
        assert g.digest == f.digest and g.seq == f.seq and g.kind == f.kind

    def test_roundtrip_truncated(self):
        f = mk_frame()
        wire = f.wire_bytes(cached=True)
        assert len(wire) == f.cached_nbytes
        g = unpack(wire, has_code=False)
        assert g.payload == f.payload and g.code == b""

    def test_truncation_is_prefix(self):
        """The cached send is a shorter PUT of the SAME buffer (Sec. III-D:
        'the ifunc message is never modified')."""
        f = mk_frame()
        assert f.pack()[: f.cached_nbytes] == f.wire_bytes(cached=True)

    def test_sentinels_present(self):
        f = mk_frame()
        buf = f.pack()
        assert buf[f.cached_nbytes - MAGIC_LEN : f.cached_nbytes] == MAGIC
        assert buf[-MAGIC_LEN:] == MAGIC

    def test_code_bytes_dominate_uncached(self):
        f = mk_frame(code=b"C" * 5159)
        assert f.full_nbytes - f.cached_nbytes == 5159 + len("abi:pure\nregion:x") + MAGIC_LEN


class TestDelivery:
    def test_partial_header_incomplete(self):
        f = mk_frame()
        assert peek_header(f.pack()[:10]) is None
        assert not delivery_complete(f.pack()[:10], expect_code=True)

    def test_partial_payload_incomplete(self):
        f = mk_frame(payload=b"\x00" * 64)
        buf = f.pack()
        assert not delivery_complete(buf[: f.cached_nbytes - 1], expect_code=False)
        assert delivery_complete(buf[: f.cached_nbytes], expect_code=False)

    def test_full_delivery_detection(self):
        f = mk_frame()
        buf = f.pack()
        assert not delivery_complete(buf[:-1], expect_code=True)
        assert delivery_complete(buf, expect_code=True)

    def test_corrupt_magic_raises(self):
        f = mk_frame()
        buf = bytearray(f.pack())
        buf[0] ^= 0xFF
        with pytest.raises(ValueError, match="header magic"):
            peek_header(buf)


@settings(max_examples=50, deadline=None)
@given(
    payload=st.binary(max_size=512),
    code=st.binary(max_size=2048),
    deps=st.lists(st.sampled_from(["abi:xrdma", "region:t", "cap:m", "returns:r"]), max_size=4),
    # seq and ack share the header's u64 word (low/high 32 bits)
    seq=st.integers(min_value=0, max_value=2**32 - 1),
    ack=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_frame_roundtrip_property(payload, code, deps, seq, ack):
    f = Frame(
        kind=FrameKind.BITCODE,
        name="prop",
        payload=payload,
        code=code,
        deps=tuple(dict.fromkeys(deps)),
        digest=np.random.default_rng(0).bytes(32),
        seq=seq,
        ack=ack,
    )
    g = unpack(f.pack(), has_code=True)
    assert g.payload == payload and g.code == code and g.seq == seq
    assert g.ack == ack
    assert peek_header(f.pack()).ack == ack
    assert g.deps == tuple(dict.fromkeys(deps))
    # truncated view always parses as payload-only
    h = unpack(f.wire_bytes(cached=True), has_code=False)
    assert h.payload == payload


@settings(max_examples=50, deadline=None)
@given(
    item=st.binary(min_size=1, max_size=64),
    count=st.integers(min_value=1, max_value=12),
    code=st.binary(min_size=1, max_size=1024),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_batch_frame_roundtrip_property(item, count, code, seed):
    """Multi-payload BATCH frames: N same-size payloads coalesce behind one
    header/code section and split back bit-identically — from the full wire
    AND from the cached-send truncation prefix of the same buffer."""
    rng = np.random.default_rng(seed)
    payloads = [bytes(rng.bytes(len(item))) for _ in range(count)]
    frames = [
        Frame(
            kind=FrameKind.BITCODE,
            name="prop_batch",
            payload=p,
            code=code,
            deps=("abi:pure",),
            digest=b"\xcc" * 32,
            seq=i,
        )
        for i, p in enumerate(payloads)
    ]
    batch = coalesce(frames)
    assert batch.n_payloads == count or count == 1
    full = batch.pack()
    g = unpack(full, has_code=True)
    assert split_payloads(g) == payloads
    assert g.code == code
    # the truncation protocol survives coalescing: cached send is a prefix
    cached = batch.wire_bytes(cached=True)
    assert full[: batch.cached_nbytes] == cached
    h = unpack(cached, has_code=False)
    assert split_payloads(h) == payloads and h.code == b""


@settings(max_examples=100, deadline=None)
@given(junk=st.binary(min_size=0, max_size=256))
def test_garbage_bytes_rejected_property(junk):
    """Arbitrary bytes never parse as a frame: either 'incomplete' (None)
    or a loud ProtocolError — never a silent wrong parse.  (A random 4-byte
    magic collision has probability 2^-32 per example; the pinned-seed
    fallback generator never produces one.)"""
    if junk[:4] == b"3CHN":  # astronomically unlikely; not the property
        return
    try:
        got = peek_header(junk)
    except ProtocolError:
        return
    assert got is None  # too short to judge: keep polling, don't guess


@settings(max_examples=50, deadline=None)
@given(
    flip_at=st.integers(min_value=0, max_value=2**31 - 1),
    payload=st.binary(min_size=1, max_size=64),
)
def test_flipped_byte_never_wrong_parse_property(flip_at, payload):
    """Corrupting one byte of a real frame yields either a loud rejection
    (ProtocolError / incomplete) or a parse whose damage is CONFINED: a
    flip inside an opaque body section (name/payload/code/deps) that still
    parses must leave every OTHER section byte-identical, and a flip in
    either MAGIC sentinel must always be rejected — corruption can never
    silently smear across section boundaries."""
    from repro.core.frame import _HDR_LEN  # section offsets for the original

    f = Frame(
        kind=FrameKind.BITCODE,
        name="flip",
        payload=payload,
        code=b"C" * 32,
        deps=("abi:pure",),
        digest=b"\xee" * 32,
    )
    buf = bytearray(f.pack())
    off = flip_at % len(buf)
    buf[off] ^= 0xFF
    name_b = f.name.encode()
    deps_b = "\n".join(f.deps).encode()
    bounds = {}  # section -> (start, end) in the packed buffer
    cur = _HDR_LEN
    for sec, n in (
        ("name", len(name_b)), ("payload", len(payload)), ("magic1", MAGIC_LEN),
        ("code", len(f.code)), ("deps", len(deps_b)), ("magic2", MAGIC_LEN),
    ):
        bounds[sec] = (cur, cur + n)
        cur += n
    flipped = next(
        (s for s, (a, b) in bounds.items() if a <= off < b), "header"
    )
    try:
        hdr = peek_header(buf)
        if hdr is None:
            return
        g = unpack(buf, has_code=hdr.code_len > 0)
    except (ProtocolError, ValueError):
        return  # loud rejection is always acceptable
    # a smashed delivery sentinel must never parse cleanly
    assert flipped not in ("magic1", "magic2"), "corrupt sentinel parsed"
    if flipped == "header":
        return  # header flips may legally re-frame; opacity below is the claim
    # body flip that parsed: damage confined to its own section
    sections = {"name": g.name.encode(), "payload": g.payload, "code": g.code,
                "deps": "\n".join(g.deps).encode()}
    originals = {"name": name_b, "payload": payload, "code": f.code,
                 "deps": deps_b}
    for sec, got in sections.items():
        if sec != flipped:
            assert got == originals[sec], f"flip in {flipped} leaked into {sec}"
    assert g.digest == f.digest and g.seq == f.seq and g.kind == f.kind


# ------------------------------------------------ propagation hop header
@settings(max_examples=60, deadline=None)
@given(
    ttl=st.integers(min_value=0, max_value=255),
    k=st.integers(min_value=0, max_value=255),
    root=st.integers(min_value=0, max_value=2**16 - 1),
    pub_id=st.integers(min_value=0, max_value=2**32 - 1),
    path=st.lists(st.integers(min_value=0, max_value=2**16 - 1), max_size=12),
    tail=st.binary(max_size=64),
)
def test_hop_header_roundtrip_property(ttl, k, root, pub_id, path, tail):
    """Hop headers roundtrip bit-exactly for arbitrary field values, and
    split_hop returns the untouched inner payload behind them."""
    hop = HopHeader(ttl=ttl, root=root, pub_id=pub_id, path=tuple(path), k=k)
    buf = pack_hop(hop)
    assert len(buf) == hop.nbytes == HOP_FIXED_NBYTES + 2 * len(path)
    got, off = unpack_hop(buf)
    assert got == hop and off == len(buf)
    hop2, inner = split_hop(buf + tail)
    assert hop2 == hop and inner == tail


@settings(max_examples=60, deadline=None)
@given(
    path=st.lists(st.integers(min_value=0, max_value=2**16 - 1), max_size=8),
    cut=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hop_header_truncation_rejected_property(path, cut):
    """EVERY proper prefix of a packed hop header is refused loudly — a
    partial delivery can never parse as a shorter valid hop."""
    hop = HopHeader(ttl=3, root=1, pub_id=9, path=tuple(path), k=2)
    buf = pack_hop(hop)
    prefix = buf[: cut % len(buf)]  # strictly shorter than the full header
    with pytest.raises(CorruptFrame):
        unpack_hop(prefix)


@settings(max_examples=100, deadline=None)
@given(junk=st.binary(max_size=128))
def test_hop_header_garbage_rejected_property(junk):
    """Arbitrary bytes either fail to parse (CorruptFrame) or — with the
    ~2^-64 chance of a path-digest collision — parse into a header whose
    re-packed form is byte-identical, i.e. never a silent wrong parse."""
    try:
        hop, off = unpack_hop(junk)
    except CorruptFrame:
        return
    assert pack_hop(hop) == junk[:off]


@settings(max_examples=50, deadline=None)
@given(
    flip_at=st.integers(min_value=0, max_value=2**31 - 1),
    path=st.lists(st.integers(min_value=0, max_value=2**16 - 1), min_size=1, max_size=8),
)
def test_hop_digest_guards_tamper_property(flip_at, path):
    """Flipping any byte of the digest-covered tail (k/root/pub_id live in
    the digest input, the path bytes entirely) is caught by the FNV check;
    a ttl flip alone may legally parse (ttl is per-hop mutable state), but
    then every digest-covered field must be intact."""
    hop = HopHeader(ttl=7, root=2, pub_id=5, path=tuple(path), k=0)
    buf = bytearray(pack_hop(hop))
    off = flip_at % len(buf)
    buf[off] ^= 0xFF
    try:
        got, _ = unpack_hop(bytes(buf))
    except CorruptFrame:
        return
    assert (got.k, got.root, got.pub_id, got.path) == (
        hop.k, hop.root, hop.pub_id, hop.path,
    )


# -------------------------------------------- rendezvous descriptor (PR 3)
@settings(max_examples=60, deadline=None)
@given(
    src=st.integers(min_value=0, max_value=2**32 - 1),
    token=st.integers(min_value=0, max_value=2**32 - 1),
    nbytes=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_rndv_descriptor_roundtrip_property(src, token, nbytes):
    desc = pack_rndv(src, token, nbytes)
    assert len(desc) == RNDV_DESC_NBYTES
    assert unpack_rndv(desc) == (src, token, nbytes)


@settings(max_examples=60, deadline=None)
@given(cut=st.integers(min_value=0, max_value=2**31 - 1))
def test_rndv_descriptor_truncation_rejected_property(cut):
    """Every proper prefix (and any over-long buffer) of a descriptor is
    refused: the descriptor is fixed-size, there is no shorter valid form."""
    desc = pack_rndv(3, 12345, 4096)
    bad = desc[: cut % RNDV_DESC_NBYTES]
    with pytest.raises(CorruptFrame):
        unpack_rndv(bad)
    with pytest.raises(CorruptFrame):
        unpack_rndv(desc + b"\x00")


@settings(max_examples=80, deadline=None)
@given(junk=st.binary(max_size=40))
def test_rndv_descriptor_garbage_rejected_property(junk):
    """Arbitrary bytes never misparse: wrong length or a set reserved word
    raises; a 16-byte buffer with a clear reserved word IS a descriptor by
    construction, and must roundtrip exactly."""
    try:
        src, token, nbytes = unpack_rndv(junk)
    except CorruptFrame:
        return
    assert pack_rndv(src, token, nbytes) == junk


def test_corrupt_frame_is_protocol_error_and_value_error():
    """CorruptFrame sits in both hierarchies: new callers catch
    ProtocolError, pre-existing callers catching ValueError still work."""
    assert issubclass(CorruptFrame, ProtocolError)
    assert issubclass(CorruptFrame, ValueError)
    with pytest.raises(ProtocolError):
        peek_header(b"XXXX" + b"\x00" * 60)


def test_batch_size_mismatch_rejected():
    """A BATCH frame whose payload section disagrees with its sub-header
    is rejected, not mis-split."""
    frames = [mk_frame(payload=b"\x01" * 8), mk_frame(payload=b"\x02" * 8)]
    batch = coalesce(frames)
    bad = Frame(
        kind=batch.kind,
        name=batch.name,
        payload=batch.payload[:-3],  # truncated payload section
        code=batch.code,
        deps=batch.deps,
        digest=batch.digest,
        flags=batch.flags,
    )
    with pytest.raises(ProtocolError, match="batch"):
        split_payloads(bad)
