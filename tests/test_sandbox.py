"""Safe code injection: install-time verifier + runtime sandbox contracts.

Hostile code is the threat model the paper's headline capability creates:
remotely injected ifuncs that recursively propagate themselves cannot be
extended on trust in a shared fabric.  Every scenario here must end the
same way — a loud SandboxViolation, a per-reason ``PEStats.refusals``
bump, and the offending digest quarantined cluster-wide (uninstalled,
sender caches forgotten, queued frames dropped, in-flight CQ futures
degraded) — with **zero effect on benign traffic** sharing the fabric.

The disabled path is equally load-bearing: with the default config no
verification runs at all (``verifier.verifies == 0`` everywhere), which
is what keeps the seven committed benchmark baselines reproducible.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    A_PUBLISH,
    A_RETURN,
    ACTION_WIDTH,
    Cluster,
    CompletionQueue,
    IFunc,
    PEStats,
    SandboxConfig,
    SandboxViolation,
    make_gossiper,
    make_tsi,
)
from repro.core.verify import count_ops

I32 = np.int32
TARGETS = ("cpu-host", "cpu-bf2")  # two triples keep toolchain builds cheap


# ------------------------------------------------------------- hostile code
@pytest.fixture(scope="module")
def tsi():
    return make_tsi()


@pytest.fixture(scope="module")
def gossiper():
    return make_gossiper()


@pytest.fixture(scope="module")
def rndv_thief():
    """Declares a transport rendezvous staging region as its linked dep —
    the one region class no shipped code may ever touch."""

    def entry(payload: jax.Array, region: jax.Array) -> jax.Array:
        return region + payload

    return IFunc.build(
        name="rndv_thief",
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((1,), I32),
        dep_avals=(jax.ShapeDtypeStruct((1,), I32),),
        deps=("region:rndv/client/0",),
        abi="update",
        targets=TARGETS,
    )


@pytest.fixture(scope="module")
def action_bomb():
    """Emits an A_RETURN row without declaring a ``returns:`` dep — an
    action its capability stamp can never contain."""

    def entry(payload: jax.Array) -> jax.Array:
        row = jnp.zeros(ACTION_WIDTH, I32)
        return row.at[0].set(A_RETURN).at[2].set(1).at[3].set(payload[0])

    return IFunc.build(
        name="action_bomb",
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((1,), I32),
        abi="xrdma",
        targets=TARGETS,
    )


@pytest.fixture(scope="module")
def reminter():
    """A rogue gossiper: structurally the ring gossiper, but each arrival
    re-publishes itself granting ttl **9** — re-minting a deeper publish
    budget than any sandbox ceiling in these tests admits."""

    def entry(
        payload: jax.Array, log: jax.Array, meta: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        me, n = meta[0], meta[1]
        nxt = jnp.where(me + 1 >= n, 0, me + 1)
        row = jnp.zeros(ACTION_WIDTH, I32)
        row = row.at[0].set(A_PUBLISH).at[1].set(nxt).at[2].set(3)
        row = row.at[3].set(9).at[5].set(payload[1])  # p0 = granted ttl 9
        return log + 1, row

    return IFunc.build(
        name="reminter",
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((2,), I32),
            jax.ShapeDtypeStruct((2,), I32),
        ),
        deps=("region:gossip_log", "cap:gossip_meta"),
        abi="propagate",
        targets=TARGETS,
    )


def counter_cluster(tsi, n_servers=2, sandbox=None):
    cl = Cluster(n_servers=n_servers)
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, I32))
    cl.toolchain.publish(tsi)
    if sandbox is not None:
        cl.set_sandbox(sandbox)
    return cl


def counters(cl):
    return [int(pe.region("counter")[0]) for pe in cl.servers]


def gossip_cluster(ifunc, n_servers=2, sandbox=None):
    cl = Cluster(n_servers=n_servers)
    n = n_servers + 1
    for i, pe in enumerate(cl.pes()):
        pe.register_region("gossip_log", np.zeros(2, I32))
        pe.register_cap("gossip_meta", np.array([i, n], I32))
    cl.toolchain.publish(ifunc)
    if sandbox is not None:
        cl.set_sandbox(sandbox)
    return cl


# ========================================================== install verifier
class TestInstallVerifier:
    def test_rndv_region_always_refused(self, rndv_thief):
        """Transport staging regions are categorically out of bounds, even
        under the most permissive enabled config: refused at install,
        quarantined, never resolvable."""
        cl = Cluster(1)
        cl.toolchain.publish(rndv_thief)
        cl.set_sandbox(SandboxConfig.on())
        cl.client.send_ifunc("server0", "rndv_thief", np.array([1], I32))
        with pytest.raises(SandboxViolation, match="rndv"):
            cl.servers[0].poll()
        srv = cl.servers[0]
        assert srv.stats.refusals["verify_region"] == 1
        assert not srv.target_cache.has_name("rndv_thief")
        assert rndv_thief.digest.hex() in srv.verifier.quarantined

    def test_region_whitelist_enforced(self, tsi):
        """A non-empty ``allowed_regions`` is a hard whitelist: tsi's
        ``region:counter`` passes only when listed."""
        ok = counter_cluster(
            tsi, sandbox=SandboxConfig.on(allowed_regions=("counter",))
        )
        ok.client.send_ifunc("server0", "tsi", np.array([5], I32))
        ok.drain()
        assert counters(ok) == [5, 0]

        bad = counter_cluster(
            tsi, sandbox=SandboxConfig.on(allowed_regions=("other",))
        )
        bad.client.send_ifunc("server0", "tsi", np.array([5], I32))
        with pytest.raises(SandboxViolation, match="counter"):
            bad.servers[0].poll()
        assert bad.servers[0].stats.refusals["verify_region"] == 1
        assert counters(bad) == [0, 0]

    def test_op_budget_refused_before_compile(self, tsi):
        """A slice over the instruction budget is refused at install —
        before XLA compiles anything (the compile is itself a resource)."""
        cl = counter_cluster(tsi, sandbox=SandboxConfig.on(max_ops=1))
        srv = cl.servers[0]
        jit0 = srv.stats.jit_ms_total
        cl.client.send_ifunc("server0", "tsi", np.array([5], I32))
        with pytest.raises(SandboxViolation, match="ops"):
            srv.poll()
        assert srv.stats.refusals["verify_ops"] == 1
        assert srv.stats.jit_ms_total == jit0  # refusal cost no compile
        assert not srv.target_cache.has_name("tsi")

    def test_cold_verify_once_then_stamp_hits(self, tsi):
        """One cold verification per (PE, digest); every later resolve of
        the same digest — including warm digest-only frames — is a stamp
        dict hit.  This is the ~0 warm-publish overhead the benchmark pins."""
        cl = counter_cluster(tsi, sandbox=SandboxConfig.on())
        for _ in range(4):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
            cl.drain()
        ver = cl.servers[0].verifier
        assert ver.verifies == 1
        assert ver.stamp_hits >= 3
        assert counters(cl) == [4, 0]

    def test_warm_tree_publish_is_all_stamp_hits(self, tsi):
        """Second tree publish of an already-stamped digest verifies
        nothing anywhere: the whole warm tree rides the stamp cache."""
        cl = counter_cluster(tsi, n_servers=4, sandbox=SandboxConfig.on())
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        cl.drain()
        cold = {pe.name: pe.verifier.verifies for pe in cl.servers}
        assert all(v == 1 for v in cold.values())
        cl.client.publish_ifunc("tsi", np.array([2], I32))
        cl.drain()
        assert all(pe.verifier.verifies == 1 for pe in cl.servers)
        assert all(pe.verifier.stamp_hits >= 1 for pe in cl.servers)
        assert counters(cl) == [7, 7, 7, 7]

    def test_disabled_path_runs_zero_verification(self, tsi):
        """Default config: no hook fires, no stamp is minted, no refusal
        is counted — the pre-sandbox runtime, bit-for-bit."""
        cl = counter_cluster(tsi, n_servers=4)  # sandbox left at default
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        cl.drain()
        for pe in cl.pes():
            assert not pe.sandbox.enabled
            assert pe.verifier.verifies == 0
            assert pe.verifier.stamp_hits == 0
            assert pe.verifier.stamps == {}
        assert cl.refusals() == {}
        assert counters(cl) == [5, 5, 5, 5]

    def test_count_ops_is_deterministic(self, tsi):
        blob = tsi.fat.extract("cpu-bf2").blob
        exported = jax.export.deserialize(blob)
        assert count_ops(exported) == count_ops(exported) > 0
        assert count_ops(None) == 0


# ============================================================ runtime quotas
class TestRuntimeQuotas:
    def test_action_outside_stamp_refused(self, action_bomb):
        """A_RETURN without a ``returns:`` dep: the capability stamp never
        grants it, so the first emitted row is refused and the digest
        quarantined — before the runtime dereferences the missing dep."""
        cl = Cluster(1)
        cl.toolchain.publish(action_bomb)
        cl.set_sandbox(SandboxConfig.on())
        cl.client.send_ifunc("server0", "action_bomb", np.array([3], I32))
        with pytest.raises(SandboxViolation, match="A_RETURN"):
            cl.servers[0].poll()
        srv = cl.servers[0]
        assert srv.stats.refusals["verify_action"] == 1
        assert action_bomb.digest.hex() in srv.verifier.quarantined

    def test_invoke_budget_burn_stops_at_quota(self, tsi):
        """max_invokes=3: the fourth invoke is refused *before* dispatch —
        the counter proves exactly three executions happened."""
        cl = counter_cluster(tsi, sandbox=SandboxConfig.on(max_invokes=3))
        for _ in range(3):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
            cl.drain()
        assert counters(cl) == [3, 0]
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        with pytest.raises(SandboxViolation, match="quota"):
            cl.servers[0].poll()
        assert counters(cl) == [3, 0]  # refused invoke never ran
        assert cl.servers[0].stats.refusals["quota_invokes"] == 1

    def test_per_invoke_payload_cap(self, tsi):
        """A single payload over the per-invoke byte cap is refused on its
        first arrival (tsi's payload is 4 bytes; cap it at 2)."""
        cl = counter_cluster(
            tsi, sandbox=SandboxConfig.on(max_invoke_payload_bytes=2)
        )
        cl.client.send_ifunc("server0", "tsi", np.array([5], I32))
        with pytest.raises(SandboxViolation, match="payload"):
            cl.servers[0].poll()
        assert counters(cl) == [0, 0]
        assert cl.servers[0].stats.refusals["quota_payload"] == 1

    def test_cumulative_payload_quota(self, tsi):
        """4-byte payloads against a 10-byte cumulative quota: two invokes
        fit (8B), the third (12B) is refused."""
        cl = counter_cluster(
            tsi, sandbox=SandboxConfig.on(max_payload_bytes=10)
        )
        for _ in range(2):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
            cl.drain()
        assert counters(cl) == [2, 0]
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        with pytest.raises(SandboxViolation, match="cumulative"):
            cl.servers[0].poll()
        assert counters(cl) == [2, 0]
        assert cl.servers[0].stats.refusals["quota_payload"] == 1

    def test_publish_fanout_quota(self, gossiper):
        """The ring gossiper re-publishes once per arrival; with
        max_publish_fanout=1 its second arrival at the same PE blows the
        cumulative fan-out ledger."""
        cl = gossip_cluster(
            gossiper, sandbox=SandboxConfig.on(max_publish_fanout=1)
        )
        cl.client.send_ifunc("server0", "gossiper", np.array([1, 5], I32))
        cl.drain()  # hop lands on server1 and stops (hops exhausted)
        assert cl.servers[0].region("gossip_log").tolist() == [1, 5]
        cl.client.send_ifunc("server0", "gossiper", np.array([1, 7], I32))
        with pytest.raises(SandboxViolation, match="fan-out"):
            cl.servers[0].poll()
        assert cl.servers[0].stats.refusals["quota_fanout"] == 1


# =============================================================== ttl ceiling
class TestTtlCeiling:
    def test_remint_beyond_config_ceiling(self, reminter):
        """Directly-sent code is stamped with the config ceiling (4); its
        attempt to grant ttl 9 on re-publish is refused at the mint."""
        cl = gossip_cluster(
            reminter, sandbox=SandboxConfig.on(max_publish_ttl=4)
        )
        cl.client.send_ifunc("server0", "reminter", np.array([1, 5], I32))
        with pytest.raises(SandboxViolation, match="ttl 9"):
            cl.servers[0].poll()
        srv = cl.servers[0]
        assert srv.stats.refusals["verify_ttl"] == 1
        assert reminter.digest.hex() in srv.verifier.quarantined
        # the refused publish never travelled: server1 saw nothing
        assert cl.servers[1].region("gossip_log").tolist() == [0, 0]

    def test_remint_beyond_admitted_hop_ttl(self, reminter):
        """A PUBLISH-delivered slice is clamped to its *admitting hop's*
        remaining ttl even under a loose config: admitted at ttl 2, its
        grant of 9 is a re-mint and is refused."""
        cl = gossip_cluster(reminter, sandbox=SandboxConfig.on())
        assert cl.client.sandbox.max_publish_ttl >= 9  # config alone allows
        cl.client.publish_to(
            "server0", "reminter", np.array([1, 5], I32), ttl=2
        )
        with pytest.raises(SandboxViolation, match="ceiling 2"):
            cl.servers[0].poll()
        assert cl.servers[0].stats.refusals["verify_ttl"] == 1


# ================================================================ quarantine
class TestQuarantine:
    def test_quarantine_is_cluster_wide(self, tsi):
        """A quota refusal on one PE banishes the digest everywhere: every
        target cache uninstalls, every sender cache forgets, later frames
        for it are refused on sight — and benign state is untouched."""
        cl = counter_cluster(
            tsi, n_servers=4, sandbox=SandboxConfig.on(max_invokes=1)
        )
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        cl.drain()
        assert counters(cl) == [5, 5, 5, 5]
        hexd = tsi.digest.hex()
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        with pytest.raises(SandboxViolation, match="quota"):
            cl.servers[0].poll()
        for pe in cl.pes():
            assert hexd in pe.verifier.quarantined
            assert not pe.target_cache.has_name("tsi")
            for peer in ("server0", "server1", "server2", "server3", "client"):
                assert not pe.sender_cache.has(peer, hexd)
        # hostile containment had zero effect on already-retired state
        assert counters(cl) == [5, 5, 5, 5]
        # a later frame for the banished digest is refused on sight
        cl.client.send_ifunc("server1", "tsi", np.array([1], I32))
        with pytest.raises(SandboxViolation, match="quarantined"):
            cl.servers[1].poll()
        roll = cl.refusals()
        assert roll["quota_invokes"] == 1
        assert roll["verify_quarantined"] >= 1
        assert counters(cl) == [5, 5, 5, 5]

    def test_quarantine_drops_queued_frames(self, tsi):
        """Frames already queued behind a credit window when their digest
        is banished are purged at the sender, counted per-PE — the fabric
        never carries banned code it already knows is banned."""
        cl = counter_cluster(tsi, sandbox=SandboxConfig.on(max_invokes=1))
        cl.set_flow(credit_window=1)
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.drain()
        assert counters(cl) == [1, 0]
        # three more: one transmits into the window, two queue at the client
        for _ in range(3):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.client.wire.queued_credit_frames() == 2
        with pytest.raises(SandboxViolation, match="quota"):
            cl.servers[0].poll()
        assert cl.client.wire.queued_credit_frames() == 0
        assert cl.client.stats.refusals["quarantine_drop"] == 2
        cl.drain()
        assert counters(cl) == [1, 0]  # nothing banned ever ran

    def test_quarantine_degrades_inflight_cq_futures(self, tsi):
        """An in-flight completion-queue future whose code is banished
        reads as expired and degrades through the validity-mask path —
        the PR 6 contract — instead of hanging; its slot is recycled."""
        cl = counter_cluster(tsi, sandbox=SandboxConfig.on())
        cq = CompletionQueue(cl.client, shape=(1,), dtype=I32, max_slots=2)
        fut = cl.client.submit(
            "server0", "tsi", np.array([7], I32), cq, expected=1
        )
        assert fut is not None and not fut.expired()
        cl.client.verifier.quarantine(tsi.digest.hex(), "tsi")
        assert fut.poisoned and fut.expired()
        rows, mask = fut.result_partial()
        assert not mask.any()  # nothing arrived, loudly attributed
        assert cq.free_slots == 2  # slot recycled, no leak


# ==================================================== tenancy + config merge
class TestStrictestMerge:
    def test_empty_is_disabled_default(self):
        assert SandboxConfig.strictest([]) == SandboxConfig()

    def test_quotas_take_tightest_nonzero(self):
        merged = SandboxConfig.strictest(
            [
                SandboxConfig.on(max_invokes=10, max_payload_bytes=0),
                SandboxConfig.on(max_invokes=3, max_payload_bytes=64),
            ]
        )
        assert merged.enabled
        assert merged.max_invokes == 3
        assert merged.max_payload_bytes == 64  # 0 = unlimited never wins

    def test_actions_intersect_regions_union_iff_all_restrict(self):
        a = SandboxConfig.on(
            allowed_actions=(0, 4, 5), allowed_regions=("x",)
        )
        b = SandboxConfig.on(
            allowed_actions=(0, 1, 4), allowed_regions=("y",)
        )
        merged = SandboxConfig.strictest([a, b])
        assert merged.allowed_actions == (0, 4)
        assert merged.allowed_regions == ("x", "y")
        # one unrestricted class -> declared-region semantics stand
        loose = SandboxConfig.strictest([a, SandboxConfig.on()])
        assert loose.allowed_regions == ()

    def test_ttl_ceiling_is_min(self):
        merged = SandboxConfig.strictest(
            [SandboxConfig.on(max_publish_ttl=8), SandboxConfig.on()]
        )
        assert merged.max_publish_ttl == 8


class TestTenantThreading:
    def test_router_installs_strictest_policy_and_serves(self):
        """A TenantClass declaring a sandbox makes the router install the
        strictest merge cluster-wide — and the gather substrate verifies
        clean under it (oracle-identical results, zero refusals)."""
        from repro.runtime.embed_service import EmbedShardService
        from repro.runtime.tenancy import TenantClass, TenantRouter

        cl = Cluster(2)
        svc = EmbedShardService(cl, vocab=32, dim=4, n_keys=4, max_slots=8)
        router = TenantRouter(
            svc,
            [
                TenantClass("a", sandbox=SandboxConfig.on(max_invokes=500)),
                TenantClass("b", sandbox=SandboxConfig.on(max_invokes=200)),
                TenantClass("c"),  # no policy declared
            ],
        )
        assert cl.client.sandbox.enabled
        assert cl.client.sandbox.max_invokes == 200  # strictest won
        keys = np.array([3, 17, 30], I32)
        rid = router.submit("a", keys)
        assert rid is not None
        done = []
        while svc.queue or svc.active:
            done += router.tick()
        (req,) = done
        assert not req.degraded
        np.testing.assert_array_equal(req.rows, svc.table[keys])
        assert cl.refusals() == {}
        # the substrate's code really went through verification
        assert any(pe.verifier.verifies > 0 for pe in cl.pes())

    def test_no_declared_sandbox_leaves_cluster_unsandboxed(self):
        from repro.runtime.embed_service import EmbedShardService
        from repro.runtime.tenancy import TenantClass, TenantRouter

        cl = Cluster(2)
        svc = EmbedShardService(cl, vocab=32, dim=4, n_keys=4, max_slots=8)
        TenantRouter(svc, [TenantClass("a"), TenantClass("b")])
        assert not cl.client.sandbox.enabled


# ================================================================ back-compat
class TestRefusalAccounting:
    def test_legacy_properties_mirror_the_dict(self):
        stats = PEStats()
        stats.refuse("publish_ttl")
        stats.refuse("publish_cycle", 2)
        stats.refuse("publish_digest")
        assert stats.publish_refused_ttl == 1
        assert stats.publish_refused_cycle == 2
        assert stats.publish_refused_digest == 1
        assert stats.as_dict()["refusals"] == {
            "publish_ttl": 1,
            "publish_cycle": 2,
            "publish_digest": 1,
        }

    def test_cluster_rollup_sums_across_pes(self, tsi):
        cl = counter_cluster(tsi, n_servers=2)
        cl.servers[0].stats.refuse("quota_invokes")
        cl.servers[1].stats.refuse("quota_invokes", 2)
        cl.client.stats.refuse("verify_ttl")
        assert cl.refusals() == {"quota_invokes": 3, "verify_ttl": 1}
