"""Heterogeneous placement layer: capability vectors, the cost-model
optimizer, the DPU predicate-pushdown filter, and restart hygiene."""

import numpy as np
import pytest

from repro.core import Capability, Cluster, TRIPLE_WIRE, WIRE_PROFILES
from repro.runtime.embed_service import EmbedShardService, FilterShardService
from repro.sharding.placement import PlacementOptimizer


def test_restart_readvertises_and_invalidates_plans():
    """A restarted PE must re-advertise its capability vector (fresh
    epoch) AND every cached placement plan priced against the dead
    incarnation must be dropped."""
    cl = Cluster(n_servers=2, hetero_wire=True)
    svc = FilterShardService(cl, vocab=256, dim=16, window=8)
    opt = PlacementOptimizer(cl)
    svc.plan_with(opt, [0])
    assert opt.cached_plans == 1
    epoch0 = cl.capabilities()["server0"].epoch
    cl.restart_server(0)
    cap = cl.capabilities()["server0"]
    assert cap is not None, "restarted PE did not re-advertise"
    assert cap.epoch > epoch0, "restart must mint a fresh capability epoch"
    assert opt.cached_plans == 0, (
        "cached plans routed to the restarted PE survived restart"
    )


# --------------------------------------------------------------- capabilities
def test_every_pe_advertises_at_connect():
    cl = Cluster(n_servers=3)
    caps = cl.capabilities()
    assert set(caps) == {"server0", "server1", "server2", "client"}
    srv, cli = caps["server0"], caps["client"]
    assert srv.isa == "cpu-bf2" and srv.wire == "thor_bf2"
    assert cli.isa == "cpu-host" and cli.wire == "thor_xeon"
    assert srv.mem_bw_class == "ddr-dpu" and cli.mem_bw_class == "ddr-host"
    # coefficients come straight from the calibrated wire profiles
    assert srv.alpha_us == WIRE_PROFILES["thor_bf2"].alpha_us
    assert cli.beta_Bus == WIRE_PROFILES["thor_xeon"].beta_Bus
    # epochs are distinct and monotone in connect order
    assert len({c.epoch for c in caps.values()}) == len(caps)


def test_kill_withdraws_capability():
    cl = Cluster(n_servers=2)
    cl.fabric.kill("server1")
    assert "server1" not in cl.capabilities()
    assert "server0" in cl.capabilities()


def test_hetero_pricing_uses_initiator_model():
    """With hetero accounting on, the same PUT costs different modeled
    time depending on who sends it; off, accounting is profile-uniform."""
    us = {}
    for hetero in (False, True):
        cl = Cluster(n_servers=1, wire="thor_bf2", hetero_wire=hetero)
        cl.servers[0].register_region("r", np.zeros(4096, np.uint8))
        cl.fabric.stats.reset()
        cl.fabric.get("client", "server0", "r", 0, 4096)
        us[hetero] = cl.fabric.stats.modeled_us
    xeon, bf2 = WIRE_PROFILES["thor_xeon"], WIRE_PROFILES["thor_bf2"]
    assert us[False] == pytest.approx(2 * bf2.alpha_us + 4096 / bf2.beta_Bus)
    # hetero: the client initiates, so its advertised thor_xeon model prices it
    assert us[True] == pytest.approx(2 * xeon.alpha_us + 4096 / xeon.beta_Bus)


# ------------------------------------------------------------- the cost model
def _mixed_optimizer(server_triple="cpu-bf2"):
    cl = Cluster(
        n_servers=2, wire="thor_xeon", server_triple=server_triple,
        hetero_wire=True,
    )
    return cl, PlacementOptimizer(cl)


PLAN_KW = dict(
    operand_bytes=24 * 96 * 4,
    result_bytes=24 * 96 * 4,
    request_payload_bytes=20,
    return_header_bytes=(3 + 24) * 4,
    op_name="filter",
    return_name="filter_return",
)


def test_optimizer_is_bit_deterministic():
    _, opt = _mixed_optimizer()
    a = opt.plan(requester="client", executor="server0", selectivity=0.25, **PLAN_KW)
    _, opt2 = _mixed_optimizer()
    b = opt2.plan(requester="client", executor="server0", selectivity=0.25, **PLAN_KW)
    assert a == b  # dataclass equality covers every priced float bit
    assert opt.priced == opt2.priced == 1
    # second identical call is a cache hit, not a re-price
    opt.plan(requester="client", executor="server0", selectivity=0.25, **PLAN_KW)
    assert opt.priced == 1


def test_selectivity_sweep_crosses_over():
    """Low selectivity pushes down; high selectivity pulls — on the same
    DPU-served cluster, purely from the survivor-byte term."""
    _, opt = _mixed_optimizer("cpu-bf2")
    lo = opt.plan(requester="client", executor="server0", selectivity=0.05, **PLAN_KW)
    hi = opt.plan(requester="client", executor="server0", selectivity=0.75, **PLAN_KW)
    assert lo.choice == "pushdown" and hi.choice == "pull"
    assert lo.pull_us == hi.pull_us  # pull side never depends on selectivity


def test_executor_overhead_flips_the_decision():
    """The hardware lever: the identical request refuses pushdown on the
    DPU (fat per-message o_us) but pushes down on the Xeon."""
    _, dpu = _mixed_optimizer("cpu-bf2")
    _, xeon = _mixed_optimizer("cpu-host")
    on_dpu = dpu.plan(requester="client", executor="server0", selectivity=0.75, **PLAN_KW)
    on_xeon = xeon.plan(requester="client", executor="server0", selectivity=0.75, **PLAN_KW)
    assert on_dpu.choice == "pull" and on_xeon.choice == "pushdown"


def test_unadvertised_peer_prices_with_fabric_profile():
    cl, opt = _mixed_optimizer()
    cl.fabric.kill("server0")
    d = opt.plan(requester="client", executor="server0", selectivity=0.5, **PLAN_KW)
    assert d.executor_epoch == 0  # the fallback capability, not a stale ad


# ------------------------------------------------------- the filter operator
@pytest.fixture(scope="module")
def filter_svc():
    cl = Cluster(n_servers=2, hetero_wire=True)
    return FilterShardService(cl, vocab=256, dim=16, window=8, seed=7)


def test_filter_matches_oracle_both_placements(filter_svc):
    svc = filter_svc
    los = svc.windows(6, seed=2)
    for sel in (0.05, 0.5, 0.95):
        th = svc.thresh_for_selectivity(sel)
        want = svc.oracle_filter(los, th)
        for arm in ("pushdown", "pull"):
            rep = svc.filter(los, th, placement=arm)
            for got, w in zip(rep.results, want):
                np.testing.assert_array_equal(got, w)


def test_filter_wire_bytes_scale_with_selectivity(filter_svc):
    svc = filter_svc
    los = svc.windows(8, seed=3)
    th_lo = svc.thresh_for_selectivity(0.05)
    th_hi = svc.thresh_for_selectivity(0.95)
    svc.filter(los, th_lo)  # warm
    lo = svc.filter(los, th_lo).put_bytes
    hi = svc.filter(los, th_hi).put_bytes
    assert lo < hi, "ragged RETURNs must shrink with survivors"


def test_filter_rejects_misaligned_windows(filter_svc):
    svc = filter_svc
    boundary = svc.rows_per_shard - svc.n_keys // 2
    with pytest.raises(ValueError, match="crosses a shard boundary"):
        svc.filter([boundary], 0.0)
    with pytest.raises(ValueError, match="outside the table"):
        svc.filter([svc.vocab - 1], 0.0)


def test_placement_policy_threads_through_cluster():
    cl = Cluster(n_servers=2, hetero_wire=True)
    svc = FilterShardService(cl, vocab=256, dim=16, window=8)
    los = svc.windows(3, seed=1)
    th = svc.thresh_for_selectivity(0.5)
    cl.set_placement("pull")
    rep = svc.filter(los, th)
    assert rep.gets == 3 and rep.puts == 0
    cl.set_placement("pushdown")
    rep = svc.filter(los, th)
    assert rep.gets == 0 and rep.puts > 0
    cl.set_placement("auto")  # small operand: the model picks pull here
    rep = svc.filter(los, th)
    assert rep.gets == 3 and rep.puts == 0
    with pytest.raises(ValueError):
        cl.set_placement("sideways")


def test_flow_profile_carries_placement_knob():
    from repro.analysis.autotune import FlowProfile, KNOB_GRID

    assert "placement" in KNOB_GRID
    prof = FlowProfile(wire="thor_xeon", placement="pull")
    assert FlowProfile.from_dict(prof.as_dict()) == prof
    cl = Cluster(n_servers=1)
    prof.apply(cl)
    assert cl.placement_policy == "pull"


def test_gather_placement_param():
    cl = Cluster(n_servers=2)
    svc = EmbedShardService(cl, vocab=64, dim=8, n_keys=4)
    batches = [np.array([1, 40], np.int32), np.array([9], np.int32)]
    want = svc.oracle(batches)
    for placement in ("pushdown", "pull"):
        rep = svc.gather(batches, placement=placement)
        for got, w in zip(rep.results, want):
            np.testing.assert_array_equal(got, w)


def test_dapc_placement_pricing():
    """plan_chase prices DAPC vs per-hop GETs through the same model: a
    deep chase amortizes one request over many hops and pushes down."""
    _, opt = _mixed_optimizer()
    deep = opt.plan_chase(requester="client", executor="server0", depth=64)
    assert deep.choice == "pushdown"
    assert deep.pull_us > deep.pushdown_us


def test_capability_for_triple_table():
    for triple, wire in TRIPLE_WIRE.items():
        cap = Capability.for_triple(triple, "cpu" if "cpu" in triple else "tpu")
        assert cap.wire == wire
        assert cap.alpha_us == WIRE_PROFILES[wire].alpha_us
        assert cap.scan_Bus > 0
        assert cap.as_dict()["isa"] == triple
