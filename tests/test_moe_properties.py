"""Property tests on MoE routing/dispatch invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.models.moe import (
    _bucket_positions,
    moe_block_replicated,
    moe_block_scatter,
    moe_capacity,
    route,
)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(1, 64),
    buckets=st.integers(1, 8),
    cap=st.integers(1, 16),
)
def test_bucket_positions_invariants(seed, n, buckets, cap):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, buckets, n), jnp.int32)
    slot, keep = _bucket_positions(dst, buckets, cap)
    slot, keep = np.asarray(slot), np.asarray(keep)
    # kept slots are unique and land in the right bucket's range
    kept = slot[keep]
    assert len(np.unique(kept)) == len(kept)
    assert np.all(kept // cap == np.asarray(dst)[keep])
    # drops happen iff a bucket overflows, and exactly the overflow count
    for b in range(buckets):
        cnt = int(np.sum(np.asarray(dst) == b))
        kept_b = int(np.sum(keep & (np.asarray(dst) == b)))
        assert kept_b == min(cnt, cap)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), topk=st.integers(1, 4))
def test_route_gates_normalized(seed, topk):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 1, (8, 8)), jnp.float32)
    gates, idx, aux = route(x, w, topk)
    s = np.asarray(jnp.sum(gates, -1))
    np.testing.assert_allclose(s, 1.0, atol=1e-3)
    assert np.asarray(idx).max() < 8
    assert float(aux) >= 0.0


def test_scatter_matches_replicated_with_full_capacity():
    """With capacity >= all tokens, the scatter dispatch must equal the
    dense gate-masked computation exactly (no drops)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    d, e, f = 16, 4, 32
    x = jax.random.normal(ks[0], (2, 8, d)) * 0.5
    wr = jax.random.normal(ks[1], (d, e)) * 0.3
    wi = jax.random.normal(ks[2], (e, d, f)) * 0.3
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.3
    wo = jax.random.normal(ks[4], (e, f, d)) * 0.3
    y1, _ = moe_block_scatter(x, wr, wi, wg, wo, topk=2, capacity_factor=16.0)
    y2, _ = moe_block_replicated(x, wr, wi, wg, wo, topk=2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_capacity_drops_pass_residual():
    """Over-capacity tokens contribute zero (their residual passes through
    at the block level) — the Switch/GShard drop semantics."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    d, e, f = 8, 2, 16
    x = jax.random.normal(ks[0], (1, 64, d))
    # router forced to expert 0: all 64 tokens collide
    wr = jnp.zeros((d, e)).at[:, 0].set(1.0)
    wi = jax.random.normal(ks[2], (e, d, f)) * 0.3
    wg = jax.random.normal(ks[3], (e, d, f)) * 0.3
    wo = jax.random.normal(ks[4], (e, f, d)) * 0.3
    y, _ = moe_block_scatter(x, wr, wi, wg, wo, topk=1, capacity_factor=0.25)
    cap = moe_capacity(64, e, 1, 0.25)
    nz = np.asarray(jnp.any(jnp.abs(y[0]) > 1e-7, axis=-1))
    # at most `cap` tokens per expert got output; the rest were dropped
    assert nz.sum() <= cap * e < 64
