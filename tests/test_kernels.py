"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, interpret=True (deliverable (c)); plus hypothesis properties on
the chase workload."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.kernels.chase.kernel import chase_shard
from repro.kernels.chase.ref import chase_ref
from repro.kernels.embed_lookup.kernel import embed_lookup
from repro.kernels.embed_lookup.ref import embed_lookup_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.wkv6.kernel import wkv6_chunked
from repro.kernels.wkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize(
    "b,h,kh,s,t,d,bq,bk,causal,cap",
    [
        (2, 4, 2, 256, 256, 64, 128, 128, True, None),
        (1, 8, 8, 128, 128, 128, 128, 64, True, 50.0),
        (2, 4, 1, 256, 512, 32, 64, 256, False, None),
        (1, 2, 2, 512, 512, 64, 256, 128, True, None),
        (1, 6, 2, 128, 256, 64, 128, 128, True, 30.0),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kh, s, t, d, bq, bk, causal, cap, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, s * t + h), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, t, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, t, d), dtype)
    got = flash_attention(q, k, v, causal=causal, softcap=cap, bq=bq, bk=bk,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


# -------------------------------------------------------------------- wkv6
@pytest.mark.parametrize(
    "b,t,h,m,chunk", [(2, 128, 2, 64, 16), (1, 256, 4, 64, 32), (2, 64, 1, 128, 16)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6(b, t, h, m, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, t * m), 5)
    r = (jax.random.normal(ks[0], (b, t, h, m), jnp.float32) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, t, h, m), jnp.float32) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, t, h, m), jnp.float32) * 0.5).astype(dtype)
    # realistic RWKV6 decay domain: log w in [-e, 0)
    x = jnp.clip(jax.random.normal(ks[3], (b, t, h, m), jnp.float32) - 1.0, -6.0, 1.0)
    w = jnp.exp(-jnp.exp(x))
    u = jax.random.normal(ks[4], (h, m), jnp.float32) * 0.3
    got, s_got = wkv6_chunked(r, k, v, w.astype(dtype), u, chunk=chunk, interpret=True)
    want, s_want = wkv6_ref(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), w, u
    )
    tol = 5e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(
        np.asarray(s_got), np.asarray(s_want), atol=tol, rtol=tol
    )


def test_wkv6_matches_model_scan():
    """The kernel oracle and the model's train-path scan are the same op."""
    from repro.models.rwkv import wkv6_scan

    ks = jax.random.split(KEY, 5)
    b, t, h, m = 2, 64, 2, 32
    r, k, v = (jax.random.normal(ks[i], (b, t, h, m)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jnp.clip(jax.random.normal(ks[3], (b, t, h, m)) - 1, -6, 1)))
    u = jax.random.normal(ks[4], (h, m)) * 0.3
    o1, s1 = wkv6_ref(r, k, v, w, u)
    o2, s2 = wkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------- chase
@pytest.mark.parametrize(
    "n_loc,b,lo,block,rounds",
    [(4096, 64, 8192, 2048, 4), (2048, 128, 0, 512, 6), (1024, 32, 1024, 1024, 3)],
)
def test_chase_kernel(n_loc, b, lo, block, rounds):
    rng = np.random.default_rng(n_loc + b)
    table = rng.integers(0, 4 * n_loc, n_loc).astype(np.int32)
    frontier = rng.integers(0, 4 * n_loc, b).astype(np.int32)
    depth = rng.integers(1, 32, b).astype(np.int32)
    f_ref, d_ref = chase_ref(
        jnp.asarray(table), jnp.asarray(frontier), jnp.asarray(depth), lo,
        max_hops=rounds * 32,
    )
    f_got, d_got = chase_shard(
        jnp.asarray(table), jnp.asarray(frontier), jnp.asarray(depth), lo,
        block=block, hops_per_visit=32, rounds=rounds, interpret=True,
    )
    assert np.array_equal(np.asarray(f_ref), np.asarray(f_got))
    assert np.array_equal(np.asarray(d_ref), np.asarray(d_got))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    depth_max=st.integers(1, 64),
)
def test_chase_kernel_property(seed, depth_max):
    """Property: for a table fully inside the shard, the kernel must fully
    resolve every chase (depth' == 0) and agree with pure-python chasing."""
    rng = np.random.default_rng(seed)
    n = 1024
    perm = rng.permutation(n)
    table = np.empty(n, np.int32)
    table[perm] = np.roll(perm, -1)  # single cycle, all local (lo=0)
    b = 16
    frontier = rng.integers(0, n, b).astype(np.int32)
    depth = rng.integers(0, depth_max + 1, b).astype(np.int32)
    f, d = chase_shard(
        jnp.asarray(table), jnp.asarray(frontier), jnp.asarray(depth), 0,
        block=n, hops_per_visit=64, rounds=1, interpret=True,
    )
    assert np.all(np.asarray(d) == 0)
    for i in range(b):
        a = frontier[i]
        for _ in range(depth[i]):
            a = table[a]
        assert int(f[i]) == int(a)


# ---------------------------------------------------------------- ssm_scan
@pytest.mark.parametrize(
    "bsz,t,d,n,chunk,bd",
    [(2, 128, 64, 16, 32, 32), (1, 64, 128, 8, 16, 128), (2, 96, 32, 16, 32, 32)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_kernel(bsz, t, d, n, chunk, bd, dtype):
    from repro.kernels.ssm_scan.kernel import ssm_scan_chunked
    from repro.kernels.ssm_scan.ref import ssm_scan_ref

    ks = jax.random.split(jax.random.fold_in(KEY, t * d + n), 5)
    x = (jax.random.normal(ks[0], (bsz, t, d)) * 0.5).astype(dtype)
    # mamba dt domain: softplus(raw - 4.6) in [1e-3, ~1e-1]
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (bsz, t, d)) - 4.6) + 1e-4).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    b = (jax.random.normal(ks[3], (bsz, t, n)) * 0.5).astype(dtype)
    c = (jax.random.normal(ks[4], (bsz, t, n)) * 0.5).astype(dtype)
    y1, h1 = ssm_scan_ref(
        x.astype(jnp.float32), dt.astype(jnp.float32), a,
        b.astype(jnp.float32), c.astype(jnp.float32),
    )
    y2, h2 = ssm_scan_chunked(x, dt, a, b, c, chunk=chunk, bd=bd, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=tol, rtol=tol)


def test_ssm_chunked_matches_model_scan():
    from repro.models.ssm import selective_scan, selective_scan_chunked

    ks = jax.random.split(KEY, 6)
    bsz, t, d, n = 2, 64, 32, 8
    x = jax.random.normal(ks[0], (bsz, t, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, t, d)) - 4.6) + 1e-4
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    b = jax.random.normal(ks[3], (bsz, t, n)) * 0.5
    c = jax.random.normal(ks[4], (bsz, t, n)) * 0.5
    h0 = jax.random.normal(ks[5], (bsz, d, n)) * 0.2
    y1, h1 = selective_scan(x, dt, a, b, c, h0)
    y2, h2 = selective_scan_chunked(x, dt, a, b, c, h0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5, rtol=1e-4)


# ------------------------------------------------------------ embed_lookup
@pytest.mark.parametrize("v_loc,d,n,lo,bt,bv", [
    (1024, 256, 512, 2048, 128, 256),
    (512, 128, 256, 0, 256, 512),
    (256, 512, 128, 256, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embed_lookup(v_loc, d, n, lo, bt, bv, dtype):
    rng = np.random.default_rng(v_loc + n)
    tab = jnp.asarray(rng.normal(0, 1, (v_loc, d)), dtype)
    ids = jnp.asarray(rng.integers(0, 4 * v_loc, n), jnp.int32)
    got = embed_lookup(tab, ids, lo, bt=bt, bv=bv, interpret=True)
    want = embed_lookup_ref(tab, ids, lo)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
    )
