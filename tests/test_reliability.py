"""The reliability layer: exactly-once delivery and attributed failure.

PR 6's contract strengthens PR 1's containment ("a lost frame loses only
itself, detected by idleness") to *recovery*: with
``ReliabilityConfig.on()`` installed, any drop/duplicate/reorder/kill
schedule either completes exactly once — retransmit timers re-drive lost
frames, the receive-side seq gate drops duplicates and re-orders
out-of-order arrivals — or fails loudly with the failure attributed to a
named peer (suspect -> dead escalation, partial results carrying a
validity mask).

The injection points are the same ones tests/test_fault_injection.py
drives (the endpoint inbox, ``Fabric.kill``) plus the new seeded Bernoulli
loss hook ``Fabric.set_loss`` the chaos suite and benchmarks share.
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    DataPlaneConfig,
    Frame,
    FrameKind,
    ReliabilityConfig,
    make_tsi,
    peek_header,
)
from repro.runtime.embed_service import EmbedShardService, ragged_batches

I32 = np.int32


def rel_pair(**kwargs):
    """Two PEs on one fabric with reliability installed (the tsi_pair of
    this suite)."""
    from repro.core.ifunc import PE, Toolchain
    from repro.core.transport import Fabric

    fabric = Fabric("ideal")
    tc = Toolchain()
    names = ["server0", "client"]
    server = PE("server0", fabric, triple="cpu-bf2", toolchain=tc, peers=names)
    client = PE("client", fabric, triple="cpu-host", toolchain=tc, peers=names)
    cfg = ReliabilityConfig.on(**kwargs)
    server.reliability = cfg
    client.reliability = cfg
    server.register_region("counter", np.zeros(1, I32))
    client.register_source(make_tsi())
    return fabric, client, server


def drive(client, server, rounds):
    n = 0
    for _ in range(rounds):
        n += client.poll() + server.poll()
    return n


class TestConfig:
    def test_default_is_disabled(self):
        cfg = ReliabilityConfig()
        assert not cfg.enabled

    def test_on_enables(self):
        assert ReliabilityConfig.on().enabled
        assert ReliabilityConfig.on(rto_ticks=7).rto_ticks == 7

    def test_backoff_schedule(self):
        cfg = ReliabilityConfig.on(rto_ticks=4, backoff=2.0)
        assert [cfg.rto_after(i) for i in range(4)] == [4, 8, 16, 32]

    def test_recovery_horizon_covers_full_budget(self):
        cfg = ReliabilityConfig.on()
        assert cfg.recovery_horizon() >= sum(
            cfg.rto_after(i) for i in range(cfg.retransmit_budget)
        )
        assert cfg.idle_grace() > cfg.recovery_horizon()


class TestWireFormat:
    def test_seq_and_ack_share_the_header_word(self):
        f = Frame(kind=FrameKind.BITCODE, name="x", payload=b"p",
                  seq=0x1234, ack=0xBEEF)
        hdr = peek_header(f.pack())
        assert hdr.seq == 0x1234 and hdr.ack == 0xBEEF

    def test_piggybacked_ack_costs_zero_wire_bytes(self):
        a = Frame(kind=FrameKind.BITCODE, name="x", payload=b"p")
        b = Frame(kind=FrameKind.BITCODE, name="x", payload=b"p",
                  seq=9, ack=1 << 31)
        assert len(a.pack()) == len(b.pack())

    def test_ack_frame_is_header_only(self):
        f = Frame(kind=FrameKind.ACK, name="", payload=b"", ack=17)
        wire = f.wire_bytes(cached=True)
        assert peek_header(wire).ack == 17
        assert len(wire) <= 80  # a bare header, no payload/code sections


class TestRetransmit:
    def test_lost_frame_is_retransmitted_and_completes(self):
        fabric, client, server = rel_pair(rto_ticks=2)
        client.send_ifunc("server0", "tsi", np.array([5], I32))
        server.endpoint.inbox.clear()  # the wire ate it
        assert client.wire.unacked_frames("server0") == 1
        drive(client, server, 40)
        assert server.region("counter")[0] == 5
        assert client.stats.retransmits >= 1
        assert client.wire.unacked_frames("server0") == 0

    def test_retransmit_backoff_is_exponential(self):
        fabric, client, server = rel_pair(rto_ticks=2, backoff=2.0)
        client.send_ifunc("server0", "tsi", np.array([1], I32))
        # eat every delivery: the frame can never be acked
        retx_at = []
        before = 0
        for _ in range(2 + 4 + 8 + 4):
            server.endpoint.inbox.clear()
            client.poll()
            if client.stats.retransmits > before:
                retx_at.append(client.progress.tick)
                before = client.stats.retransmits
        assert len(retx_at) >= 3
        gaps = np.diff(retx_at)
        assert list(gaps[:2]) == [4, 8]  # rto_after(1)=4, rto_after(2)=8

    def test_budget_exhaustion_escalates_suspect_then_dead(self):
        fabric, client, server = rel_pair(rto_ticks=1, retransmit_budget=2)
        client.send_ifunc("server0", "tsi", np.array([1], I32))
        for _ in range(30):
            server.endpoint.inbox.clear()
            client.poll()
            if client.wire.suspects():
                break
        assert "server0" in client.wire.suspects()
        assert "server0" in client.progress.detector.suspects
        assert client.stats.peers_suspected == 1
        retx_at_suspect = client.stats.retransmits
        # no sign of life within max_misses ticks: suspect becomes dead,
        # with no further retransmissions and all sender state dropped
        for _ in range(30):
            server.endpoint.inbox.clear()
            client.poll()
        assert "server0" in client.progress.detector.dead
        assert client.stats.retransmits == retx_at_suspect
        assert client.wire.unacked_frames("server0") == 0

    def test_sign_of_life_clears_suspicion(self):
        # max_misses generous: the redelivery->ack round trip must land
        # inside the suspect window for this schedule to stay deterministic
        fabric, client, server = rel_pair(rto_ticks=1, retransmit_budget=2,
                                          max_misses=8)
        client.send_ifunc("server0", "tsi", np.array([7], I32))
        held = [bytes(b) for b in server.endpoint.inbox]
        for _ in range(30):
            server.endpoint.inbox.clear()
            client.poll()
            if client.wire.suspects():
                break
        assert "server0" in client.wire.suspects()
        # the peer was alive all along: its next frame un-suspects it and
        # re-arms the retransmit timers, so the ifunc still lands
        for raw in held:
            server.endpoint.deliver(raw, src="client")
        drive(client, server, 60)
        assert "server0" not in client.wire.suspects()
        assert "server0" not in client.progress.detector.dead
        assert server.region("counter")[0] == 7


class TestExactlyOnce:
    def test_duplicate_is_dropped_at_the_seq_gate(self):
        fabric, client, server = rel_pair()
        client.send_ifunc("server0", "tsi", np.array([5], I32))
        dup = bytes(server.endpoint.inbox[0])
        server.poll()
        assert server.region("counter")[0] == 5
        # a retransmit that raced the ack: same seq, re-delivered
        server.endpoint.deliver(dup, src="client")
        assert server.poll() >= 1  # drained (a dup IS link progress) ...
        assert server.region("counter")[0] == 5  # ... but never re-runs
        assert server.stats.dup_frames_dropped == 1

    def test_out_of_order_frames_apply_in_seq_order(self):
        fabric, client, server = rel_pair()
        for v in (10, 20, 30):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        inbox = server.endpoint.inbox
        inbox.rotate(1)  # arrival order 30, 10, 20
        drive(client, server, 10)
        assert server.region("counter")[0] == 60
        assert server.stats.frames_held_ooo >= 1

    def test_invokes_exactly_once_under_heavy_loss(self):
        """The acceptance invariant: at 20% loss the counter ends exactly
        at the sum — no lost add, no double-applied retransmit."""
        fabric, client, server = rel_pair(rto_ticks=2)
        fabric.set_loss(0.2, seed=42)
        vals = list(range(1, 21))
        for v in vals:
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        for _ in range(300):
            if server.region("counter")[0] == sum(vals) and \
                    client.wire.unacked_frames() == 0:
                break
            client.poll()
            server.poll()
        assert server.region("counter")[0] == sum(vals)
        assert fabric.stats.frames_lost > 0


class TestLossInjection:
    def test_loss_rate_validated(self):
        from repro.core.transport import Fabric

        with pytest.raises(ValueError):
            Fabric("ideal").set_loss(1.0)
        with pytest.raises(ValueError):
            Fabric("ideal").set_loss(-0.1)

    def test_loss_is_seeded_and_accounted(self):
        def run(seed):
            fabric, client, server = rel_pair()
            fabric.set_loss(0.3, seed=seed)
            for v in range(10):
                client.send_ifunc("server0", "tsi", np.array([v], I32))
            return fabric.stats.frames_lost

        assert run(7) == run(7)  # deterministic
        assert run(7) > 0

    def test_zero_loss_changes_nothing(self):
        fabric, client, server = rel_pair()
        client.send_ifunc("server0", "tsi", np.array([5], I32))
        server.poll()
        assert fabric.stats.frames_lost == 0
        assert server.region("counter")[0] == 5


class TestFailureDetector:
    def test_killed_peer_is_declared_dead_and_state_cleared(self):
        cl = Cluster(2)
        cl.set_reliability(ReliabilityConfig.on(rto_ticks=1,
                                                retransmit_budget=2,
                                                max_misses=2))
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        svc.gather(ragged_batches(64, 4, 4, seed=0))  # warm everything
        cl.kill_server(1)
        cl.client.send_ifunc("server1", "gatherer",
                             np.full(4, -1, I32))  # into the void
        assert cl.client.wire.unacked_frames("server1") == 1
        for _ in range(60):
            cl.client.poll()
        det = cl.client.progress.detector
        assert "server1" in det.dead
        assert cl.client.stats.peers_declared_dead == 1
        # dead-peer state is gone: no retransmit queue, no credits held
        assert cl.client.wire.unacked_frames("server1") == 0
        assert cl.fabric.credit_outstanding("client", "server1") == 0

    def test_quiet_healthy_peer_is_never_declared_dead(self):
        """The suspect gate: a peer with nothing unacked gives no evidence
        of failure, however long it stays silent."""
        cl = Cluster(2)
        cl.set_reliability(ReliabilityConfig.on(max_misses=1))
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        svc.gather(ragged_batches(64, 2, 4, seed=0))
        for _ in range(50):  # long silence, no traffic either way
            cl.client.poll()
        assert not cl.client.progress.detector.dead


class TestServiceRecovery:
    def test_owner_death_degrades_to_partial_with_valid_mask(self):
        cl = Cluster(3)
        svc = EmbedShardService(cl, vocab=96, dim=4, n_keys=4, max_slots=8)
        cl.set_reliability(ReliabilityConfig.on(rto_ticks=1,
                                                retransmit_budget=2,
                                                max_misses=2,
                                                future_deadline=16))
        keys = np.array([5, 40, 70], I32)  # touches all three shards
        svc.submit(keys)
        cl.kill_server(1)  # owner of key 40
        svc.run()
        (req,) = svc.finished
        assert req.degraded
        assert req.valid.tolist() == [True, False, True]
        np.testing.assert_array_equal(req.rows[req.valid],
                                      svc.table[keys][req.valid])
        assert svc.cq.free_slots == svc.max_slots  # slot recycled

    def test_all_owners_dead_completes_all_invalid(self):
        cl = Cluster(2)
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        cl.set_reliability(ReliabilityConfig.on(rto_ticks=1,
                                                retransmit_budget=2,
                                                max_misses=2,
                                                future_deadline=8))
        svc.submit(np.array([5, 40], I32))
        cl.kill_server(0)
        cl.kill_server(1)
        svc.run()
        (req,) = svc.finished
        assert req.degraded and not req.valid.any()

    def test_idle_timeout_names_the_stuck_requests(self):
        """Satellite S1: the bare 'service idle' timeout now attributes —
        slots, owners, ages, resubmit counts, queued backlog."""
        cl = Cluster(2)
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        svc.gather([np.array([1], I32)])  # warm code caches
        svc.submit(np.array([3, 40], I32))
        svc.submit(np.array([7], I32))
        svc._admit()
        cl.servers[1].endpoint.inbox.clear()  # eat server1's partial
        cl.servers[0].endpoint.inbox.clear()  # and both key-frames
        with pytest.raises(TimeoutError) as exc:
            svc.run()
        msg = str(exc.value)
        assert "service idle but requests outstanding" in msg
        assert "owners=" in msg and "arrived=" in msg and "rid=" in msg
        assert "server0" in msg

    def _exhaust_two(self, strict_recovery=False):
        """Drive two concurrent gathers to resubmit-budget exhaustion in
        the same recovery sweep, with one partial row having landed."""
        cl = Cluster(2)
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8,
                                strict_recovery=strict_recovery)
        cl.set_reliability(ReliabilityConfig.on(rto_ticks=64,
                                                retransmit_budget=1,
                                                max_misses=64,
                                                future_deadline=2))
        # warm both servers' code caches with real (delivered) gathers so
        # later digest-only resubmissions are executable on arrival
        svc.gather([np.array([1], I32), np.array([40], I32)])
        svc.submit(np.array([3, 40], I32))  # spans both shards
        svc.submit(np.array([45], I32))     # server1 only
        svc._admit()

        def eat_and_expire():
            for srv in cl.servers:
                srv.endpoint.inbox.clear()
            svc.cq.advance(2)

        eat_and_expire()
        assert svc._recover() == 2  # round 1: both resubmitted
        assert svc._admit() == 2
        # round 2: request 0's local row lands via the one-sided zero-copy
        # RETURN path (no frame, no seq gate — exactly the data plane whose
        # losses the resubmit loop exists for) before the rest of the round
        # is lost; that row must survive budget exhaustion
        req0 = next(r for r in svc.active.values() if r.keys[0] == 3)
        stride = (2 + svc.cq.width) * 4
        base = req0.future.slot * stride
        cl.fabric.put_region(
            "server0", cl.client.name, svc.cq.region,
            base + 8, svc.table[3].tobytes(), doorbell=(base, 1, "or"),
        )
        eat_and_expire()
        return cl, svc

    def test_budget_exhaustion_degrades_every_expired_request(self):
        """Regression: two in-flight gathers blowing their resubmit budget
        in the same sweep used to raise TimeoutError on the *first* —
        abandoning the second mid-sweep (slot leaked, request stuck) and
        discarding the partial rows that had already arrived (the future
        was cancelled before the budget check).  Exhaustion must instead
        degrade each request to an attributed partial result, finish the
        sweep, and recycle every slot."""
        cl, svc = self._exhaust_two()
        svc._recover()  # must not raise mid-sweep
        assert not svc.active and not svc.queue
        assert svc.cq.free_slots == svc.max_slots
        done = {r.rid: r for r in svc.finished}
        r0, r1 = done[2], done[3]  # rids 0/1 were the warm-up gathers
        assert r0.degraded and r1.degraded
        assert r0.resubmits == 2 and r1.resubmits == 2
        # the row that DID arrive is preserved and attributed valid
        assert r0.valid.tolist() == [True, False]
        np.testing.assert_array_equal(r0.rows[0], svc.table[3])
        assert r1.valid.tolist() == [False]

    def test_strict_recovery_raises_once_after_the_sweep(self):
        """Under ``strict_recovery`` exhaustion still raises — but only
        after every expired future has been degraded and retired, and the
        error names every exhausted request, not just the first."""
        cl, svc = self._exhaust_two(strict_recovery=True)
        with pytest.raises(TimeoutError) as exc:
            svc._recover()
        # the sweep completed before the raise: nothing leaked
        assert not svc.active
        assert svc.cq.free_slots == svc.max_slots
        assert len(svc.finished) == 2
        msg = str(exc.value)
        assert "rid=2" in msg and "rid=3" in msg
        assert "resubmit budget" in msg


class TestKillMidRendezvous:
    def test_source_death_between_descriptor_and_get(self):
        """Satellite S3: the rendezvous descriptor is delivered, then the
        GET source dies before the pull.  The requester must detect the
        death (via the detector, not an unhandled EndpointDead), release
        its CQ slot, and degrade the request — not hang, not crash."""
        cl = Cluster(2)
        svc = EmbedShardService(cl, vocab=64, dim=64, n_keys=4, max_slots=8)
        cl.set_reliability(ReliabilityConfig.on(rto_ticks=1,
                                                retransmit_budget=2,
                                                max_misses=2,
                                                future_deadline=16))
        cl.set_dataplane(DataPlaneConfig.rendezvous(rndv_min=1))
        # warm code caches so the RETURN travels as a descriptor
        svc.gather(ragged_batches(64, 2, 4, seed=0),
                   dataplane=DataPlaneConfig.rendezvous(rndv_min=1))
        cl.set_dataplane(DataPlaneConfig.rendezvous(rndv_min=1))
        svc.submit(np.array([3, 5], I32))  # owned entirely by server0
        svc._admit()
        cl.servers[0].poll()  # server resolves; descriptor now at client
        from repro.core.frame import FrameKind as FK

        kinds = [peek_header(bytes(b)).kind for b in cl.client.endpoint.inbox]
        assert FK.RNDV in kinds  # descriptor really is in flight
        cl.kill_server(0)  # source dies before the requester pulls
        svc.run()
        (req,) = svc.finished
        assert req.degraded and not req.valid.any()
        assert cl.client.stats.rndv_dead_pulls >= 1
        assert "server0" in cl.client.progress.detector.dead
        assert svc.cq.free_slots == svc.max_slots  # CQ slot released


class TestPublishDedupRetirement:
    def test_seen_pubs_retire_once_acked(self):
        """Satellite S2: publish dedup keys are dropped once the publisher
        has seen the cumulative ack for their seq — bounded memory over an
        unbounded publish stream."""
        cl = Cluster(2)
        cl.set_reliability(ReliabilityConfig.on(ack_delay=1))
        cl.client.register_source(make_tsi())
        for pe in cl.servers:
            pe.register_region("counter", np.zeros(1, I32))
        for _ in range(5):
            cl.client.publish_ifunc("tsi", np.array([1], I32))
            cl.drain_rounds()
        for pe in cl.servers:
            assert pe.region("counter")[0] == 5
            # every dedup key retired: the ack high-water mark passed the
            # publishes, so the log and the seen-set are both drained
            assert not pe.progress._pub_log
            assert not pe.progress._seen_pubs

    def test_replayed_publish_after_retirement_is_still_dropped(self):
        """Retirement must not reopen the duplicate window: a stale
        retransmit of a retired PUBLISH dies at the seq gate instead."""
        cl = Cluster(2)
        cl.set_reliability(ReliabilityConfig.on(ack_delay=1))
        cl.client.register_source(make_tsi())
        for pe in cl.servers:
            pe.register_region("counter", np.zeros(1, I32))
        cl.client.publish_ifunc("tsi", np.array([1], I32))
        replay = [bytes(b) for b in cl.servers[0].endpoint.inbox]
        cl.drain_rounds()
        assert not cl.servers[0].progress._seen_pubs  # retired
        for raw in replay:  # the wire re-delivers the original frames
            cl.servers[0].endpoint.deliver(raw, src="client")
        cl.drain_rounds()
        for pe in cl.servers:
            assert pe.region("counter")[0] == 1  # still exactly once


class TestDisabledIsBitCompatible:
    def test_frames_carry_no_seq_when_disabled(self):
        from repro.core.ifunc import PE, Toolchain
        from repro.core.transport import Fabric

        fabric = Fabric("ideal")
        tc = Toolchain()
        names = ["server0", "client"]
        server = PE("server0", fabric, triple="cpu-bf2", toolchain=tc,
                    peers=names)
        client = PE("client", fabric, triple="cpu-host", toolchain=tc,
                    peers=names)
        server.register_region("counter", np.zeros(1, I32))
        client.register_source(make_tsi())
        client.send_ifunc("server0", "tsi", np.array([5], I32))
        hdr = peek_header(bytes(server.endpoint.inbox[0]))
        # the legacy global seq counter still stamps frames; what must be
        # absent is reliability state: no ack, no retransmit tracking
        assert hdr.ack == 0
        assert client.wire.unacked_frames() == 0
        assert not client.progress._recv and not client.progress._ack_owed

    def test_gather_wire_bytes_identical_with_reliability_off(self):
        def run(cfg):
            cl = Cluster(2)
            svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4,
                                    max_slots=8)
            if cfg is not None:
                cl.set_reliability(cfg)
            rep = svc.gather(ragged_batches(64, 6, 4, seed=3))
            return rep.put_bytes

        assert run(None) == run(ReliabilityConfig())
