"""Chaos/property suite for recursive code propagation (tree multicast).

The propagation contract under fire:

* a PUBLISH hop installs, validates, invokes, and re-publishes — and every
  validation failure (expired ttl, cycle, poisoned code) is refused *at
  that hop*, loudly, without installing stale code or riding the tree;
* the fabric is at-least-once: dropped hops lose only their subtree (and
  re-parenting re-covers it), duplicated hops are exactly-once per PE via
  the (digest, root, pub_id) dedup key, reordering changes nothing;
* a killed mid-tree PE orphans its subtree cleanly — the orphans drain,
  re-parenting covers the survivors, and nothing leaks (no wedged polls,
  no stale installs, no leaked completion-queue slots in workloads that
  ride the propagated code).
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    Frame,
    FrameFlags,
    HopHeader,
    PropagationConfig,
    ProtocolError,
    chase_ref,
    make_gossiper,
    make_tsi,
    pack_hop,
    subtree_sizes,
    tree_children,
    tree_children_map,
    tree_depth,
    tree_parent,
)
from repro.core.pointer_chase import PointerChaseApp
from repro.runtime.embed_service import EmbedShardService, ragged_batches
from repro.sharding.collectives import (
    _reducer_for_width,
    xrdma_bcast,
    xrdma_flat_push,
    xrdma_reduce,
)

I32 = np.int32
BINOMIAL = PropagationConfig()
KARY2 = PropagationConfig(topology="kary", k=2)


@pytest.fixture(scope="module")
def tsi():
    """One toolchain build of the TSI ifunc, shared by every cluster here
    (the IFunc handle is immutable; building it per-test would re-run
    jax.export for nothing)."""
    return make_tsi()


@pytest.fixture(scope="module")
def gossiper():
    return make_gossiper()


def counter_cluster(tsi, n_servers=8, wire="ideal"):
    cl = Cluster(n_servers=n_servers, wire=wire)
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, I32))
    cl.toolchain.publish(tsi)
    return cl


def counters(cl):
    return [int(pe.region("counter")[0]) for pe in cl.servers]


def forge_publish(cl, dst, name, hop, payload=b"", code=None, digest=None):
    """Hand-craft one PUBLISH hop frame and PUT it (full, code-carrying)."""
    ifn = cl.toolchain.lookup(name)
    frame = Frame(
        kind=ifn.kind,
        name=name,
        payload=pack_hop(hop) + payload,
        code=code if code is not None else ifn.code_bytes,
        deps=ifn.deps,
        digest=digest if digest is not None else ifn.digest,
        flags=FrameFlags.HOP,
    )
    cl.fabric.put("client", dst, frame.pack(), hop=True)
    return frame


# ===================================================================== tree
class TestTreeMath:
    @pytest.mark.parametrize("n", [1, 2, 3, 5, 8, 17, 33])
    @pytest.mark.parametrize("k_code", [0, 1, 2, 3])
    def test_tree_partitions_peers(self, n, k_code):
        """Every tree is a spanning tree: each non-root appears as exactly
        one node's child, for every root."""
        for root in (0, n // 2, n - 1):
            cm = tree_children_map(k_code, root, n)
            reached = [c for cs in cm.values() for c in cs]
            assert sorted(reached) == sorted(set(range(n)) - {root})

    @pytest.mark.parametrize("k_code", [0, 2])
    def test_parent_inverts_children(self, k_code):
        n, root = 17, 16
        cm = tree_children_map(k_code, root, n)
        for p, cs in cm.items():
            for c in cs:
                assert tree_parent(k_code, root, c, n) == p
        assert tree_parent(k_code, root, root, n) == root

    def test_subtree_sizes_sum(self):
        sizes = subtree_sizes(0, 16, 17)
        assert sizes[16] == 17
        cm = tree_children_map(0, 16, 17)
        for p, cs in cm.items():
            assert sizes[p] == 1 + sum(sizes[c] for c in cs)

    def test_binomial_root_fanout_is_log(self):
        assert len(tree_children(0, 16, 16, 17)) == 5  # ceil(log2 17)

    def test_depth_bounds(self):
        # binomial over 17: labels 1..15 fill an order-4 subtree (depth 4),
        # label 16 hangs off the root directly — floor(log2(n-1)) levels
        assert tree_depth(0, 16, 17) == 4
        assert tree_depth(0, 0, 16) == 4
        assert tree_depth(1, 4, 5) == 4  # 1-ary: a chain

    def test_config_validation(self):
        with pytest.raises(ValueError):
            PropagationConfig(topology="ring")
        with pytest.raises(ValueError):
            PropagationConfig(topology="kary", k=0)
        with pytest.raises(ValueError):
            PropagationConfig(ttl=0)


# ==================================================================== bcast
class TestBcast:
    @pytest.mark.parametrize("cfg", [BINOMIAL, KARY2], ids=["binomial", "kary2"])
    def test_bcast_covers_every_server_once(self, tsi, cfg):
        cl = counter_cluster(tsi)
        rep = xrdma_bcast(cl, "tsi", np.array([7], I32), config=cfg)
        assert counters(cl) == [7] * 8  # exactly once each
        assert rep.covered == rep.n_targets == 8
        assert rep.publishes == 8  # one hop frame received per server

    def test_root_sends_log_not_n(self, tsi):
        cl = counter_cluster(tsi, n_servers=16)
        rep = xrdma_bcast(cl, "tsi", np.array([1], I32))
        assert rep.client_sends == 5  # ceil(log2 17), not 16
        assert rep.client_code_sends == 5

    def test_flat_push_baseline_is_n(self, tsi):
        cl = counter_cluster(tsi, n_servers=16)
        rep = xrdma_flat_push(cl, "tsi", np.array([1], I32))
        assert rep.client_sends == rep.client_code_sends == 16
        assert counters(cl) == [1] * 16

    def test_code_travels_once_per_server(self, tsi):
        cl = counter_cluster(tsi)
        xrdma_bcast(cl, "tsi", np.array([2], I32))
        installs = sum(pe.stats.ifunc_installs for pe in cl.servers)
        assert installs == 8
        assert cl.fabric.stats.by_kind["code"] == 8 * len(tsi.code_bytes) + 8 * len(
            "\n".join(tsi.deps).encode()
        ) + 8 * 8  # code + deps + trailing MAGIC per cold frame

    def test_warm_tree_ships_no_code(self, tsi):
        cl = counter_cluster(tsi)
        xrdma_bcast(cl, "tsi", np.array([2], I32))
        rep = xrdma_bcast(cl, "tsi", np.array([3], I32))
        assert counters(cl) == [5] * 8
        assert rep.wire_bytes_by_kind["code"] == 0  # digest-only hops
        assert rep.hop_frames == 8

    def test_code_only_publish_installs_without_invoking(self, tsi):
        cl = counter_cluster(tsi)
        rep = xrdma_bcast(cl, "tsi", b"")  # bare publish: distribution only
        assert rep.covered == 8
        assert counters(cl) == [0] * 8
        invokes = sum(pe.stats.invokes for pe in cl.servers)
        assert invokes == 0

    def test_batched_runtime_bcast(self, tsi):
        cl = counter_cluster(tsi)
        cl.set_batching(True)
        rep = xrdma_bcast(cl, "tsi", np.array([4], I32))
        assert counters(cl) == [4] * 8
        assert rep.covered == 8


# ==================================================================== chaos
class TestDropChaos:
    def test_dropped_hop_loses_only_its_subtree(self, tsi):
        """Eat the hop parked at a mid-tree PE: its whole subtree stays
        uncovered, everyone else's counter is exact, nothing wedges."""
        cl = counter_cluster(tsi)  # 8 servers, client root (idx 8, n=9)
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        # root's children are servers 0,1,3,7; server3's subtree is {4,5,6}
        assert len(cl.servers[3].endpoint.inbox) == 1
        cl.servers[3].endpoint.inbox.clear()  # the wire ate the hop
        cl.drain()
        assert counters(cl) == [5, 5, 5, 0, 0, 0, 0, 5]

    def test_manual_reparent_after_drop(self, tsi):
        cl = counter_cluster(tsi)
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        cl.servers[3].endpoint.inbox.clear()
        cl.drain()
        for idx in (3, 4, 5, 6):
            cl.client.publish_to(f"server{idx}", "tsi", np.array([5], I32))
        cl.drain()
        assert counters(cl) == [5] * 8  # still exactly once each

    def test_killed_midtree_pe_reparents_survivors(self, tsi):
        """server3 dies before the bcast: its hop send fails (counted),
        the orphaned subtree {4,5,6} is re-covered by direct root
        publishes, and the dead PE loses only itself."""
        cl = counter_cluster(tsi)
        cl.kill_server(3)
        rep = xrdma_bcast(cl, "tsi", np.array([9], I32))
        assert rep.covered == rep.n_targets == 7
        assert rep.reparented == 3  # servers 4, 5, 6
        assert rep.publish_send_failures == 1
        got = counters(cl)
        assert got[3] == 0 and [got[i] for i in (0, 1, 2, 4, 5, 6, 7)] == [9] * 7

    def test_killed_midtree_pe_reparents_under_batching(self, tsi):
        """Same schedule on the batched runtime: publish sends bypass the
        send queue, so the dead child surfaces EndpointDead synchronously
        inside the fan-out (counted, contained) instead of exploding out of
        a later flush — re-parenting works identically on both runtimes."""
        cl = counter_cluster(tsi)
        cl.set_batching(True)
        cl.kill_server(3)
        rep = xrdma_bcast(cl, "tsi", np.array([9], I32))
        assert rep.covered == rep.n_targets == 7
        assert rep.reparented == 3
        assert rep.publish_send_failures == 1
        got = counters(cl)
        assert got[3] == 0 and [got[i] for i in (0, 1, 2, 4, 5, 6, 7)] == [9] * 7

    def test_killed_leaf_loses_only_itself(self, tsi):
        cl = counter_cluster(tsi)
        cl.kill_server(0)  # a root child with no subtree of its own
        rep = xrdma_bcast(cl, "tsi", np.array([9], I32))
        assert rep.covered == rep.n_targets == 7
        assert rep.reparented == 0
        assert counters(cl)[1:] == [9] * 7


class TestDuplicateChaos:
    def test_duplicated_hop_is_exactly_once(self, tsi):
        """Re-deliver every in-flight hop frame: the dedup key makes the
        broadcast exactly-once per PE — counters unchanged, dupes counted,
        and crucially no re-publish storm (publishes stay at N)."""
        cl = counter_cluster(tsi)
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        rounds = 0
        while any(pe.endpoint.inbox for pe in cl.pes()):
            for pe in cl.pes():
                inbox = pe.endpoint.inbox
                for buf in list(inbox):
                    inbox.append(bytearray(buf))  # duplicate delivery
                pe.poll()
            rounds += 1
            assert rounds < 50
        assert counters(cl) == [5] * 8
        assert sum(pe.stats.publish_dupes for pe in cl.servers) >= 8
        assert sum(pe.stats.publishes for pe in cl.pes()) == 8

    def test_same_root_new_pub_id_does_reinvoke(self, tsi):
        """Dedup is per publish, not per code: a second broadcast (fresh
        pub_id) re-invokes everywhere even though the digest is warm."""
        cl = counter_cluster(tsi)
        xrdma_bcast(cl, "tsi", np.array([2], I32))
        xrdma_bcast(cl, "tsi", np.array([3], I32))
        assert counters(cl) == [5] * 8


class TestReorderChaos:
    def test_reordered_inboxes_converge(self, tsi):
        cl = counter_cluster(tsi, n_servers=8)
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        rounds = 0
        while any(pe.endpoint.inbox for pe in cl.pes()):
            for pe in cl.pes():
                pe.endpoint.inbox.rotate(1)  # shuffle every queue, every round
                pe.poll()
            rounds += 1
            assert rounds < 100
        assert counters(cl) == [5] * 8


class TestRefusals:
    def test_expired_ttl_refused_loudly(self, tsi):
        cl = counter_cluster(tsi, n_servers=2)
        hop = HopHeader(ttl=0, root=2, pub_id=1, path=(2,), k=0)
        forge_publish(cl, "server0", "tsi", hop, np.array([5], I32).tobytes())
        with pytest.raises(ProtocolError, match="expired"):
            cl.servers[0].poll()
        assert cl.servers[0].stats.publish_refused_ttl == 1
        # refusal happened before install: no stale code registered
        assert not cl.servers[0].target_cache.has_name("tsi")
        assert counters(cl) == [0, 0]

    def test_ttl_bounds_tree_depth(self, tsi):
        """A 1-ary (chain) tree with ttl=1: only the first server is
        covered; the stop is silent and counted (normal bounding, not a
        protocol violation)."""
        cl = counter_cluster(tsi, n_servers=4)
        chain = PropagationConfig(topology="kary", k=1)
        rep = xrdma_bcast(cl, "tsi", np.array([5], I32), config=chain, ttl=1,
                          reparent=False)
        assert counters(cl) == [5, 0, 0, 0]
        assert rep.covered == 1
        assert cl.servers[0].stats.publish_stopped_ttl == 1

    def test_cycle_refused_loudly(self, tsi):
        """A hop whose visited path already contains the receiver is a
        forwarding loop: refused before install/invoke."""
        cl = counter_cluster(tsi, n_servers=3)
        hop = HopHeader(ttl=4, root=3, pub_id=1, path=(3, 1, 0), k=0)
        forge_publish(cl, "server0", "tsi", hop, np.array([5], I32).tobytes())
        with pytest.raises(ProtocolError, match="cycle"):
            cl.servers[0].poll()
        assert cl.servers[0].stats.publish_refused_cycle == 1
        assert counters(cl) == [0, 0, 0]

    def test_poisoned_code_refused_at_first_hop(self, tsi):
        """Code bytes that do not hash to the header digest are refused at
        the receiving hop: no install, no invoke, no re-publish (the tree
        never amplifies a poisoned frame)."""
        cl = counter_cluster(tsi, n_servers=4)
        code = bytearray(tsi.code_bytes)
        code[len(code) // 2] ^= 0xFF
        hop = HopHeader(ttl=8, root=4, pub_id=1, path=(4,), k=0)
        forge_publish(cl, "server0", "tsi", hop, np.array([5], I32).tobytes(),
                      code=bytes(code))
        with pytest.raises(ProtocolError, match="poisoned"):
            cl.servers[0].poll()
        assert cl.servers[0].stats.publish_refused_digest == 1
        assert not cl.servers[0].target_cache.has_name("tsi")  # no stale install
        cl.drain()
        # nothing propagated: no other server saw any traffic
        assert sum(pe.stats.msgs for pe in cl.servers[1:]) == 0
        assert counters(cl) == [0] * 4

    def test_poisoned_code_refused_mid_tree(self, tsi):
        """Poison injected at an inner hop: upstream PEs (already covered)
        keep their state, the poisoned frame's subtree gets nothing."""
        cl = counter_cluster(tsi, n_servers=8)
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        # tamper the code section of the hop parked at server3 (subtree 4,5,6)
        buf = cl.servers[3].endpoint.inbox[0]
        frame = cl.toolchain.lookup("tsi")
        idx = bytes(buf).rindex(frame.code_bytes[:32])
        buf[idx + 16] ^= 0xFF
        with pytest.raises(ProtocolError, match="poisoned"):
            cl.drain()
        cl.drain()
        assert counters(cl) == [5, 5, 5, 0, 0, 0, 0, 5]
        assert cl.servers[3].stats.publish_refused_digest == 1

    def test_tampered_hop_path_rejected(self, tsi):
        """Flip a byte inside the hop path: the FNV digest check refuses
        the frame before any hop field is trusted."""
        cl = counter_cluster(tsi, n_servers=2)
        cl.client.publish_ifunc("tsi", np.array([5], I32))
        buf = cl.servers[0].endpoint.inbox[0]
        hop_payload_off = bytes(buf).index(b"tsi") + 3
        buf[hop_payload_off + 20] ^= 0xFF  # first path entry
        with pytest.raises(ProtocolError, match="digest"):
            cl.servers[0].poll()
        assert not cl.servers[0].target_cache.has_name("tsi")

    def test_batched_poll_contains_bad_publish(self, tsi):
        """Batched runtime: a refused publish must not take the healthy
        frames drained in the same poll down with it."""
        cl = counter_cluster(tsi, n_servers=2)
        cl.servers[0].batching = True
        cl.client.publish_to("server0", "tsi", np.array([3], I32))
        hop = HopHeader(ttl=0, root=2, pub_id=99, path=(2,), k=0)
        forge_publish(cl, "server0", "tsi", hop, np.array([5], I32).tobytes())
        cl.client.publish_to("server0", "tsi", np.array([4], I32))
        with pytest.raises(ProtocolError):
            cl.servers[0].poll()
        assert counters(cl)[0] == 7  # 3 + 4 retired, the expired hop refused


# =================================================================== reduce
class TestReduce:
    @pytest.mark.parametrize("cfg", [BINOMIAL, KARY2], ids=["binomial", "kary2"])
    def test_reduce_matches_numpy_sum(self, cfg):
        cl = Cluster(n_servers=8, wire="ideal")
        rng = np.random.default_rng(0)
        vals = rng.integers(-100, 100, (9, 4)).astype(I32)
        rep = xrdma_reduce(cl, vals, config=cfg)
        np.testing.assert_array_equal(rep.result, vals.sum(axis=0))
        # N-1 upward partials: each non-root forwards exactly once
        assert rep.forwards == 8

    def test_reduce_is_multi_hop(self):
        """Partials really fold mid-tree: the deepest node's contribution
        crosses several PEs, and the root receives far fewer frames than a
        flat fan-in would send it."""
        cl = Cluster(n_servers=8, wire="ideal")
        vals = np.ones((9, 2), I32)
        xrdma_reduce(cl, vals)
        root_frames = cl.client.stats.msgs
        # root hears only from its direct children (4 partials for n=9
        # binomial) plus its own self-seed — never all 8 servers
        assert root_frames <= 6

    def test_reduce_batched_runtime(self):
        """Child partials arriving in one poll fold through the masked-scan
        propagate dispatch; the fold that completes the subtree still emits
        exactly one upward FORWARD."""
        cl = Cluster(n_servers=8, wire="ideal")
        cl.set_batching(True)
        vals = np.arange(18, dtype=I32).reshape(9, 2)
        rep = xrdma_reduce(cl, vals)
        np.testing.assert_array_equal(rep.result, vals.sum(axis=0))
        assert rep.forwards == 8

    def test_reduce_with_dead_leaf_detected_not_hung(self):
        cl = Cluster(n_servers=4, wire="ideal")
        cl.kill_server(2)
        vals = np.ones((5, 2), I32)
        with pytest.raises(TimeoutError):
            xrdma_reduce(cl, vals)


# ========================================================== A_PUBLISH / ABI
class TestSelfPropagation:
    def test_gossiper_ring_propagates_itself(self, gossiper):
        """Injected code that re-publishes ITSELF: the client sends one
        frame; the code then rides the ring on its own for `hops` hops,
        logging once per PE — no client involvement past the first send."""
        cl = Cluster(n_servers=3, wire="ideal")
        n = 4
        for i, pe in enumerate(cl.pes()):
            pe.register_region("gossip_log", np.zeros(2, I32))
            pe.register_cap("gossip_meta", np.array([i, n], I32))
        cl.toolchain.publish(gossiper)
        sends0 = cl.client.stats.sends
        cl.client.send_ifunc("server0", "gossiper", np.array([2, 5], I32))
        cl.drain()
        logs = [pe.region("gossip_log").tolist() for pe in cl.pes()]
        assert logs == [[1, 5], [1, 5], [1, 5], [0, 0]]
        assert cl.client.stats.sends - sends0 == 1
        assert cl.servers[0].stats.publishes == 1  # the code hopped onward
        assert cl.servers[1].stats.publishes == 1

    def test_gossiper_hop_budget_exhausts(self, gossiper):
        cl = Cluster(n_servers=3, wire="ideal")
        for i, pe in enumerate(cl.pes()):
            pe.register_region("gossip_log", np.zeros(2, I32))
            pe.register_cap("gossip_meta", np.array([i, 4], I32))
        cl.toolchain.publish(gossiper)
        cl.client.send_ifunc("server0", "gossiper", np.array([0, 5], I32))
        cl.drain()
        logs = [pe.region("gossip_log").tolist() for pe in cl.pes()]
        assert logs == [[1, 5], [0, 0], [0, 0], [0, 0]]  # no budget, no hop

    def test_propagate_abi_batched_fold_matches_sequential(self):
        """The propagate-ABI masked scan: N partials retired in one
        dispatch produce the same accumulator and the same single
        completing action as N per-message invokes."""
        reducer = _reducer_for_width(2)
        results = {}
        for batching in (False, True):
            cl = Cluster(n_servers=1, wire="ideal")
            pe = cl.servers[0]
            pe.batching = batching
            pe.register_region("reduce_acc", np.zeros(3, I32))
            pe.register_region("reduce_src", np.array([10, 20], I32))
            # expected 4 contributions; parent = client (idx 1); not root
            pe.register_cap("reduce_meta", np.array([4, 1, 0], I32))
            cl.toolchain.publish(reducer)
            cl.client.register_region("reduce_acc", np.zeros(3, I32))
            cl.client.register_region("reduce_src", np.zeros(2, I32))
            cl.client.register_cap("reduce_meta", np.array([99, 1, 1], I32))
            for pay in ([0, 0, 0], [1, 5, 6], [1, 7, 8], [1, 100, 200]):
                cl.client.send_ifunc("server0", "reducer", np.array(pay, I32))
            pe.poll()
            if batching:
                pe.flush()
            results[batching] = (
                pe.region("reduce_acc").copy(),
                pe.stats.forwards,
                pe.stats.invokes,
            )
        np.testing.assert_array_equal(results[False][0], results[True][0])
        np.testing.assert_array_equal(results[False][0], [4, 122, 234])
        assert results[False][1] == results[True][1] == 1  # one upward FORWARD
        assert results[True][2] < results[False][2]  # and fewer dispatches

    def test_propagate_abi_padding_rows_are_nops(self):
        """3 payloads pad to a bucket of 4: the padded row must contribute
        neither to the fold nor an action (edge-repeat padding would
        otherwise double-count the last partial)."""
        reducer = _reducer_for_width(2)
        cl = Cluster(n_servers=1, wire="ideal")
        pe = cl.servers[0]
        pe.batching = True
        pe.register_region("reduce_acc", np.zeros(3, I32))
        pe.register_region("reduce_src", np.array([1, 1], I32))
        pe.register_cap("reduce_meta", np.array([100, 1, 0], I32))
        cl.toolchain.publish(reducer)
        for pay in ([1, 2, 3], [1, 4, 5], [1, 6, 7]):
            cl.client.send_ifunc("server0", "reducer", np.array(pay, I32))
        pe.poll()
        np.testing.assert_array_equal(pe.region("reduce_acc"), [3, 12, 15])
        assert pe.stats.forwards == 0  # far from expected: no action at all


# ===================================================== workload integration
class TestWorkloadPropagation:
    def test_dapc_tree_distribution_oracle_identical(self):
        cl = Cluster(n_servers=4, wire="ideal")
        app = PointerChaseApp(cl, n_entries=512, max_slots=16, seed=3)
        starts = np.random.default_rng(3).integers(0, 512, 8).astype(I32)
        rep = app.dapc(starts, 32, mode="bitcode", propagation=BINOMIAL)
        want = [chase_ref(app.table, s, 32) for s in starts]
        assert rep.results.tolist() == want
        assert rep.hop_frames == 4  # one hop per server

    def test_dapc_tree_fewer_client_code_sends(self):
        """The conformance-matrix dispatch claim on cold clusters: tree
        distribution sends strictly fewer client code frames than flat."""
        counts = {}
        starts = np.array([0, 130, 260, 390], I32)  # one start per shard
        for arm, prop in (("flat", None), ("tree", BINOMIAL)):
            cl = Cluster(n_servers=4, wire="ideal")
            app = PointerChaseApp(cl, n_entries=512, max_slots=8, seed=0)
            rep = app.dapc(starts, 16, mode="bitcode", propagation=prop)
            assert rep.results.tolist() == [
                chase_ref(app.table, s, 16) for s in starts
            ]
            counts[arm] = cl.client.stats.code_sends
        assert counts["tree"] < counts["flat"]
        assert counts["flat"] == 4 and counts["tree"] == 3

    def test_gather_tree_distribution_oracle_identical(self):
        cl = Cluster(n_servers=4, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=8)
        batches = ragged_batches(64, 8, 4, seed=2)
        rep = svc.gather(batches, propagation=BINOMIAL)
        for got, want in zip(rep.results, svc.oracle(batches)):
            np.testing.assert_array_equal(got, want)
        assert rep.hop_frames == 4

    def test_dapc_tree_distribution_survives_dead_midtree_server(self):
        """Code distribution on a degraded cluster: server1 (a mid-tree
        node whose subtree holds server2) is dead, yet dapc with tree
        propagation completes for every chase that never visits the
        corpse's shard — the shared distribute_code re-parents the
        orphaned survivors instead of timing out."""
        cl = Cluster(n_servers=4, wire="ideal")
        app = PointerChaseApp(cl, n_entries=512, max_slots=8, seed=0)
        cl.kill_server(1)
        # a chain table confined to shard 0 (rows 0..127): never leaves it
        table = np.arange(512, dtype=I32)
        table[:128] = np.roll(np.arange(128, dtype=I32), -1)
        app.table[:] = table
        for i, pe in enumerate(cl.servers):
            if pe.endpoint.alive:
                pe.region("table_shard")[:] = table[i * 128 : (i + 1) * 128]
                pe.endpoint.touch_region("table_shard")
        starts = np.array([0, 5, 17], I32)
        rep = app.dapc(starts, 16, mode="bitcode", propagation=BINOMIAL)
        want = [chase_ref(table, s, 16) for s in starts]
        assert rep.results.tolist() == want
        # the corpse's shard is simply absent; the survivors are all warm
        digest = cl.toolchain.lookup("chaser").digest.hex()
        for idx in (0, 2, 3):
            assert cl.servers[idx].target_cache.lookup_digest(digest) is not None

    def test_gather_kill_after_distribution_leaks_no_cq_slots(self):
        """Tree-distribute, then kill a shard owner mid-burst: the lost
        requests surface as TimeoutError, cancelling their futures returns
        every completion-queue slot (no leaked slots, no stale installs
        consulted)."""
        cl = Cluster(n_servers=4, wire="ideal")
        svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=2, max_slots=8)
        svc.distribute_code(BINOMIAL)
        cl.kill_server(2)
        # [1] resolves at server0; [1, 33]'s remainder FORWARDs to the dead
        # server2 (EndpointDead at the forwarding hop); [50] resolves at 3
        for keys in ([1], [1, 33], [50]):
            svc.submit(np.array(keys, I32))
        from repro.core import EndpointDead

        errors, idle = 0, False
        for _ in range(50):
            try:
                svc.run()
                break
            except EndpointDead:
                errors += 1  # the forward to the corpse, surfaced loudly
            except TimeoutError:
                idle = True  # lost request detected by idleness
                break
        assert errors >= 1 and idle
        for req in list(svc.active.values()):
            req.future.cancel()
        svc.active.clear()
        assert svc.cq.free_slots == svc.max_slots
        # the two resolvable requests completed despite the corpse
        assert sorted(r.keys[0] for r in svc.finished) == [1, 50]


# ============================================================ restart story
class TestRestartInvalidation:
    def test_restart_server_invalidates_every_sender(self, tsi):
        """Regression (ISSUE 4 satellite): Cluster.restart_server must drop
        every peer's sender-cache entries for the restarted endpoint —
        otherwise the next send ships a digest-only frame the fresh PE
        cannot decode.  After the fix the next send simply re-pays the code
        frame and works, no ProtocolError, no manual invalidation."""
        cl = counter_cluster(tsi, n_servers=2)
        cl.client.send_ifunc("server0", "tsi", np.ones(1, I32))
        cl.drain()
        assert cl.client.sender_cache.has("server0", tsi.digest.hex())
        cl.kill_server(0)
        pe = cl.restart_server(0)
        pe.register_region("counter", np.zeros(1, I32))
        assert not cl.client.sender_cache.has("server0", tsi.digest.hex())
        code0 = cl.client.stats.code_sends
        cl.client.send_ifunc("server0", "tsi", np.ones(1, I32))
        pe.poll()  # decodes fine: the frame carried code again
        assert pe.region("counter")[0] == 1
        assert cl.client.stats.code_sends == code0 + 1

    def test_restarted_publisher_not_deduped_as_its_former_self(self, tsi):
        """A restarted PE re-mints pub_ids from zero.  Peers must drop the
        dedup keys of its previous life on restart, or its fresh publishes
        of already-seen code collide with stale (digest, root, pub_id)
        entries and are silently swallowed — exactly-once would become
        at-most-zero."""
        cl = counter_cluster(tsi, n_servers=2)
        # server0 (peer index 0) publishes as a root: pub_id 1 of its life 1
        cl.servers[0].publish_to("server1", "tsi", np.array([2], I32), ttl=1)
        cl.drain()
        assert counters(cl)[1] == 2
        cl.kill_server(0)
        pe = cl.restart_server(0)
        pe.register_region("counter", np.zeros(1, I32))
        # life 2 re-mints pub_id 1 for the same digest and root index
        pe.publish_to("server1", "tsi", np.array([3], I32), ttl=1)
        cl.drain()
        assert cl.servers[1].stats.publish_dupes == 0
        assert counters(cl)[1] == 5  # the fresh publish really ran

    def test_restart_invalidates_server_side_senders_too(self, tsi):
        """Server-to-server sender caches (FORWARD/publish paths) go stale
        on a restart exactly like the client's: the fix must invalidate
        every PE, not just the client."""
        cl = counter_cluster(tsi, n_servers=3)
        # warm server1 -> server2 via a relayed publish (server1 re-publishes)
        xrdma_bcast(cl, "tsi", np.array([1], I32),
                    config=PropagationConfig(topology="kary", k=1))
        assert cl.servers[1].sender_cache.has("server2", tsi.digest.hex())
        cl.kill_server(2)
        cl.restart_server(2)
        assert not cl.servers[1].sender_cache.has("server2", tsi.digest.hex())
