"""`hypothesis` when available, a tiny deterministic fallback when not.

The container that runs tier-1 may not ship `hypothesis`; rather than
skipping whole modules (which would silently drop every non-property test
in them too), property tests import ``given``/``settings``/``st`` from here.
The fallback drives each property with ``max_examples`` pseudo-random
samples from a fixed-seed generator — no shrinking, no database, but the
same assertions run everywhere and failures are reproducible.
"""

from __future__ import annotations

try:  # the real thing, when present
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # sample(rng) -> value

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_ignored):
            # log-uniform when both bounds are positive (matches how the
            # tests use it: scales spanning decades), uniform otherwise
            if min_value > 0 and max_value > 0:
                lo, hi = np.log(min_value), np.log(max_value)
                return _Strategy(lambda rng: float(np.exp(rng.uniform(lo, hi))))
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def binary(min_size=0, max_size=64):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return rng.bytes(n)

            return _Strategy(sample)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=8):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]

            return _Strategy(sample)

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NB: runner takes no parameters and hides fn's signature, so
            # pytest does not mistake the drawn arguments for fixtures.
            def runner():
                rng = np.random.default_rng(0xB17C0DE)
                for _ in range(getattr(runner, "_max_examples", 10)):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**drawn)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner._max_examples = getattr(fn, "_max_examples", 10)
            return runner

        return deco
