"""Progress-engine contracts: priority lanes, poll budgets, per-peer
credit windows, and CQ-backpressure admission.

The knobs all default *off* (bit-compatible with the pre-layered runtime),
so every test here turns one on deliberately and checks both the scheduling
effect (what the knob buys) and the invariants that must survive it
(exactly-once publish invokes, oracle-identical gather/dapc results, no
leaked slots/credits after faults).
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.core import Cluster, make_tsi
from repro.core.pointer_chase import PointerChaseApp, chase_ref
from repro.runtime.embed_service import EmbedShardService, ragged_batches

I32 = np.int32


@pytest.fixture(scope="module")
def tsi():
    return make_tsi()


def counter_cluster(tsi, n_servers=1, **_):
    cl = Cluster(n_servers=n_servers, wire="ideal")
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, I32))
    cl.toolchain.publish(tsi)
    return cl


def counter(cl, i=0) -> int:
    return int(cl.servers[i].region("counter")[0])


# ---------------------------------------------------------------- lanes
class TestPriorityLanes:
    def _loaded_server(self, tsi, n_data=20):
        """A server with a data backlog and one PUBLISH hop behind it.
        The code is distributed first (and the backlog built afterwards)
        so the hop is digest-only *and* resolvable — the control lane only
        promotes self-contained frames."""
        cl = counter_cluster(tsi)
        srv = cl.servers[0]
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.drain()  # code installed, sender cache warm
        for _ in range(n_data):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.client.publish_ifunc("tsi", np.array([100], I32))
        srv.batching = True
        srv.poll_budget = 4
        return cl, srv

    def test_control_jumps_the_data_backlog(self, tsi):
        cl, srv = self._loaded_server(tsi)
        srv.lanes = True
        srv.poll()
        # the hop was handled in the first budgeted poll even though 20
        # data payloads arrived ahead of it...
        assert srv.stats.publish_handled == 1
        # ...and the data backlog is still pending (budget spent on it only
        # after control drained)
        assert srv.progress.pending() > 0
        cl.drain()
        assert counter(cl) == 21 + 100  # nothing lost, nothing doubled

    def test_fifo_without_lanes(self, tsi):
        cl, srv = self._loaded_server(tsi)
        srv.lanes = False
        srv.poll()
        # FIFO: the budget went to the data frames that arrived first
        assert srv.stats.publish_handled == 0
        cl.drain()
        assert counter(cl) == 21 + 100

    def test_cold_digest_only_hop_stays_in_fifo_order(self, tsi):
        """A hop that depends on an earlier code-carrying data frame must
        NOT be promoted past it: the first tsi send carries the code, the
        publish right behind it is digest-only (warm sender cache), and
        the control lane declines frames it cannot yet resolve — no
        spurious stale-cache refusal, exactly-once invoke."""
        cl = counter_cluster(tsi)
        srv = cl.servers[0]
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))  # carries code
        cl.client.publish_ifunc("tsi", np.array([100], I32))  # digest-only hop
        srv.batching = True
        srv.lanes = True
        srv.poll_budget = 1  # one payload per poll: order is observable
        srv.poll()
        assert counter(cl) == 1  # the code-carrying data frame went first
        assert srv.stats.publish_handled == 0
        cl.drain()
        assert counter(cl) == 101
        assert srv.stats.publish_handled == 1
        assert srv.stats.publish_refused_digest == 0


# --------------------------------------------------------------- budget
class TestPollBudget:
    def test_budget_bounds_per_poll_work(self, tsi):
        cl = counter_cluster(tsi)
        srv = cl.servers[0]
        for _ in range(12):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        srv.batching = True
        srv.poll_budget = 4
        seen = []
        for _ in range(3):
            srv.poll()
            seen.append(counter(cl))
        assert seen == [4, 8, 12]

    def test_partial_consumption_of_one_coalesced_frame(self, tsi):
        """A coalesced frame larger than the budget is consumed across
        polls at exactly ``budget`` payloads per tick — one burst cannot
        blow through the bound — and the fold stays exact."""
        cl = counter_cluster(tsi)
        srv = cl.servers[0]
        cl.client.batching = True
        for _ in range(12):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.client.flush()  # one 12-payload frame
        assert len(srv.endpoint.inbox) == 1
        srv.batching = True
        srv.poll_budget = 5
        seen = []
        for _ in range(3):
            srv.poll()
            seen.append(counter(cl))
        assert seen == [5, 10, 12]

    def test_mode_switch_mid_partial_frame_is_exactly_once(self, tsi):
        """Switching batching off while a coalesced frame sits partially
        consumed at the lane head must not re-invoke the payloads the
        budgeted batched poll already retired."""
        cl = counter_cluster(tsi)
        srv = cl.servers[0]
        cl.client.batching = True
        for _ in range(4):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.client.flush()  # one 4-payload frame
        srv.batching = True
        srv.poll_budget = 2
        srv.poll()
        assert counter(cl) == 2  # payloads 0-1 retired, offset recorded
        srv.batching = False  # mode switch with the frame still pending
        srv.poll()
        assert counter(cl) == 4  # payloads 2-3 only — never 6

    def test_budget_none_is_drain_all(self, tsi):
        cl = counter_cluster(tsi)
        for _ in range(7):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        cl.servers[0].poll()
        assert counter(cl) == 7


# -------------------------------------------------------------- credits
class TestCreditWindow:
    def test_window_exactly_full_no_stall(self, tsi):
        cl = counter_cluster(tsi)
        cl.client.credit_window = 4
        for _ in range(4):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.fabric.stats.credit_stalls == 0
        assert cl.client.wire.queued_credit_frames() == 0
        assert len(cl.servers[0].endpoint.inbox) == 4

    def test_one_beyond_window_stalls_then_recovers(self, tsi):
        cl = counter_cluster(tsi)
        cl.client.credit_window = 4
        for _ in range(5):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.fabric.stats.credit_stalls == 1
        assert cl.client.stats.credit_stalls == 1
        assert cl.client.wire.queued_credit_frames("server0") == 1
        assert len(cl.servers[0].endpoint.inbox) == 4  # the peer was not flooded
        cl.servers[0].poll()  # processes 4, returns their credits
        assert counter(cl) == 4
        assert cl.client.poll() > 0  # the pump counts as progress
        assert cl.client.wire.queued_credit_frames() == 0
        cl.servers[0].poll()
        assert counter(cl) == 5  # nothing lost

    def test_later_frames_queue_behind_stalled_ones(self, tsi):
        """Per-destination FIFO holds: once one frame stalls, every later
        data frame queues behind it even if a credit freed meanwhile."""
        cl = counter_cluster(tsi)
        cl.client.credit_window = 2
        for _ in range(4):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.client.wire.queued_credit_frames("server0") == 2
        cl.drain()
        assert counter(cl) == 4

    def test_control_frames_bypass_the_window(self, tsi):
        cl = counter_cluster(tsi)
        cl.client.credit_window = 1
        cl.client.send_ifunc("server0", "tsi", np.array([1], I32))  # window full
        stalls0 = cl.fabric.stats.credit_stalls
        sent = cl.client.publish_ifunc("tsi", np.array([10], I32))
        assert sent == ["server0"]  # the hop went out immediately
        assert cl.fabric.stats.credit_stalls == stalls0
        cl.drain()
        assert counter(cl) == 11

    def test_stalled_frames_dropped_when_peer_dies(self, tsi):
        cl = counter_cluster(tsi)
        cl.client.credit_window = 2
        for _ in range(4):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.client.wire.queued_credit_frames("server0") == 2
        cl.kill_server(0)
        cl.client.poll()  # pump hits the dead endpoint
        assert cl.client.wire.queued_credit_frames("server0") == 0
        assert cl.client.stats.credit_dropped == 2

    def test_kill_returns_credits_for_unprocessed_frames(self, tsi):
        """A dead peer's inbox drops its frames — the sender's window must
        reopen (a restarted peer starts empty), or the flow deadlocks."""
        cl = counter_cluster(tsi)
        cl.client.credit_window = 2
        for _ in range(2):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.fabric.credit_outstanding("client", "server0") == 2
        cl.kill_server(0)
        assert cl.fabric.credit_outstanding("client", "server0") == 0
        cl.restart_server(0)
        cl.servers[0].register_region("counter", np.zeros(1, I32))
        for _ in range(2):
            cl.client.send_ifunc("server0", "tsi", np.array([1], I32))
        assert cl.fabric.stats.credit_stalls == 0  # the window was fresh
        cl.drain()
        assert counter(cl) == 2


# ----------------------------------------------- CQ-backpressure admission
class TestAdmissionControl:
    def make_service(self, max_slots=4, n_servers=2):
        cl = Cluster(n_servers=n_servers, wire="ideal")
        return EmbedShardService(
            cl, vocab=64, dim=4, n_keys=4, max_slots=max_slots, seed=1
        )

    def test_full_cq_never_kills_inflight_requests(self):
        """Regression for the pre-layering behaviour where slot exhaustion
        raised mid-batch: 3x more requests than slots now saturate the CQ
        (observed), nothing raises, and every request completes exactly."""
        svc = self.make_service(max_slots=4)
        cl = svc.cluster
        batches = ragged_batches(svc.vocab, 12, svc.n_keys, seed=2)
        for b in batches:
            svc.submit(b)
        saturated = False
        rounds = 0
        while svc.queue or svc.active:
            svc._admit()
            # observe saturation between admission and the polls that
            # retire completions (an ideal wire completes within the tick)
            saturated = saturated or (
                svc.cq.free_slots == 0 and len(svc.queue) > 0
            )
            for pe in cl.alive_pes():
                pe.poll()
            svc._retire()
            rounds += 1
            assert rounds < 10_000
        assert saturated, "test never saturated the CQ — shrink max_slots"
        assert svc.cq.free_slots == 4
        got = {r.rid: r.rows for r in svc.finished}
        for rid, want in enumerate(svc.oracle(batches)):
            np.testing.assert_array_equal(got[rid], want)

    def test_cancel_under_exhaustion_releases_exactly_one_slot(self):
        svc = self.make_service(max_slots=3)
        cl = svc.cluster
        futs = [
            cl.client.submit("server0", "gatherer",
                             svc._pad(np.array([k], I32)), svc.cq, expected=1)
            for k in (1, 2, 3)
        ]
        assert svc.cq.free_slots == 0
        assert cl.client.submit("server0", "gatherer",
                                svc._pad(np.array([4], I32)),
                                svc.cq, expected=1) is None
        futs[1].cancel()
        assert svc.cq.free_slots == 1  # exactly one slot came back
        futs[1].cancel()  # idempotent: no double release
        assert svc.cq.free_slots == 1
        fut = cl.client.submit("server0", "gatherer",
                               svc._pad(np.array([4], I32)), svc.cq, expected=1)
        assert fut is not None
        assert svc.cq.free_slots == 0
        cl.run_until(fut.done)
        np.testing.assert_array_equal(fut.result()[0], svc.table[4])
        # the cancelled slot's late RETURN (if any) cannot corrupt: drain
        # and check the other in-flight futures still complete correctly
        cl.run_until(lambda: futs[0].done() and futs[2].done())
        np.testing.assert_array_equal(futs[0].result()[0], svc.table[1])
        np.testing.assert_array_equal(futs[2].result()[0], svc.table[3])


# ----------------------------------------------------- property: invariants
@settings(max_examples=4, deadline=None)
@given(
    lanes=st.sampled_from([False, True]),
    budget=st.sampled_from([2, 5, None]),
    window=st.sampled_from([0, 3, 16]),
    publish_tick=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**20),
)
def test_any_interleaving_preserves_gather_and_publish_invariants(
    lanes, budget, window, publish_tick, seed
):
    """Any combination of lanes/budget/credits, any publish timing: gather
    results stay bit-identical to the take oracle and the concurrent tree
    publish invokes exactly once per server."""
    cl = Cluster(n_servers=4, wire="ideal")
    svc = EmbedShardService(cl, vocab=64, dim=4, n_keys=4, max_slots=4, seed=3)
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, I32))
    cl.toolchain.publish(make_tsi())
    batches = ragged_batches(svc.vocab, 10, svc.n_keys, seed=seed)
    want = svc.oracle(batches)
    cl.set_batching(True)
    svc.batching = True
    cl.set_flow(lanes=lanes, credit_window=window, poll_budget=budget)
    for b in batches:
        svc.submit(b)
    tick = 0
    published = False
    while svc.queue or svc.active or not published or any(
        int(pe.region("counter")[0]) != 9 for pe in cl.servers
    ):
        tick += 1
        if tick == publish_tick:
            cl.client.publish_ifunc("tsi", np.array([9], I32))
            published = True
        svc.tick()
        assert tick < 10_000
    counters = [int(pe.region("counter")[0]) for pe in cl.servers]
    assert counters == [9] * 4  # exactly-once, no dupes, no losses
    got = {r.rid: r.rows for r in svc.finished}
    for rid, w in enumerate(want):
        np.testing.assert_array_equal(got[rid], w)


@settings(max_examples=3, deadline=None)
@given(
    budget=st.sampled_from([3, None]),
    window=st.sampled_from([0, 4]),
    seed=st.integers(min_value=0, max_value=2**20),
)
def test_dapc_oracle_identical_under_flow_knobs(budget, window, seed):
    """The pointer chase retires oracle-identical under any budget/credit
    configuration (the knobs change scheduling, never results)."""
    rng = np.random.default_rng(seed)
    cl = Cluster(n_servers=4, wire="ideal")
    app = PointerChaseApp(cl, n_entries=64, max_slots=16, seed=7)
    cl.set_flow(lanes=True, credit_window=window, poll_budget=budget)
    starts = rng.integers(0, 64, size=8).astype(I32)
    depth = 12
    rep = app.dapc(starts, depth, batching=True)
    want = [chase_ref(app.table, s, depth) for s in starts]
    assert rep.results.tolist() == want
