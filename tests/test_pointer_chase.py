"""DAPC / GBPC / AM pointer-chase integration tests (paper Secs. IV-C/D)."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis, or local fallback

from repro.core import Cluster, PointerChaseApp, chase_ref, make_chain


@pytest.fixture(scope="module")
def app():
    cluster = Cluster(n_servers=4, wire="thor_bf2")
    return PointerChaseApp(cluster, n_entries=1024, max_slots=64, seed=42)


def expected(app, starts, depth):
    return np.array([chase_ref(app.table, s, depth) for s in starts], np.int32)


class TestChainConstruction:
    def test_chain_is_single_cycle(self):
        t = make_chain(256, seed=1)
        seen, a = set(), 0
        for _ in range(256):
            assert a not in seen
            seen.add(a)
            a = int(t[a])
        assert a == 0 and len(seen) == 256


class TestDeepChase:
    """Mode-by-mode oracle agreement lives in test_conformance.py (the
    single parametrized {mode} x {batching} x {seed} matrix); this keeps
    only the depth-300 case that exceeds the conformance matrix's range."""

    def test_dapc_deep(self, app):
        starts = np.arange(8) * 100 % app.n_entries
        rep = app.dapc(starts, 300, mode="bitcode")
        np.testing.assert_array_equal(rep.results, expected(app, starts, 300))


class TestTrafficShape:
    """The paper's scalability argument, as byte/op accounting."""

    def test_gbpc_ops_scale_with_depth(self, app):
        depth = 32
        rep = app.gbpc(np.array([5]), depth)
        assert rep.gets == depth  # one round trip per hop, always
        assert rep.puts == 0

    def test_dapc_network_ops_only_on_locality_breaks(self, app):
        depth = 32
        rep = app.dapc(np.array([5], np.int32), depth, mode="bitcode")
        # puts = initial inject + forwards + 1 return <= depth+2, and in
        # expectation ~ depth * (n_servers-1)/n_servers + 2
        assert rep.puts <= depth + 2
        start_owner_hops = rep.puts - 2
        assert 0 <= start_owner_hops <= depth

    def test_dapc_cached_beats_uncached_bytes(self, app):
        starts = np.arange(4, dtype=np.int32)
        app.cluster.client.caching_enabled = True
        warm = app.dapc(starts, 16, mode="bitcode")  # caches already warm
        for pe in app.cluster.pes():
            pe.caching_enabled = False
        try:
            cold = app.dapc(starts, 16, mode="bitcode")
        finally:
            for pe in app.cluster.pes():
                pe.caching_enabled = True
        assert cold.put_bytes > warm.put_bytes * 5  # code bytes dominate

    def test_am_frames_smaller_than_uncached_ifunc(self, app):
        starts = np.arange(4, dtype=np.int32)
        rep_am = app.dapc(starts, 16, mode="am")
        per_msg_am = rep_am.put_bytes / rep_am.puts
        assert per_msg_am < 120  # payload-only frames


_PROP_APP_CACHE: dict = {}


@settings(max_examples=10, deadline=None)
@given(
    depth=st.integers(min_value=1, max_value=200),
    start=st.integers(min_value=0, max_value=1023),
)
def test_dapc_matches_oracle_property(depth, start):
    """Property: for any (start, depth), DAPC == numpy oracle == GBPC."""
    if "app" not in _PROP_APP_CACHE:
        cluster = Cluster(n_servers=8, wire="ideal")
        _PROP_APP_CACHE["app"] = PointerChaseApp(cluster, n_entries=512, max_slots=8, seed=7)
    app = _PROP_APP_CACHE["app"]
    start %= app.n_entries
    want = chase_ref(app.table, start, depth)
    got_dapc = app.dapc(np.array([start], np.int32), depth, mode="bitcode").results[0]
    got_gbpc = app.gbpc(np.array([start], np.int32), depth).results[0]
    assert got_dapc == want == got_gbpc
