"""Autotuner suite (PR 9): determinism, profile round trip, live loading.

Contracts pinned here:

* **Determinism** — same trace + same wire + same seed yields a
  bit-identical :class:`FlowProfile` (and identical search history), in
  memory and across a serialize/load cycle of the trace.
* **Profile round trip** — ``FlowProfile`` survives ``save``/``load``
  exactly; malformed profile files raise :class:`ProfileError`, never
  ``KeyError``/``JSONDecodeError``.
* **Live loading** — ``Cluster.set_flow(profile=<path>)`` installs every
  knob on every PE from the plain-JSON artifact, explicit kwargs win, and
  a tuned profile improves live ``modeled_us`` over the default runtime
  with oracle-identical results (the benchmark's claim, at test scale).
"""

import json

import numpy as np
import pytest

from repro.analysis import (
    KNOB_GRID,
    FlowProfile,
    ProfileError,
    ReplayModel,
    TraceError,
    autotune,
    capture,
    load_trace,
    replay_stats,
    save_trace,
)
from repro.analysis.autotune import RNDV_OFF
from repro.core import Cluster, PointerChaseApp, chase_ref

I32 = np.int32


@pytest.fixture(scope="module")
def captured():
    """One warm dapc run captured under the default runtime (the trace
    shape ``benchmarks/autotune.py`` feeds the tuner)."""
    cl = Cluster(n_servers=4, wire="thor_xeon")
    app = PointerChaseApp(cl, n_entries=512, max_slots=16, seed=0)
    rng = np.random.default_rng(1)
    starts = rng.integers(0, 512, 16).astype(I32)
    app.dapc(starts, 16)
    app.dapc(starts, 16, batching=True)
    with capture(cl) as rec:
        rep = app.dapc(starts, 16)
    want = np.array([chase_ref(app.table, s, 16) for s in starts], I32)
    np.testing.assert_array_equal(rep.results, want)
    return rec, rep.modeled_us


# ------------------------------------------------------------ determinism
def test_autotune_is_deterministic(captured):
    rec, _ = captured
    a = autotune(rec, seed=0)
    b = autotune(rec, seed=0)
    assert a.profile == b.profile
    assert a.as_dict() == b.as_dict()  # history, knob order, costs — all of it


def test_autotune_deterministic_across_serialization(captured, tmp_path):
    rec, _ = captured
    path = str(tmp_path / "run.jsonl")
    save_trace(rec, path)
    from_file = autotune(load_trace(path), seed=0)
    from_memory = autotune(rec, seed=0)
    assert from_file.as_dict() == from_memory.as_dict()


def test_seed_changes_knob_order_not_validity(captured):
    rec, _ = captured
    a = autotune(rec, seed=0)
    b = autotune(rec, seed=7)
    assert a.knob_order != b.knob_order  # the permutation really is seeded
    # both must still strictly beat the default on the replay estimate
    assert a.tuned_us < a.default_us
    assert b.tuned_us < b.default_us


def test_tuned_beats_default_on_replay(captured):
    rec, live_default_us = captured
    rep = autotune(rec, seed=0)
    model = ReplayModel(rec)
    # the default-profile estimate is exact: it re-prices the captured run
    assert model.cost(FlowProfile(wire="thor_xeon")) == pytest.approx(
        live_default_us, abs=1e-6
    )
    assert rep.default_us == pytest.approx(live_default_us, abs=1e-6)
    assert rep.tuned_us < rep.default_us
    assert rep.improvement_pct > 0
    assert rep.evaluations >= sum(len(v) for v in KNOB_GRID.values())


def test_autotune_unknown_wire_raises(captured):
    rec, _ = captured
    with pytest.raises(TraceError, match="unknown wire"):
        autotune(rec, wire="warp_drive")


# ------------------------------------------------------ profile round trip
def test_flowprofile_save_load_roundtrip(tmp_path):
    p = FlowProfile(
        wire="thor_bf2",
        batching=True,
        lanes=True,
        credit_window=16,
        poll_budget=8,
        eager_max=64,
        rndv_min=4096,
        zerocopy=True,
        k_code=3,
        tenant_budgets=(("bg", 4), ("hot", 32)),
    )
    path = str(tmp_path / "prof.json")
    p.save(path)
    assert FlowProfile.load(path) == p
    # and the dict form is plain JSON (what Cluster.set_flow consumes)
    assert json.load(open(path))["schema"] == "xrdma-flowprofile/1"


def test_flowprofile_defaults_are_runtime_defaults():
    p = FlowProfile(wire="ideal")
    assert not p.batching and not p.lanes and not p.zerocopy
    assert p.credit_window == 0 and p.poll_budget is None
    assert p.eager_max == 256 and p.rndv_min == RNDV_OFF
    assert p.k_code is None and p.tenant_budgets == ()


@pytest.mark.parametrize(
    "bad",
    [
        {"schema": "xrdma-flowprofile/999"},
        {"schema": "xrdma-flowprofile/1", "credit_window": "many"},
        {"schema": "xrdma-flowprofile/1", "rndv_min": [1]},
        {"schema": "xrdma-flowprofile/1", "tenant_budgets": {"t": "much"}},
        "not a dict",
        42,
    ],
)
def test_malformed_profile_raises_profile_error(bad):
    with pytest.raises(ProfileError):
        FlowProfile.from_dict(bad)


def test_profile_load_errors_are_typed(tmp_path):
    with pytest.raises(ProfileError, match="cannot read"):
        FlowProfile.load(str(tmp_path / "absent.json"))
    p = tmp_path / "garbage.json"
    p.write_text("{nope")
    with pytest.raises(ProfileError, match="invalid JSON"):
        FlowProfile.load(str(p))


# ------------------------------------------------------------ live loading
def test_set_flow_loads_profile_from_disk(tmp_path):
    prof = FlowProfile(
        wire="ideal",
        batching=True,
        lanes=True,
        credit_window=8,
        poll_budget=16,
        eager_max=64,
        rndv_min=4096,
        zerocopy=True,
        k_code=2,
        tenant_budgets=(("bg", 4),),
    )
    path = str(tmp_path / "tuned.json")
    prof.save(path)
    cl = Cluster(n_servers=2, wire="ideal")
    cl.set_flow(profile=path)
    for pe in cl.pes():
        assert pe.batching is True
        assert pe.lanes is True
        assert pe.credit_window == 8
        assert pe.poll_budget == 16
        assert pe.dataplane.eager_max == 64
        assert pe.dataplane.rndv_min == 4096
        assert pe.dataplane.zerocopy is True
        assert pe.propagation.topology == "kary" and pe.propagation.k == 2
        assert pe.wire.tenant_budgets == {"bg": 4}


def test_set_flow_explicit_kwargs_beat_profile():
    cl = Cluster(n_servers=2, wire="ideal")
    prof = FlowProfile(wire="ideal", lanes=True, credit_window=64, poll_budget=8)
    cl.set_flow(lanes=False, credit_window=4, profile=prof.as_dict())
    for pe in cl.pes():
        assert pe.lanes is False  # explicit kwarg won
        assert pe.credit_window == 4  # explicit kwarg won
        assert pe.poll_budget == 8  # profile filled the unset knob


def test_profile_apply_matches_set_flow(tmp_path):
    prof = FlowProfile(wire="ideal", batching=True, lanes=True, credit_window=8)
    a, b = Cluster(n_servers=2, wire="ideal"), Cluster(n_servers=2, wire="ideal")
    prof.apply(a)
    path = str(tmp_path / "p.json")
    prof.save(path)
    b.set_flow(profile=path)
    for pa, pb in zip(a.pes(), b.pes()):
        assert (pa.batching, pa.lanes, pa.credit_window) == (
            pb.batching, pb.lanes, pb.credit_window,
        )


def test_tuned_profile_improves_live_run_oracle_identical(captured, tmp_path):
    """The benchmark's claim at test scale: tune from the captured trace,
    install through the disk loader, and the live tuned run beats the
    live default with bit-identical results."""
    rec, _ = captured
    tuned = autotune(rec, seed=0).profile
    cl = Cluster(n_servers=4, wire="thor_xeon")
    app = PointerChaseApp(cl, n_entries=512, max_slots=16, seed=0)
    rng = np.random.default_rng(1)
    starts = rng.integers(0, 512, 16).astype(I32)
    want = np.array([chase_ref(app.table, s, 16) for s in starts], I32)
    app.dapc(starts, 16)
    app.dapc(starts, 16, batching=True)
    default = app.dapc(starts, 16)
    np.testing.assert_array_equal(default.results, want)
    path = str(tmp_path / "tuned.json")
    tuned.save(path)
    cl.set_flow(profile=path)
    live = app.dapc(starts, 16, batching=tuned.batching, dataplane=tuned.dataplane())
    np.testing.assert_array_equal(live.results, want)
    assert live.modeled_us < default.modeled_us


def test_replay_fidelity_of_tuning_trace(captured):
    """The trace the tuner consumes reproduces the live counters — knob
    decisions are justified by the file alone."""
    rec, live_default_us = captured
    st, _ = replay_stats(rec)
    assert st.modeled_us == pytest.approx(live_default_us, abs=1e-9)
