"""ifunc runtime integration tests: install, cache, invoke, X-RDMA actions."""

import numpy as np
import pytest


from repro.core import (
    FatBitcode,
    FrameKind,
    ISAMismatch,
    ProtocolError,
    Toolchain,
    make_spawner,
    make_tsi,
)
from repro.core.transport import Fabric
from repro.core.ifunc import PE


@pytest.fixture()
def pair():
    """A host client and a DPU-role server on an ideal fabric."""
    fabric = Fabric("ideal")
    tc = Toolchain()
    names = ["server0", "client"]
    server = PE("server0", fabric, triple="cpu-bf2", toolchain=tc, peers=names)
    client = PE("client", fabric, triple="cpu-host", toolchain=tc, peers=names)
    return fabric, client, server


class TestTSI:
    def test_increment_roundtrip(self, pair):
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        client.register_source(make_tsi())
        client.send_ifunc("server0", "tsi", np.array([5], np.int32))
        assert server.poll() == 1
        assert server.region("counter")[0] == 5
        client.send_ifunc("server0", "tsi", np.array([3], np.int32))
        server.poll()
        assert server.region("counter")[0] == 8

    def test_caching_protocol(self, pair):
        """First frame carries code; subsequent frames are truncated; the
        target JITs exactly once (Sec. III-D / Fig. 4)."""
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        tsi = client.register_source(make_tsi())
        n_full = client.send_ifunc("server0", "tsi", np.array([1], np.int32))
        n_cached = client.send_ifunc("server0", "tsi", np.array([1], np.int32))
        assert n_full > n_cached
        assert n_full - n_cached == len(tsi.code_bytes) + len("\n".join(tsi.deps)) + 8
        server.poll()
        assert server.target_cache.stats.jit_compiles == 1
        assert server.stats.invokes == 2
        assert client.sender_cache.stats.hits == 1
        assert client.sender_cache.stats.bytes_saved == len(tsi.code_bytes)

    def test_uncached_mode_resends_code(self, pair):
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        client.register_source(make_tsi())
        client.caching_enabled = False
        n1 = client.send_ifunc("server0", "tsi", np.array([1], np.int32))
        n2 = client.send_ifunc("server0", "tsi", np.array([1], np.int32))
        assert n1 == n2  # full frame every time
        server.poll()
        # target still JITs once: digest cache is independent of the sender
        assert server.target_cache.stats.jit_compiles == 1
        assert server.region("counter")[0] == 2

    def test_truncated_to_unknown_raises(self, pair):
        """A stale sender cache (e.g. after target restart) is a protocol
        error the runtime layer must recover from."""
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        tsi = client.register_source(make_tsi())
        frame = tsi.make_frame(np.array([1], np.int32).tobytes())
        fabric.put("client", "server0", frame.wire_bytes(cached=True))
        with pytest.raises(ProtocolError, match="restarted"):
            server.poll()


class TestBinaryVsBitcode:
    def test_binary_exact_triple_runs(self, pair):
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        client.register_source(make_tsi(targets=("cpu-bf2",), kind=FrameKind.BINARY))
        client.send_ifunc("server0", "tsi", np.array([2], np.int32))
        server.poll()
        assert server.region("counter")[0] == 2

    def test_binary_wrong_triple_is_isa_mismatch(self, pair):
        """The Sec. III-B problem: an x86 .so cannot run on an Arm DPU."""
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        client.register_source(make_tsi(targets=("cpu-host",), kind=FrameKind.BINARY))
        client.send_ifunc("server0", "tsi", np.array([2], np.int32))
        with pytest.raises(ISAMismatch):
            server.poll()

    def test_fat_bitcode_falls_back_by_platform(self, pair):
        """Fat-bitcode with only a cpu-host slice still runs on cpu-bf2:
        same platform, target re-optimizes (Sec. III-C)."""
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, np.int32))
        client.register_source(make_tsi(targets=("cpu-host",)))  # BITCODE kind
        client.send_ifunc("server0", "tsi", np.array([4], np.int32))
        server.poll()
        assert server.region("counter")[0] == 4

    def test_fat_bitcode_multiarch_slices(self):
        """The fat archive really contains one slice per toolchain target."""
        tsi = make_tsi(targets=("cpu-host", "cpu-bf2", "tpu-v5e"))
        fat = FatBitcode.from_bytes(tsi.code_bytes)
        assert fat.triples() == ("cpu-bf2", "cpu-host", "tpu-v5e")
        # tpu slice exists even though it was built on a cpu-only machine
        # (cross-lowering, like building AArch64 bitcode on a Xeon)
        assert len(fat.slices["tpu-v5e"]) > 0


class TestSpawn:
    def test_injected_code_generates_new_code(self, pair):
        """Chain: client injects spawner into server0; the spawner's action
        SPAWNs a TSI ifunc onto the client — recursive propagation."""
        fabric, client, server = pair
        client.register_region("counter", np.zeros(1, np.int32))
        tc_spawner = make_spawner()
        server_tc = server.toolchain
        server_tc.publish(make_tsi())  # artifact available on server's "disk"
        client.register_source(tc_spawner)
        # payload: [dst=client index (=1), increment=9]
        client.send_ifunc("server0", "spawner", np.array([1, 9], np.int32))
        server.poll()  # installs spawner, emits TSI at client
        assert server.stats.spawns == 1
        client.poll()  # installs TSI (code came over the wire), runs it
        assert client.region("counter")[0] == 9
        assert client.target_cache.stats.jit_compiles == 1  # tsi only; spawner ran on server
