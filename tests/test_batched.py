"""Batched X-RDMA runtime: coalesced frames, grouped dispatch, equivalence.

Three layers under test:

* wire — multi-payload frames (``coalesce``/``split_payloads``) and the one-
  ``alpha_us``-per-coalesced-PUT accounting in the fabric's wire model;
* target runtime — N same-type payloads retired by ONE XLA dispatch
  (``PEStats.invokes``), update-ABI payloads folded into the region exactly;
* app — batched ``dapc`` bit-identical to the per-message baseline and to
  the ``chase_ref`` numpy oracle across modes / depths / server counts /
  ragged batch sizes.

Plus the sender-cache regression: truncation is keyed by code *digest*, so
republishing an ifunc under the same name re-ships the new code instead of
silently truncating against the stale executable.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    Cluster,
    Frame,
    FrameFlags,
    FrameKind,
    IFunc,
    PointerChaseApp,
    Toolchain,
    chase_ref,
    coalesce,
    make_tsi,
    split_payloads,
)
from repro.core.ifunc import PE
from repro.core.transport import WIRE_PROFILES, Fabric

I32 = np.int32


# ------------------------------------------------------------- frame layer
def mk(payload, name="foo", digest=b"\xaa" * 32, code=b"C" * 64):
    return Frame(
        kind=FrameKind.BITCODE,
        name=name,
        payload=payload,
        code=code,
        deps=("abi:pure",),
        digest=digest,
    )


class TestMultiPayloadFrame:
    def test_roundtrip(self):
        frames = [mk(bytes([i]) * 8) for i in range(5)]
        batch = coalesce(frames)
        assert batch.flags & FrameFlags.BATCH
        assert batch.n_payloads == 5
        from repro.core.frame import unpack

        got = unpack(batch.pack(), has_code=True)
        assert split_payloads(got) == [f.payload for f in frames]
        assert got.code == frames[0].code

    def test_single_frame_passthrough(self):
        f = mk(b"\x01" * 8)
        assert coalesce([f]) is f
        assert split_payloads(f) == [f.payload]
        assert f.n_payloads == 1

    def test_truncated_batch_is_prefix(self):
        """Coalescing keeps the truncation protocol: cached send is a
        prefix PUT of the same buffer, code travels at most once."""
        batch = coalesce([mk(bytes([i]) * 8) for i in range(4)])
        assert batch.pack()[: batch.cached_nbytes] == batch.wire_bytes(cached=True)
        from repro.core.frame import unpack

        got = unpack(batch.wire_bytes(cached=True), has_code=False)
        assert len(split_payloads(got)) == 4

    def test_mixed_types_refuse_to_coalesce(self):
        with pytest.raises(ValueError, match="not the same ifunc"):
            coalesce([mk(b"x" * 8), mk(b"y" * 8, digest=b"\xbb" * 32)])

    def test_ragged_payloads_refuse_to_coalesce(self):
        with pytest.raises(ValueError, match="ragged"):
            coalesce([mk(b"x" * 8), mk(b"y" * 4)])


class TestCoalescedWireAccounting:
    """One coalesced PUT costs one alpha_us + summed bytes (the whole point)."""

    def test_alpha_amortizes(self):
        wire = WIRE_PROFILES["thor_xeon"]
        frames = [mk(bytes([i]) * 8) for i in range(16)]

        fab_one = Fabric(wire)
        fab_one.connect("dst")
        batch = coalesce(frames)
        buf = batch.wire_bytes(cached=True)
        fab_one.put("src", "dst", buf, n_payloads=batch.n_payloads)
        assert fab_one.stats.coalesced_frames == 1
        assert fab_one.stats.coalesced_payloads == 16
        assert fab_one.stats.modeled_us == pytest.approx(
            wire.alpha_us + len(buf) / wire.beta_Bus
        )

        fab_n = Fabric(wire)
        fab_n.connect("dst")
        for f in frames:
            fab_n.put("src", "dst", f.wire_bytes(cached=True))
        assert fab_n.stats.coalesced_frames == 0
        # 16 alphas vs 1: the batched PUT must save ~15 alphas of latency
        saved = fab_n.stats.modeled_us - fab_one.stats.modeled_us
        assert saved > 14 * wire.alpha_us


# ----------------------------------------------------------- target runtime
@pytest.fixture()
def pair():
    fabric = Fabric("ideal")
    tc = Toolchain()
    names = ["server0", "client"]
    server = PE("server0", fabric, triple="cpu-bf2", toolchain=tc, peers=names)
    client = PE("client", fabric, triple="cpu-host", toolchain=tc, peers=names)
    return fabric, client, server


class TestBatchedDispatch:
    def test_tsi_burst_is_one_dispatch(self, pair):
        """N concurrent TSIs: one coalesced PUT, one XLA dispatch, exact sum."""
        fabric, client, server = pair
        client.batching = server.batching = True
        server.register_region("counter", np.zeros(1, I32))
        client.register_source(make_tsi())
        for v in range(1, 14):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        client.flush()
        server.poll()
        assert server.region("counter")[0] == sum(range(1, 14))
        assert fabric.stats.puts == 1
        assert fabric.stats.coalesced_frames == 1
        assert fabric.stats.coalesced_payloads == 13
        assert server.stats.invokes == 1  # ONE dispatch for 13 payloads
        assert server.stats.batched_invokes == 1
        assert server.stats.invoked_payloads == 13

    def test_batch_frame_on_unbatched_receiver(self, pair):
        """A coalesced frame is valid input for a per-message PE: it splits
        and invokes payload-by-payload (receiver batching is independent)."""
        fabric, client, server = pair
        client.batching = True  # sender coalesces
        server.batching = False  # receiver does not
        server.register_region("counter", np.zeros(1, I32))
        client.register_source(make_tsi())
        for v in (3, 4, 5):
            client.send_ifunc("server0", "tsi", np.array([v], I32))
        client.flush()
        server.poll()
        assert server.region("counter")[0] == 12
        assert server.stats.invokes == 3  # per-payload dispatches

    def test_bucket_padding_bounds_compiles(self, pair):
        """Batched executables are cached per power-of-two bucket: bursts of
        5, 6, 8 payloads share the bucket-8 compile."""
        fabric, client, server = pair
        client.batching = server.batching = True
        server.register_region("counter", np.zeros(1, I32))
        client.register_source(make_tsi())
        total = 0
        for burst in (5, 6, 8, 3):
            for v in range(burst):
                client.send_ifunc("server0", "tsi", np.array([v], I32))
                total += v
            client.flush()
            server.poll()
        assert server.region("counter")[0] == total
        # buckets: 8 (for 5, 6, 8) and 4 (for 3) -> exactly two batched compiles
        assert server.target_cache.batched_compiles == 2


class TestBatchedRobustness:
    def test_ragged_am_payloads_flush_separately(self, pair):
        """Same-name AM frames with different payload sizes must not poison
        the flush: they travel as separate coalesced PUTs."""
        fabric, client, server = pair
        client.batching = server.batching = True
        got = []
        server.am_table["h"] = lambda pe, pay: got.append(pay)
        client.send_am("server0", "h", b"ab")
        client.send_am("server0", "h", b"abcd")
        client.send_am("server0", "h", b"cd")
        client.flush()
        server.poll()
        assert sorted(got) == [b"ab", b"abcd", b"cd"]
        assert fabric.stats.puts == 2  # one 2-payload batch + one single

    def test_bad_frame_does_not_discard_batch(self, pair):
        """A stale-cache frame in a drained batch raises, but every healthy
        frame in the same batch is still invoked first."""
        from repro.core import ProtocolError

        fabric, client, server = pair
        server.batching = True
        server.register_region("counter", np.zeros(1, I32))
        tsi = make_tsi()
        client.register_source(tsi)
        client.send_ifunc("server0", "tsi", np.array([7], I32))
        # truncated frame for an ifunc the server has never seen
        bad = mk(b"\x01" * 8, name="ghost", digest=b"\xdd" * 32)
        fabric.put("client", "server0", bad.wire_bytes(cached=True))
        client.send_ifunc("server0", "tsi", np.array([4], I32))
        with pytest.raises(ProtocolError):
            server.poll()
        assert server.region("counter")[0] == 11  # both healthy payloads ran

    def test_dapc_does_not_leak_batched_mode(self):
        """dapc(batching=True) must restore per-message mode: a later direct
        send on the same cluster goes straight to the wire, not a queue."""
        cl = Cluster(n_servers=2, wire="ideal")
        app = PointerChaseApp(cl, n_entries=128, max_slots=8, seed=5)
        starts = np.arange(4, dtype=I32)
        app.dapc(starts, 7, mode="bitcode", batching=True)
        assert not cl.client.batching
        cl.servers[0].register_region("counter", np.zeros(1, I32))
        cl.client.register_source(make_tsi())
        nbytes = cl.client.send_ifunc("server0", "tsi", np.array([9], I32))
        assert nbytes > 0  # transmitted immediately, not queued
        cl.servers[0].poll()
        assert cl.servers[0].region("counter")[0] == 9


class TestSenderCacheDigestKeying:
    """Regression: republishing an ifunc under the same name with new code
    must re-ship the code — keying truncation by name silently ran stale
    executables on fresh payloads."""

    @staticmethod
    def _ctr(name, scale):
        def entry(payload, counter):
            return counter + scale * payload[0]

        return IFunc.build(
            name=name,
            fn=entry,
            payload_aval=jax.ShapeDtypeStruct((1,), I32),
            dep_avals=(jax.ShapeDtypeStruct((1,), I32),),
            deps=("region:counter",),
            abi="update",
            targets=("cpu-host",),
        )

    def test_republished_code_travels_and_runs(self, pair):
        fabric, client, server = pair
        server.register_region("counter", np.zeros(1, I32))
        client.register_source(self._ctr("ctr", scale=1))
        n_v1_full = client.send_ifunc("server0", "ctr", np.array([5], I32))
        n_v1_cached = client.send_ifunc("server0", "ctr", np.array([5], I32))
        server.poll()
        assert server.region("counter")[0] == 10
        assert n_v1_cached < n_v1_full  # same digest: truncated

        # rebuild under the SAME name with different code (scale 10)
        client.register_source(self._ctr("ctr", scale=10))
        n_v2 = client.send_ifunc("server0", "ctr", np.array([5], I32))
        server.poll()
        # new digest missed the sender cache -> full frame travelled ...
        assert n_v2 > n_v1_cached
        # ... and the target runs the NEW code, not the stale executable
        assert server.region("counter")[0] == 60
        assert server.target_cache.stats.jit_compiles == 2

    def test_republished_code_runs_batched(self, pair):
        fabric, client, server = pair
        client.batching = server.batching = True
        server.register_region("counter", np.zeros(1, I32))
        client.register_source(self._ctr("ctr", scale=1))
        client.send_ifunc("server0", "ctr", np.array([2], I32))
        client.flush()
        server.poll()
        client.register_source(self._ctr("ctr", scale=10))
        for v in (1, 2):
            client.send_ifunc("server0", "ctr", np.array([v], I32))
        client.flush()
        server.poll()
        assert server.region("counter")[0] == 2 + 10 * 3


# ------------------------------------------------------------------- app
class TestBatchedDapcEquivalence:
    """Property-style equivalence: batched == per-message == numpy oracle
    across modes, depths, server counts, and ragged batch sizes."""

    @pytest.mark.parametrize("n_servers", [2, 5])
    @pytest.mark.parametrize("mode", ["bitcode", "binary", "am"])
    def test_modes_match_oracle(self, n_servers, mode):
        cl = Cluster(n_servers=n_servers, wire="ideal")
        app = PointerChaseApp(cl, n_entries=640, max_slots=32, seed=11)
        rng = np.random.default_rng(13)
        for n in (1, 3, 8, 21, 32):  # ragged: exercises several pad buckets
            starts = rng.integers(0, app.n_entries, n).astype(I32)
            for depth in (1, 7, 64):
                want = np.array(
                    [chase_ref(app.table, s, depth) for s in starts], I32
                )
                per_msg = app.dapc(starts, depth, mode=mode, batching=False)
                batched = app.dapc(starts, depth, mode=mode, batching=True)
                np.testing.assert_array_equal(per_msg.results, want)
                np.testing.assert_array_equal(batched.results, want)

    def test_batched_amortizes_at_scale(self):
        """The acceptance numbers: 256 concurrent chases, depth 64, 8
        servers, thor_xeon — >=5x fewer dispatches, >=30% lower modeled
        wire time, bit-identical results."""
        cl = Cluster(n_servers=8, wire="thor_xeon")
        app = PointerChaseApp(cl, n_entries=1 << 14, max_slots=256, seed=0)
        rng = np.random.default_rng(1)
        starts = rng.integers(0, app.n_entries, 256).astype(I32)
        app.dapc(starts, 64, mode="bitcode")  # warm caches/compiles
        base = app.dapc(starts, 64, mode="bitcode", batching=False)
        bat = app.dapc(starts, 64, mode="bitcode", batching=True)
        want = np.array([chase_ref(app.table, s, 64) for s in starts], I32)
        np.testing.assert_array_equal(base.results, want)
        np.testing.assert_array_equal(bat.results, want)
        assert base.invokes >= 5 * bat.invokes
        assert bat.modeled_us <= 0.7 * base.modeled_us
        assert bat.coalesced_frames > 0
        assert bat.coalesced_payloads > bat.coalesced_frames
