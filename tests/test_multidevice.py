"""Multi-device semantics: the sharded paths must compute the SAME numbers
as the single-device references.  Runs in a subprocess with 8 host-platform
devices (the dry-run owns 512; tests keep their own process clean)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

results = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))

# ---- 1. compute-to-data embedding == plain lookup
from repro.models.embedding import embed_c2d, embed_plain
rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(0, 1, (64, 16)), jnp.float32)
ids = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
got = jax.jit(lambda t, i: embed_c2d(t, i, mesh, batch_axes=("data",)))(table, ids)
want = embed_plain(table, ids)
results["embed_c2d"] = float(jnp.max(jnp.abs(got - want)))

# ---- 2. MoE a2a dispatch == scatter reference (same routing decisions)
from repro.models.moe import moe_block_a2a, moe_block_scatter
d, e, f, topk = 16, 8, 32, 2
ks = jax.random.split(jax.random.PRNGKey(1), 5)
x = jax.random.normal(ks[0], (2, 8, d)) * 0.5          # (B=2, S=8): S%4==0
wr = jax.random.normal(ks[1], (d, e)) * 0.3
wi = jax.random.normal(ks[2], (e, d, f)) * 0.3
wg = jax.random.normal(ks[3], (e, d, f)) * 0.3
wo = jax.random.normal(ks[4], (e, f, d)) * 0.3
y1, aux1 = jax.jit(lambda *a: moe_block_a2a(*a, topk=topk, mesh=mesh, capacity_factor=8.0))(x, wr, wi, wg, wo)
y2, aux2 = moe_block_scatter(x, wr, wi, wg, wo, topk, capacity_factor=8.0)
# NOTE: capacity semantics differ at the margin (per-pair vs per-expert
# buckets); with generous capacity both keep every token and must agree.
results["moe_a2a"] = float(jnp.max(jnp.abs(y1 - y2)))
results["moe_aux"] = abs(float(aux1) - float(aux2))

# ---- 3. DAPC shard_map chase == oracle
from repro.sharding.compute_to_data import chase_oracle, dapc_shard_map
n = 4096
perm = rng.permutation(n); table = np.empty(n, np.int32); table[perm] = np.roll(perm, -1)
starts = rng.integers(0, n, 32).astype(np.int32)
got = np.asarray(dapc_shard_map(jnp.asarray(table), jnp.asarray(starts), 17, mesh))
results["dapc"] = int(np.sum(got != chase_oracle(table, starts, 17)))

# ---- 3b. gather shard_map == take oracle (the serving-shape sibling)
from repro.sharding.compute_to_data import gather_ref, gather_shard_map
etab = jnp.asarray(rng.normal(0, 1, (512, 16)), jnp.float32)
gkeys = rng.integers(0, 512, 64).astype(np.int32)
ggot = np.asarray(gather_shard_map(etab, jnp.asarray(gkeys), mesh))
results["gather"] = int(np.sum(ggot != gather_ref(etab, gkeys)))

# ---- 4. sharded train step == single-device train step (loss + params)
from repro.configs import get_config
from repro.models.zoo import ShapeSpec, build_params, make_batch, make_train_step
from repro.optim import AdamW
from repro.optim.adamw import OptState
from repro.sharding.partition import batch_shardings, state_shardings, rules_for_train
cfg = get_config("granite-moe-1b-a400m", smoke=True).replace(n_experts=8, topk=2)
params, axes = build_params(cfg, 0)
opt = AdamW(lr=1e-3)
batch = make_batch(cfg, ShapeSpec("t", 32, 4, "train"), 7)
state0 = {"params": params, "opt": opt.init(params), "step": jnp.int32(0)}
s_plain, m_plain = jax.jit(make_train_step(cfg, opt))(state0, batch)
sh = state_shardings(params, axes, mesh, rules=rules_for_train(cfg, mesh))
b_sh = batch_shardings(batch, mesh)
step = make_train_step(cfg, opt, mesh=mesh)
s_shard, m_shard = jax.jit(step, in_shardings=(sh, b_sh), out_shardings=(sh, None))(state0, batch)
results["train_loss_delta"] = abs(float(m_plain["loss"]) - float(m_shard["loss"]))
pdeltas = [float(jnp.max(jnp.abs(s_plain["params"][k].astype(jnp.float32) -
                                  s_shard["params"][k].astype(jnp.float32)))) for k in params]
results["train_param_delta"] = max(pdeltas)

# ---- 5. attend_sp == attend (odd head count)
from repro.models.attention import attend, attend_sp
q = jax.random.normal(ks[0], (2, 16, 5, 8))
k = jax.random.normal(ks[1], (2, 16, 5, 8))
v = jax.random.normal(ks[2], (2, 16, 5, 8))
pos = jnp.arange(16)
a = attend(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=7)
b = jax.jit(lambda q, k, v: attend_sp(q, k, v, q_pos=pos, k_pos=pos, mesh=mesh,
                                      batch_axes=("data",), chunk=0, causal=True,
                                      window=7))(q, k, v)
results["attend_sp"] = float(jnp.max(jnp.abs(a - b)))

# ---- 6. elastic restore: checkpoint saved once, restored onto a DIFFERENT
# mesh with different shardings (the lost-a-host path)
import tempfile
from repro.checkpoint import restore_state, save_state
from repro.sharding.partition import param_shardings
with tempfile.TemporaryDirectory() as td:
    save_state(td, {"params": params}, step=3)
    like = jax.eval_shape(lambda: {"params": params})
    small_mesh = jax.make_mesh((4, 2), ("data", "model"))  # "lost" devices
    new_sh = {"params": param_shardings(params, axes, small_mesh)}
    restored, step = restore_state(td, like, shardings=new_sh)
    deltas = [float(jnp.max(jnp.abs(restored["params"][k].astype(jnp.float32)
                                    - params[k].astype(jnp.float32))))
              for k in params]
    results["elastic_restore"] = max(deltas)
    results["elastic_step"] = step

print("RESULTS::" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def multidev_results():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, cwd=ROOT, timeout=1200,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULTS::")][-1]
    return json.loads(line[len("RESULTS::"):])


def test_embed_c2d_matches_plain(multidev_results):
    assert multidev_results["embed_c2d"] < 1e-6


def test_moe_a2a_matches_scatter(multidev_results):
    assert multidev_results["moe_a2a"] < 1e-4
    # the aux load-balance loss is estimated per-EP-rank then averaged in
    # the a2a path (the standard EP formulation); product-of-means !=
    # mean-of-products, so it differs from the global estimator by O(0.1)
    # on tiny token counts — a regularizer-choice difference, not a bug
    assert multidev_results["moe_aux"] < 0.2


def test_dapc_shard_map_matches_oracle(multidev_results):
    assert multidev_results["dapc"] == 0


def test_gather_shard_map_matches_oracle(multidev_results):
    """8-way sharded gather_shard_map is bit-identical to the numpy take."""
    assert multidev_results["gather"] == 0


def test_sharded_train_step_matches_plain(multidev_results):
    # loss differs by the aux-estimator term (weight 0.01) and by which
    # tokens hit capacity drops (per-(src,dst) vs per-expert buckets);
    # parameters after one AdamW step must still agree closely
    assert multidev_results["train_loss_delta"] < 0.05
    assert multidev_results["train_param_delta"] < 5e-3


def test_attend_sp_matches_attend(multidev_results):
    assert multidev_results["attend_sp"] < 1e-5


def test_elastic_restore_with_reshard(multidev_results):
    """Unsharded-on-disk leaves restore bit-exactly onto a different mesh."""
    assert multidev_results["elastic_restore"] == 0.0
    assert multidev_results["elastic_step"] == 3
