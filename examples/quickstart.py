"""Quickstart: the Three-Chains runtime in 60 lines.

Builds a 2-server + client cluster over the simulated RDMA fabric, ships a
Target-Side-Increment ifunc (code + payload travel together), watches the
caching protocol truncate the second send, runs an X-RDMA pointer chase,
and demonstrates recursive code propagation (Spawner -> TSI).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    Cluster,
    PointerChaseApp,
    chase_ref,
    make_spawner,
    make_tsi,
)


def main() -> None:
    cl = Cluster(n_servers=2, wire="thor_bf2")  # paper-calibrated wire model
    for pe in cl.servers:
        pe.register_region("counter", np.zeros(1, np.int32))
    cl.toolchain.publish(make_tsi())
    cl.toolchain.publish(make_spawner())

    # --- 1. ship code+data; the first frame carries the fat-bitcode
    n0 = cl.client.send_ifunc("server0", "tsi", np.array([5], np.int32))
    cl.drain()
    n1 = cl.client.send_ifunc("server0", "tsi", np.array([7], np.int32))
    cl.drain()
    print(f"counter on server0 = {cl.servers[0].region('counter')[0]} (want 12)")
    print(f"first send {n0} B (code travels), second {n1} B (cache hit, "
          f"{100 - 100 * n1 // n0}% smaller)")

    # --- 2. injected code that GENERATES new code: Spawner lands on
    # server0 and spawns a TSI onto server1 (recursive propagation)
    cl.client.send_ifunc("server0", "spawner", np.array([1, 42], np.int32))
    cl.drain()
    print(f"counter on server1 = {cl.servers[1].region('counter')[0]} (want 42) "
          f"— code propagated server0 -> server1 without the client")

    # --- 3. X-RDMA pointer chase: compute goes to the data
    app = PointerChaseApp(cl, n_entries=1 << 12, max_slots=8)
    starts = np.arange(8, dtype=np.int32) * 100
    rep = app.dapc(starts, depth=64, mode="bitcode")
    want = [chase_ref(app.table, s, 64) for s in starts]
    assert rep.results.tolist() == want
    print(f"DAPC: 8 chases x depth 64 -> {rep.puts} messages, "
          f"{rep.put_bytes} wire bytes, results verified")
    rep_get = app.gbpc(starts, depth=64)
    print(f"GBPC baseline: {rep_get.gets} GET round-trips, modeled "
          f"{rep_get.modeled_us:.0f} us vs DAPC {rep.modeled_us:.0f} us "
          f"({rep_get.modeled_us / rep.modeled_us:.2f}x slower)")


if __name__ == "__main__":
    main()
