"""End-to-end driver: train a width-reduced gemma2-family LM for a few
hundred steps through the production code path — config zoo,
compute-to-data embedding, AdamW + cosine schedule, token pipeline, async
checkpointing, fault-tolerant driver.

The default is a ~50M config that fits this container's single CPU core
at a few seconds per step; ``--d-model 768 --layers 8`` gives the ~118M
variant (same code path, ~3x the step time here, trivial on real HW).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import json
import math
import time

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt-train-lm")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import DataConfig
    from repro.models.zoo import build_params, param_count
    from repro.optim import AdamW, cosine_schedule
    from repro.runtime import TrainDriver

    d = args.d_model
    cfg = get_config("gemma2-2b").replace(
        name=f"gemma2-mini-d{d}", n_layers=args.layers, d_model=d,
        n_heads=max(d // 64, 4), n_kv_heads=max(d // 128, 2),
        head_dim=64, d_ff=4 * d, vocab=32_000, window=128,
        embed_mult=math.sqrt(float(d)),
        remat=False, attn_chunk=0, microbatch=1,
    )
    n = param_count(build_params(cfg, 0)[0])
    print(f"config {cfg.name}: {n/1e6:.1f}M params")

    driver = TrainDriver(
        cfg,
        ckpt_dir=args.ckpt_dir,
        opt=AdamW(lr=cosine_schedule(6e-4, warmup_steps=30, total_steps=args.steps)),
        data=DataConfig(
            seq_len=args.seq_len, global_batch=args.global_batch, vocab=cfg.vocab
        ),
        ckpt_every=100,
    )
    t0 = time.time()
    report = driver.run(args.steps)
    k = max(len(report.losses) // 10, 1)
    curve = [round(sum(report.losses[i:i+k])/len(report.losses[i:i+k]), 3)
             for i in range(0, len(report.losses), k)]
    out = {
        "params_m": round(n / 1e6, 1),
        "steps": report.steps_run,
        "loss_curve": curve,
        "first_loss": round(report.losses[0], 3),
        "last_loss": round(report.losses[-1], 3),
        "tokens_per_s": round(args.seq_len * args.global_batch / report.step_time_s),
        "wall_min": round((time.time() - t0) / 60, 1),
    }
    print(json.dumps(out))
    # the driver auto-resumes from any committed checkpoint in --ckpt-dir
    # (that is the FT feature); only assert convergence for scratch runs
    if report.steps_run == args.steps:
        head = sum(report.losses[:10]) / 10
        tail = sum(report.losses[-10:]) / 10
        assert tail < head, f"loss must decrease ({head:.3f} -> {tail:.3f})"
    else:
        print(f"(resumed run: {report.steps_run}/{args.steps} fresh steps)")


if __name__ == "__main__":
    main()
