"""X-RDMA Gather: an embedding-shard service, both renderings.

1. the faithful runtime (core/ + runtime/embed_service): the Gatherer
   ifunc really travels, resolves the locally-owned keys next to each
   shard, FORWARDs the remainder to the owning PEs, and partial results
   RETURN out-of-order into the client's completion queue — many gathers
   overlapped in flight, retired through the batched runtime;
2. the compiled SPMD rendering (sharding/compute_to_data.gather_shard_map):
   the steady state of the same algorithm as a shard_map collective with
   the Pallas embed_lookup kernel as the per-shard resolver on TPU.

Run:  PYTHONPATH=src python examples/xrdma_embed_service.py [--tiny]
"""

import argparse

import numpy as np


def runtime_rendering(tiny: bool) -> None:
    from repro.core import Cluster
    from repro.runtime.embed_service import EmbedShardService, ragged_batches

    print("== runtime rendering (code really moves) ==")
    n_servers, vocab, dim, n_req = (2, 128, 8, 12) if tiny else (8, 4096, 32, 256)
    cl = Cluster(n_servers=n_servers, wire="thor_xeon")
    svc = EmbedShardService(
        cl, vocab=vocab, dim=dim, n_keys=8, max_slots=min(64, n_req), seed=0
    )
    batches = ragged_batches(vocab, n_req, svc.n_keys, seed=1)
    want = svc.oracle(batches)

    print(f"{n_req} gather requests x <= {svc.n_keys} keys over {n_servers} shards")
    print("path        net_ops  wire_KB  modeled_us  XLA_dispatches")
    for label, rep in (
        ("get/row", svc.gather_get(batches)),
        ("xrdma", svc.gather(batches, batching=False)),
        ("xrdma+batch", svc.gather(batches, batching=True)),
    ):
        for got, w in zip(rep.results, want):
            assert np.array_equal(got, w), "diverged from numpy take oracle"
        wire_kb = (rep.put_bytes + rep.get_bytes) / 1024
        print(
            f"{label:11s} {rep.network_ops:7d} {wire_kb:8.1f}"
            f" {rep.modeled_us:11.1f} {rep.invokes:15d}"
        )
    print("all paths bit-identical to the numpy take oracle")


def compiled_rendering(tiny: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.sharding.compute_to_data import gather_ref, gather_shard_map

    print("\n== compiled SPMD rendering (steady state: keys move, rows psum) ==")
    vocab, dim, b = (128, 8, 16) if tiny else (4096, 64, 256)
    rng = np.random.default_rng(2)
    table = rng.standard_normal((vocab, dim)).astype(np.float32)
    keys = rng.integers(0, vocab, b).astype(np.int32)
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    got = np.asarray(
        gather_shard_map(jnp.asarray(table), jnp.asarray(keys), mesh)
    )
    assert np.array_equal(got, gather_ref(table, keys))
    print(
        f"gather_shard_map over {jax.device_count()} device(s): {b} keys x "
        f"dim {dim} verified; wire cost = one {dim}-row per key "
        "(table never moves)"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="smoke-test sizes")
    args = ap.parse_args()
    runtime_rendering(args.tiny)
    compiled_rendering(args.tiny)
