"""High-level-language ifuncs: the paper's Julia story (Sec. III-E), here.

The paper integrates Julia by having GPUCompiler.jl extract an LLVM IR
module from a high-level function, which Three-Chains then ships like any
C ifunc.  The JAX analogue is free: ANY traceable python/jnp function IS
the high-level program, and `jax.export` is our GPUCompiler — the same
toolchain call cross-compiles it for every target triple.

The demo is the one the paper's conclusion imagines: "machine-learning
and online-statistics libraries ... for data processing on DPUs".  A
host ships a *normalization + outlier-clip + running-moments* program to
two storage-side DPU PEs; the data never leaves the DPUs — only the code
(once, 5-6 KB) and the per-shard moment summaries (16 B) move.

Run:  PYTHONPATH=src python examples/dpu_preprocessing.py [--tiny]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, IFunc

SHARD = 4096


# ---- the "high-level library code" (think Julia OnlineStats): written in
# plain jnp, no knowledge of frames/fabric/triples ------------------------
def preprocess(payload: jax.Array, shard: jax.Array) -> jax.Array:
    """Clip outliers at payload[0] sigmas, normalize, return the cleaned
    shard with its (count, mean, var, clipped) stats appended."""
    sigmas = payload[0]
    mu = jnp.mean(shard)
    sd = jnp.std(shard) + 1e-9
    lo, hi = mu - sigmas * sd, mu + sigmas * sd
    clipped = jnp.sum((shard < lo) | (shard > hi)).astype(shard.dtype)
    clean = jnp.clip(shard, lo, hi)
    out = (clean - jnp.mean(clean)) / (jnp.std(clean) + 1e-9)
    stats = jnp.stack([jnp.float32(shard.shape[0]), mu, sd * sd, clipped])
    return jnp.concatenate([out, stats])


def main(shard: int = SHARD) -> None:
    cl = Cluster(n_servers=2, wire="thor_bf2", server_triple="cpu-bf2")
    rng = np.random.default_rng(0)
    # raw data lives ON the DPUs (computational-storage role)
    shards = []
    n_glitch = max(2, shard // 100)  # ~1% outliers at any size
    for i, pe in enumerate(cl.servers):
        raw = rng.normal(3.0, 2.0, shard).astype(np.float32)
        raw[rng.integers(0, shard, n_glitch)] += 100.0  # sensor glitches
        pe.register_region("raw", raw)
        shards.append(raw)

    # "compile" the high-level function with the Three-Chains toolchain:
    # fat-bitcode for x86 hosts, BF2 DPUs, and TPU hosts alike
    ifunc = IFunc.build(
        name="preprocess",
        fn=preprocess,
        payload_aval=jax.ShapeDtypeStruct((1,), jnp.float32),
        dep_avals=(jax.ShapeDtypeStruct((shard,), jnp.float32),),
        deps=("region:raw",),
        abi="pure",
        targets=("cpu-host", "cpu-bf2", "tpu-v5e"),
    )
    cl.toolchain.publish(ifunc)

    sent = 0
    for i in range(2):
        sent += cl.client.send_ifunc(f"server{i}", "preprocess",
                                     np.array([3.0], np.float32))
    cl.drain()

    for i, pe in enumerate(cl.servers):
        (result,) = pe.completed
        clean, stats = result[:-4], result[-4:]
        want = np.asarray(preprocess(jnp.array([3.0]), jnp.asarray(shards[i])))
        assert np.allclose(result, want, atol=1e-5)
        print(f"DPU server{i}: n={stats[0]:.0f} mean={stats[1]:.2f} "
              f"var={stats[2]:.2f} clipped={stats[3]:.0f} "
              f"| normalized shard stays on-DPU (|mean|={abs(clean.mean()):.1e})")
    jit_ms = sum(pe.stats.jit_ms_total for pe in cl.servers)
    print(f"code moved once: {sent} B total for both DPUs "
          f"(fat-bitcode, 3 target triples); one-time JIT {jit_ms:.0f} ms; "
          f"the 2x{shard*4//1024} KiB of data moved 0 B")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="smoke-test sizes")
    args = ap.parse_args()
    main(shard=256 if args.tiny else SHARD)
