"""Serving example: batched prefill + greedy decode on a small model.

Exercises the inference path end to end — prefill writes the KV cache,
serve_step extends it one token at a time — for three different cache
families (dense GQA / RWKV state / hybrid attn+SSM).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.zoo import (
    ShapeSpec,
    build_params,
    frontend_len,
    init_kv_cache,
    make_batch,
    make_serve_step,
)


def serve(arch: str, batch: int = 2, prompt: int = 32, gen: int = 16) -> None:
    from repro.models.zoo import _head, forward

    cfg = get_config(arch, smoke=True)
    params, _ = build_params(cfg, 0)
    spec = ShapeSpec("s", prompt, batch, "prefill")
    b = make_batch(cfg, spec, seed=0)
    t_max = prompt + gen
    fl = frontend_len(cfg, prompt)

    @jax.jit
    def prefill(params, b):
        cache = init_kv_cache(cfg, batch, t_max, enc_len=fl, dtype=cfg.dtype)
        h, cache, _ = forward(cfg, params, b, caches=cache, offset=jnp.int32(0),
                              return_hidden=True)
        return _head(cfg, params, h[:, -1:, :])[:, -1, :], cache

    step = jax.jit(make_serve_step(cfg))
    logits, cache = prefill(params, b)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, cache = step(params, cache, tok, jnp.int32(prompt + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    dt = (time.perf_counter() - t0) / (gen - 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"{arch:22s} cache={sorted(init_kv_cache(cfg, 1, 8).keys())} "
          f"{1e3*dt:6.1f} ms/tok  ids[:8]={out[:8]}")


if __name__ == "__main__":
    for arch in ("yi-9b", "rwkv6-1.6b", "hymba-1.5b", "seamless-m4t-medium"):
        serve(arch)
