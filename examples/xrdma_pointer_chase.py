"""X-RDMA pointer chase, both renderings of the paper's idea:

1. the faithful runtime (core/): Chaser ifuncs really travel, JIT, cache,
   and recursively forward between processing elements — sweep depth and
   compare DAPC vs GBPC vs Active Messages like Figs 5-8;
2. the compiled SPMD rendering (sharding/compute_to_data): the same
   algorithm as a shard_map collective program, with the Pallas chase
   kernel as the per-shard resolver.

Run:  PYTHONPATH=src python examples/xrdma_pointer_chase.py [--tiny]
"""

import argparse

import numpy as np


def runtime_rendering(tiny: bool) -> None:
    from repro.core import Cluster, PointerChaseApp, chase_ref

    print("== runtime rendering (code really moves) ==")
    n_servers, n_entries = (2, 1 << 8) if tiny else (8, 1 << 14)
    depths = (4, 16) if tiny else (16, 64, 256)
    cl = Cluster(n_servers=n_servers, wire="thor_bf2")
    app = PointerChaseApp(cl, n_entries=n_entries, max_slots=16)
    starts = np.random.default_rng(0).integers(0, n_entries, 16).astype(np.int32)
    print("depth  mode      msgs   wire_KB   modeled_us   rate(chases/s)")
    for depth in depths:
        for mode in ("get", "am", "bitcode"):
            rep = (
                app.gbpc(starts, depth)
                if mode == "get"
                else app.dapc(starts, depth, mode=mode)
            )
            expect = [chase_ref(app.table, s, depth) for s in starts]
            assert rep.results.tolist() == expect
            n_msg = rep.puts + rep.gets
            rate = 16 / (rep.modeled_us / 1e6)
            print(
                f"{depth:5d}  {mode:8s} {n_msg:5d} {(rep.put_bytes+rep.get_bytes)/1024:9.1f}"
                f" {rep.modeled_us:12.1f} {rate:14.0f}"
            )


def compiled_rendering(tiny: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.chase.kernel import chase_shard
    from repro.sharding.compute_to_data import chase_oracle, dapc_shard_map

    print("\n== compiled SPMD rendering (steady state: indices move) ==")
    n, b, depth = (1 << 8, 8, 8) if tiny else (1 << 14, 64, 32)
    rng = np.random.default_rng(1)
    perm = rng.permutation(n)
    table = np.empty(n, np.int32)
    table[perm] = np.roll(perm, -1)
    starts = rng.integers(0, n, b).astype(np.int32)
    mesh = jax.make_mesh((1, jax.device_count()), ("data", "model"))
    got = np.asarray(dapc_shard_map(jnp.asarray(table), jnp.asarray(starts), depth, mesh))
    want = chase_oracle(table, starts, depth)
    assert np.array_equal(got, want)
    print(f"dapc_shard_map over {jax.device_count()} device(s): {b} chases x "
          f"depth {depth} verified; wire cost = 4 B/hop/chase (one int32)")

    # per-shard resolver as the Pallas kernel (interpret mode on CPU)
    f, d = chase_shard(
        jnp.asarray(table), jnp.asarray(starts),
        jnp.full(b, depth, jnp.int32), 0,
        block=n, hops_per_visit=depth, rounds=1, interpret=True,
    )
    assert np.array_equal(np.asarray(f), want) and int(np.asarray(d).max()) == 0
    print(f"Pallas chase kernel resolved all {b} chases in-VMEM (interpret mode)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="smoke-test sizes")
    args = ap.parse_args()
    runtime_rendering(args.tiny)
    compiled_rendering(args.tiny)
