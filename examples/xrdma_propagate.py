"""Recursive code propagation, end to end (paper Sec. I's signature claim).

Three escalating demos on the simulated RDMA fabric:

1. tree multicast — one TSI ifunc reaches every server with O(log N)
   client dispatches (vs the flat O(N) push), warm re-broadcast moves
   zero code bytes;
2. multi-hop reduction — every PE contributes a vector, partials fold
   at each tree level (propagate-ABI masked scan) and only completed
   subtrees forward up;
3. self-propagation — a gossiper ifunc whose *shipped code* re-publishes
   itself around a ring: the client sends one frame, the code does the
   rest.

Run:  PYTHONPATH=src python examples/xrdma_propagate.py [--tiny]
"""

import argparse

import numpy as np

from repro.core import Cluster, PropagationConfig, make_gossiper, make_tsi
from repro.sharding.collectives import xrdma_bcast, xrdma_flat_push, xrdma_reduce


def bcast_demo(n_servers: int) -> None:
    print(f"== tree multicast vs flat push ({n_servers} servers, thor_bf2) ==")

    def fresh() -> Cluster:
        cl = Cluster(n_servers=n_servers, wire="thor_bf2")
        for pe in cl.servers:
            pe.register_region("counter", np.zeros(1, np.int32))
        cl.toolchain.publish(make_tsi())
        return cl

    payload = np.array([7], np.int32)
    flat = xrdma_flat_push(fresh(), "tsi", payload)
    cl = fresh()
    tree = xrdma_bcast(cl, "tsi", payload)
    warm = xrdma_bcast(cl, "tsi", payload)
    assert all(int(pe.region("counter")[0]) == 14 for pe in cl.servers)
    print("arm    client_sends  code_KB  completion_us")
    for label, rep in (("flat", flat), ("tree", tree), ("warm", warm)):
        print(
            f"{label:6s} {rep.client_sends:12d} "
            f"{rep.wire_bytes_by_kind['code'] / 1024:8.1f} "
            f"{rep.modeled_completion_us:13.1f}"
        )
    print(f"tree multicast verified: every counter incremented exactly once "
          f"per broadcast, {flat.client_sends}->{tree.client_sends} client "
          f"dispatches")


def reduce_demo(n_servers: int) -> None:
    print(f"\n== multi-hop tree reduction ({n_servers} servers) ==")
    cl = Cluster(n_servers=n_servers, wire="thor_bf2")
    rng = np.random.default_rng(0)
    values = rng.integers(0, 100, (n_servers + 1, 4)).astype(np.int32)
    rep = xrdma_reduce(cl, values)
    assert np.array_equal(rep.result, values.sum(axis=0))
    print(f"reduced {n_servers + 1} x 4-vector in {rep.rounds} rounds, "
          f"{rep.forwards} upward partials (tree-folded), result "
          f"{rep.result.tolist()} verified against numpy sum")


def gossip_demo() -> None:
    print("\n== self-propagating code (gossiper ring) ==")
    cl = Cluster(n_servers=3, wire="ideal")
    n = 4
    for i, pe in enumerate(cl.pes()):
        pe.register_region("gossip_log", np.zeros(2, np.int32))
        pe.register_cap("gossip_meta", np.array([i, n], np.int32))
    cl.toolchain.publish(make_gossiper())
    cl.client.send_ifunc("server0", "gossiper", np.array([2, 5], np.int32))
    cl.drain()
    visited = [pe.name for pe in cl.pes() if pe.region("gossip_log")[0]]
    print(f"client sent ONE frame to server0; the code then re-published "
          f"itself: visited {visited}")
    assert visited == ["server0", "server1", "server2"]
    print("gossip verified: one visit per ring hop, zero further client sends")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true", help="smoke-test sizes")
    args = ap.parse_args()
    n = 4 if args.tiny else 16
    bcast_demo(n)
    reduce_demo(4 if args.tiny else 8)
    gossip_demo()
