"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend (conformer feature extractor) is a STUB per the brief:
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, D).  The
backbone is real: a bidirectional self-attention encoder and a causal
decoder with cross-attention, both scanned over layers.

Decode path: self-attention KV cache grows with generated tokens; the
cross-attention K/V are computed once from the encoder output at prefill
and stay frozen in the cache (xk/xv) — generation never re-touches the
encoder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .attention import attend
from .common import ModelConfig, ParamFactory, mlp, rms_norm
from .transformer import add_attn_params, add_mlp_params, attn_sublayer

Params = dict[str, jax.Array]


def add_encdec_params(f: ParamFactory, cfg: ModelConfig) -> None:
    E = cfg.enc_layers
    # encoder blocks
    f.add("enc.ln1", (E, cfg.d_model), ("layers", "embed"), init="zeros")
    f.add("enc.ln2", (E, cfg.d_model), ("layers", "embed"), init="zeros")
    add_attn_params(f, cfg, "enc", n_layers=E)
    add_mlp_params(f, cfg, "enc", n_layers=E)
    f.add("enc.final_ln", (cfg.d_model,), ("embed",), init="zeros")
    # decoder blocks: self-attn + cross-attn + mlp
    L = cfg.n_layers
    f.add("blocks.ln1", (L, cfg.d_model), ("layers", "embed"), init="zeros")
    f.add("blocks.lnx", (L, cfg.d_model), ("layers", "embed"), init="zeros")
    f.add("blocks.ln2", (L, cfg.d_model), ("layers", "embed"), init="zeros")
    add_attn_params(f, cfg, "blocks")
    add_attn_params(f, cfg, "blocks", tag="_x")  # cross-attention projections
    add_mlp_params(f, cfg, "blocks")


def _strip(p: Params, prefix: str) -> dict:
    pl = len(prefix) + 1
    return {k[pl:]: v for k, v in p.items() if k.startswith(prefix + ".")}


# ------------------------------------------------------------------ encoder
def encode(cfg: ModelConfig, params: Params, x: jax.Array, mesh=None) -> jax.Array:
    """x: (B, S_enc, D) frame embeddings -> (B, S_enc, D) encoder states."""
    enc_p = _strip(params, "enc")
    final_ln = enc_p.pop("final_ln")
    pos = jnp.arange(x.shape[1])

    def body(h, p_l):
        if mesh is not None:
            from repro.sharding.partition import sp_constrain

            h = sp_constrain(h, mesh)
        a = rms_norm(h, p_l["ln1"], cfg.norm_eps)
        att, _ = attn_sublayer(
            a, p_l, cfg, pos=pos, window=jnp.int32(0), cache=None, offset=None,
            causal=False, mesh=mesh,
        )
        h = h + att
        a = rms_norm(h, p_l["ln2"], cfg.norm_eps)
        h = h + mlp(a, p_l["wi"], p_l.get("wg"), p_l["wo2"], cfg.act)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, enc_p)
    return rms_norm(x, final_ln, cfg.norm_eps)


# ----------------------------------------------------------- cross-attention
def cross_attend(
    h: jax.Array,
    p: dict,
    cfg: ModelConfig,
    xk: jax.Array,  # (B, S_enc, K, hd) precomputed enc keys
    xv: jax.Array,
    mesh=None,
) -> jax.Array:
    from .attention import attend_chunked, auto_chunk

    b, s, _ = h.shape
    q = (h @ p["wq_x"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    # cross-attention is position-free (no RoPE), never causal
    kw = dict(
        q_pos=jnp.arange(s), k_pos=jnp.arange(xk.shape[1]), causal=False,
        window=None, cap=None,
    )
    b_loc, h_loc = b, cfg.n_heads
    if mesh is not None:
        from repro.sharding.partition import axis_size, data_axes

        d = data_axes(mesh)
        if d and b_loc % axis_size(mesh, d) == 0:
            b_loc //= axis_size(mesh, d)
        m = mesh.shape.get("model", 1)
        if h_loc % m == 0:
            h_loc //= m
    c = auto_chunk(b_loc, h_loc, s, xk.shape[1], cap=cfg.attn_chunk or s)
    if cfg.attn_chunk and c < s:
        out = attend_chunked(
            q, xk.astype(q.dtype), xv.astype(q.dtype), chunk=c, **kw
        )
    else:
        out = attend(q, xk.astype(q.dtype), xv.astype(q.dtype), **kw)
    return out.reshape(b, s, -1) @ p["wo_x"]


def cross_kv(cfg: ModelConfig, p_l: dict, enc_out: jax.Array):
    """Per-layer cross K/V from encoder states. p_l keys: wk_x, wv_x."""
    b, t, _ = enc_out.shape
    k = (enc_out @ p_l["wk_x"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p_l["wv_x"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# ------------------------------------------------------------------ decoder
def decoder_block(
    h: jax.Array,
    p_l: dict,
    cfg: ModelConfig,
    *,
    pos: jax.Array,
    xk: jax.Array,
    xv: jax.Array,
    cache: dict | None,
    offset: jax.Array | None,
    mesh=None,
):
    a = rms_norm(h, p_l["ln1"], cfg.norm_eps)
    kv = None if cache is None else (cache["k"], cache["v"])
    att, new_kv = attn_sublayer(
        a, p_l, cfg, pos=pos, window=jnp.int32(0), cache=kv, offset=offset,
        mesh=mesh,
    )
    h = h + att
    a = rms_norm(h, p_l["lnx"], cfg.norm_eps)
    h = h + cross_attend(a, p_l, cfg, xk, xv, mesh=mesh)
    a = rms_norm(h, p_l["ln2"], cfg.norm_eps)
    h = h + mlp(a, p_l["wi"], p_l.get("wg"), p_l["wo2"], cfg.act)
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = new_kv
    return h, new_cache


def run_decoder(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,  # (B, S_dec, D) token embeddings
    *,
    enc_out: jax.Array | None = None,  # (B, S_enc, D); None => use cached xk/xv
    pos: jax.Array,
    caches: dict | None = None,  # leading-L pytree {k, v, xk, xv}
    offset: jax.Array | None = None,
    mesh=None,
):
    dec_p = _strip(params, "blocks")

    def body(h, xs):
        p_l, cache_l = xs
        if mesh is not None:
            from repro.sharding.partition import sp_constrain

            h = sp_constrain(h, mesh)
        if enc_out is not None:
            xk, xv = cross_kv(cfg, p_l, enc_out)
            if cache_l is not None:
                cache_l = dict(cache_l)
                cache_l["xk"], cache_l["xv"] = (
                    xk.astype(cache_l["xk"].dtype),
                    xv.astype(cache_l["xv"].dtype),
                )
        else:
            assert cache_l is not None, "decode without enc_out needs cached xk/xv"
            xk, xv = cache_l["xk"], cache_l["xv"]
        h, new_cache = decoder_block(
            h, p_l, cfg, pos=pos, xk=xk, xv=xv, cache=cache_l, offset=offset,
            mesh=mesh,
        )
        return h, new_cache

    if cfg.remat:
        body = jax.checkpoint(body)
    x, new_caches = lax.scan(body, x, (dec_p, caches))
    return x, new_caches
