"""Shared model substrate: config, parameter factory, norms, MLP, RoPE, loss.

Parameters are a *flat* dict ``{"path.to.leaf": jax.Array}`` with a parallel
dict of logical-axis tuples (``{"path.to.leaf": ("layers","embed","q_dim")}``)
produced by the same factory.  Flat dicts keep sharding-spec derivation,
checkpointing, and compression hooks trivial, and stacked leading ``layers``
dims make ``lax.scan`` over blocks natural (small HLO => tractable 512-device
compiles).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, jax.Array]
Axes = dict[str, tuple[str | None, ...]]

VOCAB_PAD_MULTIPLE = 2048  # 16-way vocab shards stay 128-lane aligned


def pad_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. Fields follow the assignment table verbatim."""

    name: str = "tiny"
    family: str = "dense"  # dense | moe | rwkv | hybrid | encdec
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 256
    vocab: int = 512
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    window: int | None = None  # sliding-window size for local layers
    global_every: int = 0  # k>0: every k-th layer is global, rest local
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    mlp_gated: bool = True  # SwiGLU/GeGLU (3 mats) vs plain act-MLP (2 mats)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    topk: int = 0
    # recurrent state
    ssm_state: int = 0  # hymba mamba-head state size
    rwkv_head_dim: int = 64
    # 0 = sequential scan (reference); >0 = chunked matmul formulation of
    # the WKV6 recurrence (the Pallas kernel's math — 4 MXU matmuls per
    # chunk instead of C tiny steps; the train-path perf lever)
    rwkv_chunk: int = 0
    # same lever for the selective (Mamba) scan in hybrid blocks
    ssm_chunk: int = 0
    # encoder-decoder
    enc_layers: int = 0
    # stub modality frontend (vlm patch embeds / audio frames via input_specs)
    frontend: str | None = None  # None | "patch" | "audio"
    # numerics / training
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # compute-to-data embedding (paper's technique at tensor scale)
    c2d_embedding: bool = True
    embed_mult: float = 1.0  # gemma multiplies embeddings by sqrt(d_model)
    # q-chunked attention: bound the live logits block to (chunk x T) when
    # S > chunk (XLA-native flash; the Pallas kernel is the TPU hot path)
    attn_chunk: int = 0
    # gradient-accumulation microbatches for train_step (memory lever for
    # the 15-42B archs whose activations exceed HBM at the assigned batch)
    microbatch: int = 1

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM / windowed hybrids.)"""
        return self.family in ("rwkv", "hybrid") or self.global_every > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_is_global(self, i: int) -> bool:
        if self.global_every <= 0:
            return True
        return (i % self.global_every) == (self.global_every - 1)


# ------------------------------------------------------------------ factory
class ParamFactory:
    """Builds the flat param dict + logical axes; one RNG stream per leaf."""

    def __init__(self, key: jax.Array, dtype=jnp.float32, abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract  # ShapeDtypeStruct-only (dry-run, no alloc)
        self.params: Params = {}
        self.axes: Axes = {}

    def _next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def add(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: tuple[str | None, ...],
        init: str = "normal",
        scale: float | None = None,
    ) -> None:
        assert len(shape) == len(axes), (name, shape, axes)
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, self.dtype)
            self.axes[name] = axes
            return
        if init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        elif init == "const":
            arr = jnp.full(shape, scale, self.dtype)
        else:  # truncated-normal fan-in scaling
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
            arr = (
                jax.random.truncated_normal(self._next(), -2.0, 2.0, shape, jnp.float32)
                * scale
            ).astype(self.dtype)
        self.params[name] = arr
        self.axes[name] = axes

    def done(self) -> tuple[Params, Axes]:
        return self.params, self.axes


# ------------------------------------------------------------------- layers
def rms_norm(x: jax.Array, gain: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gain.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def mlp(x: jax.Array, wi: jax.Array, wg: jax.Array | None, wo: jax.Array, act: str) -> jax.Array:
    """SwiGLU when wg is present, plain act-MLP otherwise."""
    h = x @ wi
    a = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    if wg is not None:
        a = a * (x @ wg)
    return a @ wo


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embeddings. x: [..., seq, heads, head_dim], positions: [..., seq]."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


# ------------------------------------------------------------ chunked scan
def scan_chunked_remat(step, carry, xs, chunk: int, enabled: bool = True):
    """lax.scan over T with sqrt-T memory for reverse-mode AD.

    Differentiating a T-step scan saves T carries; training a 4096-token
    RWKV layer would checkpoint 4096 (B,H,M,M) states (~34 GB/device).
    Scanning T/C chunks with a remat'd inner C-step scan keeps only
    (T/C + C) carries — minimized at C ~ sqrt(T) — at the cost of one
    extra forward over each chunk in the backward pass (the standard
    recurrent-remat trade; the Pallas WKV6 kernel replaces the inner scan
    entirely on TPU).
    """
    leaves = jax.tree_util.tree_leaves(xs)
    t = leaves[0].shape[0]
    if not enabled or chunk <= 0 or t % chunk or t <= chunk:
        return jax.lax.scan(step, carry, xs)
    n = t // chunk

    def split(a):
        return a.reshape(n, chunk, *a.shape[1:])

    xs_c = jax.tree_util.tree_map(split, xs)

    @jax.checkpoint
    def chunk_body(c, x_c):
        return jax.lax.scan(step, c, x_c)

    carry, ys_c = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape(t, *a.shape[2:]), ys_c
    )
    return carry, ys


# --------------------------------------------------------------------- loss
def cross_entropy(
    logits: jax.Array, labels: jax.Array, vocab: int, mask: jax.Array | None = None
) -> jax.Array:
    """Mean NLL over valid positions; padded-vocab slots are masked to -inf
    (the padded one-hot-matmul embedding never *writes* them, but the LM head
    produces garbage logits there)."""
    v = logits.shape[-1]
    if v > vocab:
        neg = jnp.asarray(-1e9, logits.dtype)
        logits = jnp.where(jnp.arange(v) < vocab, logits, neg)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
