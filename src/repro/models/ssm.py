"""Selective SSM (Mamba-style) head for the Hymba hybrid blocks.

Hymba runs attention heads and SSM heads *in parallel* inside each block and
mean-combines their (normalized) outputs.  The SSM here is a standard
selective scan: input-dependent (dt, B, C), diagonal A, short causal conv.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t      (per channel, N states)
    y_t = C_t . h_t + D * x_t

Train path is a ``lax.scan`` over time (parallel over batch/channels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, ParamFactory, scan_chunked_remat

CONV_K = 4  # short causal conv width
SSM_CHUNK = 64  # sqrt-T remat chunking for the train-time recurrence


def add_ssm_params(f: ParamFactory, cfg: ModelConfig, prefix: str) -> None:
    L, D, N = cfg.n_layers, cfg.d_model, cfg.ssm_state
    lay = lambda *s: (L, *s)
    f.add(f"{prefix}.w_in", lay(D, 2 * D), ("layers", "embed", "q_dim"))
    f.add(f"{prefix}.conv", lay(CONV_K, D), ("layers", None, "q_dim"), scale=0.5)
    f.add(f"{prefix}.w_bcdt", lay(D, 2 * N + 1), ("layers", "q_dim", None))
    # Mamba dt init: softplus(raw + bias) lands in [1e-3, 1e-1]; this is
    # both the published init AND what keeps per-chunk cumulative decays
    # inside f32 range for the chunked formulation
    f.add(f"{prefix}.dt_bias", lay(D), ("layers", "q_dim"), init="const", scale=-4.6)
    f.add(f"{prefix}.a_log", lay(D, N), ("layers", "q_dim", None), init="zeros")
    f.add(f"{prefix}.d_skip", lay(D), ("layers", "q_dim"), init="ones")
    f.add(f"{prefix}.w_out", lay(D, D), ("layers", "q_dim", "embed"))


def causal_conv(x: jax.Array, kernel: jax.Array, prev: jax.Array | None):
    """Depthwise causal conv. x: (B,T,D), kernel: (K,D), prev: (B,K-1,D)."""
    k = kernel.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)  # (B, T+K-1, D)
    out = sum(xp[:, i : i + x.shape[1], :] * kernel[i] for i in range(k))
    return out, xp[:, -(k - 1) :, :]


def selective_scan(
    x: jax.Array,  # (B, T, D) post-conv activations
    dt: jax.Array,  # (B, T, D) positive step sizes
    a: jax.Array,  # (D, N) negative continuous-time decay
    b: jax.Array,  # (B, T, N)
    c: jax.Array,  # (B, T, N)
    h0: jax.Array | None = None,  # (B, D, N)
):
    bsz, t, d = x.shape
    n = a.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), f32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,D), (B,D), (B,N), (B,N)
        decay = jnp.exp(dt_t[..., None] * a[None])  # (B, D, N)
        drive = (dt_t * x_t)[..., None] * b_t[:, None, :]  # (B, D, N)
        h = decay * h + drive
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = tuple(
        jnp.moveaxis(v.astype(f32), 1, 0) for v in (x, dt, b, c)
    )
    h, ys = scan_chunked_remat(step, h0, xs, SSM_CHUNK)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def selective_scan_chunked(
    x: jax.Array,  # (B, T, D) post-conv activations
    dt: jax.Array,  # (B, T, D) positive step sizes
    a: jax.Array,  # (D, N) negative continuous-time decay
    b: jax.Array,  # (B, T, N)
    c: jax.Array,  # (B, T, N)
    h0: jax.Array | None = None,  # (B, D, N)
    chunk: int = 32,
):
    """Chunked matmul formulation of the diagonal selective scan (the same
    treatment kernels/wkv6 gives the RWKV recurrence — see EXPERIMENTS
    §Perf #9/#13).  With P_t = prod_{s<=t} exp(dt_s A) inside a chunk:

        y_t   = C_t . (P_t h_0)  +  sum_{s<=t} C_t . (P_t / P_s) b_s
        h_out = exp(cum_C) (h_0 + sum_s b_s / P_s)

    The pairwise term is one einsum over the state dim with a causal-
    inclusive mask — T/C chunk steps of matmuls instead of T scalar steps,
    saving only per-chunk carries for the backward pass.
    """
    bsz, t, d = x.shape
    n = a.shape[-1]
    f32 = jnp.float32
    chunk = min(chunk, t)
    if t % chunk:
        return selective_scan(x, dt, a, b, c, h0)
    nc = t // chunk
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), f32)

    def split(v, feat):  # (B, T, F) -> (nc, B, C, F)
        return jnp.moveaxis(v.astype(f32).reshape(bsz, nc, chunk, feat), 1, 0)

    xs = (split(x, d), split(dt, d), split(b, n), split(c, n))
    mask = (jnp.arange(chunk)[None, :] <= jnp.arange(chunk)[:, None]).astype(f32)

    @jax.checkpoint
    def body(h, inp):
        x_c, dt_c, b_c, c_c = inp  # (B, C, D|N)
        log_a = dt_c[..., None] * a[None, None]  # (B, C, D, N), negative
        cum = jnp.maximum(jnp.cumsum(log_a, axis=1), -60.0)  # inclusive
        p = jnp.exp(cum)
        drive = (dt_c * x_c)[..., None] * b_c[:, :, None, :]  # (B, C, D, N)
        k = drive * jnp.exp(-cum)
        q = c_c[:, :, None, :] * p  # (B, C, D, N)
        # intra-chunk: scores over the state dim, causal-inclusive
        s = jnp.einsum("btdn,bsdn->bdts", q, k)  # (B, D, C, C)
        y_intra = jnp.einsum("bdts,ts->btd", s, mask)
        y_inter = jnp.einsum("btdn,bdn->btd", q, h)
        h = jnp.exp(cum[:, -1]) * (h + jnp.sum(k, axis=1))
        return h, (y_inter + y_intra)

    h, ys = lax.scan(body, h0.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, t, d)
    return y.astype(x.dtype), h


def ssm_head(
    x: jax.Array,  # (B, T, D) block input (already normed)
    p: dict,  # per-layer slices under "ssm."
    cfg: ModelConfig,
    state: dict | None = None,
    mesh=None,
):
    """Returns (y, new_state). state = {"conv": (B,K-1,D), "h": (B,D,N)}."""
    st = state or {}
    n = cfg.ssm_state
    xz = x @ p["ssm.w_in"]  # (B,T,2D)
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv(xin, p["ssm.conv"], st.get("conv"))
    xc = jax.nn.silu(xc)
    bcdt = xc @ p["ssm.w_bcdt"]  # (B,T,2N+1)
    b_in, c_in, dt_raw = bcdt[..., :n], bcdt[..., n : 2 * n], bcdt[..., -1:]
    # scalar dt per token, per-channel learned bias -> (B, T, D) step sizes
    dt = jax.nn.softplus(dt_raw + p["ssm.dt_bias"][None, None, :]) + 1e-4
    a = -jnp.exp(p["ssm.a_log"].astype(jnp.float32))  # (D,N), negative
    # Mamba TP: the diagonal recurrence is independent per channel, so the
    # scan shards D over `model` and runs the full T on each rank's channel
    # slice — T-sharded inputs would instead broadcast every remat chunk
    # (measured 230 GB/step of permutes+gathers on hymba, EXPERIMENTS §Perf)
    if mesh is not None and "model" in mesh.axis_names and x.shape[1] > 1:
        from repro.sharding.partition import channel_constrain

        xc = channel_constrain(xc, mesh)
        dt = channel_constrain(dt, mesh)
    # chunked matmul form for TRAINING (bwd-heavy; measured 2x on hymba);
    # prefill keeps the scan — the C^2 constant loses at 32k in the XLA
    # path (the Pallas ssm_scan kernel wins both on real TPU)
    if cfg.ssm_chunk and x.shape[1] > 1 and state is None:
        y, h = selective_scan_chunked(
            xc, dt, a, b_in, c_in, None, chunk=cfg.ssm_chunk
        )
    else:
        y, h = selective_scan(xc, dt, a, b_in, c_in, st.get("h"))
    y = y + xc * p["ssm.d_skip"]
    y = y * jax.nn.silu(z)
    out = y @ p["ssm.w_out"]
    return out, {"conv": conv_state, "h": h}
