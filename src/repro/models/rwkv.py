"""RWKV6 "Finch": attention-free time mixing with data-dependent decay.

The defining Finch feature — the per-channel, per-timestep decay
``w_t = exp(-exp(w0 + tanh(x_w A) B))`` (a low-rank data-dependent function
of the shifted input) — is implemented exactly.  The static token-shift
interpolations use plain learned ``mu`` vectors (the paper's second-order
ddlerp LoRA on r/k/v/g is an accuracy refinement orthogonal to the systems
behaviour; noted in DESIGN.md).

The recurrence per head (head_dim M, state S in R^{MxM}) is::

    out_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T

Train path: ``lax.scan`` over time, vectorized over (batch, heads) — an XLA
while loop (serial in T, parallel everywhere else).  The Pallas WKV6 kernel
(repro.kernels.wkv6) implements the same recurrence blocked in VMEM for the
TPU target and is validated against :func:`wkv6_scan`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, ParamFactory, rms_norm, scan_chunked_remat

LORA_DIM = 64  # decay LoRA bottleneck
WKV_CHUNK = 64  # sqrt-T remat chunking for the train-time recurrence


def wkv6_scan(
    r: jax.Array,  # (B, T, H, M)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # (B, T, H, M) decay factors in (0, 1)
    u: jax.Array,  # (H, M) current-token bonus
    state: jax.Array | None = None,  # (B, H, M, M)
) -> tuple[jax.Array, jax.Array]:
    b, t, h, m = r.shape
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((b, h, m, m), f32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, M) each
        bonus = jnp.sum(r_t * u[None] * k_t, axis=-1, keepdims=True) * v_t
        out = jnp.einsum("bhm,bhmn->bhn", r_t, S) + bonus
        S = w_t[..., :, None] * S + k_t[..., :, None] * v_t[..., None, :]
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(f32), 1, 0) for a in (r, k, v, w))
    state, outs = scan_chunked_remat(step, state, xs, WKV_CHUNK)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), state


def wkv6_chunked(
    r: jax.Array,  # (B, T, H, M)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,  # (H, M)
    state: jax.Array | None = None,  # (B, H, M, M)
    chunk: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Chunked matmul formulation of the WKV6 recurrence — the same math
    as kernels/wkv6 (see that module for the derivation), expressed in
    batched jnp so the dry-run/CPU path gets the MXU-friendly program
    shape: T/C chunk steps of 4 matmuls instead of T scalar-ish steps.
    Validated against :func:`wkv6_scan` in tests."""
    b, t, h, m = r.shape
    f32 = jnp.float32
    chunk = min(chunk, t)
    if t % chunk:
        return wkv6_scan(r, k, v, w, u, state)
    nc = t // chunk
    if state is None:
        state = jnp.zeros((b, h, m, m), f32)

    def split(a):  # (B, T, H, M) -> (nc, B, H, C, M)
        return jnp.moveaxis(
            a.astype(f32).reshape(b, nc, chunk, h, m), (1, 3), (0, 2)
        )

    rs, ks, vs, ws = split(r), split(k), split(v), split(w)
    rows = jnp.arange(chunk)[:, None]
    cols = jnp.arange(chunk)[None, :]
    strict = (cols < rows).astype(f32)

    @jax.checkpoint
    def body(S, xs):
        r_c, k_c, v_c, w_c = xs  # (B, H, C, M)
        logw = jnp.log(jnp.maximum(w_c, 1e-38))
        cum = jnp.cumsum(logw, axis=2)
        log_p = jnp.maximum(cum - logw, -60.0)  # log prod_{s<t}
        log_pc = jnp.maximum(cum[:, :, -1:, :], -60.0)
        r_dec = r_c * jnp.exp(log_p)
        k_inv = k_c * jnp.exp(-jnp.maximum(cum, -60.0))
        k_rem = k_c * jnp.exp(log_pc - jnp.maximum(cum, -60.0))
        inter = jnp.einsum("bhcm,bhmn->bhcn", r_dec, S)
        a = jnp.einsum("bhcm,bhdm->bhcd", r_dec, k_inv) * strict
        intra = jnp.einsum("bhcd,bhdn->bhcn", a, v_c)
        bonus = jnp.sum(r_c * u[None, :, None, :] * k_c, -1, keepdims=True) * v_c
        S = jnp.exp(log_pc).swapaxes(-1, -2) * S + jnp.einsum(
            "bhcm,bhcn->bhmn", k_rem, v_c
        )
        return S, inter + intra + bonus

    state, outs = lax.scan(body, state, (rs, ks, vs, ws))
    # (nc, B, H, C, M) -> (B, T, H, M)
    outs = jnp.moveaxis(outs, (0, 3), (1, 2)).reshape(b, t, h, m)
    return outs.astype(r.dtype), state


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x[:, t-1] with x[:, -1]'s predecessor carried across calls (decode)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def add_rwkv_block_params(f: ParamFactory, cfg: ModelConfig, prefix: str = "blocks") -> None:
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    m = cfg.rwkv_head_dim
    h = D // m
    lay = lambda *s: (L, *s)
    f.add(f"{prefix}.ln1", lay(D), ("layers", "embed"), init="zeros")
    f.add(f"{prefix}.ln2", lay(D), ("layers", "embed"), init="zeros")
    for mu in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        f.add(f"{prefix}.tm.{mu}", lay(D), ("layers", "embed"), init="zeros")
    f.add(f"{prefix}.tm.w0", lay(D), ("layers", "embed"), init="zeros")
    f.add(f"{prefix}.tm.wA", lay(D, LORA_DIM), ("layers", "embed", None))
    f.add(f"{prefix}.tm.wB", lay(LORA_DIM, D), ("layers", None, "embed"))
    f.add(f"{prefix}.tm.u", lay(h, m), ("layers", "heads", None), init="zeros")
    for w in ("wr", "wk", "wv", "wg"):
        f.add(f"{prefix}.tm.{w}", lay(D, D), ("layers", "embed", "q_dim"))
    f.add(f"{prefix}.tm.wo", lay(D, D), ("layers", "q_dim", "embed"))
    f.add(f"{prefix}.tm.ln_x", lay(D), ("layers", "q_dim"), init="zeros")
    f.add(f"{prefix}.cm.mu_k", lay(D), ("layers", "embed"), init="zeros")
    f.add(f"{prefix}.cm.mu_r", lay(D), ("layers", "embed"), init="zeros")
    f.add(f"{prefix}.cm.wk", lay(D, F), ("layers", "embed", "ffn"))
    f.add(f"{prefix}.cm.wv", lay(F, D), ("layers", "ffn", "embed"))
    f.add(f"{prefix}.cm.wr", lay(D, D), ("layers", "embed", "q_dim"))


def time_mix(
    x: jax.Array,  # (B, T, D)
    p: dict,  # per-layer param slices, keys tm.*
    cfg: ModelConfig,
    shift_prev: jax.Array | None = None,
    wkv_state: jax.Array | None = None,
    mesh=None,
):
    b, t, d = x.shape
    m = cfg.rwkv_head_dim
    h = d // m
    xp = _token_shift(x, shift_prev)
    xx = xp - x
    xr = x + xx * p["tm.mu_r"]
    xk = x + xx * p["tm.mu_k"]
    xv = x + xx * p["tm.mu_v"]
    xw = x + xx * p["tm.mu_w"]
    xg = x + xx * p["tm.mu_g"]
    # data-dependent decay (the Finch contribution)
    dd = jnp.tanh(xw @ p["tm.wA"]) @ p["tm.wB"]
    w = jnp.exp(-jnp.exp((p["tm.w0"] + dd).astype(jnp.float32)))  # (B,T,D)
    r = (xr @ p["tm.wr"]).reshape(b, t, h, m)
    k = (xk @ p["tm.wk"]).reshape(b, t, h, m)
    v = (xv @ p["tm.wv"]).reshape(b, t, h, m)
    g = jax.nn.silu(xg @ p["tm.wg"])
    w = w.reshape(b, t, h, m)
    # head parallelism for the WKV recurrence: heads are independent, so
    # shard H over `model` and keep T whole per rank (see ssm.py note)
    if mesh is not None and t > 1:
        from repro.sharding.partition import channel_constrain

        r, k, v, w = (channel_constrain(a, mesh, c_axis=2) for a in (r, k, v, w))
    if cfg.rwkv_chunk and t > 1:
        out, wkv_state = wkv6_chunked(
            r, k, v, w, p["tm.u"], wkv_state, chunk=cfg.rwkv_chunk
        )
    else:
        out, wkv_state = wkv6_scan(r, k, v, w, p["tm.u"], wkv_state)
    # per-head groupnorm
    out = out.reshape(b, t, h, m)
    mean = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = ((out - mean) * lax.rsqrt(var + 64e-5)).reshape(b, t, d)
    out = out * (1.0 + p["tm.ln_x"])
    y = (out.astype(x.dtype) * g) @ p["tm.wo"]
    return y, x[:, -1:, :], wkv_state


def channel_mix(
    x: jax.Array,
    p: dict,
    shift_prev: jax.Array | None = None,
):
    xp = _token_shift(x, shift_prev)
    xx = xp - x
    xk = x + xx * p["cm.mu_k"]
    xr = x + xx * p["cm.mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm.wk"]))
    y = jax.nn.sigmoid(xr @ p["cm.wr"]) * (kk @ p["cm.wv"])
    return y, x[:, -1:, :]


def rwkv_block(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    state: dict | None = None,
    mesh=None,
):
    """One RWKV6 block. ``state`` (decode): {"tm_shift","cm_shift","wkv"}."""
    st = state or {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    att, tm_shift, wkv = time_mix(
        h, p, cfg, st.get("tm_shift"), st.get("wkv"), mesh=mesh
    )
    x = x + att
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    ffn, cm_shift = channel_mix(h, p, st.get("cm_shift"))
    x = x + ffn
    new_state = {"tm_shift": tm_shift, "cm_shift": cm_shift, "wkv": wkv}
    return x, new_state
