"""Model zoo: build params, forward, and the train/prefill/serve steps for
every assigned architecture family through one API.

Entry points (all pure; jit/pjit at the launch layer):

* ``build_params(cfg, seed)``          -> (params, logical_axes)
* ``input_specs(cfg, shape)``          -> {name: ShapeDtypeStruct}, the
  dry-run stand-ins (weak-type-correct, no allocation)
* ``make_batch(cfg, shape, seed)``     -> concrete random batch (smoke/train)
* ``init_kv_cache(cfg, batch, t_max)`` -> leading-L cache pytree
* ``make_train_step(cfg, opt)``        -> (state, batch) -> (state, metrics)
* ``make_prefill_step(cfg)``           -> (params, batch) -> (logits, cache)
* ``make_serve_step(cfg)``             -> (params, cache, tok, pos) -> (logits, cache)

Shapes follow the assignment: ``train_*`` lowers train_step, ``prefill_*``
lowers prefill_step, ``decode_*``/``long_*`` lower serve_step (one token
against a seq_len-deep cache).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, ParamFactory, cross_entropy, rms_norm, softcap
from .embedding import embed_tokens, lm_head
from .encdec import add_encdec_params, encode, run_decoder
from .ssm import CONV_K
from .transformer import add_block_params, run_blocks

Params = dict[str, jax.Array]
AUX_LOSS_WEIGHT = 0.01


# ------------------------------------------------------------------- shapes
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def frontend_len(cfg: ModelConfig, seq_len: int) -> int:
    """Stub modality frontends: how many positions the frontend occupies."""
    if cfg.frontend == "patch":  # ViT patch embeds (internvl2)
        return min(256, seq_len // 4)
    if cfg.frontend == "audio":  # downsampled audio frames (seamless)
        return max(seq_len // 4, 16)
    return 0


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (long_500k needs sub-quadratic.)"""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            f"{cfg.name}: pure full-attention arch — 500k-token decode would "
            f"need a {shape.seq_len}-deep dense KV per layer; skipped per brief "
            f"(DESIGN.md §Arch-applicability)"
        )
    return True, ""


# ------------------------------------------------------------------- params
def build_params(
    cfg: ModelConfig, seed: int = 0, dtype=None, abstract: bool = False
) -> tuple[Params, dict]:
    f = ParamFactory(jax.random.PRNGKey(seed), dtype or cfg.dtype, abstract=abstract)
    f.add("embed.tok", (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), scale=0.02)
    if cfg.is_encdec:
        add_encdec_params(f, cfg)
    else:
        add_block_params(f, cfg)
    f.add("final_ln", (cfg.d_model,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        f.add("head.w", (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"))
    return f.done()


def param_count(params: Params) -> int:
    return sum(int(np.prod(p.shape)) for p in params.values())


# ------------------------------------------------------------------ forward
def _head(cfg: ModelConfig, params: Params, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    w = params["embed.tok"].T if cfg.tie_embeddings else params["head.w"]
    return softcap(lm_head(h, w), cfg.final_softcap)


def forward(
    cfg: ModelConfig,
    params: Params,
    batch: dict[str, jax.Array],
    *,
    caches: Any = None,
    offset: jax.Array | None = None,
    mesh=None,
    embed_mode: str | None = None,
    return_hidden: bool = False,
):
    """Returns (logits, new_caches, aux_loss). ``batch`` keys by family:
    tokens (+labels/mask for train), patch_embeds (vlm), frames (audio)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    mode = embed_mode or ("c2d" if (cfg.c2d_embedding and mesh is not None) else "plain")
    b_axes: tuple[str, ...] = ()
    if mesh is not None:
        from repro.sharding.partition import data_axes, axis_size

        d = data_axes(mesh)
        if d and b % axis_size(mesh, d) == 0:
            b_axes = d
        if "model" not in mesh.axis_names:
            mode = "plain"
    if "token_rows" in batch:
        # serving-tier bypass: embedding rows were gathered remotely
        # (CQ futures over the PE fabric) instead of looked up here —
        # rows arrive pre-lookup, so the rest of the pipeline (embed_mult,
        # frontends, blocks) is shared with the local-embed path
        x = batch["token_rows"].astype(cfg.dtype)
    else:
        x = embed_tokens(
            params["embed.tok"], tokens, mode=mode, mesh=mesh, batch_axes=b_axes
        )
    if cfg.embed_mult != 1.0:
        x = (x.astype(jnp.float32) * cfg.embed_mult).astype(x.dtype)
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))

    off = offset if offset is not None else jnp.int32(0)
    pos = off + jnp.arange(s)

    if cfg.is_encdec:
        enc_out = None
        if "frames" in batch:  # train / prefill: run the encoder
            enc_out = encode(cfg, params, batch["frames"].astype(cfg.dtype), mesh=mesh)
        h, new_caches = run_decoder(
            cfg, params, x, enc_out=enc_out, pos=pos, caches=caches,
            offset=offset, mesh=mesh,
        )
        aux = jnp.float32(0.0)
    else:
        h, new_caches, aux = run_blocks(
            cfg, params, x, pos=pos, caches=caches, offset=offset, mesh=mesh
        )
    if return_hidden:
        return h, new_caches, aux
    return _head(cfg, params, h), new_caches, aux


# ----------------------------------------------------------------- KV cache
def init_kv_cache(
    cfg: ModelConfig,
    batch: int,
    t_max: int,
    enc_len: int = 0,
    dtype=jnp.bfloat16,
    as_specs: bool = False,
) -> Any:
    """Leading-L cache pytree (scan xs). ``as_specs`` returns
    ShapeDtypeStructs instead of zeros (dry-run)."""
    L, D = cfg.n_layers, cfg.d_model
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if as_specs else (
        lambda s, dt: jnp.zeros(s, dt)
    )
    if cfg.family == "rwkv":
        h = D // cfg.rwkv_head_dim
        m = cfg.rwkv_head_dim
        return {
            "tm_shift": mk((L, batch, 1, D), dtype),
            "cm_shift": mk((L, batch, 1, D), dtype),
            "wkv": mk((L, batch, h, m, m), jnp.float32),
        }
    cache = {
        "k": mk((L, batch, t_max, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": mk((L, batch, t_max, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    if cfg.family == "hybrid":
        cache["conv"] = mk((L, batch, CONV_K - 1, D), dtype)
        cache["h"] = mk((L, batch, D, cfg.ssm_state), jnp.float32)
    if cfg.is_encdec:
        cache["xk"] = mk((L, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        cache["xv"] = mk((L, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
    return cache


# -------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeSpec | str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    fl = frontend_len(cfg, s)
    if shape.kind == "train":
        specs: dict[str, Any] = {
            "tokens": sds((b, s), i32),
            "labels": sds((b, s), i32),
            "mask": sds((b, s), jnp.float32),
        }
        if cfg.frontend == "patch":
            specs["patch_embeds"] = sds((b, fl, cfg.d_model), jnp.float32)
        if cfg.frontend == "audio":
            specs["frames"] = sds((b, fl, cfg.d_model), jnp.float32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((b, s), i32)}
        if cfg.frontend == "patch":
            specs["patch_embeds"] = sds((b, fl, cfg.d_model), jnp.float32)
        if cfg.frontend == "audio":
            specs["frames"] = sds((b, fl, cfg.d_model), jnp.float32)
        return specs
    # decode: one token against a seq_len-deep cache
    cache = init_kv_cache(cfg, b, s, enc_len=fl, as_specs=True)
    return {
        "tokens": sds((b, 1), i32),
        "pos": sds((), i32),
        "cache": cache,
    }


def make_batch(cfg: ModelConfig, shape: ShapeSpec | str, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (train/prefill kinds)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    rng = np.random.default_rng(seed)
    b, s = shape.global_batch, shape.seq_len
    fl = frontend_len(cfg, s)
    batch: dict[str, Any] = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    }
    if shape.kind == "train":
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
        mask = np.ones((b, s), np.float32)
        mask[:, :fl] = 0.0  # frontend positions carry no LM loss
        batch["mask"] = jnp.asarray(mask)
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, fl, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (b, fl, cfg.d_model)), jnp.float32
        )
    return batch


# -------------------------------------------------------------------- steps
LOSS_CHUNK = 512  # S-chunked softmax-xent: the (B,S,V) f32 logits never exist


def _chunked_xent(
    cfg: ModelConfig, params: Params, h: jax.Array, labels, mask
) -> jax.Array:
    """Head matmul + cross-entropy over S chunks of the final hidden state.

    The full (B, S, Vp) f32 logits tensor (1.5-2.5 GB/device for the
    152k/256k-vocab archs) never materializes: each chunk's logits live
    only inside a remat'd scan body.  This is the standard chunked-softmax
    loss (MaxText-style)."""
    b, s, _ = h.shape
    c = LOSS_CHUNK
    if s % c or s <= c:
        logits = _head(cfg, params, h)
        return cross_entropy(logits, labels, cfg.vocab, mask)
    n = s // c
    hs = jnp.moveaxis(h.reshape(b, n, c, -1), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    ms = (
        jnp.moveaxis(mask.reshape(b, n, c), 1, 0)
        if mask is not None
        else jnp.ones((n, b, c), jnp.float32)
    )

    @jax.checkpoint
    def body(acc, xs):
        h_i, l_i, m_i = xs
        logits = _head(cfg, params, h_i)
        v = logits.shape[-1]
        if v > cfg.vocab:
            neg = jnp.asarray(-1e9, logits.dtype)
            logits = jnp.where(jnp.arange(v) < cfg.vocab, logits, neg)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        nll, cnt = acc
        return (nll + jnp.sum((lse - gold) * m_i), cnt + jnp.sum(m_i)), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return nll / jnp.maximum(cnt, 1.0)


def loss_fn(
    cfg: ModelConfig, params: Params, batch: dict, mesh=None
) -> tuple[jax.Array, dict]:
    h, _, aux = forward(cfg, params, batch, mesh=mesh, return_hidden=True)
    nll = _chunked_xent(cfg, params, h, batch["labels"], batch.get("mask"))
    loss = nll + AUX_LOSS_WEIGHT * aux
    return loss, {"nll": nll, "aux": aux}


def make_train_step(
    cfg: ModelConfig, opt, mesh=None, zero1: bool = True, fsdp: bool = False
) -> Callable:
    """(state, batch) -> (state, metrics); state = {params, opt, step}.

    ``cfg.microbatch > 1`` splits the batch and accumulates gradients over
    a remat'd scan: peak activation memory divides by the microbatch count
    while the f32 accumulator lives at the ZeRO/FSDP sharding (tiny).  The
    per-device batch is fixed by the assignment's global_batch, so this is
    THE memory lever for the 15-42B train cells.
    """
    constrain = None
    if mesh is not None and zero1:
        from repro.sharding.partition import (
            fsdp_shardings,
            rules_for_train,
            zero1_shardings,
        )

        p_sds, axes = build_params(cfg, abstract=True)
        rules = rules_for_train(cfg, mesh)
        constrain = (
            fsdp_shardings(p_sds, axes, mesh, rules=rules)
            if fsdp
            else zero1_shardings(p_sds, axes, mesh, rules=rules)
        )

    def wsc_tree(grads):
        if constrain is None:
            return grads
        return {
            k: jax.lax.with_sharding_constraint(g, constrain[k])
            for k, g in grads.items()
        }

    def grad_once(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, mesh=mesh), has_aux=True
        )(params)
        return loss, parts, grads

    def train_step(state: dict, batch: dict):
        mb = max(int(cfg.microbatch), 1)
        b = batch["tokens"].shape[0]
        if mb > 1 and b % mb == 0:
            split = lambda x: jnp.moveaxis(
                x.reshape(mb, b // mb, *x.shape[1:]), 0, 0
            )
            mbatches = {k: split(v) for k, v in batch.items()}

            def body(acc, mbatch):
                loss, parts, grads = grad_once(state["params"], mbatch)
                grads = wsc_tree(grads)
                acc = {
                    k: a + grads[k].astype(jnp.float32) for k, a in acc.items()
                }
                return acc, (loss, parts)

            acc0 = {
                k: jnp.zeros(p.shape, jnp.float32)
                for k, p in state["params"].items()
            }
            acc0 = wsc_tree(acc0)
            acc, (losses, parts) = jax.lax.scan(body, acc0, mbatches)
            grads = {k: a / mb for k, a in acc.items()}
            loss = jnp.mean(losses)
            parts = {k: jnp.mean(v) for k, v in parts.items()}
        else:
            loss, parts, grads = grad_once(state["params"], batch)
        new_params, new_opt, om = opt.update(
            grads, state["opt"], state["params"], constrain=constrain
        )
        metrics = {"loss": loss, **parts, **om, "step": state["step"] + 1}
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return train_step


def make_eval_step(cfg: ModelConfig, mesh=None) -> Callable:
    def eval_step(params: Params, batch: dict):
        loss, parts = loss_fn(cfg, params, batch, mesh=mesh)
        return {"loss": loss, **parts}

    return eval_step


def make_prefill_step(cfg: ModelConfig, mesh=None) -> Callable:
    """Populate a seq_len cache from the prompt; logits for the last token."""

    def prefill_step(params: Params, batch: dict):
        b, s = batch["tokens"].shape
        fl = frontend_len(cfg, s)
        cache = init_kv_cache(cfg, b, s, enc_len=fl, dtype=cfg.dtype)
        if cfg.is_encdec and "frames" not in batch:
            raise ValueError("enc-dec prefill needs frames")
        h, cache, _ = forward(
            cfg, params, batch, caches=cache, offset=jnp.int32(0), mesh=mesh,
            return_hidden=True,
        )
        # head over the LAST position only: the (B, S, V) prompt logits
        # are never needed for decoding and never materialize
        logits = _head(cfg, params, h[:, -1:, :])
        return logits[:, -1, :], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None, remote_embed: bool = False) -> Callable:
    """One decode step: next-token logits + updated cache.

    ``remote_embed`` builds the serving-tier variant whose embedding rows
    are fetched off-host (gathered via CQ futures over the PE fabric,
    see :class:`repro.runtime.tenancy.RemoteEmbedClient`): the step takes
    an extra ``rows`` argument — ``(B, S, D)`` pre-lookup embedding rows —
    and never touches ``params["embed.tok"]`` for the lookup, so the two
    variants produce bit-identical streams when fed the same rows."""

    if remote_embed:

        def serve_step_remote(
            params: Params, cache: Any, tokens: jax.Array, pos: jax.Array,
            rows: jax.Array,
        ):
            logits, cache, _ = forward(
                cfg, params, {"tokens": tokens, "token_rows": rows},
                caches=cache, offset=pos, mesh=mesh,
            )
            return logits[:, -1, :], cache

        return serve_step_remote

    def serve_step(params: Params, cache: Any, tokens: jax.Array, pos: jax.Array):
        logits, cache, _ = forward(
            cfg, params, {"tokens": tokens}, caches=cache, offset=pos, mesh=mesh
        )
        return logits[:, -1, :], cache

    return serve_step


def make_steps(cfg: ModelConfig, opt=None, mesh=None) -> dict[str, Callable]:
    from repro.optim import AdamW

    opt = opt or AdamW()
    return {
        "train": make_train_step(cfg, opt, mesh=mesh),
        "eval": make_eval_step(cfg, mesh=mesh),
        "prefill": make_prefill_step(cfg, mesh=mesh),
        "serve": make_serve_step(cfg, mesh=mesh),
    }
