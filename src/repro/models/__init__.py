"""Model zoo: the 10 assigned architectures as one composable LM substrate.

Families: dense GQA transformers, MoE transformers (EP token dispatch =
X-RDMA compute-to-data at tensor scale), RWKV6 linear attention, hybrid
attn+SSM (Hymba), encoder-decoder (Seamless backbone), VLM/audio backbones
with stub modality frontends.
"""

from .common import ModelConfig
from .zoo import build_params, init_kv_cache, input_specs, make_steps

__all__ = ["ModelConfig", "build_params", "init_kv_cache", "input_specs", "make_steps"]
