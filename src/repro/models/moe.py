"""Mixture-of-Experts: top-k routing with expert-parallel dispatch.

This layer is the paper's technique at tensor scale: *ship the tokens to
the shard that owns their expert* (the X-RDMA Chaser — indices+payload
travel, tables stay put), vs. *replicate the experts* (the GET baseline).

Dispatch modes:

* ``a2a`` (compute-to-data, production path) — explicit ``shard_map``:
  tokens are bucketed by destination EP rank, exchanged with
  ``lax.all_to_all`` over the ``model`` axis, processed by the local
  experts, and returned by a second all_to_all.  Wire cost per token:
  2 x topk x D x capacity-slack — independent of expert count.  This is
  the exact collective the paper's DAPC maps to; the naive scatter
  formulation (kept below as ``scatter`` for ablation) lowers under GSPMD
  to (E*C, D)-sized all-reduces per topk slot — measured 40x more
  collective bytes (EXPERIMENTS.md §Perf).

* ``eplocal`` — every rank runs its E_loc experts over all tokens,
  gate-masked, one psum of (N, D) partials.  Compute-inflated by E/topk
  over the useful work, but comm is one small psum: the right trade for
  S=1 decode steps.  Used automatically when tokens cannot shard over the
  EP axis.

* ``replicated`` (move-data-to-compute, the GET/GBPC baseline) — every
  device evaluates all experts over its tokens; expert weights replicated.

* ``scatter`` — the original capacity-buffer scatter/gather formulation
  (single-device reference semantics; the oracle the a2a path is tested
  against).

Router: softmax -> top-k (renormalized) + Shazeer load-balance aux loss.
Overflow beyond capacity is dropped (residual passes through), the
Switch/GShard scheme.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as compat_shard_map


def moe_capacity(n_tokens: int, n_experts: int, topk: int, factor: float = 1.25) -> int:
    c = int(n_tokens * topk * factor / n_experts)
    return max(8, -(-c // 8) * 8)  # multiple of 8, at least 8


def route(x: jax.Array, w_router: jax.Array, topk: int):
    """x: (N, D) -> gates (N, k), idx (N, k), aux load-balance loss."""
    logits = (x.astype(jnp.float32)) @ w_router.astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, topk)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    e = w_router.shape[-1]
    onehot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)  # primary expert
    aux = e * jnp.mean(jnp.mean(onehot, axis=0) * jnp.mean(probs, axis=0))
    return gates.astype(x.dtype), idx, aux


def _bucket_positions(dst: jax.Array, n_buckets: int, capacity: int):
    """Rank of each element within its destination bucket (cumsum, no sort).

    dst: (M,) int32 bucket ids. Returns (slot, keep): slot in
    [0, n_buckets*capacity), keep=False for overflow drops.
    """
    onehot = jax.nn.one_hot(dst, n_buckets, dtype=jnp.int32)  # (M, B)
    ranks = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(ranks, dst[:, None], axis=1)[:, 0]
    keep = pos < capacity
    slot = dst * capacity + jnp.minimum(pos, capacity - 1)
    return slot, keep


def expert_ffn(buf: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    """(E, C, D) x per-expert SwiGLU -> (E, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    a = jax.nn.silu(h) * g
    return jnp.einsum("ecf,efd->ecd", a, wo)


# --------------------------------------------------------- scatter reference
def moe_block_scatter(
    x: jax.Array,  # (B, S, D)
    w_router: jax.Array,  # (D, E)
    wi: jax.Array,  # (E, D, F)
    wg: jax.Array,
    wo: jax.Array,
    topk: int,
    capacity_factor: float = 1.25,
):
    """Capacity-buffer scatter/gather (single-device reference semantics)."""
    b, s, d = x.shape
    e = w_router.shape[-1]
    n = b * s
    xt = x.reshape(n, d)
    gates, idx, aux = route(xt, w_router, topk)
    cap = moe_capacity(n, e, topk, capacity_factor)
    slot, keep = _bucket_positions(idx.reshape(-1), e, cap)
    slot, keep = slot.reshape(n, topk), keep.reshape(n, topk)

    buf = jnp.zeros((e * cap, d), x.dtype)
    contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(x.dtype)
    for j in range(topk):  # topk is tiny (2 or 8); unrolled adds stay fusable
        buf = buf.at[slot[:, j]].add(xt * contrib[:, j])
    y_buf = expert_ffn(buf.reshape(e, cap, d), wi, wg, wo).reshape(e * cap, d)
    y = jnp.zeros_like(xt)
    for j in range(topk):
        y = y + y_buf[slot[:, j]] * (gates[:, j] * keep[:, j])[:, None]
    return y.reshape(b, s, d), aux


# ----------------------------------------------------- a2a production path
def moe_block_a2a(
    x: jax.Array,  # (B, S, D) sharded P(data, None, None)
    w_router: jax.Array,
    wi: jax.Array,  # (E, D, F) sharded P(model/EP, None, None)
    wg: jax.Array,
    wo: jax.Array,
    topk: int,
    mesh: Mesh,
    ep_axis: str = "model",
    capacity_factor: float = 1.25,
):
    """Token dispatch by explicit all_to_all over the EP axis (shard_map).

    Per device: bucket local tokens by destination rank (cumsum, capacity
    C_pair per (src,dst) pair), all_to_all the (M, C_pair, D) buckets,
    run the E_loc local experts gate-masked over the received tokens,
    all_to_all back, combine at the source slots.
    """
    b, s, d = x.shape
    e = w_router.shape[-1]
    m = mesh.shape[ep_axis]
    assert e % m == 0, (e, m)
    e_loc = e // m
    from repro.sharding.partition import data_axes

    d_axes = data_axes(mesh)
    d_spec = (d_axes if len(d_axes) > 1 else d_axes[0]) if d_axes else None
    b_div = d_axes and b % _axes_size(mesh, d_axes) == 0
    b_spec = d_spec if b_div else None
    s_div = s % m == 0 and s >= m
    if not s_div:
        # tokens cannot shard over the EP axis (decode S=1): eplocal mode
        return _moe_eplocal(
            x, w_router, wi, wg, wo, topk, mesh, ep_axis, b_spec
        )

    n_loc = (b // _axes_size(mesh, d_axes) if b_div else b) * (s // m)
    c_pair = max(8, -(-int(n_loc * topk * capacity_factor / m) // 8) * 8)

    def body(x_l, wr, wi_l, wg_l, wo_l):
        bl, sl, _ = x_l.shape
        n = bl * sl
        xt = x_l.reshape(n, d)
        gates, idx, aux = route(xt, wr, topk)
        dst = (idx // e_loc).reshape(-1)  # destination EP rank per choice
        e_local_id = (idx % e_loc).reshape(-1)
        slot, keep = _bucket_positions(dst, m, c_pair)
        contrib = jnp.where(keep[:, None], 1.0, 0.0).astype(x_l.dtype)
        xk = jnp.repeat(xt, topk, axis=0)  # (n*k, D) choice-major payloads
        send = jnp.zeros((m * c_pair, d), x_l.dtype).at[slot].add(xk * contrib)
        meta = jnp.full((m * c_pair,), e_loc, jnp.int32)  # e_loc = invalid
        meta = meta.at[slot].set(jnp.where(keep, e_local_id, e_loc))

        recv = jax.lax.all_to_all(
            send.reshape(m, c_pair, d), ep_axis, split_axis=0, concat_axis=0,
            tiled=False,
        ).reshape(m * c_pair, d)
        meta_r = jax.lax.all_to_all(
            meta.reshape(m, c_pair), ep_axis, split_axis=0, concat_axis=0,
            tiled=False,
        ).reshape(m * c_pair)

        # local experts, gate-masked over received tokens (E_loc is 1-2)
        y_r = jnp.zeros_like(recv)
        for el in range(e_loc):
            mask = (meta_r == el)[:, None].astype(recv.dtype)
            h = (recv * mask) @ wi_l[el]
            g = (recv * mask) @ wg_l[el]
            y_r = y_r + (jax.nn.silu(h) * g) @ wo_l[el] * mask

        back = jax.lax.all_to_all(
            y_r.reshape(m, c_pair, d), ep_axis, split_axis=0, concat_axis=0,
            tiled=False,
        ).reshape(m * c_pair, d)
        y_fl = back[slot] * (gates.reshape(-1) * keep).astype(x_l.dtype)[:, None]
        y = jnp.sum(y_fl.reshape(n, topk, d), axis=1)
        aux = jax.lax.pmean(aux, ep_axis)
        for ax in d_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(bl, sl, d), aux

    y, aux = compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(b_spec, ep_axis, None),
            P(None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
        ),
        out_specs=(P(b_spec, ep_axis, None), P()),
    )(x, w_router, wi, wg, wo)
    return y, aux


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _moe_eplocal(x, w_router, wi, wg, wo, topk, mesh, ep_axis, b_spec):
    """Each rank: its E_loc experts over ALL tokens, gate-masked, one psum.

    Right for S=1 decode (tokens can't shard over EP; compute is tiny)."""
    b, s, d = x.shape
    e = w_router.shape[-1]
    m = mesh.shape[ep_axis]
    e_loc = e // m

    def body(x_l, wr, wi_l, wg_l, wo_l):
        bl, sl, _ = x_l.shape
        n = bl * sl
        xt = x_l.reshape(n, d)
        gates, idx, aux = route(xt, wr, topk)
        me = jax.lax.axis_index(ep_axis)
        y = jnp.zeros_like(xt)
        for el in range(e_loc):
            ge = me * e_loc + el  # global expert id owned by this rank
            gate_e = jnp.sum(
                jnp.where(idx == ge, gates, jnp.zeros((), gates.dtype)), axis=-1
            )  # (n,)
            h = xt @ wi_l[el]
            g = xt @ wg_l[el]
            y = y + (jax.nn.silu(h) * g) @ wo_l[el] * gate_e[:, None]
        y = jax.lax.psum(y, ep_axis)
        aux = jax.lax.pmean(aux, ep_axis)
        return y.reshape(bl, sl, d), aux

    y, aux = compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(b_spec, None, None),
            P(None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
            P(ep_axis, None, None),
        ),
        out_specs=(P(b_spec, None, None), P()),
    )(x, w_router, wi, wg, wo)
    return y, aux


# ------------------------------------------------------ replicated baseline
def moe_block_replicated(
    x: jax.Array,
    w_router: jax.Array,
    wi: jax.Array,
    wg: jax.Array,
    wo: jax.Array,
    topk: int,
):
    """GET-style baseline: all experts run over all tokens, gate-masked.

    Compute cost E/topk x the dispatch path; expert weights replicated
    (all-gathered under GSPMD) — the paper's GBPC analogue."""
    b, s, d = x.shape
    n = b * s
    xt = x.reshape(n, d)
    gates, idx, aux = route(xt, w_router, topk)
    e = w_router.shape[-1]
    dense_gates = jnp.zeros((n, e), x.dtype)
    for j in range(topk):
        dense_gates = dense_gates.at[jnp.arange(n), idx[:, j]].add(gates[:, j])
    h = jnp.einsum("nd,edf->enf", xt, wi)
    g = jnp.einsum("nd,edf->enf", xt, wg)
    y_all = jnp.einsum("enf,efd->end", jax.nn.silu(h) * g, wo)
    y = jnp.einsum("end,ne->nd", y_all, dense_gates)
    return y.reshape(b, s, d), aux


def moe_block(x, w_router, wi, wg, wo, topk, mode: str = "c2d", mesh=None):
    e = w_router.shape[-1]
    if mode == "replicated":
        return moe_block_replicated(x, w_router, wi, wg, wo, topk)
    if (
        mode == "c2d"
        and mesh is not None
        and "model" in mesh.axis_names
        and e % mesh.shape["model"] == 0
    ):
        return moe_block_a2a(x, w_router, wi, wg, wo, topk, mesh)
    return moe_block_scatter(x, w_router, wi, wg, wo, topk)
