"""Token embedding + LM head: compute-to-data (c2d) vs. gather baselines.

This is the paper's DAPC-vs-GBPC dichotomy rendered at tensor scale
(DESIGN.md §2).  The vocabulary table is sharded over the ``model`` mesh
axis.  To look up a token you either:

* **c2d** (ship the indices — X-RDMA style): every shard looks up the ids
  that fall inside its own vocab slice (masked local take) and the partial
  (B, S, D) results are ``psum``-combined.  Wire cost per token: one D-dim
  vector reduce — independent of vocab size.  Implemented with
  ``shard_map`` so the collective is explicit and auditable in the HLO.

* **gather** (ship the data — GET/GBPC style): replicate (all-gather) the
  table, then take locally.  Wire cost per step: the whole table
  (vocab × D), the analogue of GBPC pulling entries to the client.

* **auto**: plain ``jnp.take`` under GSPMD — whatever the partitioner
  picks.  Kept as a reference point for §Perf.

The LM head is the transpose problem: h @ W produces vocab-sharded logits
(softmax over a sharded axis — GSPMD inserts the max/sum all-reduces, which
are D-free and cheap).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map as compat_shard_map


def embed_plain(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Single-device / smoke-test path."""
    return jnp.take(table, ids, axis=0)


def embed_auto(table: jax.Array, ids: jax.Array) -> jax.Array:
    """GSPMD-native gather: the partitioner decides the collective."""
    return jnp.take(table, ids, axis=0)


def embed_gather(table: jax.Array, ids: jax.Array, mesh: Mesh | None) -> jax.Array:
    """GET-style baseline: force table replication before the local take."""
    if mesh is not None:
        table = jax.lax.with_sharding_constraint(
            table, NamedSharding(mesh, P(None, None))
        )
    return jnp.take(table, ids, axis=0)


def embed_c2d(
    table: jax.Array,
    ids: jax.Array,
    mesh: Mesh,
    vocab_axis: str = "model",
    batch_axes: tuple[str, ...] = ("data",),
) -> jax.Array:
    """Ship-the-indices lookup over a vocab-sharded table.

    table: (Vp, D) sharded P("model", None); ids: (B, S) sharded over batch.
    Each shard takes ids falling in [lo, hi) from its local slice, zeroes
    the rest, and the partials are psum'd over the vocab axis — the Chaser
    pattern: the table never moves, D-sized results do.
    """
    n_shards = mesh.shape[vocab_axis]
    vp = table.shape[0]
    assert vp % n_shards == 0, (vp, n_shards)
    local_v = vp // n_shards

    def local_lookup(tab: jax.Array, ids_l: jax.Array) -> jax.Array:
        shard = jax.lax.axis_index(vocab_axis)
        lo = shard * local_v
        loc = ids_l - lo
        inside = (loc >= 0) & (loc < local_v)
        loc = jnp.clip(loc, 0, local_v - 1)
        part = jnp.take(tab, loc, axis=0)
        part = jnp.where(inside[..., None], part, jnp.zeros((), part.dtype))
        return jax.lax.psum(part, vocab_axis)

    b = tuple(batch_axes) if batch_axes else None
    return compat_shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(P(vocab_axis, None), P(b, None)),
        out_specs=P(b, None, None),
    )(table, ids)


def embed_tokens(
    table: jax.Array,
    ids: jax.Array,
    mode: str = "plain",
    mesh: Mesh | None = None,
    vocab_axis: str = "model",
    batch_axes: tuple[str, ...] = ("data",),
) -> jax.Array:
    if mode == "c2d" and mesh is not None:
        return embed_c2d(table, ids, mesh, vocab_axis, batch_axes)
    if mode == "gather":
        return embed_gather(table, ids, mesh)
    if mode == "auto":
        return embed_auto(table, ids)
    return embed_plain(table, ids)


def lm_head(h: jax.Array, w: jax.Array) -> jax.Array:
    """h: (B, S, D) @ w: (D, Vp) -> vocab-sharded logits (B, S, Vp)."""
    return jnp.einsum("bsd,dv->bsv", h, w)
