"""Transformer stacks: dense / MoE / hybrid decoder-only + encoder-decoder.

Layers are stacked on a leading ``layers`` axis and iterated with
``lax.scan`` (small HLO: one block body regardless of depth — this is what
keeps 512-device SPMD compiles tractable).  The same ``forward`` serves
training (no cache), prefill (cache write from offset 0) and decode
(cache write at offset t): caches are scan xs/ys.

Block families:
  dense   — GQA attention + SwiGLU MLP (starcoder2, qwen2.5, yi, gemma2,
            internvl2 backbone)
  moe     — GQA attention + top-k expert MLP (phi3.5-moe, granite-moe)
  rwkv    — RWKV6 time-mix + channel-mix (attention-free)
  hybrid  — parallel GQA + SSM heads, then SwiGLU MLP (hymba)
  encdec  — bidirectional encoder + causal decoder with cross-attention
            (seamless backbone)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .attention import attend, attend_chunked, attend_sp, qkv_proj, update_kv_cache
from .common import ModelConfig, ParamFactory, mlp, rms_norm
from .moe import moe_block
from .rwkv import add_rwkv_block_params, rwkv_block
from .ssm import add_ssm_params, ssm_head

Params = dict[str, jax.Array]


# ----------------------------------------------------------------- params
def add_attn_params(
    f: ParamFactory, cfg: ModelConfig, prefix: str, n_layers: int | None = None, tag: str = ""
) -> None:
    L = n_layers if n_layers is not None else cfg.n_layers
    D = cfg.d_model
    lay = lambda *s: (L, *s)
    f.add(f"{prefix}.wq{tag}", lay(D, cfg.qkv_dim), ("layers", "embed", "q_dim"))
    f.add(f"{prefix}.wk{tag}", lay(D, cfg.kv_dim), ("layers", "embed", "kv_dim"))
    f.add(f"{prefix}.wv{tag}", lay(D, cfg.kv_dim), ("layers", "embed", "kv_dim"))
    f.add(f"{prefix}.wo{tag}", lay(cfg.qkv_dim, D), ("layers", "q_dim", "embed"))
    if cfg.qkv_bias and not tag:
        f.add(f"{prefix}.bq", lay(cfg.qkv_dim), ("layers", "q_dim"), init="zeros")
        f.add(f"{prefix}.bk", lay(cfg.kv_dim), ("layers", "kv_dim"), init="zeros")
        f.add(f"{prefix}.bv", lay(cfg.kv_dim), ("layers", "kv_dim"), init="zeros")


def add_mlp_params(f: ParamFactory, cfg: ModelConfig, prefix: str, n_layers: int | None = None) -> None:
    L = n_layers if n_layers is not None else cfg.n_layers
    D, F = cfg.d_model, cfg.d_ff
    f.add(f"{prefix}.wi", (L, D, F), ("layers", "embed", "ffn"))
    if cfg.mlp_gated:
        f.add(f"{prefix}.wg", (L, D, F), ("layers", "embed", "ffn"))
    f.add(f"{prefix}.wo2", (L, F, D), ("layers", "ffn", "embed"))


def add_moe_params(f: ParamFactory, cfg: ModelConfig, prefix: str) -> None:
    L, D, F, E = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    f.add(f"{prefix}.router", (L, D, E), ("layers", "embed", None), scale=0.02)
    f.add(f"{prefix}.we_i", (L, E, D, F), ("layers", "experts", "embed", "ffn"))
    f.add(f"{prefix}.we_g", (L, E, D, F), ("layers", "experts", "embed", "ffn"))
    f.add(f"{prefix}.we_o", (L, E, F, D), ("layers", "experts", "ffn", "embed"))


def add_block_params(f: ParamFactory, cfg: ModelConfig, prefix: str = "blocks") -> None:
    if cfg.family == "rwkv":
        add_rwkv_block_params(f, cfg, prefix)
        return
    L = cfg.n_layers
    f.add(f"{prefix}.ln1", (L, cfg.d_model), ("layers", "embed"), init="zeros")
    f.add(f"{prefix}.ln2", (L, cfg.d_model), ("layers", "embed"), init="zeros")
    add_attn_params(f, cfg, prefix)
    if cfg.family == "moe":
        add_moe_params(f, cfg, prefix)
    else:
        add_mlp_params(f, cfg, prefix)
    if cfg.family == "hybrid":
        add_ssm_params(f, cfg, prefix + ".ssm")
        f.add(f"{prefix}.beta_attn", (L, cfg.d_model), ("layers", "embed"), init="ones")
        f.add(f"{prefix}.beta_ssm", (L, cfg.d_model), ("layers", "embed"), init="ones")


# ------------------------------------------------------------- sublayers
def attn_sublayer(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    pos: jax.Array,  # (S,) absolute positions of x's tokens
    window: jax.Array,  # scalar int32: 0 => global
    cache: tuple[jax.Array, jax.Array] | None,
    offset: jax.Array | None,
    causal: bool = True,
    mesh=None,
):
    from .common import rope as _rope

    q, k, v = qkv_proj(
        x,
        p["wq"], p["wk"], p["wv"],
        p.get("bq"), p.get("bk"), p.get("bv"),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
    )
    q = _rope(q, pos, cfg.rope_theta)
    k = _rope(k, pos, cfg.rope_theta)
    s = x.shape[1]
    chunked = cfg.attn_chunk and s > cfg.attn_chunk and pos.ndim == 1

    # sequence-parallel attention for head counts that do not divide the
    # TP axis (qwen 40H, hymba 25H, gemma2 8H): explicit shard_map keeps
    # queries S-sharded end to end — see attention.attend_sp
    sp_attn = (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.n_heads % mesh.shape["model"] != 0
        and s % mesh.shape["model"] == 0
        and s > 1
        and pos.ndim == 1
    )

    def _attend(q, k, v, *, q_pos, k_pos, k_valid=None):
        if sp_attn and k_valid is None and k.shape[1] == s:
            from repro.sharding.partition import axis_size, data_axes

            d = data_axes(mesh)
            b_axes = d if (d and q.shape[0] % axis_size(mesh, d) == 0) else ()
            return attend_sp(
                q, k, v, q_pos=q_pos, k_pos=k_pos, mesh=mesh,
                batch_axes=b_axes, chunk=cfg.attn_chunk, causal=causal,
                window=window, cap=cfg.attn_softcap,
            )
        if chunked:
            from .attention import auto_chunk

            # per-device logits block: batch shards over data, heads over
            # model (when divisible) — size the q-chunk for what remains
            b_loc, h_loc = q.shape[0], q.shape[2]
            if mesh is not None:
                from repro.sharding.partition import axis_size, data_axes

                d = data_axes(mesh)
                if d and b_loc % axis_size(mesh, d) == 0:
                    b_loc //= axis_size(mesh, d)
                m = mesh.shape.get("model", 1)
                if h_loc % m == 0:
                    h_loc //= m
            c = auto_chunk(b_loc, h_loc, s, k.shape[1], cap=cfg.attn_chunk)
            return attend_chunked(
                q, k, v, q_pos=q_pos, k_pos=k_pos, chunk=c,
                causal=causal, window=window, cap=cfg.attn_softcap,
                k_valid=k_valid,
            )
        return attend(
            q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal, window=window,
            cap=cfg.attn_softcap, k_valid=k_valid,
        )

    if cache is not None:
        k_cache, v_cache = cache
        k_cache, v_cache = update_kv_cache(k_cache, v_cache, k, v, offset)
        t_max = k_cache.shape[1]
        if s == t_max:
            # prefill fills the whole cache from offset 0: attending over
            # the fresh k/v is identical and skips the cache-layout round
            # trip (also unlocks the SP path for odd-head archs)
            out = _attend(q, k, v, q_pos=pos, k_pos=pos)
        else:
            k_pos = jnp.arange(t_max)
            k_valid = (k_pos < offset + s)[None, :]
            k_valid = jnp.broadcast_to(k_valid, (x.shape[0], t_max))
            out = _attend(
                q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                q_pos=pos, k_pos=k_pos, k_valid=k_valid,
            )
        new_cache = (k_cache, v_cache)
    else:
        out = _attend(q, k, v, q_pos=pos, k_pos=pos)
        new_cache = None
    return out.reshape(*x.shape[:2], -1) @ p["wo"], new_cache


def _strip(p: Params, prefix: str) -> dict:
    pl = len(prefix) + 1
    return {k[pl:]: v for k, v in p.items() if k.startswith(prefix + ".")}


# ---------------------------------------------------------------- blocks
def block_apply(
    x: jax.Array,
    p: dict,  # per-layer slices (keys without the "blocks." prefix)
    cfg: ModelConfig,
    *,
    pos: jax.Array,
    window: jax.Array,
    cache: Any,
    offset: jax.Array | None,
    mesh=None,
):
    """One decoder block of any family. Returns (x, new_cache, aux_loss)."""
    if cfg.family == "rwkv":
        x, new_state = rwkv_block(x, p, cfg, cache, mesh=mesh)
        return x, new_state, jnp.float32(0.0)

    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kv_cache = None if cache is None else (cache["k"], cache["v"])
    att, new_kv = attn_sublayer(
        h, p, cfg, pos=pos, window=window, cache=kv_cache, offset=offset,
        mesh=mesh,
    )
    if cfg.family == "hybrid":
        ssm_state = None if cache is None else {"conv": cache["conv"], "h": cache["h"]}
        ssm_out, new_ssm = ssm_head(h, _strip_keep(p, "ssm"), cfg, ssm_state, mesh=mesh)
        att = 0.5 * (att * p["beta_attn"] + ssm_out * p["beta_ssm"])
    x = x + att
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.family == "moe":
        y, aux = moe_block(
            h, p["router"], p["we_i"], p["we_g"], p["we_o"], cfg.topk,
            mode="c2d" if cfg.c2d_embedding else "replicated", mesh=mesh,
        )
    else:
        y = mlp(h, p["wi"], p.get("wg"), p["wo2"], cfg.act)
    x = x + y

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        if new_kv is not None:
            new_cache["k"], new_cache["v"] = new_kv
        if cfg.family == "hybrid":
            new_cache["conv"], new_cache["h"] = new_ssm["conv"], new_ssm["h"]
    return x, new_cache, aux


def _strip_keep(p: dict, sub: str) -> dict:
    """{'ssm.w_in': v} -> {'ssm.w_in': v} filtered (ssm_head expects 'ssm.' keys)."""
    return {k: v for k, v in p.items() if k.startswith(sub + ".")}


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer window sizes: 0 = global attention."""
    w = np.zeros(cfg.n_layers, np.int32)
    if cfg.window and cfg.global_every > 0:
        for i in range(cfg.n_layers):
            if not cfg.layer_is_global(i):
                w[i] = cfg.window
    elif cfg.window:
        w[:] = cfg.window
    return w


def run_blocks(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,
    *,
    pos: jax.Array,
    caches: Any = None,
    offset: jax.Array | None = None,
    prefix: str = "blocks",
    mesh=None,
):
    """Scan the block stack. caches: pytree with leading layer axis or None."""
    block_p = _strip(params, prefix)
    windows = jnp.asarray(layer_windows(cfg))
    # sequence-parallel residual stream (Megatron-SP): residuals (and so
    # the remat stack) are stored S-sharded; blocks gather what they need.
    # Recurrent families work too — their T-scans force a gather at the
    # scan input, but the stored carry stays 1/|model|.
    sp = mesh is not None

    def body(carry, xs):
        h, aux = carry
        p_l, win_l, cache_l = xs
        if sp:
            from repro.sharding.partition import sp_constrain

            h = sp_constrain(h, mesh)
        h, new_cache, aux_l = block_apply(
            h, p_l, cfg, pos=pos, window=win_l, cache=cache_l, offset=offset,
            mesh=mesh,
        )
        return (h, aux + aux_l), new_cache

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), new_caches = lax.scan(body, (x, jnp.float32(0.0)), (block_p, windows, caches))
    return x, new_caches, aux / cfg.n_layers
