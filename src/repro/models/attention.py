"""Grouped-query attention: train/prefill (full-sequence) and decode paths.

One einsum-based implementation covers all assigned archs: MHA (seamless,
kv=heads), GQA (kv<heads), sliding-window local layers + logit softcapping
(gemma2), QKV bias (qwen2.5).  Head grouping is explicit — q is reshaped to
(batch, seq, kv_heads, group, head_dim) so the contraction never repeats K/V
(repeat-free GQA keeps HLO bytes honest for the roofline).

The XLA-native einsum path is the default (visible to cost_analysis, GSPMD-
shardable); the Pallas flash kernel (repro.kernels.flash_attention) is an
opt-in for TPU prefill hot spots and is validated against this module.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compat import shard_map as compat_shard_map

from .common import softcap

NEG = -2.0**30  # mask value safe in bf16/f32

LOGITS_BUDGET = 512 * 1024 * 1024  # max live (chunk x T) f32 logits block


def auto_chunk(b: int, h: int, s: int, t: int, cap: int) -> int:
    """Largest power-of-2 q-chunk that divides ``s``, respects ``cap``, and
    keeps the (B, H, chunk, T) f32 logits block under LOGITS_BUDGET —
    chunk=1024 is right at T=4k but 10x over budget at T=32k."""
    limit = max(LOGITS_BUDGET // max(b * h * t * 4, 1), 128)
    c = 16
    while c * 2 <= min(cap, limit, s) and s % (c * 2) == 0:
        c *= 2
    return c


def _grouped(q: jax.Array, n_kv: int) -> jax.Array:
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attend(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, T, K, hd)
    v: jax.Array,  # (B, T, K, hd)
    *,
    q_pos: jax.Array,  # (S,) or (B, S) query positions
    k_pos: jax.Array,  # (T,) or (B, T) key positions
    causal: bool = True,
    window: jax.Array | int | None = None,  # 0 / None => global
    cap: float | None = None,
    k_valid: jax.Array | None = None,  # (B, T) cache-slot validity
    scale: float | None = None,
) -> jax.Array:
    """Returns (B, S, H, hd). Mask semantics: attend iff
    k_pos <= q_pos (causal) and q_pos - k_pos < window (local layers) and
    k_valid (decode: slot is filled)."""
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    qg = _grouped(q, n_kv)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32)
    logits = softcap(logits * scale, cap)

    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]  # (B|1, S)
    kp = k_pos if k_pos.ndim == 2 else k_pos[None, :]  # (B|1, T)
    mask = jnp.ones((qp.shape[0], s, kp.shape[-1]), bool)
    if causal:
        mask &= kp[:, None, :] <= qp[:, :, None]
    if window is not None:
        w = jnp.asarray(window)
        local = qp[:, :, None] - kp[:, None, :] < w
        mask &= jnp.where(w > 0, local, True)
    if k_valid is not None:
        mask &= k_valid[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG)

    att = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", att, v)
    return out.reshape(b, s, h, d)


def attend_chunked(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, T, K, hd)
    v: jax.Array,
    *,
    q_pos: jax.Array,  # (S,)
    k_pos: jax.Array,  # (T,)
    chunk: int,
    causal: bool = True,
    window: jax.Array | int | None = None,
    cap: float | None = None,
    k_valid: jax.Array | None = None,
) -> jax.Array:
    """Query-chunked attention: identical math to :func:`attend`, but the
    live logits block is (chunk x T) instead of (S x T).  This is the
    XLA-native memory shape of flash attention (the Pallas kernel
    additionally tiles T through VMEM); it is what makes 32k prefill fit.

    Requires S % chunk == 0 and 1-D q_pos (prefill/train, not ragged).
    """
    b, s, h, d = q.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, d), 1, 0)  # (nc, B, C, H, hd)
    pc = q_pos.reshape(nc, chunk)

    def body(_, xs):
        q_i, pos_i = xs
        o_i = attend(
            q_i, k, v, q_pos=pos_i, k_pos=k_pos, causal=causal,
            window=window, cap=cap, k_valid=k_valid,
        )
        return None, o_i

    _, out = jax.lax.scan(body, None, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)


def attend_sp(
    q: jax.Array,  # (B, S, H, hd) — S sharded over `axis`
    k: jax.Array,  # (B, S, K, hd) — S sharded over `axis`
    v: jax.Array,
    *,
    q_pos: jax.Array,  # (S,) full positions
    k_pos: jax.Array,  # (S,)
    mesh,
    axis: str = "model",
    batch_axes: tuple[str, ...] = (),
    chunk: int = 0,
    causal: bool = True,
    window: jax.Array | int | None = None,
    cap: float | None = None,
) -> jax.Array:
    """Sequence-parallel attention as an explicit shard_map.

    For archs whose head count does not divide the TP axis (qwen 40H,
    hymba 25H, gemma2 8H), the residual stream is S-sharded and heads
    cannot shard — so each rank keeps its S/|axis| queries, all-gathers
    the (small, GQA) K/V, and runs q-chunked attention locally.  The only
    collective is the K/V gather; GSPMD's alternative (resharding q/k/v
    per layer) measured 25x the bytes on qwen (EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    b, s, h, d = q.shape
    m = mesh.shape[axis]
    assert s % m == 0, (s, m)
    s_loc = s // m
    bspec = (tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]) if batch_axes else None
    win = jnp.asarray(0 if window is None else window, jnp.int32)

    def body(q_l, k_l, v_l, q_pos_f, k_pos_f, win_s):
        me = jax.lax.axis_index(axis)
        k_full = jax.lax.all_gather(k_l, axis, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_l, axis, axis=1, tiled=True)
        pos_l = jax.lax.dynamic_slice_in_dim(q_pos_f, me * s_loc, s_loc)
        kw = dict(
            q_pos=pos_l, k_pos=k_pos_f, causal=causal, window=win_s, cap=cap
        )
        c = auto_chunk(q_l.shape[0], h, s_loc, s, cap=chunk or s_loc)
        if c < s_loc:
            return attend_chunked(q_l, k_full, v_full, chunk=c, **kw)
        return attend(q_l, k_full, v_full, **kw)

    return compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, axis, None, None),
            P(bspec, axis, None, None),
            P(bspec, axis, None, None),
            P(None),
            P(None),
            P(),
        ),
        out_specs=P(bspec, axis, None, None),
    )(q, k, v, q_pos, k_pos, win)


def qkv_proj(
    x: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    bq: jax.Array | None = None,
    bk: jax.Array | None = None,
    bv: jax.Array | None = None,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = x @ wq
    k = x @ wk
    v = x @ wv
    if bq is not None:
        q, k, v = q + bq, k + bk, v + bv
    return (
        q.reshape(b, s, n_heads, head_dim),
        k.reshape(b, s, n_kv, head_dim),
        v.reshape(b, s, n_kv, head_dim),
    )


def update_kv_cache(
    k_cache: jax.Array,  # (B, T, K, hd)
    v_cache: jax.Array,
    k_new: jax.Array,  # (B, S, K, hd)
    v_new: jax.Array,
    offset: jax.Array,  # scalar: number of tokens already cached
) -> tuple[jax.Array, jax.Array]:
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), offset, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), offset, 1)
    return k_cache, v_cache
