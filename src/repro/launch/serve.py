"""Serving launcher: batched prefill + decode loop.

``python -m repro.launch.serve --arch gemma2-2b --batch 4 --prompt-len 64
--gen 32`` prefills a batch of prompts and decodes greedily, reporting
prefill/decode throughput.  The full-config serving path (32k/500k caches,
T-sharded over ``model``) is exercised abstractly by the dry-run; this
driver runs the same serve_step end-to-end on reduced configs.
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction, default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--remote-embed",
        action="store_true",
        help="serving-tier mode: fetch embedding rows from an embedding-shard "
        "service (CQ gathers over the PE fabric) instead of a local lookup "
        "(tests/test_tenancy.py pins the streams bit-identical)",
    )
    ap.add_argument("--embed-servers", type=int, default=2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models.zoo import (
        ShapeSpec,
        build_params,
        frontend_len,
        init_kv_cache,
        make_batch,
        make_prefill_step,
        make_serve_step,
    )

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _ = build_params(cfg, args.seed)
    t_max = args.prompt_len + args.gen

    # prefill against a cache sized for the whole session
    spec = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    batch = make_batch(cfg, spec, seed=args.seed)
    fl = frontend_len(cfg, args.prompt_len)

    def prefill_fn(params, batch):
        from repro.models.zoo import _head, forward

        cache = init_kv_cache(cfg, args.batch, t_max, enc_len=fl, dtype=cfg.dtype)
        h, cache, _ = forward(
            cfg, params, batch, caches=cache, offset=jnp.int32(0),
            return_hidden=True,
        )
        return _head(cfg, params, h[:, -1:, :])[:, -1, :], cache

    prefill = jax.jit(prefill_fn)
    serve = jax.jit(make_serve_step(cfg, remote_embed=args.remote_embed))

    embed_client = None
    if args.remote_embed:
        from repro.runtime.tenancy import RemoteEmbedClient

        embed_client = RemoteEmbedClient(
            np.asarray(params["embed.tok"], np.float32),
            n_servers=args.embed_servers,
        )
        batch = dict(batch)
        batch["token_rows"] = jnp.asarray(embed_client.rows(np.asarray(batch["tokens"])))

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.gen):
        toks.append(np.asarray(tok[:, 0]))
        if embed_client is not None:
            rows = jnp.asarray(embed_client.rows(np.asarray(tok)))
            logits, cache = serve(
                params, cache, tok, jnp.int32(args.prompt_len + i), rows
            )
        else:
            logits, cache = serve(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(toks, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    out = {
        "arch": cfg.name,
        "batch": args.batch,
        "prompt_len": args.prompt_len,
        "generated": int(gen.shape[1]),
        "prefill_s": round(t_prefill, 3),
        "prefill_tok_s": round(args.batch * args.prompt_len / t_prefill),
        "decode_ms_per_tok": round(1e3 * t_decode / args.gen, 2),
        "decode_tok_s": round(args.batch * args.gen / t_decode),
        "sample_ids": gen[0, :8].tolist(),
    }
    if embed_client is not None:
        out["remote_embed"] = True
        out["embed_servers"] = args.embed_servers
        out["embed_gathers"] = embed_client.gathers
    print(json.dumps(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
