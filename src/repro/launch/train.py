"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant TrainDriver (runtime/driver.py) over the token
pipeline with async checkpointing.  On this container it trains reduced
(``--smoke``) configs for real; full configs train the same code path on
a real TPU slice — the mesh and shardings come from the same
partition-plan module the dry-run proves out.
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction, default=True,
                    help="reduced config (CPU-trainable); --no-smoke = full config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--data", choices=["synthetic", "memmap"], default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--mesh", choices=["none", "host"], default="none",
                    help="host = mesh over this process's devices")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a simulated host loss (fault-tolerance demo)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.data import DataConfig
    from repro.optim import AdamW, cosine_schedule
    from repro.runtime import TrainDriver

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = None
    if args.mesh == "host":
        n = jax.device_count()
        from repro.launch.mesh import make_smoke_mesh

        mesh = make_smoke_mesh(data=n, model=1)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    data = DataConfig(
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        vocab=cfg.vocab,
        source=args.data,
        path=args.data_path,
    )
    driver = TrainDriver(
        cfg,
        ckpt_dir=f"{args.ckpt_dir}/{cfg.name}",
        opt=opt,
        mesh=mesh,
        data=data,
        ckpt_every=args.ckpt_every,
    )
    t0 = time.time()
    report = driver.run(args.steps, fail_at_step=args.fail_at_step)
    out = {
        "arch": cfg.name,
        "steps": report.steps_run,
        "restarts": report.restarts,
        "restored_steps": report.restored_steps,
        "first_loss": report.losses[0] if report.losses else None,
        "last_loss": report.losses[-1] if report.losses else None,
        "step_time_s": round(report.step_time_s, 4),
        "wall_s": round(time.time() - t0, 1),
        "tokens_per_s": round(
            args.seq_len * args.global_batch / max(report.step_time_s, 1e-9)
        ),
    }
    print(json.dumps(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
