"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the real (single) device.

Mesh shapes:
  single-pod  (16, 16)      axes ("data", "model")   = 256 chips/pod
  multi-pod   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

``pod`` is the hierarchical data-parallel axis: batch shards over
(pod, data); gradient reduction is reduce-scatter within the pod before
anything crosses the inter-pod links (sharding/collectives.py).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1) -> Mesh:
    """A mesh over however many (CPU) devices the test process has."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_name(mesh: Mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def n_devices(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
