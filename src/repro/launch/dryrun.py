import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import (jax locks the device
count at first init), and must never run from conftest/pyproject — smoke
tests see 1 device, this process sees 512 placeholders.

Per cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for every input (no allocation),
  3. jit(step, in_shardings, out_shardings).lower(...).compile(),
  4. records memory_analysis() (fits-HBM proof), cost_analysis(),
     the loop-corrected HLO analysis (analysis/hlo.py), and the roofline
     terms (analysis/roofline.py) as one JSON row.

Single-cell mode (the default) keeps each XLA compile in its own process;
``--all`` drives every cell through subprocesses so one OOM/sharding bug
cannot take down the sweep.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out artifacts/dryrun.jsonl
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def _build_cell(arch: str, shape_name: str, mesh_kind: str, opts) -> dict:
    from repro.analysis.hlo import analyze_hlo
    from repro.analysis.roofline import HW_V5E, model_flops_per_step, roofline
    from repro.configs import SHAPES, cell_supported, get_config
    from repro.launch.mesh import make_production_mesh, mesh_name, n_devices
    from repro.models.zoo import (
        build_params,
        init_kv_cache,
        input_specs,
        frontend_len,
        make_prefill_step,
        make_serve_step,
        make_train_step,
    )
    from repro.optim import AdamW
    from repro.optim.adamw import OptState
    from repro.sharding.partition import (
        SERVE_RULES,
        batch_shardings,
        cache_shardings,
        param_shardings,
        rules_for_train,
        state_shardings,
    )

    cfg = get_config(arch)
    if opts.embed_mode:
        cfg = cfg.replace(c2d_embedding=opts.embed_mode == "c2d")
    if opts.remat is not None:
        cfg = cfg.replace(remat=opts.remat)
    spec = SHAPES[shape_name]
    ok, why = cell_supported(cfg, spec)
    row: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": spec.kind,
        "embed_mode": "c2d" if cfg.c2d_embedding else "gather",
        "zero1": bool(opts.zero1),
        "fsdp": bool(opts.fsdp),
    }
    if not ok:
        row.update(status="skipped", reason=why)
        return row

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ndev = n_devices(mesh)
    row["mesh_shape"] = mesh_name(mesh)
    row["devices"] = ndev

    params_sds, axes = build_params(cfg, abstract=True)
    # serving stores weights 2-D sharded (TP x data) so the big archs fit
    # without optimizer headroom; training picks per-arch rules
    p_sh = param_shardings(params_sds, axes, mesh, rules=SERVE_RULES)
    sds = jax.ShapeDtypeStruct
    t0 = time.perf_counter()

    if spec.kind == "train":
        opt = AdamW()
        f32 = jnp.float32
        opt_sds = OptState(
            m={k: sds(p.shape, f32) for k, p in params_sds.items()},
            v={k: sds(p.shape, f32) for k, p in params_sds.items()},
            count=sds((), jnp.int32),
        )
        state_sds = {"params": params_sds, "opt": opt_sds, "step": sds((), jnp.int32)}
        st_sh = state_shardings(
            params_sds, axes, mesh, rules=rules_for_train(cfg, mesh),
            zero1=opts.zero1, fsdp=opts.fsdp,
        )
        batch_sds = input_specs(cfg, spec)
        b_sh = batch_shardings(batch_sds, mesh)
        step = make_train_step(cfg, opt, mesh=mesh, fsdp=opts.fsdp)
        jitted = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        args = (state_sds, batch_sds)
        step_tokens = spec.tokens
    elif spec.kind == "prefill":
        batch_sds = input_specs(cfg, spec)
        b_sh = batch_shardings(batch_sds, mesh)
        step = make_prefill_step(cfg, mesh=mesh)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        args = (params_sds, batch_sds)
        step_tokens = spec.tokens
    else:  # decode
        specs = input_specs(cfg, spec)
        cache_sds = specs["cache"]
        c_sh = cache_shardings(cache_sds, mesh)
        tok_sh = batch_shardings({"tokens": specs["tokens"]}, mesh)["tokens"]
        pos_sh = batch_shardings({"pos": specs["pos"]}, mesh)["pos"]
        step = make_serve_step(cfg, mesh=mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        args = (params_sds, cache_sds, specs["tokens"], specs["pos"])
        step_tokens = spec.global_batch  # one token per sequence per step

    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):  # older JAX: one dict per device
        xla_cost = xla_cost[0] if xla_cost else {}
    text = compiled.as_text()
    hc = analyze_hlo(text)
    mf = model_flops_per_step(cfg, spec.kind, step_tokens)
    mem_per_dev = (
        mem.argument_size_in_bytes
        + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    rep = roofline(
        arch, shape_name, mesh_kind, ndev, hc, mf, HW_V5E, memory_per_dev=mem_per_dev
    )
    row.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        out_bytes=mem.output_size_in_bytes,
        alias_bytes=mem.alias_size_in_bytes,
        peak_bytes_per_dev=mem_per_dev,
        fits_hbm=bool(mem_per_dev <= HW_V5E.hbm_bytes),
        xla_flops_per_dev=xla_cost.get("flops", 0.0),
        hlo_flops_per_dev=hc.flops,
        hlo_bytes_per_dev=hc.bytes_accessed,
        hlo_bytes_major_per_dev=hc.bytes_major,
        collective_bytes_per_dev=hc.collective_bytes,
        collective_by_kind={k: round(v) for k, v in hc.collective_by_kind.items()},
        collective_count=hc.collective_count,
        while_trips=hc.while_trip_counts[:8],
        model_flops=mf,
        t_compute_s=rep.t_compute,
        t_memory_s=rep.t_memory,
        t_collective_s=rep.t_collective,
        dominant=rep.dominant,
        useful_ratio=round(rep.useful_ratio, 4),
        mfu_bound=round(rep.mfu_bound, 4),
    )
    return row


def run_cell(arch: str, shape_name: str, mesh_kind: str, opts) -> dict:
    try:
        return _build_cell(arch, shape_name, mesh_kind, opts)
    except Exception as e:  # a failing cell is a bug in our sharding
        return {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "trace": traceback.format_exc()[-2000:],
        }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--embed-mode", choices=["c2d", "gather"], default=None)
    ap.add_argument("--zero1", action=argparse.BooleanOptionalAction, default=True)
    ap.add_argument("--fsdp", action=argparse.BooleanOptionalAction, default=True)
    ap.add_argument("--remat", action=argparse.BooleanOptionalAction, default=None)
    ap.add_argument("--timeout", type=int, default=1800)
    opts = ap.parse_args()

    if opts.all:
        from repro.configs import ARCH_IDS, SHAPES

        fails = 0
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mesh_kind in ("single", "multi"):
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                    ]
                    if opts.out:
                        cmd += ["--out", opts.out]
                    if opts.embed_mode:
                        cmd += ["--embed-mode", opts.embed_mode]
                    if not opts.zero1:
                        cmd += ["--no-zero1"]
                    r = subprocess.run(cmd, timeout=opts.timeout)
                    fails += r.returncode != 0
        return 1 if fails else 0

    assert opts.arch and opts.shape, "--arch and --shape required (or --all)"
    row = run_cell(opts.arch, opts.shape, opts.mesh, opts)
    print(json.dumps(row))
    if opts.out:
        with open(opts.out, "a") as f:
            f.write(json.dumps(row) + "\n")
    return 0 if row.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
