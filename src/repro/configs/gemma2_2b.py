"""gemma2-2b — local+global alternating, logit softcap [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
256k vocab: the DAPC-iest embedding table — the c2d-vs-gather gap is
largest here.  Alternating 4096-token sliding-window / global layers =>
long_500k runs (global-layer KV is T-sharded; noted in DESIGN.md).
Ties embeddings, softcaps attention (50) and final logits (30), scales
embeddings by sqrt(d_model) — all per the tech report.
"""

import math

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab=256000,
        window=4096,
        global_every=2,      # even layers local, odd layers global
        attn_softcap=50.0,
        final_softcap=30.0,
        tie_embeddings=True,
        embed_mult=math.sqrt(2304.0),
        act="gelu",
        attn_chunk=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="gemma2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=512, window=16, embed_mult=8.0,
        remat=False, attn_chunk=0,
    )
