"""Assigned architecture registry: ``--arch <id>`` resolution.

Each module defines ``full()`` (the exact assigned config) and ``smoke()``
(reduced same-family config for CPU tests).  The dry-run exercises full
configs abstractly (ShapeDtypeStruct only); smoke tests run real steps.
"""

from __future__ import annotations

from importlib import import_module

from repro.models.common import ModelConfig
from repro.models.zoo import SHAPES, ShapeSpec, cell_supported, input_specs

ARCH_IDS: tuple[str, ...] = (
    "rwkv6-1.6b",
    "phi3.5-moe-42b-a6.6b",
    "granite-moe-1b-a400m",
    "internvl2-26b",
    "starcoder2-15b",
    "qwen2.5-14b",
    "yi-9b",
    "gemma2-2b",
    "hymba-1.5b",
    "seamless-m4t-medium",
)

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1_6b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "granite-moe-1b-a400m": "granite_moe",
    "internvl2-26b": "internvl2_26b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2.5-14b": "qwen25_14b",
    "yi-9b": "yi_9b",
    "gemma2-2b": "gemma2_2b",
    "hymba-1.5b": "hymba_1_5b",
    "seamless-m4t-medium": "seamless_m4t",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke() if smoke else mod.full()


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS


def all_cells() -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its supported/skip-reason flag."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, spec in SHAPES.items():
            ok, why = cell_supported(cfg, spec)
            out.append((arch, sname, ok, why))
    return out


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ShapeSpec",
    "all_cells",
    "cell_supported",
    "get_config",
    "input_specs",
    "list_archs",
]
