"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
Tiny experts + high top-k: the message-rate-bound regime (like TSI — many
small dispatches), 2 experts/device under 16-way EP.
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab=49155,
        n_experts=32,
        topk=8,
        attn_chunk=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab=512, n_experts=8, topk=4, remat=False,
        attn_chunk=0,
    )
