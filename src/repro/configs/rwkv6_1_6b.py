"""rwkv6-1.6b — Finch: attention-free, data-dependent decay [arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536; head_dim 64 => 32 wkv heads.
Attention-free => runs long_500k (state is O(H*M^2), not O(T)).
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="rwkv",
        n_layers=24,
        d_model=2048,
        n_heads=32,          # d_model / rwkv_head_dim
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab=65536,
        rwkv_head_dim=64,
        rwkv_chunk=64,   # chunked-matmul WKV6 train path (kernels/wkv6 math)
        act="relu_sq",       # rwkv channel-mix uses squared relu internally
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="rwkv6-smoke", n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
        head_dim=64, d_ff=256, vocab=512, remat=False,
    )
