"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert vocab=32064, MoE 16e top-2.
EP: 16 experts over 16-way model axis = 1 expert/device — the flagship
X-RDMA-at-tensor-scale cell (token dispatch IS the Chaser pattern).
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab=32064,
        n_experts=16,
        topk=2,
        attn_chunk=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="phi35-moe-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=64, vocab=512, n_experts=4, topk=2, remat=False,
        attn_chunk=0,
    )
