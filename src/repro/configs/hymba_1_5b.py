"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Every block runs attention heads and an SSM head in parallel and
mean-combines (models/ssm.py).  Sliding-window attention on most layers
with periodic global layers (the Hymba recipe) + constant-size SSM state
=> long_500k runs.  25 heads: the head-indivisible partition-plan cell.
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab=32001,
        ssm_state=16,
        ssm_chunk=32,    # chunked-matmul selective scan (kernels/ssm_scan math)
        window=2048,
        global_every=8,      # layers 7, 15, 23, 31 are global
        attn_chunk=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="hymba-smoke", n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
        head_dim=32, d_ff=128, vocab=512, ssm_state=8, window=16,
        remat=False, attn_chunk=0,
    )
