"""yi-9b — llama-arch GQA [arXiv:2403.04652].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
Full attention => long_500k skipped.
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab=64000,
        attn_chunk=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="yi-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=512, remat=False, attn_chunk=0,
    )
