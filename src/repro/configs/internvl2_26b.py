"""internvl2-26b — InternViT + InternLM2 [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The ViT frontend is a STUB per the brief: input_specs supplies 256
precomputed patch embeddings which overwrite the first positions.
Full attention => long_500k skipped (DESIGN.md §Arch-applicability).
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab=92553,
        frontend="patch",
        attn_chunk=1024,
        microbatch=2,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="internvl2-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=512, remat=False, attn_chunk=0,
    )
