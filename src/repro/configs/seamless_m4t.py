"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596].

12L(dec) + 12L(enc) d_model=1024 16H (kv=16: full MHA) d_ff=4096
vocab=256206.  The audio frontend (conformer feature extractor) is a STUB
per the brief: input_specs supplies precomputed frame embeddings
(B, seq//4, D) as encoder input.  Full attention => long_500k skipped.
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        n_layers=12,
        enc_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=256206,
        frontend="audio",
        act="gelu",
        mlp_gated=False,
        attn_chunk=1024,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="seamless-smoke", n_layers=2, enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, remat=False,
        attn_chunk=0,
    )
