"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
Largest d_ff of the pool (24576): the TP-sharding stress cell.
Full attention => long_500k skipped.
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        act="gelu",          # starcoder2 uses an ungated gelu MLP
        mlp_gated=False,
        attn_chunk=1024,
        microbatch=2,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="starcoder2-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab=512, remat=False, attn_chunk=0,
    )
