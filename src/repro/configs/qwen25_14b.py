"""qwen2.5-14b — GQA, QKV bias [hf:Qwen/Qwen2.5-14B family].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, qkv bias.
40 heads is NOT divisible by the 16-way model axis: the partition plan
falls back to batch-sharded attention (train) / seq-sharded (prefill) —
see sharding/partition.py plan rules.
"""

from repro.models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        attn_chunk=1024,
        microbatch=2,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen25-smoke", n_layers=2, d_model=120, n_heads=5, n_kv_heads=1,
        head_dim=24, d_ff=256, vocab=512, remat=False, attn_chunk=0,
    )
