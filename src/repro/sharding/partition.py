"""Logical-axis -> mesh partition rules.

Every parameter leaf carries a tuple of *logical* axis names from the
ParamFactory (("layers", "embed", "ffn") etc.).  This module maps logical
axes onto mesh axes with divisibility checking: a rule only applies if the
dim is divisible by the mesh-axis extent, otherwise the dim is left
unsharded (GSPMD would pad; we refuse instead — padding silently inflates
the roofline).

Default rules (Megatron-style TP over ``model``):

    vocab   -> model    (c2d embedding + vocab-parallel LM head)
    ffn     -> model    (MLP column/row parallel, one psum per block)
    q_dim   -> model    (attention column parallel on the flat head dim)
    kv_dim  -> model    (GQA K/V projections where kv_dim divides)
    heads   -> model    (per-head state, e.g. RWKV wkv state / u bonus)
    experts -> model    (EP: the token all_to_all is the X-RDMA dispatch)
    embed   -> None     (activations stay batch-sharded; no 2D weight TP)
    layers  -> None     (scan axis)

ZeRO-1: optimizer moments additionally shard their largest free dim over
``data`` (pure re-sharding — the AdamW update is elementwise, so this is
free compute-wise and divides optimizer memory by |data|).
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")  # batch-like mesh axes, outermost first

DEFAULT_RULES: dict[str, str | None] = {
    "vocab": "model",
    "ffn": "model",
    "q_dim": "model",
    "kv_dim": "model",
    "heads": "model",
    "experts": "model",
    "embed": None,
    "layers": None,
}

# Serving: weights are stationary and must fit without optimizer headroom,
# so shard 2-D — TP over `model` plus `embed` (the D dim of every
# projection) over `data`.  Activations at decode are tiny, so the extra
# contraction psums are noise; prefill pays FSDP-style per-layer gathers.
SERVE_RULES: dict[str, str | None] = {**DEFAULT_RULES, "embed": "data"}


def rules_for_train(cfg, mesh: Mesh) -> dict[str, str | None]:
    """Per-arch train rules.

    Archs whose head count does not divide `model` (qwen 40H, hymba 25H,
    gemma2 8H) cannot propagate TP through the head reshape — GSPMD then
    reshards (B,S,H,hd) q/k/v per layer, measured at 670 MB/layer/direction
    on qwen (1.25 TB/step total).  For those archs we DON'T TP the
    attention/SSM projections at all: weights replicate over `model` (FSDP
    still shards them over `data`), and the whole attention block runs
    sequence-parallel — per-device FLOPs identical (S/16 x full heads vs
    S x heads/16), resharding eliminated, only a K/V all-gather remains.
    """
    rules = dict(DEFAULT_RULES)
    if "model" in mesh.axis_names and cfg.n_heads % mesh.shape["model"] != 0:
        rules["q_dim"] = None
        rules["kv_dim"] = None
        rules["heads"] = None
    return rules


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes: str | tuple[str, ...]) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def divisible(dim: int, mesh: Mesh, axes: str | tuple[str, ...]) -> bool:
    return dim % axis_size(mesh, axes) == 0


def spec_for(
    shape: tuple[int, ...],
    logical: tuple[str | None, ...],
    mesh: Mesh,
    rules: Mapping[str, str | None] = DEFAULT_RULES,
) -> P:
    """PartitionSpec for one leaf, honoring divisibility."""
    parts: list[Any] = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        ax = rules.get(name) if name else None
        if ax is None or ax not in mesh.axis_names or ax in used:
            parts.append(None)
        elif divisible(dim, mesh, ax):
            parts.append(ax)
            used.add(ax)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(
    params_or_avals: Mapping[str, Any],
    axes: Mapping[str, tuple[str | None, ...]],
    mesh: Mesh,
    rules: Mapping[str, str | None] = DEFAULT_RULES,
) -> dict[str, NamedSharding]:
    out = {}
    for k, p in params_or_avals.items():
        out[k] = NamedSharding(mesh, spec_for(tuple(p.shape), axes[k], mesh, rules))
    return out


def zero1_shardings(
    params_or_avals: Mapping[str, Any],
    axes: Mapping[str, tuple[str | None, ...]],
    mesh: Mesh,
    rules: Mapping[str, str | None] = DEFAULT_RULES,
    enabled: bool = True,
) -> dict[str, NamedSharding]:
    """Moment shardings: param spec + ``data`` on the largest free dim.

    This is ZeRO-1 as a sharding decision: each data-parallel rank holds
    1/|data| of every moment tensor.  GSPMD turns the gradient all-reduce
    into reduce-scatter + the update's param write into all-gather — the
    canonical ZeRO schedule — with no optimizer-code changes.
    """
    d_axes = data_axes(mesh)
    out = {}
    for k, p in params_or_avals.items():
        base = spec_for(tuple(p.shape), axes[k], mesh, rules)
        parts = list(base) + [None] * (len(p.shape) - len(base))
        if enabled and d_axes:
            free = [
                (dim, i)
                for i, (dim, s) in enumerate(zip(p.shape, parts))
                if s is None and divisible(dim, mesh, d_axes)
            ]
            if free:
                _, i = max(free)
                parts[i] = d_axes if len(d_axes) > 1 else d_axes[0]
        while parts and parts[-1] is None:
            parts.pop()
        out[k] = NamedSharding(mesh, P(*parts))
    return out


def fsdp_shardings(
    params_or_avals: Mapping[str, Any],
    axes: Mapping[str, tuple[str | None, ...]],
    mesh: Mesh,
    rules: Mapping[str, str | None] = DEFAULT_RULES,
) -> dict[str, NamedSharding]:
    """ZeRO-3/FSDP parameter shardings: TP spec + ``data`` on the largest
    free dim of every leaf.

    (A layers-axis variant — sharding the stacked L dim over data so the
    per-layer gather stays inside the scan — was measured WORSE: jit
    in_shardings cannot pad non-divisible L, and where it could, temp
    memory grew ~20%.  Recorded in EXPERIMENTS.md §Perf as a refuted
    hypothesis.)
    """
    return zero1_shardings(params_or_avals, axes, mesh, rules, enabled=True)


def state_shardings(
    param_avals: Mapping[str, Any],
    axes: Mapping[str, tuple[str | None, ...]],
    mesh: Mesh,
    rules: Mapping[str, str | None] = DEFAULT_RULES,
    zero1: bool = True,
    fsdp: bool = False,
) -> dict[str, Any]:
    """Shardings for the train-state pytree {params, opt: OptState, step}.

    ``fsdp`` shards the *parameters* themselves over ``data`` on top of TP
    (ZeRO-3 style): GSPMD all-gathers each layer's weights inside the
    layer scan and reduce-scatters its grads — mandatory for the 26B/42B
    archs whose TP-only weights+grads alone exceed one chip's HBM."""
    from repro.optim.adamw import OptState

    if fsdp:
        p_sh = fsdp_shardings(param_avals, axes, mesh, rules)
        m_sh = p_sh if zero1 else param_shardings(param_avals, axes, mesh, rules)
    else:
        p_sh = param_shardings(param_avals, axes, mesh, rules)
        m_sh = zero1_shardings(param_avals, axes, mesh, rules, enabled=zero1)
    scalar = NamedSharding(mesh, P())
    return {
        "params": p_sh,
        "opt": OptState(m=dict(m_sh), v=dict(m_sh), count=scalar),
        "step": scalar,
    }


def sp_constrain(x, mesh: Mesh | None, s_axis: int = 1):
    """Megatron-style sequence parallelism on activations (B, S, D).

    Constrains S over ``model`` (and B over the data axes) at block
    boundaries, so remat residuals are stored 1/|model|-sharded; GSPMD
    inserts the all-gather before TP matmuls and the reduce-scatter after
    — the Megatron-SP schedule.  No-op when S or B do not divide.
    """
    if mesh is None or "model" not in mesh.axis_names or x.ndim < 3:
        return x
    specs: list = [None] * x.ndim
    d = data_axes(mesh)
    if d and x.shape[0] % axis_size(mesh, d) == 0:
        specs[0] = d if len(d) > 1 else d[0]
    if x.shape[s_axis] > 1 and divisible(x.shape[s_axis], mesh, "model"):
        specs[s_axis] = "model"
    else:
        return x  # nothing to gain from a batch-only constraint here
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*specs))
    )


def channel_constrain(x, mesh: Mesh | None, c_axis: int = -1):
    """Channel/head parallelism for recurrent scans: shard the LAST dim of
    (B, T, C...) over ``model`` and batch over data — time stays whole on
    every rank, so the sequential scan runs collective-free."""
    if mesh is None or "model" not in mesh.axis_names:
        return x
    specs: list = [None] * x.ndim
    d = data_axes(mesh)
    if d and x.shape[0] % axis_size(mesh, d) == 0:
        specs[0] = d if len(d) > 1 else d[0]
    ci = c_axis % x.ndim
    if not divisible(x.shape[ci], mesh, "model"):
        return x
    specs[ci] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*specs)))


def batch_shardings(
    batch_specs: Mapping[str, Any], mesh: Mesh
) -> dict[str, NamedSharding]:
    """Batch inputs: leading dim over (pod, data) when divisible."""
    d = data_axes(mesh)
    out = {}
    for k, s in batch_specs.items():
        if d and s.shape and divisible(s.shape[0], mesh, d):
            spec = P(d if len(d) > 1 else d[0])
        else:
            spec = P()
        out[k] = NamedSharding(mesh, spec)
    return out


def cache_shardings(cache_specs: Any, mesh: Mesh) -> Any:
    """Decode caches: batch -> data (when divisible), time/state -> model.

    k/v/xk/xv: (L, B, T, K, hd)  -> P(None, data?, model_on_T?, None, None)
    wkv:       (L, B, H, M, M)   -> P(None, data?, model_on_H?)
    conv/h/shift small states    -> P(None, data?)
    T-sharding the KV cache is the c2d move for decode: queries visit the
    shard that owns the cache slice; partial softmax stats psum back.
    """
    d = data_axes(mesh)
    d_spec = d if len(d) > 1 else (d[0] if d else None)

    def one(path: str, s: Any) -> NamedSharding:
        shape = s.shape
        parts: list[Any] = [None] * len(shape)
        if len(shape) >= 2 and d and divisible(shape[1], mesh, d):
            parts[1] = d_spec
        if len(shape) >= 3 and shape[2] > 1 and divisible(shape[2], mesh, "model"):
            parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return {k: one(k, v) for k, v in cache_specs.items()}
