"""Distributed collectives: compiled-mesh reductions and X-RDMA multi-hop
tree collectives over the simulated fabric.

Compiled-mesh side (used by the launch layer when ``--grad-compress`` is on;
the dry-run's collective-bytes term shows the 4x payload reduction):

* :func:`hierarchical_psum` — two-level gradient reduction for multi-pod
  meshes: reduce fully inside the pod first, then once across pods, so the
  slow inter-pod links carry each gradient byte exactly once (and only
  1/|intra-pod| of ranks talk across pods under GSPMD's reduce-scatter
  lowering).

* :func:`compressed_grad_psum` — int8 error-feedback gradient compression
  for the pod axis: quantize to int8 with a per-tensor scale, all-reduce
  the int8 payload (4x fewer bytes on the slowest links), dequantize, and
  carry the quantization error into the next step (error feedback keeps
  the optimizer unbiased in expectation).  The error buffer is part of the
  train state.

X-RDMA side (the runtime where code really travels, paper Sec. I):

* :func:`xrdma_bcast` — tree multicast of one ifunc (code + payload) with
  O(log N) root dispatches, subtree re-parenting for mid-tree deaths, and
  a LogP-style completion-time model for the A/B against
  :func:`xrdma_flat_push` (the O(N) point-to-point baseline).
* :func:`xrdma_reduce` — the inverse flow: every PE contributes a local
  vector, children RETURN partials that fold into their parent's
  accumulator via the propagate-ABI masked scan, and the folded partial
  forwards up only when the subtree is complete.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, IFunc, PropagationConfig
from repro.core.propagate import (
    subtree_sizes,
    tree_children_map,
    tree_completion_us,
    tree_parent,
)
from repro.core.transport import WireReportMixin
from repro.core.xrdma import make_reducer

Params = dict[str, jax.Array]


def hierarchical_psum(tree: Any, axes: tuple[str, ...]) -> Any:
    """psum innermost-first: ('pod','data') reduces data, then pod."""

    def red(x: jax.Array) -> jax.Array:
        for ax in reversed(axes):
            x = jax.lax.psum(x, ax)
        return x

    return jax.tree_util.tree_map(red, tree)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_with_feedback(
    grad: jax.Array, err: jax.Array, axis: str
) -> tuple[jax.Array, jax.Array]:
    """One tensor's compressed all-reduce over ``axis`` with error feedback.

    Returns (reduced_grad_f32, new_error).  Called under shard_map (the
    launch layer maps it over the pod axis); the int8 payload is what
    crosses the wire.  The quantization scale is agreed globally first
    (a scalar pmax — free next to the payload), so every rank's int8 units
    mean the same thing and the int32-accumulated sum dequantizes exactly;
    error feedback carries each rank's own rounding residual forward.
    """
    g = grad.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    acc = jax.lax.psum(q.astype(jnp.int32), axis)
    return acc.astype(jnp.float32) * scale, new_err


def init_error_feedback(params: Params) -> Params:
    return {k: jnp.zeros(p.shape, jnp.float32) for k, p in params.items()}


# ======================================================================
# X-RDMA multi-hop collectives (the runtime where code really travels)
# ======================================================================
@dataclass
class PropagateReport(WireReportMixin):
    """Accounting for one multicast (tree or flat) over the fabric.

    ``modeled_completion_us`` is the LogP-style *parallel* completion time
    (see :func:`repro.core.propagate.tree_completion_us`) — the number the
    tree wins on; ``modeled_us`` stays the fabric's serial wire-latency sum
    (the tree's is never lower: every PE still receives the code once, plus
    hop headers)."""

    covered: int  # targets that hold the code when the multicast settled
    n_targets: int  # alive non-root peers the multicast was meant to reach
    rounds: int
    client_sends: int  # frames the root itself dispatched
    client_code_sends: int  # of those, frames carrying code bytes
    publishes: int  # hop frames sent cluster-wide (root + re-publishes)
    publish_dupes: int
    publish_send_failures: int
    reparented: int  # orphaned-subtree members the root re-covered directly
    modeled_completion_us: float
    puts: int
    gets: int
    put_bytes: int
    get_bytes: int
    modeled_us: float
    coalesced_frames: int = 0
    coalesced_payloads: int = 0
    region_puts: int = 0
    region_put_bytes: int = 0
    hop_frames: int = 0
    wire_bytes_by_kind: dict = field(default_factory=dict)


@dataclass
class ReduceReport(WireReportMixin):
    """Accounting for one tree reduction."""

    result: np.ndarray  # (width,) folded int32 vector at the root
    rounds: int
    forwards: int  # upward partial FORWARDs (== inner tree nodes + leaves)
    puts: int
    gets: int
    put_bytes: int
    get_bytes: int
    modeled_us: float
    coalesced_frames: int = 0
    coalesced_payloads: int = 0
    region_puts: int = 0
    region_put_bytes: int = 0
    hop_frames: int = 0
    wire_bytes_by_kind: dict = field(default_factory=dict)


def _cluster_publish_stats(cluster: Cluster) -> dict[str, int]:
    out = {"publishes": 0, "publish_dupes": 0, "publish_send_failures": 0}
    for pe in cluster.pes():
        out["publishes"] += pe.stats.publishes
        out["publish_dupes"] += pe.stats.publish_dupes
        out["publish_send_failures"] += pe.stats.publish_send_failures
    return out


def _multicast_completion_us(
    cluster: Cluster,
    ifn: IFunc,
    inner_nbytes: int,
    children: dict[int, list[int]],
    root: int,
    hop_headers: bool,
) -> float:
    """Completion-time model for one multicast over ``children``: per-edge
    frame sizes from the sender-cache state *before* the frames move (cold
    edges pay the code section, warm edges a digest-only frame), hop-header
    bytes growing with the sender's tree depth."""
    from repro.core.frame import Frame, hop_nbytes

    pes = cluster.pes()
    depth: dict[int, int] = {root: 0}
    stack = [root]
    while stack:
        p = stack.pop()
        for c in children.get(p, ()):
            depth[c] = depth[p] + 1
            stack.append(c)
    code = ifn.code_bytes
    hexd = ifn.digest.hex()

    def edge_nbytes(p: int, c: int) -> int:
        extra = hop_nbytes(depth[p] + 1) if hop_headers else 0
        f = Frame(
            kind=ifn.kind,
            name=ifn.name,
            payload=b"\x00" * (extra + inner_nbytes),
            code=code,
            deps=ifn.deps,
        )
        warm = pes[p].sender_cache.has(pes[c].name, hexd)
        return f.cached_nbytes if warm else f.full_nbytes

    return tree_completion_us(cluster.fabric.wire, children, root, edge_nbytes)


def xrdma_bcast(
    cluster: Cluster,
    name: str,
    payload: np.ndarray | bytes = b"",
    *,
    config: PropagationConfig | None = None,
    ttl: int | None = None,
    reparent: bool = True,
    reset_stats: bool = True,
    max_rounds: int = 100_000,
) -> PropagateReport:
    """Tree multicast of one ifunc (code + payload) to every other peer.

    The root publishes only to its spanning-tree children — O(log N)
    dispatches for the binomial default — and every PE that installs the
    code re-publishes it one level down (``repro.core.ifunc`` PUBLISH
    path).  An empty ``payload`` distributes code without invoking it; a
    non-empty payload is invoked at every covered PE.

    Fault handling lives in :meth:`repro.core.cluster.Cluster.publish_and_cover`
    (shared with ``Cluster.distribute_code``): after the fabric settles,
    any alive peer still missing the code (its publish was dropped, or its
    tree parent died mid-hop) is re-covered by a *direct* root publish
    (``reparent=True``) — the orphaned subtree drains cleanly because
    re-parent publishes carry a fresh pub_id, and duplicates of the
    original publish that later surface are dropped by the dedup key.
    Unlike ``distribute_code`` this layer *reports* partial coverage
    instead of raising: a payload broadcast to the survivors is a result,
    not a protocol violation.
    """
    cfg = config or PropagationConfig()
    client = cluster.client
    ifn = client.resolve_source(name)
    pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
    n = len(client.peers)
    root = cluster.client_index
    if reset_stats:
        cluster.fabric.stats.reset()
    sends0, code0 = client.stats.sends, client.stats.code_sends
    pub0 = _cluster_publish_stats(cluster)
    children = tree_children_map(cfg.k_code, root, n)
    modeled_completion = _multicast_completion_us(
        cluster, ifn, len(pay), children, root, hop_headers=True
    )
    n_targets = sum(1 for pe in cluster.servers if pe.endpoint.alive)
    rounds, reparented, still = cluster.publish_and_cover(
        name, pay, config=cfg, ttl=ttl, reparent=reparent, max_rounds=max_rounds
    )
    pub1 = _cluster_publish_stats(cluster)
    st = cluster.fabric.stats
    return PropagateReport(
        covered=n_targets - len(still),
        n_targets=n_targets,
        rounds=rounds,
        client_sends=client.stats.sends - sends0,
        client_code_sends=client.stats.code_sends - code0,
        publishes=pub1["publishes"] - pub0["publishes"],
        publish_dupes=pub1["publish_dupes"] - pub0["publish_dupes"],
        publish_send_failures=pub1["publish_send_failures"]
        - pub0["publish_send_failures"],
        reparented=reparented,
        modeled_completion_us=modeled_completion,
        **st.report_kwargs(),
    )


def xrdma_flat_push(
    cluster: Cluster,
    name: str,
    payload: np.ndarray | bytes = b"",
    *,
    reset_stats: bool = True,
    max_rounds: int = 100_000,
) -> PropagateReport:
    """The O(N) baseline: the root pushes code + payload point-to-point to
    every alive peer itself (what every pre-propagation workload did).
    Reported through the same :class:`PropagateReport` so the A/B is
    column-for-column, with the completion model over the star tree."""
    client = cluster.client
    ifn = client.resolve_source(name)
    hexd = ifn.digest.hex()
    pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
    root = cluster.client_index
    pes = cluster.pes()
    targets = [i for i, pe in enumerate(pes) if i != root and pe.endpoint.alive]
    if reset_stats:
        cluster.fabric.stats.reset()
    sends0, code0 = client.stats.sends, client.stats.code_sends
    star = {root: targets}
    modeled_completion = _multicast_completion_us(
        cluster, ifn, len(pay), star, root, hop_headers=False
    )
    for i in targets:
        client.send_ifunc(pes[i].name, name, pay)
    if client.batching:
        client.flush()
    rounds = cluster.drain_rounds(max_rounds)
    st = cluster.fabric.stats
    covered = sum(
        1 for i in targets if pes[i].target_cache.lookup_digest(hexd) is not None
    )
    return PropagateReport(
        covered=covered,
        n_targets=len(targets),
        rounds=rounds,
        client_sends=client.stats.sends - sends0,
        client_code_sends=client.stats.code_sends - code0,
        publishes=0,
        publish_dupes=0,
        publish_send_failures=0,
        reparented=0,
        modeled_completion_us=modeled_completion,
        **st.report_kwargs(),
    )


_reducer_for_width = lru_cache(maxsize=None)(make_reducer)


def xrdma_reduce(
    cluster: Cluster,
    values: np.ndarray,
    *,
    config: PropagationConfig | None = None,
    reset_stats: bool = True,
) -> ReduceReport:
    """Tree reduction: fold one int32 vector per PE down to the client.

    ``values`` is ``(n_servers + 1, width)`` — row ``i`` is peer ``i``'s
    contribution (the client's own row last, matching the cluster's peer
    indexing).  The reducer ifunc broadcasts down the same spanning tree
    (code + seed payload via :func:`xrdma_bcast`'s machinery), every PE
    folds its local ``reduce_src`` into its ``reduce_acc``, and each
    completed subtree FORWARDs its folded partial one hop up — children's
    partials folding at the parent through the propagate-ABI masked scan —
    until the root's count covers the whole cluster and it emits DONE.
    O(log N) hops deep, N-1 upward frames total, no O(N) client fan-in.
    """
    values = np.asarray(values, np.int32)
    n = cluster.n_servers + 1
    if values.shape[0] != n:
        raise ValueError(f"values must carry one row per peer ({n})")
    width = values.shape[1]
    cfg = config or PropagationConfig()
    cluster.set_propagation(cfg)
    root = cluster.client_index
    sizes = subtree_sizes(cfg.k_code, root, n)
    pes = cluster.pes()
    for i, pe in enumerate(pes):
        pe.register_region("reduce_acc", np.zeros(1 + width, np.int32))
        pe.register_region("reduce_src", values[i].copy())
        pe.register_cap(
            "reduce_meta",
            np.array(
                [sizes[i], tree_parent(cfg.k_code, root, i, n),
                 1 if i == root else 0],
                np.int32,
            ),
        )
    cluster.toolchain.publish(_reducer_for_width(width))
    if reset_stats:
        cluster.fabric.stats.reset()
    forwards0 = sum(pe.stats.forwards for pe in pes)
    seed = np.zeros(1 + width, np.int32)
    done0 = len(cluster.client.completed)
    # the root seeds its own contribution locally; the tree seeds the rest
    cluster.client.send_ifunc("client", "reducer", seed)
    cluster.client.publish_ifunc("reducer", seed, config=cfg)
    if cluster.client.batching:
        cluster.client.flush()
    rounds = cluster.run_until(lambda: len(cluster.client.completed) > done0)
    out = np.asarray(cluster.client.completed[-1], np.int32)
    assert out[0] == n, f"root folded {out[0]} of {n} contributions"
    st = cluster.fabric.stats
    return ReduceReport(
        result=out[1:].copy(),
        rounds=rounds,
        forwards=sum(pe.stats.forwards for pe in pes) - forwards0,
        **st.report_kwargs(),
    )
