"""Distributed-optimization collectives.

* :func:`hierarchical_psum` — two-level gradient reduction for multi-pod
  meshes: reduce fully inside the pod first, then once across pods, so the
  slow inter-pod links carry each gradient byte exactly once (and only
  1/|intra-pod| of ranks talk across pods under GSPMD's reduce-scatter
  lowering).

* :func:`compressed_grad_psum` — int8 error-feedback gradient compression
  for the pod axis: quantize to int8 with a per-tensor scale, all-reduce
  the int8 payload (4x fewer bytes on the slowest links), dequantize, and
  carry the quantization error into the next step (error feedback keeps
  the optimizer unbiased in expectation).  The error buffer is part of the
  train state.

These are used by the launch layer when ``--grad-compress`` is on; the
dry-run's collective-bytes term shows the 4x payload reduction directly.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, jax.Array]


def hierarchical_psum(tree: Any, axes: tuple[str, ...]) -> Any:
    """psum innermost-first: ('pod','data') reduces data, then pod."""

    def red(x: jax.Array) -> jax.Array:
        for ax in reversed(axes):
            x = jax.lax.psum(x, ax)
        return x

    return jax.tree_util.tree_map(red, tree)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_with_feedback(
    grad: jax.Array, err: jax.Array, axis: str
) -> tuple[jax.Array, jax.Array]:
    """One tensor's compressed all-reduce over ``axis`` with error feedback.

    Returns (reduced_grad_f32, new_error).  Called under shard_map (the
    launch layer maps it over the pod axis); the int8 payload is what
    crosses the wire.  The quantization scale is agreed globally first
    (a scalar pmax — free next to the payload), so every rank's int8 units
    mean the same thing and the int32-accumulated sum dequantizes exactly;
    error feedback carries each rank's own rounding residual forward.
    """
    g = grad.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    acc = jax.lax.psum(q.astype(jnp.int32), axis)
    return acc.astype(jnp.float32) * scale, new_err


def init_error_feedback(params: Params) -> Params:
    return {k: jnp.zeros(p.shape, jnp.float32) for k, p in params.items()}
