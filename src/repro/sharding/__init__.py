"""Distribution layer: logical-axis partition rules, compute-to-data
collective programs, and distributed-optimization collectives."""

from .partition import (
    DATA_AXES,
    batch_shardings,
    cache_shardings,
    data_axes,
    divisible,
    param_shardings,
    spec_for,
    state_shardings,
    zero1_shardings,
)

__all__ = [
    "DATA_AXES",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "divisible",
    "param_shardings",
    "spec_for",
    "state_shardings",
    "zero1_shardings",
]
