"""Distribution layer: logical-axis partition rules, compute-to-data
collective programs, heterogeneous placement pricing, and
distributed-optimization collectives."""

from .compute_to_data import (
    chase_oracle,
    dapc_shard_map,
    gather_ref,
    gather_shard_map,
    gbpc_reference,
)
from .placement import PlacementDecision, PlacementOptimizer
from .partition import (
    DATA_AXES,
    batch_shardings,
    cache_shardings,
    data_axes,
    divisible,
    param_shardings,
    spec_for,
    state_shardings,
    zero1_shardings,
)

__all__ = [
    "DATA_AXES",
    "batch_shardings",
    "chase_oracle",
    "dapc_shard_map",
    "gather_ref",
    "gather_shard_map",
    "gbpc_reference",
    "PlacementDecision",
    "PlacementOptimizer",
    "cache_shardings",
    "data_axes",
    "divisible",
    "param_shardings",
    "spec_for",
    "state_shardings",
    "zero1_shardings",
]
