"""The paper's X-RDMA pointer chase as a compiled SPMD tensor program.

``core/pointer_chase.py`` realizes DAPC faithfully: code frames really
travel between PEs, install, and recursively forward.  This module is the
TPU-idiomatic rendering of the *steady state* of the same algorithm (all
code cached everywhere — the regime the paper's own evaluation shows is
what matters): the pointer table is sharded over a mesh axis, B chases
advance as a lock-step frontier, and each round every shard resolves the
frontier entries it owns and the ownership exchange is a psum of
index-sized messages — the Chaser's FORWARD, as a collective.

* :func:`dapc_shard_map` — compute-to-data: per round, each shard looks
  up its owned subset locally (masked take) and the new frontier psums
  back.  Wire bytes per chase-hop: one int32 (times the collective
  factor) — independent of table size.

* :func:`gbpc_reference`  — move-data-to-compute: the client gathers the
  *table shard* entries it needs (all-gather in the worst case / one
  GET per hop in the faithful core version).

The per-shard local resolution loop is the Pallas ``chase`` kernel's job
on TPU (kernels/chase); here the reference uses masked takes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map as _shard_map


def dapc_shard_map(
    table: jax.Array,  # (N,) int32 successor table, sharded over ``axis``
    starts: jax.Array,  # (B,) int32, replicated
    depth: int,
    mesh: Mesh,
    axis: str = "model",
) -> jax.Array:
    """Lock-step frontier pointer chase, compute-to-data.

    Each round: every shard resolves frontier entries that live in its
    slice (masked local take), contributes zeros elsewhere, and the next
    frontier is the psum.  ``depth`` rounds total.  One chase is still a
    serial dependence chain (intrinsic to the workload); throughput comes
    from B concurrent chases, exactly like the paper's message-rate
    argument.
    """
    n = table.shape[0]
    shards = mesh.shape[axis]
    assert n % shards == 0
    local_n = n // shards

    def local(table_l: jax.Array, frontier: jax.Array) -> jax.Array:
        me = jax.lax.axis_index(axis)
        lo = me * local_n

        def hop(f, _):
            loc = f - lo
            inside = (loc >= 0) & (loc < local_n)
            nxt = jnp.take(table_l, jnp.clip(loc, 0, local_n - 1))
            nxt = jnp.where(inside, nxt, 0)
            # FORWARD: ship the index to whichever shard owns it next
            return jax.lax.psum(nxt, axis), None

        out, _ = jax.lax.scan(hop, frontier, None, length=depth)
        return out

    return _shard_map(
        local, mesh=mesh, in_specs=(P(axis), P()), out_specs=P()
    )(table, starts)


def gather_shard_map(
    table: jax.Array,  # (V, D) embedding rows, sharded over ``axis``
    keys: jax.Array,  # (B,) int32 global row ids, replicated
    mesh: Mesh,
    axis: str = "model",
    use_pallas: bool | None = None,
) -> jax.Array:
    """Steady-state X-RDMA Gather as a collective program (the serving-shape
    sibling of :func:`dapc_shard_map`).

    Each shard resolves the keys it owns — the Pallas ``embed_lookup``
    one-hot-MXU kernel on TPU, the masked-take reference elsewhere — and
    contributes zero rows for the rest; the psum is the Gatherer's partial
    RETURNs meeting in the requester's completion slot.  Wire bytes per
    key: one D-row (times the collective factor) — the table never moves,
    exactly the runtime rendering's byte accounting.
    """
    v = table.shape[0]
    shards = mesh.shape[axis]
    assert v % shards == 0
    local_v = v // shards
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    def local(table_l: jax.Array, ks: jax.Array) -> jax.Array:
        me = jax.lax.axis_index(axis)
        lo = (me * local_v).astype(jnp.int32)
        if use_pallas:
            from repro.kernels.embed_lookup.kernel import embed_lookup

            part = embed_lookup(table_l, ks, lo)
        else:
            from repro.kernels.embed_lookup.ref import embed_lookup_ref

            part = embed_lookup_ref(table_l, ks, lo)
        # partial RETURN: rows psum to the requester, zeros elsewhere
        return jax.lax.psum(part, axis)

    return _shard_map(
        local, mesh=mesh, in_specs=(P(axis), P()), out_specs=P()
    )(table, keys)


def gather_ref(table, keys):
    """Pure numpy oracle: a plain row take."""
    import numpy as np

    return np.asarray(table)[np.asarray(keys)]


def gbpc_reference(
    table: jax.Array,
    starts: jax.Array,
    depth: int,
    mesh: Mesh | None = None,
) -> jax.Array:
    """GET-style baseline: chase against the (logically) gathered table.

    Under GSPMD with a sharded table this forces the all-gather — the
    tensor-scale equivalent of the client pulling entries to itself.
    """
    if mesh is not None:
        table = jax.lax.with_sharding_constraint(table, NamedSharding(mesh, P()))

    def hop(f, _):
        return jnp.take(table, f), None

    out, _ = jax.lax.scan(hop, starts, None, length=depth)
    return out


def chase_oracle(table, starts, depth):
    """Pure numpy oracle."""
    import numpy as np

    f = np.asarray(starts).copy()
    t = np.asarray(table)
    for _ in range(depth):
        f = t[f]
    return f
