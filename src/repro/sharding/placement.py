"""Cost-model-driven placement: ship compute to data, or pull data to compute.

The paper's central claim is that moving *code* (a few hundred bytes of
bitcode, sent once thanks to the SenderCache) next to the data beats
moving the *data* to the code — but only when the hardware and the
workload cooperate.  A BlueField DPU has cheap proximity to its DRAM and
an expensive per-message CPU overhead; a Xeon initiator has a fat
read path but pays two wire alphas per GET.  This module prices both
sides of that trade with the same calibrated wire arithmetic the
autotuner replays traces through, and emits a deterministic
:class:`PlacementDecision` that the serving tier
(``runtime/embed_service.py``) and the pointer-chase miniapp consume.

Per request the two scores are::

  pushdown = [cold code frame / n]                      (SenderCache-amortized)
           + lat_req(request frame) + o_req             (initiator posts request)
           + lat_exe(return frame(selectivity)) + o_exe (executor posts survivors)
           + operand_bytes / scan_bw(executor)          (executor touches operand)

  pull     = pull_messages * 2*alpha_req
           + operand_bytes / beta_req                   (GET round trips)
           + operand_bytes / scan_bw(initiator)         (initiator touches operand)

where every coefficient comes from the *advertised capability vector* of
the PE that initiates each message (``Fabric.advertise``), not from a
cluster-wide wire profile — that asymmetry is the whole point: a filter
whose survivors are 5% of the window pushes down on a DPU-homed shard,
and the very same request pulls when the executor's per-message ``o_us``
is high or the selectivity approaches 1.

Decisions are pure float arithmetic over the advertised coefficients:
same capabilities + same arguments is bit-identical, and plans are cached
by argument until :meth:`PlacementOptimizer.invalidate_peer` drops them
(``Cluster.restart_server`` calls that — a restarted PE re-advertises and
its old prices are garbage).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.transport import Capability, WireModel

#: Fixed header + trailing MAGIC bytes around one frame's name/payload
#: sections (mirrors ``core/frame.py`` and ``analysis/autotune.py``).
FRAME_OVERHEAD = 64 + 8


def _fallback_capability(wire: WireModel) -> Capability:
    """Price an un-advertised peer with the fabric-wide profile (legacy
    PEs connected before the capability layer, or test doubles)."""
    return Capability(
        isa="unknown",
        platform="cpu",
        wire=wire.name,
        alpha_us=wire.alpha_us,
        beta_Bus=wire.beta_Bus,
        o_us=wire.o_us,
        beta_tput_Bus=wire.beta_tput_Bus or wire.beta_Bus,
        mem_bw_class="ddr-host",
    )


@dataclass(frozen=True)
class PlacementDecision:
    """One priced placement choice (both sides kept for auditability)."""

    choice: str  # "pushdown" | "pull"
    pushdown_us: float  # per-request estimate, code cost amortized over n
    pull_us: float
    requester: str
    executor: str
    requester_epoch: int  # capability epochs the prices were read under
    executor_epoch: int

    @property
    def margin_us(self) -> float:
        """How much the chosen side wins by (>= 0)."""
        return abs(self.pull_us - self.pushdown_us)

    def as_dict(self) -> dict:
        return {
            "choice": self.choice,
            "pushdown_us": round(self.pushdown_us, 6),
            "pull_us": round(self.pull_us, 6),
            "requester": self.requester,
            "executor": self.executor,
        }


class PlacementOptimizer:
    """Prices pushdown vs pull against the fabric's capability registry.

    Construct it over a live :class:`~repro.core.cluster.Cluster`; it
    registers itself so ``Cluster.restart_server`` can invalidate cached
    plans whose prices referenced the dead PE's capability vector.
    """

    def __init__(self, cluster):
        self.cluster = cluster
        self._plans: dict[tuple, PlacementDecision] = {}
        self.priced = 0  # cache misses — observability + tests
        cluster.register_placement(self)

    # -- capability access ---------------------------------------------------
    def capability(self, name: str) -> Capability:
        cap = self.cluster.fabric.capability(name)
        if cap is None:
            return _fallback_capability(self.cluster.fabric.wire)
        return cap

    # -- the decision --------------------------------------------------------
    def plan(
        self,
        *,
        requester: str,
        executor: str,
        operand_bytes: int,
        result_bytes: int,
        selectivity: float = 1.0,
        request_payload_bytes: int = 0,
        op_name: str = "filter",
        return_name: str = "filter_return",
        return_header_bytes: int = 0,
        code_bytes: int = 0,
        code_cached: bool = True,
        n_requests: int = 1,
        pull_messages: int = 1,
    ) -> PlacementDecision:
        """Price one operator placement and cache the decision.

        ``operand_bytes`` is what the executing side must touch per
        request; ``result_bytes * selectivity`` is what comes back over
        the wire under pushdown; ``pull_messages`` is how many GETs the
        pull side needs to fetch the operand (1 for a contiguous window,
        K for K scattered rows).
        """
        key = (
            requester, executor, op_name, return_name,
            int(operand_bytes), int(result_bytes), float(selectivity),
            int(request_payload_bytes), int(return_header_bytes),
            int(code_bytes), bool(code_cached), int(n_requests),
            int(pull_messages),
        )
        hit = self._plans.get(key)
        if hit is not None:
            return hit
        req = self.capability(requester)
        exe = self.capability(executor)
        self.priced += 1
        push = self._pushdown_us(
            req, exe, operand_bytes, result_bytes, selectivity,
            request_payload_bytes, op_name, return_name,
            return_header_bytes, code_bytes, code_cached, n_requests,
        )
        pull = self._pull_us(req, operand_bytes, pull_messages)
        decision = PlacementDecision(
            choice="pushdown" if push < pull else "pull",
            pushdown_us=push,
            pull_us=pull,
            requester=requester,
            executor=executor,
            requester_epoch=req.epoch,
            executor_epoch=exe.epoch,
        )
        self._plans[key] = decision
        return decision

    def _pushdown_us(
        self, req: Capability, exe: Capability,
        operand_bytes: int, result_bytes: int, selectivity: float,
        request_payload_bytes: int, op_name: str, return_name: str,
        return_header_bytes: int, code_bytes: int, code_cached: bool,
        n_requests: int,
    ) -> float:
        req_m, exe_m = req.model(), exe.model()
        code_us = 0.0
        if not code_cached and code_bytes:
            # one cold frame carries the whole fat-bitcode; the
            # SenderCache truncates every later frame, so amortize
            code_us = req_m.latency_us(
                FRAME_OVERHEAD + len(op_name) + request_payload_bytes + code_bytes
            ) / max(n_requests, 1)
        request_us = (
            req_m.latency_us(FRAME_OVERHEAD + len(op_name) + request_payload_bytes)
            + req.o_us
        )
        survivor_bytes = int(math.ceil(selectivity * result_bytes))
        return_us = (
            exe_m.latency_us(
                FRAME_OVERHEAD + len(return_name) + return_header_bytes + survivor_bytes
            )
            + exe.o_us
        )
        scan_us = operand_bytes / exe.scan_Bus
        return code_us + request_us + return_us + scan_us

    def _pull_us(
        self, req: Capability, operand_bytes: int, pull_messages: int
    ) -> float:
        pull_messages = max(int(pull_messages), 1)
        wire_us = (
            pull_messages * 2.0 * req.alpha_us + operand_bytes / req.beta_Bus
        )
        return wire_us + operand_bytes / req.scan_Bus

    # -- pointer-chase placement --------------------------------------------
    def plan_chase(
        self,
        *,
        requester: str,
        executor: str,
        depth: int,
        locality_breaks: int | None = None,
        entry_bytes: int = 4,
        code_bytes: int = 0,
        code_cached: bool = True,
        n_chases: int = 1,
    ) -> PlacementDecision:
        """DAPC vs GBPC through the same arithmetic.

        A chase of ``depth`` hops pulls ``depth`` entry-sized GETs under
        GBPC; under DAPC it ships one request and hops between shards
        only at locality breaks (default: every hop — the worst case the
        paper's Sec. IV-C measures against).
        """
        breaks = depth if locality_breaks is None else locality_breaks
        return self.plan(
            requester=requester,
            executor=executor,
            operand_bytes=depth * entry_bytes,
            # FORWARD frames between shards + one final RETURN payload
            result_bytes=(breaks + 1) * 4 * entry_bytes,
            selectivity=1.0,
            request_payload_bytes=16,
            op_name="chaser",
            return_name="chaser",
            code_bytes=code_bytes,
            code_cached=code_cached,
            n_requests=n_chases,
            pull_messages=depth,
        )

    # -- cache maintenance ---------------------------------------------------
    def invalidate_peer(self, name: str) -> int:
        """Drop every cached plan priced against ``name``'s capability
        vector.  Returns how many plans were dropped."""
        stale = [
            k for k, d in self._plans.items()
            if name in (d.requester, d.executor)
        ]
        for k in stale:
            del self._plans[k]
        return len(stale)

    def invalidate_all(self) -> int:
        n = len(self._plans)
        self._plans.clear()
        return n

    @property
    def cached_plans(self) -> int:
        return len(self._plans)
