"""Data pipeline."""

from .pipeline import DataConfig, TokenPipeline, synthetic_corpus

__all__ = ["DataConfig", "TokenPipeline", "synthetic_corpus"]
