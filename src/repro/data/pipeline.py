"""Sharded, prefetching token pipeline.

Sources:
* ``synthetic`` — a deterministic Zipfian token stream (evaluation and
  smoke tests; seeded per (epoch, shard) so every data-parallel rank reads
  a disjoint, reproducible slice).
* ``memmap``   — a flat uint16/uint32 token file (np.memmap), the usual
  packed-corpus format; sharded by contiguous stripes per rank.

The pipeline is *stateless given (step, shard)* — restart-safe by
construction: after a crash the runtime resumes from checkpoint step k and
the pipeline regenerates batch k+1 bit-for-bit (no reader state to
checkpoint).  A small background thread keeps ``prefetch`` batches ready.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator

import numpy as np


def synthetic_corpus(path: str | Path, n_tokens: int, vocab: int, seed: int = 0) -> Path:
    """Write a packed uint32 token file (for the memmap source)."""
    rng = np.random.default_rng(seed)
    toks = zipf_tokens(rng, n_tokens, vocab)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    toks.astype(np.uint32).tofile(path)
    return path


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int, alpha: float = 1.1) -> np.ndarray:
    """Zipf-distributed ids in [0, vocab) — LM-like marginal statistics."""
    z = rng.zipf(alpha, size=n)
    return ((z - 1) % vocab).astype(np.int32)


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    source: str = "synthetic"  # synthetic | memmap
    path: str | None = None
    seed: int = 0
    shard_id: int = 0  # this host's stripe
    n_shards: int = 1
    prefetch: int = 2

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class TokenPipeline:
    """Iterator of {"tokens", "labels", "mask"} int32/float32 numpy batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm: np.ndarray | None = None
        if cfg.source == "memmap":
            assert cfg.path, "memmap source needs a path"
            raw = np.memmap(cfg.path, dtype=np.uint32, mode="r")
            self._mm = raw
        self._q: queue.Queue = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._step = 0

    # ------------------------------------------------------------- batches
    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        cfg = self.cfg
        b, s = cfg.local_batch, cfg.seq_len
        if self._mm is not None:
            span = b * (s + 1)
            total = len(self._mm)
            stride = total // cfg.n_shards
            lo = cfg.shard_id * stride
            off = lo + (step * span) % max(stride - span, 1)
            flat = np.asarray(self._mm[off : off + span], dtype=np.int32) % cfg.vocab
            chunk = flat.reshape(b, s + 1)
        else:
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) * 65_537 + cfg.shard_id
            )
            chunk = zipf_tokens(rng, b * (s + 1), cfg.vocab).reshape(b, s + 1)
        return {
            "tokens": chunk[:, :-1].astype(np.int32),
            "labels": chunk[:, 1:].astype(np.int32),
            "mask": np.ones((b, s), np.float32),
        }

    # ------------------------------------------------------------ prefetch
    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self, step: int = 0) -> "TokenPipeline":
        self._step = step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        if self._thread is None:
            batch = self.batch_at(self._step)
            self._step += 1
            return batch
        _, batch = self._q.get()
        return batch
