"""Compiled-artifact + runtime analysis: HLO cost/collective parsing,
roofline, replayable trace capture, and the knob-space autotuner."""

from .autotune import (
    KNOB_GRID,
    FlowProfile,
    ProfileError,
    ReplayModel,
    TuneReport,
    autotune,
)
from .hlo import HloCost, analyze_hlo
from .roofline import HW_V5E, RooflineReport, roofline
from .trace import (
    Trace,
    TraceError,
    TraceRecorder,
    capture,
    load_trace,
    replay_stats,
    save_trace,
)

__all__ = [
    "HW_V5E",
    "FlowProfile",
    "HloCost",
    "KNOB_GRID",
    "ProfileError",
    "ReplayModel",
    "RooflineReport",
    "Trace",
    "TraceError",
    "TraceRecorder",
    "TuneReport",
    "analyze_hlo",
    "autotune",
    "capture",
    "load_trace",
    "replay_stats",
    "roofline",
    "save_trace",
]
