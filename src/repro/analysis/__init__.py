"""Compiled-artifact analysis: HLO cost/collective parsing + roofline."""

from .hlo import HloCost, analyze_hlo
from .roofline import HW_V5E, RooflineReport, roofline

__all__ = ["HW_V5E", "HloCost", "RooflineReport", "analyze_hlo", "roofline"]
