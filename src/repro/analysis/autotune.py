"""Profile-driven autotuning of the runtime knob space.

The runtime grew a real configuration space — batching, the data-plane
thresholds (``eager_max``/``rndv_min``/``zerocopy``), credit windows, poll
budgets, priority lanes, the propagation tree's fanout — all hand-tuned
per calibrated hardware profile.  This module closes the loop the ROADMAP
asked for: **replay a captured trace through the calibrated wire model
under candidate knob settings** (:class:`ReplayModel`), search the
discrete knob grid per hardware profile with deterministic coordinate
descent (:func:`autotune`), and emit a tuned :class:`FlowProfile` that
``Cluster.set_flow(profile=...)`` loads from disk.

The estimator re-derives, from a trace captured under the *default*
runtime (per-message, framed), what the fabric's ``modeled_us`` would be
under a candidate profile:

* **batching** — data sends are regrouped by (src, dst, kind, name,
  payload size, poll cycle): one coalesced frame per group costs one
  ``alpha`` plus the summed bytes, exactly the wire layer's coalesce rule
  (ragged payload sizes refuse to merge, which is why zero-copy can beat
  framed batching on ragged RETURN streams).
* **data plane** — every RETURN (``ret`` event) is re-selected through the
  candidate :class:`DataPlaneConfig`: framed RETURNs join the coalesced
  streams, zero-copy RETURNs join per-(src, dst, cycle) doorbell-batched
  write chains (``alpha + sum(bytes)/beta + (k-1)*o``), rendezvous RETURNs
  cost a framed 16-byte descriptor plus a GET round trip.  The ``zc``
  field captured per RETURN is the counterfactual write-burst size, so the
  re-selection needs no knowledge of the slab layout.
* **placement** — the heterogeneous placement axis (pushdown vs pull) is
  carried as a knob so tuned profiles pin a cluster-wide policy via
  ``Cluster.set_placement``, but it is cost-neutral in the replay (a
  trace captured under one placement has no counterfactual byte stream
  for the other — pricing that flip is
  :class:`repro.sharding.placement.PlacementOptimizer`'s job against the
  live capability registry), so like ``lanes`` the search keeps the
  incumbent.
* **flow knobs** — ``poll_budget`` and ``credit_window`` never reduce
  modeled wire time (they bound memory and latency inversion, not bytes),
  so the estimator charges them honest per-split/per-stall overheads and
  the search keeps them at their defaults unless a future trace kind
  rewards them; ``lanes`` and the tree fanout are cost-neutral on
  reorder-insensitive traces and likewise stay put.

Everything iterates in event order with a seed-pinned knob permutation,
so the same trace + profile + seed yields a bit-identical tuned profile
(tests/test_autotune.py).
"""

from __future__ import annotations

import json
from bisect import bisect_right
from dataclasses import dataclass, field, replace
from typing import Iterable

import numpy as np

from repro.core.dataplane import DataPlaneConfig
from repro.core.frame import FrameKind
from repro.core.propagate import PropagationConfig
from repro.core.transport import WIRE_PROFILES, WireModel

from .trace import Trace, TraceError, TraceRecorder, _trace_of

#: ``rndv_min`` value that disables rendezvous (matches the core default).
RNDV_OFF = 1 << 62

#: Fixed header + trailing MAGIC bytes around one frame's name/payload/code
#: sections (mirrors ``core/frame.py``; the estimator only needs the sum).
FRAME_OVERHEAD = 64 + 8

#: The discrete knob grid coordinate descent walks, in declaration order
#: (the search permutes the *knob* order by seed, never the value order).
KNOB_GRID: dict[str, tuple] = {
    "batching": (False, True),
    "zerocopy": (False, True),
    "eager_max": (0, 64, 256, 1024, 4096),
    "rndv_min": (4096, 16384, 32768, 65536, RNDV_OFF),
    "lanes": (False, True),
    "credit_window": (0, 8, 16, 32, 64),
    "poll_budget": (None, 8, 16, 32, 64),
    "k_code": (None, 0, 2, 3, 4),
    "placement": (None, "pushdown", "pull"),
}


class ProfileError(ValueError):
    """A FlowProfile file/dict is malformed or schema-incompatible."""


PROFILE_SCHEMA = "xrdma-flowprofile/1"


@dataclass(frozen=True)
class FlowProfile:
    """One complete knob assignment for a hardware profile.

    The defaults ARE the runtime's defaults (per-message, framed,
    unwindowed), so ``FlowProfile(wire=...)`` is the hand-tuned baseline
    every A/B measures against.  ``k_code=None`` leaves the cluster's
    propagation policy untouched; ``0`` forces binomial, ``k>=2`` a k-ary
    tree.  ``tenant_budgets`` is a sorted tuple of (tenant, payloads)
    pairs so the profile stays hashable and deterministic.
    """

    wire: str = "ideal"
    batching: bool = False
    lanes: bool = False
    credit_window: int = 0
    poll_budget: int | None = None
    eager_max: int = 256
    rndv_min: int = RNDV_OFF
    zerocopy: bool = False
    k_code: int | None = None
    placement: str | None = None
    tenant_budgets: tuple[tuple[str, int], ...] = ()

    def dataplane(self) -> DataPlaneConfig:
        return DataPlaneConfig(
            eager_max=self.eager_max, rndv_min=self.rndv_min, zerocopy=self.zerocopy
        )

    def propagation(self) -> PropagationConfig | None:
        if self.k_code is None:
            return None
        if self.k_code == 0:
            return PropagationConfig()
        return PropagationConfig(topology="kary", k=self.k_code)

    def as_dict(self) -> dict:
        return {
            "schema": PROFILE_SCHEMA,
            "wire": self.wire,
            "batching": self.batching,
            "lanes": self.lanes,
            "credit_window": self.credit_window,
            "poll_budget": self.poll_budget,
            "eager_max": self.eager_max,
            "rndv_min": self.rndv_min,
            "zerocopy": self.zerocopy,
            "k_code": self.k_code,
            "placement": self.placement,
            "tenant_budgets": dict(self.tenant_budgets),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FlowProfile":
        if not isinstance(d, dict):
            raise ProfileError(f"profile is not an object: {d!r}")
        schema = d.get("schema", PROFILE_SCHEMA)
        if schema != PROFILE_SCHEMA:
            raise ProfileError(f"not a {PROFILE_SCHEMA} profile (got {schema!r})")
        try:
            budgets = d.get("tenant_budgets", {})
            return cls(
                wire=str(d.get("wire", "ideal")),
                batching=bool(d.get("batching", False)),
                lanes=bool(d.get("lanes", False)),
                credit_window=int(d.get("credit_window", 0)),
                poll_budget=(
                    None if d.get("poll_budget") is None else int(d["poll_budget"])
                ),
                eager_max=int(d.get("eager_max", 256)),
                rndv_min=int(d.get("rndv_min", RNDV_OFF)),
                zerocopy=bool(d.get("zerocopy", False)),
                k_code=(None if d.get("k_code") is None else int(d["k_code"])),
                placement=(
                    None if d.get("placement") is None else str(d["placement"])
                ),
                tenant_budgets=tuple(sorted((str(k), int(v)) for k, v in dict(budgets).items())),
            )
        except (TypeError, ValueError) as e:
            raise ProfileError(f"malformed profile field: {e}") from None

    def save(self, path: str) -> None:
        with open(path, "w") as fp:
            json.dump(self.as_dict(), fp, indent=1)
            fp.write("\n")

    @classmethod
    def load(cls, path: str) -> "FlowProfile":
        try:
            with open(path) as fp:
                d = json.load(fp)
        except OSError as e:
            raise ProfileError(f"cannot read profile {path!r}: {e}") from None
        except json.JSONDecodeError as e:
            raise ProfileError(f"profile {path!r}: invalid JSON ({e.msg})") from None
        return cls.from_dict(d)

    def apply(self, cluster) -> None:
        """Install every knob on a live cluster (batching, data plane,
        propagation, flow, tenant budgets) via the core's plain-JSON
        profile loader."""
        cluster.set_flow(profile=self.as_dict())


# ----------------------------------------------------------------- estimator
def _uvarint_len(v: int) -> int:
    n = 1
    while v >= 0x80:
        v >>= 7
        n += 1
    return n


@dataclass
class _Send:
    src: str
    dst: str
    kind: int
    name: str
    n: int  # wire bytes as captured
    p: int  # payloads packed
    pb: int  # payload bytes
    cb: int  # code bytes
    cycle: int

    @property
    def spp(self) -> int:
        return self.pb // max(self.p, 1)


@dataclass
class _Ret:
    src: str
    dst: str
    name: str
    n: int  # framed payload bytes
    zc: int  # zero-copy write-burst bytes (-1: no slab)
    cached: bool
    cycle: int
    send_n: int = 0  # wire bytes of the captured framed send (0 if none)


class ReplayModel:
    """Replays one captured trace under candidate knob settings.

    Expects a trace captured under the default runtime (per-message,
    framed RETURNs) — what ``benchmarks/autotune.py`` records — and
    estimates the fabric ``modeled_us`` a candidate :class:`FlowProfile`
    would produce for the same logical workload.  All aggregation follows
    fixed event order, so estimates are bit-deterministic.
    """

    def __init__(self, trace: Trace | TraceRecorder, wire: WireModel | str | None = None):
        tr = _trace_of(trace)
        if wire is None:
            wire = tr.wire_name
        self.wire = WIRE_PROFILES[wire] if isinstance(wire, str) else wire

        polls: dict[str, list[int]] = {}
        for ev in tr.events:
            if ev["k"] == "poll":
                polls.setdefault(ev["src"], []).append(ev["i"])

        def cycle_of(src: str, i: int) -> int:
            return bisect_right(polls.get(src, ()), i)

        self.data_sends: list[_Send] = []
        self.rets: list[_Ret] = []
        self.poll_sizes: list[int] = []  # payloads retired per poll event
        # (kind, (src,dst), payloads) stream for the credit-window model
        self._flow: list[tuple[bool, tuple[str, str], int]] = []
        self.base_us = 0.0  # knob-invariant wire time
        ret_names: set[str] = set()
        pending: dict[tuple[str, str, str], list[int]] = {}
        w = self.wire
        for ev in tr.events:
            k = ev["k"]
            if k == "send":
                src, dst, n = ev["src"], ev["dst"], ev["n"]
                name = ev.get("name", "")
                control = bool(ev.get("hop")) or ev.get("kind") in (
                    int(FrameKind.RNDV), int(FrameKind.ACK)
                )
                if control:
                    # hop frames never coalesce and descriptors/ACKs are
                    # latency-critical singles: knob-invariant
                    self.base_us += w.latency_us(n)
                    continue
                key = (src, dst, name)
                if name in ret_names and pending.get(key):
                    # the framed flight of a RETURN the data plane may
                    # re-route: its bytes belong to the ret record
                    self.rets[pending[key].pop(0)].send_n = n
                    self._flow.append((True, (src, dst), ev.get("p", 1)))
                    continue
                self.data_sends.append(
                    _Send(
                        src=src, dst=dst, kind=int(ev.get("kind", 0)), name=name,
                        n=n, p=int(ev.get("p", 1)), pb=int(ev.get("pb", 0)),
                        cb=int(ev.get("cb", 0)), cycle=cycle_of(src, ev["i"]),
                    )
                )
                self._flow.append((True, (src, dst), ev.get("p", 1)))
            elif k == "ret":
                name = ev.get("name", "")
                ret_names.add(name)
                rec = _Ret(
                    src=ev["src"], dst=ev["dst"], name=name, n=ev["n"],
                    zc=int(ev.get("zc", -1)), cached=bool(ev.get("cached", False)),
                    cycle=cycle_of(ev["src"], ev["i"]),
                )
                pending.setdefault((ev["src"], ev["dst"], name), []).append(
                    len(self.rets)
                )
                self.rets.append(rec)
            elif k == "ack" or k == "retx":
                self.base_us += w.latency_us(ev.get("n", FRAME_OVERHEAD))
            elif k == "get":
                self.base_us += 2 * w.alpha_us + ev["n"] / w.beta_Bus
            elif k == "rput":
                self.base_us += (
                    w.latency_us(ev["n"]) + (ev["w"] - 1) * w.o_us
                )
            elif k == "poll":
                self.poll_sizes.append(int(ev["p"]))
            elif k == "frame":
                self._flow.append((False, (ev["src"], ev["dst"]), ev["p"]))
            # put events mirror sends/acks/retx byte-for-byte; stall /
            # cq_alloc / cq_free carry no wire time

    # -- cost pieces --------------------------------------------------------
    def _single_us(self, s: _Send) -> float:
        """Per-message cost of one captured send (decomposing a captured
        coalesced frame into per-payload frames if needed)."""
        w = self.wire
        if s.p <= 1:
            return w.latency_us(s.n)  # exact: the captured bytes
        sub = _uvarint_len(s.p) + _uvarint_len(s.spp)
        hdr = s.n - s.pb - s.cb - sub
        return s.p * w.alpha_us + (s.p * hdr + s.pb + s.cb) / w.beta_Bus

    def _group_us(self, members: list[_Send]) -> float:
        """Cost of one coalesced frame carrying every member's payloads."""
        w = self.wire
        first = members[0]
        if len(members) == 1 and first.p <= 1:
            return w.latency_us(first.n)
        hdr = first.n - first.pb - first.cb
        if first.p > 1:  # strip the captured frame's own batch subheader
            hdr -= _uvarint_len(first.p) + _uvarint_len(first.spp)
        total_p = sum(m.p for m in members)
        sub = _uvarint_len(total_p) + _uvarint_len(first.spp)
        nbytes = hdr + sub + sum(m.pb for m in members) + sum(m.cb for m in members)
        return w.latency_us(nbytes)

    def _ret_framed_single(self, r: _Ret) -> float:
        n = r.send_n or (FRAME_OVERHEAD + len(r.name) + r.n)
        return self.wire.latency_us(n)

    def cost(self, profile: FlowProfile) -> float:
        """Estimated fabric ``modeled_us`` under ``profile``."""
        w = self.wire
        dp = profile.dataplane()
        total = self.base_us

        # --- data sends (requests, forwards, AMs) under the batching knob
        if profile.batching:
            groups: dict[tuple, list[_Send]] = {}
            for s in self.data_sends:
                groups.setdefault(
                    (s.src, s.dst, s.kind, s.name, s.spp, s.cb > 0, s.cycle), []
                ).append(s)
            for members in groups.values():
                total += self._group_us(members)
        else:
            for s in self.data_sends:
                total += self._single_us(s)

        # --- RETURNs re-selected through the candidate data plane
        framed_groups: dict[tuple, list[_Ret]] = {}
        zc_chains: dict[tuple, list[int]] = {}
        desc_groups: dict[tuple, tuple[int, str]] = {}
        solo = 0  # unbatched RETURNs get unique keys (no grouping)
        for r in self.rets:
            proto = dp.select(r.n, slab=r.zc >= 0, code_cached=r.cached)
            solo += 1
            if proto == "zerocopy":
                # doorbell-batched write chain per peer per cycle
                key = (r.src, r.dst, r.cycle) if profile.batching else (solo,)
                zc_chains.setdefault(key, []).append(r.zc)
            elif proto == "rendezvous":
                # framed 16-byte descriptor (coalescable) + one GET pull
                key = (r.src, r.dst, r.name, r.cycle) if profile.batching else (solo,)
                desc_groups[key] = (
                    (desc_groups.get(key, (0, r.name))[0] + 1), r.name
                )
                total += 2 * w.alpha_us + r.n / w.beta_Bus
            else:
                key = (
                    (r.src, r.dst, r.name, r.n, r.cycle)
                    if profile.batching
                    else (solo,)
                )
                framed_groups.setdefault(key, []).append(r)
        for key, members in framed_groups.items():
            if len(members) == 1:
                total += self._ret_framed_single(members[0])
            else:
                first = members[0]
                hdr = FRAME_OVERHEAD + len(first.name)
                sub = _uvarint_len(len(members)) + _uvarint_len(first.n)
                total += w.latency_us(hdr + sub + sum(m.n for m in members))
        for writes in zc_chains.values():
            total += w.latency_us(sum(writes)) + (len(writes) - 1) * w.o_us
        for count, name in desc_groups.values():
            hdr = FRAME_OVERHEAD + len(name)
            if count == 1:
                total += w.latency_us(hdr + 16)
            else:
                sub = _uvarint_len(count) + _uvarint_len(16)
                total += w.latency_us(hdr + sub + count * 16)

        # --- flow knobs: honest overheads, never wins
        if profile.poll_budget:
            b = profile.poll_budget
            for p in self.poll_sizes:
                total += (-(-p // b) - 1) * w.o_us
        if profile.credit_window:
            total += self._window_stalls(profile.credit_window) * w.o_us
        return total

    def _window_stalls(self, window: int) -> int:
        occ: dict[tuple[str, str], int] = {}
        stalls = 0
        for is_send, link, p in self._flow:
            if is_send:
                if occ.get(link, 0) >= window:
                    stalls += 1
                occ[link] = occ.get(link, 0) + p
            else:
                occ[link] = max(0, occ.get(link, 0) - p)
        return stalls


# -------------------------------------------------------------------- search
@dataclass
class TuneReport:
    """What one autotune run decided and why."""

    profile: FlowProfile
    default_us: float
    tuned_us: float
    evaluations: int
    passes: int
    knob_order: tuple[str, ...] = ()
    history: list = field(default_factory=list)

    @property
    def improvement_pct(self) -> float:
        if self.default_us <= 0:
            return 0.0
        return 100.0 * (1.0 - self.tuned_us / self.default_us)

    def as_dict(self) -> dict:
        return {
            "profile": self.profile.as_dict(),
            "default_modeled_us": round(self.default_us, 3),
            "tuned_modeled_us": round(self.tuned_us, 3),
            "improvement_pct": round(self.improvement_pct, 2),
            "evaluations": self.evaluations,
            "passes": self.passes,
            "knob_order": list(self.knob_order),
            "history": list(self.history),
        }


def autotune(
    trace: Trace | TraceRecorder,
    wire: str | None = None,
    seed: int = 0,
    grid: dict[str, tuple] | None = None,
    max_passes: int = 8,
) -> TuneReport:
    """Coordinate descent over :data:`KNOB_GRID` against one trace.

    Starts from the hand-tuned default profile, sweeps one knob at a time
    (knob order permuted once by ``seed`` — value order is the grid's),
    accepts only strict improvements, and repeats until a full pass
    changes nothing.  Same trace + same wire + same seed is bit-identical:
    every float accumulates in fixed event order and ties keep the
    incumbent value.
    """
    tr = _trace_of(trace)
    if wire is None:
        wire = tr.wire_name
    if wire not in WIRE_PROFILES:
        raise TraceError(f"unknown wire profile {wire!r}")
    model = ReplayModel(tr, wire)
    grid = dict(grid or KNOB_GRID)
    knobs = list(grid)
    order = [knobs[i] for i in np.random.default_rng(seed).permutation(len(knobs))]

    best = FlowProfile(wire=wire)
    best_cost = model.cost(best)
    default_cost = best_cost
    evals = 1
    history: list = []
    passes = 0
    for passes in range(1, max_passes + 1):
        changed = False
        for knob in order:
            for value in grid[knob]:
                if getattr(best, knob) == value:
                    continue
                cand = replace(best, **{knob: value})
                c = model.cost(cand)
                evals += 1
                if c < best_cost - 1e-9:
                    history.append([knob, value, round(c, 3)])
                    best, best_cost = cand, c
                    changed = True
        if not changed:
            break
    return TuneReport(
        profile=best,
        default_us=default_cost,
        tuned_us=best_cost,
        evaluations=evals,
        passes=passes,
        knob_order=tuple(order),
        history=history,
    )


def load_traces(paths: Iterable[str]) -> list[Trace]:
    """Convenience: load several trace files (each validated)."""
    from .trace import load_trace

    return [load_trace(p) for p in paths]
