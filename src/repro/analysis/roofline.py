"""Three-term roofline from a compiled dry-run artifact.

Hardware model: TPU v5e (the assignment's target)::

    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI                 ~50 GB/s per link; effective per-chip collective
                        bandwidth modeled as ICI_EFF = 100 GB/s (2 usable
                        links sustained on a 2-D torus slice)

Terms (all in seconds, per step, per chip — the partitioned HLO module is
already the per-device program):

    compute    = flops_per_device / PEAK
    memory     = bytes_per_device / HBM
    collective = wire_bytes_per_device / ICI_EFF

``wire_bytes`` scales each collective's operand bytes by its ring factor:
all-reduce moves ~2x its payload per chip, all-gather/reduce-scatter
(n-1)/n =~ 1x, all-to-all (n-1)/n =~ 1x, collective-permute 1x.

The dominant term is the bottleneck; the roofline fraction we report for
a compute-bound cell is compute / max(all terms) (an upper bound on
achievable MFU for this program shape on this mesh).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .hlo import HloCost

TFLOP = 1e12
GB = 1e9


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # bytes/s per chip
    ici_eff: float  # effective collective bytes/s per chip
    hbm_bytes: float  # capacity per chip


HW_V5E = Hardware(
    name="tpu-v5e", peak_flops=197 * TFLOP, hbm_bw=819 * GB, ici_eff=100 * GB,
    hbm_bytes=16 * GB,
)

_RING_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "ragged-all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # raw per-device quantities
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    # terms, seconds
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    # usefulness
    model_flops: float  # 6*N*D analytic
    hlo_total_flops: float
    useful_ratio: float  # model_flops / hlo_total_flops
    mfu_bound: float  # compute / max(term)
    memory_per_dev_bytes: float = 0.0  # from memory_analysis (fits HBM?)
    collective_by_kind: dict = field(default_factory=dict)
    note: str = ""

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "devices": self.n_devices,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant,
            "model_flops": f"{self.model_flops:.3e}",
            "hlo_flops": f"{self.hlo_total_flops:.3e}",
            "useful_ratio": round(self.useful_ratio, 3),
            "mfu_bound": round(self.mfu_bound, 3),
            "hbm_gb_per_dev": round(self.memory_per_dev_bytes / GB, 2),
        }


def wire_bytes(cost: HloCost) -> float:
    return sum(
        v * _RING_FACTOR.get(k, 1.0) for k, v in cost.collective_by_kind.items()
    )


def roofline(
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    cost: HloCost,
    model_flops: float,
    hw: Hardware = HW_V5E,
    memory_per_dev: float = 0.0,
    note: str = "",
) -> RooflineReport:
    wb = wire_bytes(cost)
    t_c = cost.flops / hw.peak_flops
    # memory term from major-op traffic (dots/gathers/scatters/collectives)
    # — the TPU bound assuming perfect elementwise fusion; bytes_accessed
    # is the pessimistic every-op bound, reported alongside
    t_m = (cost.bytes_major or cost.bytes_accessed) / hw.hbm_bw
    t_x = wb / hw.ici_eff
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    hlo_total = cost.flops * n_devices
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        n_devices=n_devices,
        flops_per_dev=cost.flops,
        bytes_per_dev=cost.bytes_accessed,
        wire_bytes_per_dev=wb,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dominant,
        model_flops=model_flops,
        hlo_total_flops=hlo_total,
        useful_ratio=model_flops / hlo_total if hlo_total else 0.0,
        mfu_bound=t_c / max(max(terms.values()), 1e-30),
        memory_per_dev_bytes=memory_per_dev,
        collective_by_kind=dict(cost.collective_by_kind),
        note=note,
    )


def model_flops_per_step(cfg, shape_kind: str, tokens: int) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D(tokens) train, 2*N_active decode/prefill.

    N counts *active* parameters (MoE: topk/n_experts of expert params;
    embedding table excluded, LM head included)."""
    from repro.models.zoo import build_params
    import jax
    import numpy as np

    params = jax.eval_shape(lambda: build_params(cfg)[0])
    n_total = 0
    n_embed = 0
    n_expert = 0
    for k, p in params.items():
        n = int(np.prod(p.shape))
        n_total += n
        if k == "embed.tok":
            n_embed = n
        if ".we_" in k:
            n_expert += n
    n = n_total - n_embed
    if cfg.tie_embeddings:
        n += n_embed  # tied head matmul is real compute
    if cfg.n_experts and cfg.topk:
        n -= n_expert * (1 - cfg.topk / cfg.n_experts)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens
