"""Loop-aware HLO text analysis: FLOPs, memory traffic, collective bytes.

Why not ``compiled.cost_analysis()``?  XLA's HloCostAnalysis visits a
``while`` body ONCE — a model scanned over 48 layers reports 1/48th of its
real FLOPs (verified empirically; see EXPERIMENTS.md §Roofline
methodology).  Since every model here scans its blocks (that is what keeps
512-device compiles tractable), we parse the optimized post-partitioning
HLO text ourselves and multiply loop bodies by their trip counts.

Counting rules, per instruction:

* ``dot``           2 * prod(output dims) * prod(lhs contracting dims)
* ``convolution``   approximated via kernel-elements MACs
* collectives       operand bytes, tagged by kind
* memory traffic    operand bytes + output bytes at fusion boundaries
                    (a fusion reads inputs / writes outputs exactly once —
                    the HBM-traffic semantics we want); cheap bookkeeping
                    ops (tuple/gte/bitcast/param/constant) contribute 0
* ``while``         body cost x trip count (trip count = max integer
                    constant in the condition computation — exact for
                    lax.scan lowerings)
* ``fusion``/calls  FLOPs and collectives recurse; bytes do not cross
                    fusion boundaries

All shapes in a post-SPMD module are PER-DEVICE shapes, so every number
here is per-device; multiply by device count for machine totals.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "u1": 1, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "parameter(0)",
    "rng-get-and-update-state", "opt-barrier", "domain", "token",
}

# async wrappers: the -done op carries no new traffic
_ASYNC_SUFFIX = ("-start", "-done", "-update")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0  # pessimistic: every op at fusion grain
    bytes_major: float = 0.0  # TPU-roofline: dots/gathers/scatters/colls
    collective_bytes: float = 0.0
    collective_by_kind: dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    dot_flops: float = 0.0
    while_trip_counts: list[int] = field(default_factory=list)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            flops=self.flops * k,
            bytes_accessed=self.bytes_accessed * k,
            bytes_major=self.bytes_major * k,
            collective_bytes=self.collective_bytes * k,
            collective_by_kind={a: b * k for a, b in self.collective_by_kind.items()},
            collective_count=int(self.collective_count * k),
            dot_flops=self.dot_flops * k,
            while_trip_counts=list(self.while_trip_counts),
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        self.bytes_major += other.bytes_major
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v
        self.collective_count += other.collective_count
        self.dot_flops += other.dot_flops
        self.while_trip_counts.extend(other.while_trip_counts)


# ops whose operand/output traffic survives perfect elementwise fusion on a
# TPU: MXU reads/writes, HBM-resident gathers/scatters, layout changes, and
# the wire.  The optimistic `bytes_major` sums traffic over these only —
# the honest TPU memory-roofline term (`bytes_accessed` is the pessimistic
# every-op bound, inflated by the CPU backend's weaker fusion).
_MAJOR_OPS = {
    "dot", "convolution", "gather", "scatter", "scatter-add",
    "dynamic-slice", "dynamic-update-slice", "transpose", "sort",
    "reduce-window", "select-and-scatter",
}


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str  # everything after the operand list
    line: str


def _parse_instr(line: str) -> Instr | None:
    s = _COMMENT_RE.sub("", line.strip())
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") or "=" not in s:
        return None
    name, _, rhs = s.partition("=")
    name = name.strip().lstrip("%")
    rhs = rhs.strip()
    # --- output type: tuple "(...)" or single "dtype[dims]{layout}"
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str, rest = rhs[: i + 1], rhs[i + 1 :]
    else:
        m = re.match(r"(\w+\[[\d,]*\](?:\{[^}]*\})?)", rhs)
        if not m:
            return None
        type_str, rest = m.group(1), rhs[m.group(1).__len__() :]
    rest = rest.strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    # --- operand list: matching parens from the opcode's '('
    start = rest.index("(")
    depth = 0
    end = start
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            end = i
            break
    oper_str = rest[start + 1 : end]
    attrs = rest[end + 1 :]
    operands = []
    for o in _split_top(oper_str):
        o = o.strip()
        # operand refs print as "%name" or (some XLA versions) typed:
        # "f32[64,64]{1,0} %name" — take the referenced symbol either way
        ref = re.search(r"%([\w.\-]+)", o)
        if ref:
            operands.append(ref.group(1))
        elif re.match(r"^[\w.\-]+$", o):
            operands.append(o)
    return Instr(name, type_str, opcode, operands, attrs, s)


def _split_top(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
            continue
        depth += ch in "([{"
        depth -= ch in ")]}"
        cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def parse_module(text: str) -> tuple[dict[str, list[Instr]], str, dict[str, Instr]]:
    """-> (computations, entry_name, global symbol table)."""
    comps: dict[str, list[Instr]] = {}
    symbols: dict[str, Instr] = {}
    entry = ""
    cur: str | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if s == "}":
            cur = None
            continue
        m = _COMP_HDR.match(s)
        if m and "=" not in s.split("(")[0]:
            cur = m.group(1)
            comps[cur] = []
            if s.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        ins = _parse_instr(s)
        if ins is not None:
            comps[cur].append(ins)
            symbols[ins.name] = ins
    return comps, entry, symbols


def _operand_bytes(ins: Instr, symbols: dict[str, Instr]) -> int:
    total = 0
    for o in ins.operands:
        ref = symbols.get(o)
        if ref is not None:
            total += _shape_bytes(ref.type_str)
    return total


def _dot_flops(ins: Instr, symbols: dict[str, Instr]) -> float:
    out_elems = 1
    for d in _first_dims(ins.type_str):
        out_elems *= d
    lhs = symbols.get(ins.operands[0]) if ins.operands else None
    lhs_dims = _first_dims(lhs.type_str) if lhs else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, symbols: dict[str, Instr]) -> float:
    out_dims = _first_dims(ins.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    ker = symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
    k_elems = 1
    for d in (_first_dims(ker.type_str) if ker else []):
        k_elems *= d
    out_feat = out_dims[-1] if out_dims else 1
    return 2.0 * out_elems * max(k_elems // max(out_feat, 1), 1)


def _trip_count(instrs: list[Instr]) -> int:
    best = 1
    for ins in instrs:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def _attr_comp(ins: Instr, key: str) -> str | None:
    m = re.search(rf"{key}=%?([\w.\-]+)", ins.attrs)
    return m.group(1) if m else None


def _attr_comps(ins: Instr, key: str) -> list[str]:
    m = re.search(rf"{key}=\{{([^}}]*)\}}", ins.attrs)
    if not m:
        one = _attr_comp(ins, key)
        return [one] if one else []
    return [c.strip().lstrip("%") for c in m.group(1).split(",") if c.strip()]


def _analyze_comp(
    comp: str,
    comps: dict[str, list[Instr]],
    symbols: dict[str, Instr],
    cache: dict[str, HloCost],
) -> HloCost:
    if comp in cache:
        return cache[comp]
    cache[comp] = HloCost()  # cycle guard
    total = HloCost()
    for ins in comps.get(comp, ()):
        op = ins.opcode
        base = op
        for suf in _ASYNC_SUFFIX:
            if base.endswith(suf):
                base = base[: -len(suf)]
                break
        if op == "dot":
            f = _dot_flops(ins, symbols)
            total.flops += f
            total.dot_flops += f
        elif op == "convolution":
            f = _conv_flops(ins, symbols)
            total.flops += f
            total.dot_flops += f
        if base in COLLECTIVE_OPS and not op.endswith(("-done", "-update")):
            b = _operand_bytes(ins, symbols)
            if b == 0:
                b = _shape_bytes(ins.type_str)
            total.collective_bytes += b
            total.collective_count += 1
            total.collective_by_kind[base] = total.collective_by_kind.get(base, 0.0) + b
        # ---- memory traffic at fusion boundaries
        if op not in _ZERO_COST and not op.endswith(("-done", "-update")):
            traffic = _shape_bytes(ins.type_str) + _operand_bytes(ins, symbols)
            total.bytes_accessed += traffic
            if op in _MAJOR_OPS or base in COLLECTIVE_OPS:
                total.bytes_major += traffic
        # ---- called computations
        if op == "while":
            body = _attr_comp(ins, "body")
            cond = _attr_comp(ins, "condition")
            trips = _trip_count(comps.get(cond, [])) if cond else 1
            if body:
                sub = _analyze_comp(body, comps, symbols, cache)
                total.add(sub.scaled(trips))
                total.while_trip_counts.append(trips)
        elif op == "fusion":
            callee = _attr_comp(ins, "calls")
            if callee:
                sub = _analyze_comp(callee, comps, symbols, cache)
                total.flops += sub.flops
                total.dot_flops += sub.dot_flops
                # a fusion's real HBM traffic is its boundary traffic; the
                # interior only decides whether it counts as "major"
                if sub.bytes_major > 0:
                    total.bytes_major += _shape_bytes(ins.type_str) + _operand_bytes(
                        ins, symbols
                    )
                total.collective_bytes += sub.collective_bytes
                total.collective_count += sub.collective_count
                for k, v in sub.collective_by_kind.items():
                    total.collective_by_kind[k] = (
                        total.collective_by_kind.get(k, 0.0) + v
                    )
        elif op in ("call", "custom-call", "async-start"):
            for key in ("to_apply", "calls", "called_computations"):
                for name in _attr_comps(ins, key):
                    if name in comps:
                        sub = _analyze_comp(name, comps, symbols, cache)
                        total.add(sub)
        elif op == "conditional":
            branches = _attr_comps(ins, "branch_computations")
            if not branches:
                branches = [
                    c
                    for key in ("true_computation", "false_computation")
                    for c in _attr_comps(ins, key)
                ]
            worst = HloCost()
            for b in branches:
                sub = _analyze_comp(b, comps, symbols, cache)
                if sub.flops + sub.bytes_accessed > worst.flops + worst.bytes_accessed:
                    worst = sub
            total.add(worst)
    cache[comp] = total
    return total


def analyze_hlo(text: str) -> HloCost:
    """Analyze an optimized (post-partitioning) HLO module from its entry."""
    comps, entry, symbols = parse_module(text)
    if not entry:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    return _analyze_comp(entry, comps, symbols, {})
