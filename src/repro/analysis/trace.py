"""Unified trace capture: every wire/runtime event as one replayable record.

The benchmarks so far reported *aggregate* counters (``TrafficStats`` /
``PEStats``).  This module adds the event stream underneath them: a
:class:`TraceRecorder` attached to a :class:`repro.core.transport.Fabric`
(``fabric.tracer``) receives one record per PUT, one-sided write burst,
GET, frame send, credit stall, retransmit, ACK, poll, frame consumption,
RETURN protocol decision, and CQ slot transition — tagged with a global
event index, src/dst endpoint names, byte counts, and tenant.  Captured
runs serialize to JSONL (:func:`save_trace` / :func:`load_trace`) and
replay *losslessly* back into the aggregate counters the live run
reported (:func:`replay_stats`), which is what makes trace-driven
autotuning (:mod:`repro.analysis.autotune`) testable: any knob decision
justified on a trace can be re-derived from the file alone.

Capture is strictly opt-in and zero-overhead when off: every hook in the
core runtime is ``tracer = ...; if tracer is not None: tracer.emit(...)``
— no event objects, no buffering, no per-frame allocation unless a
recorder is attached (tests/test_trace.py pins this down).

Event schema (``"k"`` selects the kind; ``"i"`` is the global sequence):

======== ============================== ===================================
kind     emitted by                     fields beyond k/i
======== ============================== ===================================
put      ``Fabric.put``                 src dst n p [by hop tn lost]
rput     ``Fabric.put_region_multi``    src dst n w [lw gd]
get      ``Fabric.get``                 src dst n [region]
send     ``WireLayer._transmit``        src dst n p kind name pb cb cached
                                        [hop tn seq]
stall    ``WireLayer.put_now``          src dst [tn budget]
retx     ``WireLayer.on_tick``          src dst seq n
ack      ``WireLayer.send_ack``         src dst ack
poll     ``ProgressEngine.poll``        src tick p
frame    ``ProgressEngine`` (consume)   src dst p done
ret      ``PE.return_payload``          src dst name n zc cached proto
cq_alloc ``CompletionQueue.try_alloc``  src slot epoch [tn]
cq_free  ``CompletionQueue._release``   src slot
======== ============================== ===================================

``n`` is always bytes (for ``ret``: the framed payload bytes, with ``zc``
the zero-copy write-burst bytes, ``-1`` when the RETURN has no slab);
``p`` is payload units; ``by`` the :data:`repro.core.transport.BYTE_KINDS`
attribution of a framed PUT.
"""

from __future__ import annotations

import json
from typing import IO, Iterable

from repro.core.transport import WIRE_PROFILES, TrafficStats, WireModel

#: Trace file format identifier (first JSONL record's ``schema`` field).
SCHEMA = "xrdma-trace/1"

#: Every event kind a valid trace may contain.
EVENT_KINDS = frozenset(
    {
        "put", "rput", "get", "send", "stall", "retx", "ack",
        "poll", "frame", "ret", "cq_alloc", "cq_free",
    }
)

#: Per-kind required fields (beyond ``k``/``i``) and their types —
#: validated at load time so a replay never dies on ``KeyError``.
_REQUIRED: dict[str, tuple[tuple[str, type], ...]] = {
    "put": (("src", str), ("dst", str), ("n", int), ("p", int)),
    "rput": (("src", str), ("dst", str), ("n", int), ("w", int)),
    "get": (("src", str), ("dst", str), ("n", int)),
    "send": (("src", str), ("dst", str), ("n", int), ("p", int)),
    "stall": (("src", str), ("dst", str)),
    "retx": (("src", str), ("dst", str), ("n", int)),
    "ack": (("src", str), ("dst", str)),
    "poll": (("src", str), ("p", int)),
    "frame": (("src", str), ("dst", str), ("p", int)),
    "ret": (("src", str), ("dst", str), ("n", int)),
    "cq_alloc": (("src", str), ("slot", int)),
    "cq_free": (("src", str), ("slot", int)),
}


class TraceError(ValueError):
    """A trace file/stream is truncated, malformed, or schema-incompatible.

    The *only* error surface of :func:`load_trace`: raw ``KeyError`` /
    ``json.JSONDecodeError`` never escape (garbage input is an expected
    condition for files that travel between machines and CI artifacts)."""


class TraceRecorder:
    """Append-only event sink one :class:`Fabric` publishes into.

    Hot-path contract: :meth:`emit` is only ever called behind a
    ``tracer is not None`` guard, so a detached runtime pays one attribute
    load per hook site and nothing else."""

    __slots__ = ("events", "wire_name", "meta")

    def __init__(self, wire_name: str = "ideal", meta: dict | None = None) -> None:
        self.events: list[dict] = []
        self.wire_name = wire_name
        self.meta = dict(meta or {})

    def emit(self, k: str, **fields) -> None:
        fields["k"] = k
        fields["i"] = len(self.events)
        self.events.append(fields)

    def __len__(self) -> int:
        return len(self.events)


class Trace:
    """A loaded (or freshly captured) trace: header + validated events."""

    __slots__ = ("header", "events")

    def __init__(self, header: dict, events: list[dict]) -> None:
        self.header = header
        self.events = events

    @property
    def wire_name(self) -> str:
        return self.header.get("wire", "ideal")

    @classmethod
    def from_recorder(cls, rec: TraceRecorder) -> "Trace":
        header = {"schema": SCHEMA, "wire": rec.wire_name, "events": len(rec.events)}
        if rec.meta:
            header["meta"] = dict(rec.meta)
        return cls(header, list(rec.events))

    def __len__(self) -> int:
        return len(self.events)


def capture(target, meta: dict | None = None):
    """Context manager: attach a fresh recorder to ``target`` (a Cluster,
    an app holding ``.fabric``, or a Fabric) for the duration of the block.

    >>> with capture(cluster) as rec:
    ...     app.dapc(starts, depth, batching=True)
    >>> save_trace(rec, "run.jsonl")
    """
    return _Capture(target, meta)


class _Capture:
    def __init__(self, target, meta: dict | None) -> None:
        self.fabric = getattr(target, "fabric", target)
        self.meta = meta
        self.recorder: TraceRecorder | None = None
        self._prev = None

    def __enter__(self) -> TraceRecorder:
        self.recorder = TraceRecorder(self.fabric.wire.name, self.meta)
        self._prev = self.fabric.tracer
        self.fabric.tracer = self.recorder
        return self.recorder

    def __exit__(self, *exc) -> None:
        self.fabric.tracer = self._prev


# ------------------------------------------------------------ serialization
def _trace_of(trace) -> Trace:
    if isinstance(trace, TraceRecorder):
        return Trace.from_recorder(trace)
    if isinstance(trace, Trace):
        return trace
    raise TypeError(f"expected Trace or TraceRecorder, got {type(trace).__name__}")


def dump_trace(trace: Trace | TraceRecorder, fp: IO[str]) -> int:
    """Write one header line + one line per event; returns events written."""
    tr = _trace_of(trace)
    fp.write(json.dumps(tr.header, separators=(",", ":")) + "\n")
    for ev in tr.events:
        fp.write(json.dumps(ev, separators=(",", ":")) + "\n")
    return len(tr.events)


def save_trace(trace: Trace | TraceRecorder, path: str) -> int:
    with open(path, "w") as fp:
        return dump_trace(trace, fp)


def _check_event(ev, lineno: int) -> dict:
    if not isinstance(ev, dict):
        raise TraceError(f"line {lineno}: event is not an object")
    kind = ev.get("k")
    if kind not in EVENT_KINDS:
        raise TraceError(f"line {lineno}: unknown event kind {kind!r}")
    for name, typ in _REQUIRED[kind]:
        val = ev.get(name)
        # bool is an int subclass; an int field holding True is garbage
        if not isinstance(val, typ) or (typ is int and isinstance(val, bool)):
            raise TraceError(
                f"line {lineno}: {kind!r} event field {name!r} missing or "
                f"not {typ.__name__} (got {val!r})"
            )
    return ev


def parse_trace(lines: Iterable[str]) -> Trace:
    """Parse JSONL trace lines; every malformation raises :class:`TraceError`."""
    header: dict | None = None
    events: list[dict] = []
    lineno = 0
    for line in lines:
        lineno += 1
        if not line.strip():
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            raise TraceError(f"line {lineno}: invalid JSON ({e.msg})") from None
        if header is None:
            if not isinstance(obj, dict) or obj.get("schema") != SCHEMA:
                raise TraceError(
                    f"line 1: not a {SCHEMA} trace header "
                    f"(got {obj.get('schema') if isinstance(obj, dict) else obj!r})"
                )
            header = obj
            continue
        events.append(_check_event(obj, lineno))
    if header is None:
        raise TraceError("empty trace: no header line")
    declared = header.get("events")
    if isinstance(declared, int) and declared != len(events):
        raise TraceError(
            f"truncated trace: header declares {declared} events, file has "
            f"{len(events)}"
        )
    return Trace(header, events)


def load_trace(path: str) -> Trace:
    """Load + validate one JSONL trace file; raises :class:`TraceError` on
    any truncation or garbage (never ``KeyError``/``JSONDecodeError``)."""
    try:
        with open(path) as fp:
            return parse_trace(fp)
    except OSError as e:
        raise TraceError(f"cannot read trace {path!r}: {e}") from None
    except UnicodeDecodeError as e:
        raise TraceError(f"trace {path!r} is not UTF-8 text: {e}") from None


# ------------------------------------------------------------------- replay
def replay_stats(
    trace: Trace | TraceRecorder, wire: WireModel | str | None = None
) -> tuple[TrafficStats, dict[str, dict[str, int]]]:
    """Re-derive the live run's aggregate counters from the event stream.

    Returns ``(traffic, pe_stats)`` where ``traffic`` reproduces every
    field of the fabric's :class:`TrafficStats` — including the modeled
    float accumulators, bit-identically, because events replay in emission
    order through the same arithmetic — and ``pe_stats`` maps PE name to
    the trace-visible :class:`PEStats` subset: ``sends``, ``code_sends``,
    ``credit_stalls``, ``retransmits``, ``acks_sent``, ``msgs``,
    ``zerocopy_returns``, ``rndv_returns``.
    """
    tr = _trace_of(trace)
    if wire is None:
        wire = tr.wire_name
    w = WIRE_PROFILES[wire] if isinstance(wire, str) else wire
    st = TrafficStats()
    pes: dict[str, dict[str, int]] = {}

    def pe(name: str) -> dict[str, int]:
        got = pes.get(name)
        if got is None:
            got = pes[name] = {
                "sends": 0, "code_sends": 0, "credit_stalls": 0,
                "retransmits": 0, "acks_sent": 0, "msgs": 0,
                "zerocopy_returns": 0, "rndv_returns": 0,
            }
        return got

    for ev in tr.events:
        k = ev["k"]
        if k == "put":
            n = ev["n"]
            t = w.latency_us(n)
            st.puts += 1
            st.put_bytes += n
            st.modeled_us += t
            st.modeled_tput_us += w.inverse_throughput_us(n)
            by = ev.get("by")
            st.add_kinds(by if by is not None else {"payload": n})
            p = ev["p"]
            if p > 1:
                st.coalesced_frames += 1
                st.coalesced_payloads += p
            if ev.get("hop"):
                st.hop_frames += 1
                st.hop_bytes += n
            tn = ev.get("tn")
            if tn is not None:
                st.tenant_puts[tn] = st.tenant_puts.get(tn, 0) + 1
                st.tenant_put_bytes[tn] = st.tenant_put_bytes.get(tn, 0) + n
            if ev.get("lost"):
                st.frames_lost += 1
                st.lost_bytes += n
        elif k == "rput":
            n, nw = ev["n"], ev["w"]
            t = w.latency_us(n) + (nw - 1) * w.o_us
            st.region_puts += 1
            st.region_put_bytes += n
            st.modeled_us += t
            st.modeled_tput_us += (nw - 1) * w.o_us + w.inverse_throughput_us(n)
            st.add_kinds({"region": n})
            st.region_writes_lost += ev.get("lw", 0)
            st.region_guard_drops += ev.get("gd", 0)
        elif k == "get":
            n = ev["n"]
            t = 2 * w.alpha_us + n / w.beta_Bus
            st.gets += 1
            st.get_bytes += n
            st.modeled_us += t
            st.modeled_tput_us += t
            st.add_kinds({"region": n})
        elif k == "stall":
            st.credit_stalls += 1
            pe(ev["src"])["credit_stalls"] += 1
            tn = ev.get("tn")
            if ev.get("budget") and tn is not None:
                st.tenant_stalls[tn] = st.tenant_stalls.get(tn, 0) + 1
        elif k == "send":
            d = pe(ev["src"])
            d["sends"] += 1
            if not ev.get("cached", True) and ev.get("cb", 0) > 0:
                d["code_sends"] += 1
        elif k == "retx":
            pe(ev["src"])["retransmits"] += 1
        elif k == "ack":
            pe(ev["src"])["acks_sent"] += 1
        elif k == "frame":
            if ev.get("done", True):
                pe(ev["dst"])["msgs"] += 1
        elif k == "ret":
            proto = ev.get("proto", "framed")
            if proto == "zerocopy":
                pe(ev["src"])["zerocopy_returns"] += 1
            elif proto == "rendezvous":
                pe(ev["src"])["rndv_returns"] += 1
        # poll / cq_alloc / cq_free carry no aggregate counters
    return st, pes


def pe_stats_subset(stats) -> dict[str, int]:
    """Project one live :class:`PEStats` onto the trace-visible subset
    :func:`replay_stats` reconstructs (for round-trip assertions)."""
    return {
        "sends": stats.sends,
        "code_sends": stats.code_sends,
        "credit_stalls": stats.credit_stalls,
        "retransmits": stats.retransmits,
        "acks_sent": stats.acks_sent,
        "msgs": stats.msgs,
        "zerocopy_returns": stats.zerocopy_returns,
        "rndv_returns": stats.rndv_returns,
    }
