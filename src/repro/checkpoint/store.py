"""Checkpoint store: per-leaf .npy shards + manifest, async, verifiable.

Layout (one directory per step)::

    ckpt_dir/step_000042/
      MANIFEST.json    {leaf path -> {file, shape, dtype, sha256}}
      params.embed.tok.npy ...
      _COMMIT          written last — a directory without it is torn and
                       ignored by restore (crash-during-write safety)

Design points for the 1000-node story:
* **Async**: ``CheckpointStore.save_async`` snapshots the state to host
  memory (device_get) on the training thread, then writes on a background
  thread — the step loop never blocks on disk.
* **Integrity**: per-leaf sha256 in the manifest, verified on restore.
* **Restore-with-reshard (elastic)**: leaves are saved UNSHARDED (gathered
  to host), so a restore may apply *any* new sharding — the elastic path
  after losing a host re-lays the same logical state onto a smaller mesh
  (``runtime/elastic.py``).
* **Retention**: keep the last ``keep`` checkpoints, delete older only
  after a newer _COMMIT exists.
"""

from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

COMMIT = "_COMMIT"
MANIFEST = "MANIFEST.json"

_UINT_OF_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """np.save cannot serialize ml_dtypes (bf16/f8) — store a uint view and
    remember the logical dtype in the manifest."""
    name = arr.dtype.name
    try:
        np.dtype(name)
        if arr.dtype.kind != "V":
            return arr, name
    except TypeError:
        pass
    return arr.view(_UINT_OF_SIZE[arr.dtype.itemsize]), name


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    import ml_dtypes

    return arr.view(getattr(ml_dtypes, dtype_name))


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    flat: dict[str, Any] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            flat.update(_flatten(tree[k], f"{prefix}{k}."))
        return flat
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if len(leaves) == 1 and treedef.num_leaves == 1 and not isinstance(tree, dict):
        flat[prefix.rstrip(".")] = leaves[0]
        return flat
    for i, leaf in enumerate(leaves):
        flat[f"{prefix}{i}"] = leaf
    return flat


def save_state(path: str | Path, state: Any, step: int) -> Path:
    """Synchronous save of a pytree-of-arrays (host-gathered, unsharded)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = Path(path) / f"step_{step:09d}"
    tmp = out.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest: dict[str, dict] = {"__step__": step, "leaves": {}}
    for keypath, leaf in flat:
        name = jax.tree_util.keystr(keypath).strip("[]'\"").replace("']['", ".")
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{hashlib.sha1(name.encode()).hexdigest()[:16]}.npy"
        savable, dtype_name = _to_savable(arr)
        np.save(tmp / fname, savable)
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    (tmp / COMMIT).write_text("ok")
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)
    return out


def latest_step(path: str | Path) -> int | None:
    path = Path(path)
    if not path.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in path.iterdir()
        if p.name.startswith("step_") and (p / COMMIT).exists()
    ]
    return max(steps) if steps else None


def restore_state(
    path: str | Path,
    like: Any,
    step: int | None = None,
    shardings: Any = None,
    verify: bool = True,
) -> tuple[Any, int]:
    """Restore into the structure of ``like``; apply ``shardings`` if given
    (the elastic restore path — any mesh works, leaves are unsharded on
    disk)."""
    path = Path(path)
    step = latest_step(path) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {path}")
    d = path / f"step_{step:09d}"
    manifest = json.loads((d / MANIFEST).read_text())["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sflat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, (keypath, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(keypath).strip("[]'\"").replace("']['", ".")
        meta = manifest.get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = _from_saved(np.load(d / meta["file"]), meta["dtype"])
        if verify:
            got = hashlib.sha256(arr.tobytes()).hexdigest()
            if got != meta["sha256"]:
                raise IOError(f"checksum mismatch for {name}")
        if sflat is not None:
            out.append(jax.device_put(arr, sflat[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointStore:
    """Async checkpointing with retention."""

    def __init__(self, path: str | Path, keep: int = 3):
        self.path = Path(path)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved: list[int] = []
        self._err: BaseException | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def save_async(self, state: Any, step: int) -> None:
        self.wait()  # one in flight at a time
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), state)

        def work() -> None:
            try:
                save_state(self.path, host, step)
                self.saved.append(step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.path.iterdir()
            if p.name.startswith("step_") and (p / COMMIT).exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.path / f"step_{s:09d}", ignore_errors=True)
