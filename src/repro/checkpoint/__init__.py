"""Checkpointing: sharded .npz trees, async writer, integrity digests."""

from .store import (
    CheckpointStore,
    latest_step,
    restore_state,
    save_state,
)

__all__ = ["CheckpointStore", "latest_step", "restore_state", "save_state"]
