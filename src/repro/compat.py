"""Cross-version JAX shims.

``shard_map`` was promoted out of ``jax.experimental`` with its
replication-check kwarg renamed (``check_rep`` -> ``check_vma``); every
explicit-collective module routes through this one wrapper so the repo
runs on either side of that promotion.
"""

from __future__ import annotations

import jax


def shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map with the replication/VMA check disabled, on any JAX."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
