"""Sharded optimizers."""

from .adamw import AdamW, OptState, cosine_schedule, global_norm

__all__ = ["AdamW", "OptState", "cosine_schedule", "global_norm"]
