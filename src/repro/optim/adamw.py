"""AdamW with global-norm clipping and schedules, as pure pytree ops.

The optimizer state mirrors the parameter tree leaf-for-leaf ({m, v}), so
the sharding layer can assign the *same* NamedSharding to a parameter and
its moments (TP shards), or ZeRO-shard the moments along ``data``
(``sharding.partition.zero_shard_axes``) — the update stays elementwise
either way, which is what makes ZeRO-1 a pure re-sharding decision here.

Moments are kept in f32 regardless of parameter dtype (bf16 training needs
f32 second moments; this is the MaxText/Megatron default).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = dict[str, jax.Array]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def cosine_schedule(
    peak_lr: float, warmup_steps: int = 100, total_steps: int = 10_000, floor: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


@dataclass(frozen=True)
class OptState:
    """Leaf-parallel moments + scalar step count."""

    m: Params
    v: Params
    count: jax.Array

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.m, self.v, self.count), None

    @classmethod
    def tree_unflatten(cls, _, children):  # pragma: no cover
        return cls(*children)


jax.tree_util.register_pytree_node(
    OptState, OptState.tree_flatten, lambda aux, ch: OptState(*ch)
)


@dataclass(frozen=True)
class AdamW:
    """AdamW + decoupled weight decay + global-norm clip.

    ``lr`` may be a float or a schedule ``step -> lr``.
    ``wd_skip`` names substrings of parameter paths exempt from decay
    (norm gains, biases — the usual exemptions).
    """

    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    wd_skip: tuple[str, ...] = ("ln", "bias", "norm", ".b")

    def init(self, params: Params) -> OptState:
        zeros = {k: jnp.zeros(p.shape, jnp.float32) for k, p in params.items()}
        return OptState(
            m=zeros,
            v={k: jnp.zeros(p.shape, jnp.float32) for k, p in params.items()},
            count=jnp.zeros((), jnp.int32),
        )

    def _decays(self, name: str) -> bool:
        return not any(s in name for s in self.wd_skip)

    def update(
        self,
        grads: Params,
        state: OptState,
        params: Params,
        constrain: dict[str, Any] | None = None,
    ) -> tuple[Params, OptState, dict[str, jax.Array]]:
        """``constrain`` maps leaf name -> NamedSharding of the *moment*
        (ZeRO) domain.  Pinning the f32 update arithmetic there makes GSPMD
        emit the canonical ZeRO-1 schedule: gradients reduce-scatter onto
        the moment shards (instead of all-reduce), the elementwise update
        runs 1/|data|-sharded (f32 temporaries shrink |data|-fold), and
        only the new bf16 params all-gather back to the TP layout.  Without
        it GSPMD prefers the parameter layout and all-gathers the f32
        moments every step (measured 4x the collective bytes)."""
        count = state.count + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        else:
            gnorm = global_norm(grads)
            scale = jnp.float32(1.0)
        lr = self.lr(count) if callable(self.lr) else jnp.float32(self.lr)
        c = count.astype(jnp.float32)
        bc1 = 1.0 - self.b1**c
        bc2 = 1.0 - self.b2**c

        def wsc(x, k):
            if constrain is not None and k in constrain:
                return jax.lax.with_sharding_constraint(x, constrain[k])
            return x

        new_p: Params = {}
        new_m: Params = {}
        new_v: Params = {}
        for k, p in params.items():
            g = wsc(grads[k].astype(jnp.float32), k) * scale
            m = self.b1 * state.m[k] + (1 - self.b1) * g
            v = self.b2 * state.v[k] + (1 - self.b2) * jnp.square(g)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay and self._decays(k):
                upd = upd + self.weight_decay * wsc(p.astype(jnp.float32), k)
            new_p[k] = (wsc(p.astype(jnp.float32), k) - lr * upd).astype(p.dtype)
            new_m[k] = m
            new_v[k] = v
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, OptState(m=new_m, v=new_v, count=count), metrics
