"""Fat-bitcode: multi-target portable code archives.

The paper ships LLVM bitcode compiled for every ISA the ifunc may land on
("fat-bitcode", Fig. 3) so the target can extract the slice matching its own
triple and JIT-optimize it for the local microarchitecture.

The JAX analogue of LLVM bitcode is a ``jax.export`` blob: serialized,
versioned StableHLO that is platform-portable and is re-lowered/optimized by
the *target's* XLA backend at deserialization+jit time (ORC-JIT's role).  A
:class:`FatBitcode` maps target triples (e.g. ``cpu-host``, ``tpu-v5e``) to
export blobs; archives are content-addressed by a sha256 digest, which is what
the caching protocol (frame truncation + target JIT cache) keys on.
"""

from __future__ import annotations

import hashlib
import io
import struct
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.export

from .frame import CorruptFrame

# Target triples. ``platform`` is what jax.export lowers for; ``mcpu`` models
# the micro-architecture field the paper optimizes for on the target (A64FX
# SVE vs. Xeon AVX2). On this container only the cpu slice is *executable*,
# but tpu slices are still *generated* (cross-lowering), exactly like the
# paper generating AArch64 bitcode on a Xeon.
_TRIPLE_PLATFORM: dict[str, str] = {
    "cpu-host": "cpu",
    "cpu-a64fx": "cpu",
    "cpu-bf2": "cpu",
    "tpu-v5e": "tpu",
}

DEFAULT_TOOLCHAIN_TARGETS: tuple[str, ...] = ("cpu-host", "tpu-v5e")

_MAGIC = b"FBC1"


def platform_of(triple: str) -> str:
    try:
        return _TRIPLE_PLATFORM[triple]
    except KeyError:
        raise ValueError(f"unknown target triple: {triple!r}") from None


def local_triple() -> str:
    """The triple of the processing element we are running on."""
    plat = jax.default_backend()
    return "cpu-host" if plat == "cpu" else "tpu-v5e"


@dataclass(frozen=True)
class BitcodeSlice:
    """One target's worth of code: the analogue of a single .bc file."""

    triple: str
    blob: bytes

    @property
    def digest(self) -> str:
        return hashlib.sha256(self.blob).hexdigest()


@dataclass
class FatBitcode:
    """Archive of per-triple export blobs (paper Fig. 3 BITCODE fields)."""

    slices: dict[str, bytes] = field(default_factory=dict)

    # -- construction -------------------------------------------------------
    @classmethod
    def build(
        cls,
        fn: Callable[..., Any],
        in_avals: Sequence[jax.ShapeDtypeStruct],
        targets: Sequence[str] = DEFAULT_TOOLCHAIN_TARGETS,
        fn_by_platform: Mapping[str, Callable[..., Any]] | None = None,
    ) -> "FatBitcode":
        """Cross-compile ``fn`` for every toolchain target.

        Mirrors "the Three-Chains toolchain will generate bitcode files for
        all the targets supported by the toolchain's Clang compiler".

        ``fn_by_platform`` optionally overrides the entry per *platform*
        (``"cpu"``/``"tpu"``) or per exact *triple* (``"cpu-bf2"``): the
        toolchain analogue of per-ISA intrinsics behind one source — e.g.
        the Gatherer ships a Pallas ``embed_lookup`` body in its TPU slice
        and the masked-take reference everywhere else, and the pushdown
        Filter ships a masked-take body in its DPU (``cpu-bf2``) slice.
        A triple key wins over its platform key (both map to the same
        lowering platform — the BF2's Arm cores are still ``"cpu"`` to
        XLA, but its slice may carry a different body).  Every slice must
        compute the same function; only the lowering differs.  A slice
        whose override fails to cross-lower (e.g. a Pallas TPU kernel that
        this JAX build cannot serialize from a CPU-only machine) falls
        back to the portable ``fn``.
        """
        slices: dict[str, bytes] = {}
        overrides = dict(fn_by_platform or {})
        for triple in targets:
            plat = platform_of(triple)
            entry = overrides.get(triple, overrides.get(plat, fn))
            try:
                exported = jax.export.export(
                    jax.jit(entry), platforms=[plat]
                )(*in_avals)
            except Exception:
                if entry is fn:
                    raise
                exported = jax.export.export(jax.jit(fn), platforms=[plat])(
                    *in_avals
                )
            slices[triple] = exported.serialize()
        return cls(slices=slices)

    # -- the wire format ----------------------------------------------------
    def to_bytes(self) -> bytes:
        out = io.BytesIO()
        out.write(_MAGIC)
        out.write(struct.pack("<H", len(self.slices)))
        for triple in sorted(self.slices):
            blob = self.slices[triple]
            t = triple.encode()
            out.write(struct.pack("<HI", len(t), len(blob)))
            out.write(t)
            out.write(blob)
        return out.getvalue()

    @classmethod
    def from_bytes(cls, data: bytes) -> "FatBitcode":
        """Parse one archive; anything malformed — truncated slice table,
        undecodable triple, lengths past the end of the buffer — is a loud
        :class:`~repro.core.frame.CorruptFrame`, never a struct/index/
        decode error leaking out of a hostile frame."""
        if data[:4] != _MAGIC:
            raise CorruptFrame("not a fat-bitcode archive")
        if len(data) < 6:
            raise CorruptFrame("corrupt fat-bitcode: truncated slice count")
        (n,) = struct.unpack_from("<H", data, 4)
        off = 6
        slices: dict[str, bytes] = {}
        for _ in range(n):
            if len(data) < off + 6:
                raise CorruptFrame("corrupt fat-bitcode: truncated slice header")
            tlen, blen = struct.unpack_from("<HI", data, off)
            off += 6
            if len(data) < off + tlen + blen:
                raise CorruptFrame("corrupt fat-bitcode: slice exceeds archive")
            try:
                triple = data[off : off + tlen].decode()
            except UnicodeDecodeError as e:
                raise CorruptFrame(
                    f"corrupt fat-bitcode: undecodable triple ({e})"
                ) from None
            off += tlen
            slices[triple] = data[off : off + blen]
            off += blen
        return cls(slices=slices)

    # -- target-side extraction --------------------------------------------
    def extract(self, triple: str | None = None) -> BitcodeSlice:
        """Pick the slice matching the local target triple.

        Falls back to any slice with the same *platform* (µarch variants of
        one ISA share bitcode; ORC-JIT specializes at codegen time).
        """
        triple = triple or local_triple()
        if triple in self.slices:
            return BitcodeSlice(triple, self.slices[triple])
        want = platform_of(triple)
        for t, blob in sorted(self.slices.items()):
            if platform_of(t) == want:
                return BitcodeSlice(t, blob)
        raise LookupError(
            f"fat-bitcode has no slice for {triple!r} (have {sorted(self.slices)})"
        )

    @property
    def digest(self) -> str:
        return hashlib.sha256(self.to_bytes()).hexdigest()

    @property
    def nbytes(self) -> int:
        return len(self.to_bytes())

    def triples(self) -> tuple[str, ...]:
        return tuple(sorted(self.slices))


def deserialize_and_jit(blob: bytes) -> tuple[Callable[..., Any], tuple]:
    """Target-side ORC-JIT analogue: deserialize a slice and wrap in jit.

    Returns (compiled callable, in_avals). The first invocation pays XLA
    compile (the paper's ms-scale JIT cost); subsequent calls hit XLA's
    executable cache, which is what :class:`repro.core.cache.TargetCodeCache`
    keeps alive across messages.
    """
    exported = jax.export.deserialize(blob)
    return jax.jit(exported.call), tuple(exported.in_avals)


def deserialize_eager(blob: bytes) -> tuple[Callable[..., Any], tuple]:
    """Binary-mode analogue: code arrives ready-to-run, no target JIT.

    Mirrors binary ifuncs (Sec. III-B): zero compile latency on target but no
    target-µarch optimization. The call goes through the deserialized
    executable without an outer jit wrapper.
    """
    exported = jax.export.deserialize(blob)
    return exported.call, tuple(exported.in_avals)
