"""Recursive code propagation: spanning-tree multicast of injected code.

The paper's signature claim (Sec. I) is that remotely injected code "can
recursively propagate itself to other remote machines": a PE that installs
shipped code may re-publish it onward, so distributing one ifunc to N peers
costs the source O(log N) sends instead of O(N) point-to-point pushes.
This module holds the *shape* of that propagation:

* :class:`PropagationConfig` — per-PE policy (tree topology, fanout, ttl),
  threaded through :class:`repro.core.cluster.Cluster` exactly like
  :class:`repro.core.dataplane.DataPlaneConfig`.
* tree math — binomial and k-ary spanning trees over the cluster's dense
  peer-index space, rooted at *any* peer (indices are relabeled
  ``(i - root) mod n`` so one rule serves every root).
* :func:`tree_completion_us` — the LogP-style completion-time model for a
  multicast: a sender injects successive child frames ``o_us`` apart, each
  hop pays ``alpha_us`` latency, and subtrees proceed in parallel.  This is
  the quantity a tree wins on: the *serial* wire-byte total of tree and
  flat push is identical (every PE receives the code once either way, plus
  the tree's small hop headers), but the root's NIC stops being the serial
  bottleneck.

Wire-format counterpart: :class:`repro.core.frame.HopHeader` (ttl + path
digest); runtime counterpart: the PUBLISH path in
:mod:`repro.core.pe.progress` (target side) and the publish fan-out on the
:mod:`repro.core.pe.pe` facade (source side).

Safety counterpart: :mod:`repro.core.verify`.  Under a sandbox the
verifier caps recursive propagation *below* this module's ttl: a digest's
capability stamp records ``min(SandboxConfig.max_publish_ttl, admitting
hop's ttl)``, so shipped code re-publishing itself (A_PUBLISH) can spend
hops but never re-mint a budget larger than the one it arrived with —
``DEFAULT_TTL`` here is the ceiling an *unsandboxed* publish starts from.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

from .transport import WireModel

#: Default remaining-hop budget for a fresh publish: covers a binomial tree
#: of 2^16 PEs or a binary k-ary tree 16 levels deep — deep enough for any
#: cluster this runtime simulates, small enough to strangle a forwarding
#: loop that somehow survives the path-based cycle refusal.
DEFAULT_TTL = 16

BINOMIAL = 0  #: wire k-code for the binomial tree (HopHeader.k == 0)


@dataclass(frozen=True)
class PropagationConfig:
    """Per-PE propagation policy (all trees are over the dense peer-index
    space X-RDMA action vectors use).

    ``topology`` — ``"binomial"`` (fanout falls with depth: peer 2^j gets
    its subtree early and keeps the root's NIC busy exactly ``ceil(log2 n)``
    sends) or ``"kary"`` (fixed fanout ``k``: shallower trees for small
    ``n``, bounded per-node send burst).
    ``ttl`` — hop budget stamped into fresh publishes from this PE.
    """

    topology: str = "binomial"
    k: int = 2
    ttl: int = DEFAULT_TTL

    def __post_init__(self) -> None:
        if self.topology not in ("binomial", "kary"):
            raise ValueError(f"unknown tree topology {self.topology!r}")
        if self.topology == "kary" and self.k < 1:
            raise ValueError("k-ary tree needs k >= 1")
        if not 1 <= self.ttl <= 255:
            raise ValueError("ttl must fit the hop header's u8")

    @property
    def k_code(self) -> int:
        """The tree-shape byte that travels in the hop header."""
        return BINOMIAL if self.topology == "binomial" else self.k

    # convenience pass-throughs so callers hold one object
    def children(self, root: int, me: int, n: int) -> list[int]:
        return tree_children(self.k_code, root, me, n)

    def parent(self, root: int, me: int, n: int) -> int:
        return tree_parent(self.k_code, root, me, n)


# ------------------------------------------------------------- tree shapes
def _binomial_children_label(l: int, n: int) -> list[int]:
    """Children of label ``l`` in the binomial broadcast tree over labels
    0..n-1: ``l + 2^j`` for ascending j below ``l``'s lowest set bit (the
    root, label 0, parents every power of two)."""
    limit = (l & -l) if l else 1 << max(n - 1, 1).bit_length()
    out, j = [], 1
    while j < limit and l + j < n:
        out.append(l + j)
        j <<= 1
    return out


def _binomial_parent_label(l: int) -> int:
    """Parent of label ``l``: clear its lowest set bit (root parents itself)."""
    return l - (l & -l) if l else 0


def _kary_children_label(l: int, n: int, k: int) -> list[int]:
    return [c for c in range(k * l + 1, k * l + k + 1) if c < n]


def _kary_parent_label(l: int, k: int) -> int:
    return (l - 1) // k if l else 0


def tree_children(k_code: int, root: int, me: int, n: int) -> list[int]:
    """Peer indices ``me`` re-publishes to, in the tree rooted at ``root``
    over ``n`` peers (``k_code`` 0 = binomial, else k-ary fanout)."""
    l = (me - root) % n
    labels = (
        _binomial_children_label(l, n)
        if k_code == BINOMIAL
        else _kary_children_label(l, n, k_code)
    )
    return [(c + root) % n for c in labels]


def tree_parent(k_code: int, root: int, me: int, n: int) -> int:
    """Peer index ``me`` reports to (``root`` maps to itself)."""
    l = (me - root) % n
    p = (
        _binomial_parent_label(l)
        if k_code == BINOMIAL
        else _kary_parent_label(l, k_code)
    )
    return (p + root) % n


def tree_children_map(k_code: int, root: int, n: int) -> dict[int, list[int]]:
    """The whole tree at once: peer index -> list of child peer indices."""
    return {i: tree_children(k_code, root, i, n) for i in range(n)}


def subtree_sizes(k_code: int, root: int, n: int) -> dict[int, int]:
    """Peer index -> number of tree nodes in its subtree (itself included).
    This is the contribution count a reduction over the same tree expects
    from each node before it may fold upward."""
    children = tree_children_map(k_code, root, n)
    sizes: dict[int, int] = {}

    def size(i: int) -> int:
        if i not in sizes:
            sizes[i] = 1 + sum(size(c) for c in children[i])
        return sizes[i]

    size(root)
    assert len(sizes) == n and sizes[root] == n, "tree does not span the peers"
    return sizes


def tree_depth(k_code: int, root: int, n: int) -> int:
    """Longest root-to-leaf hop count (the ttl a full-coverage publish needs)."""
    children = tree_children_map(k_code, root, n)

    def depth(i: int) -> int:
        return 1 + max((depth(c) for c in children[i]), default=-1)

    return depth(root)


# --------------------------------------------------- completion-time model
def tree_completion_us(
    wire: WireModel,
    children: Mapping[int, Sequence[int]],
    root: int,
    edge_nbytes: Callable[[int, int], int],
) -> float:
    """Modeled multicast completion time over an arbitrary rooted tree.

    LogP-style: a node sends to its children back-to-back (successive
    injections ``inverse_throughput_us`` apart — gap + bytes at the
    pipelined bandwidth), each frame then pays the ``alpha_us`` wire hop,
    and every subtree proceeds in parallel from its own arrival time.
    ``edge_nbytes(parent, child)`` supplies the per-edge frame size (cold
    edges carry code, warm edges a digest-only frame).  A flat push is the
    same model over a star tree — which is exactly why it loses: the root
    serializes all N injections while the tree amortizes them down the
    levels.
    """
    arrive = {root: 0.0}
    stack = [root]
    while stack:
        p = stack.pop()
        t = arrive[p]
        for c in children.get(p, ()):  # send order = tree child order
            t += wire.inverse_throughput_us(edge_nbytes(p, c))
            arrive[c] = t + wire.alpha_us
            stack.append(c)
    return max(arrive.values())
