"""The two caches of the Three-Chains protocol (Sec. III-D, Fig. 4).

* :class:`SenderCache` — source side. A hash table keyed by
  (endpoint, code digest): if present, the target has seen *these exact
  bytes*, so the PUT is truncated at the first MAGIC (code bytes never
  travel again).  Keying by digest rather than ifunc name matters when an
  ifunc is republished under the same name with different code (e.g. a
  rebuilt ``chaser`` after a table resize): the new digest misses, the new
  code travels, and the target never invokes stale code on a fresh payload.

* :class:`TargetCodeCache` — target side. Digest-keyed registry of JIT'd
  executables (the ORC-JIT in-memory cache): the first frame of a type pays
  deserialize+compile; every later frame of that type goes straight to
  invoke. Also remembers which ifunc *names* are registered, which is how the
  receiver decides whether to expect a truncated or a full frame.  The
  batched runtime additionally caches one *batched* executable per
  (digest, padding bucket): a vmapped/`lax.map`-ped rendering of the same
  code that retires a whole (B, ...) payload block in one XLA dispatch.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    bytes_saved: int = 0
    jit_compiles: int = 0
    jit_ms_total: float = 0.0

    def as_dict(self) -> dict[str, float]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "bytes_saved": self.bytes_saved,
            "jit_compiles": self.jit_compiles,
            "jit_ms_total": round(self.jit_ms_total, 3),
        }


class SenderCache:
    """Tracks which (endpoint, code digest) pairs have already received code."""

    def __init__(self) -> None:
        self._seen: set[tuple[str, str]] = set()
        self._lock = threading.Lock()
        self.stats = CacheStats()

    def has(self, endpoint: str, digest: str) -> bool:
        """Non-mutating peek: does the target already hold this code?  Used
        by the data plane to decide protocols (a rendezvous descriptor
        cannot carry code) without claiming a send happened."""
        with self._lock:
            return (endpoint, digest) in self._seen

    def mark(self, endpoint: str, digest: str) -> None:
        """Record that the target holds this code *without* a send having
        happened: a completed tree publish confirmed coverage (the paper's
        predeployment-by-propagation), so later sends may truncate.  Unlike
        :meth:`check_and_add` this neither counts a hit nor a miss — no
        frame moved."""
        with self._lock:
            self._seen.add((endpoint, digest))

    def check_and_add(self, endpoint: str, digest: str, code_nbytes: int) -> bool:
        """True if the target already has the code (=> truncate the send)."""
        key = (endpoint, digest)
        with self._lock:
            if key in self._seen:
                self.stats.hits += 1
                self.stats.bytes_saved += code_nbytes
                return True
            self._seen.add(key)
            self.stats.misses += 1
            return False

    def forget(self, endpoint: str, digest: str) -> None:
        """Drop one (endpoint, digest) entry: the sender has reason to
        believe this specific delivery never happened (failed PUT, subtree
        re-parent after a drop) and must re-send the full frame."""
        with self._lock:
            self._seen.discard((endpoint, digest))

    def invalidate_endpoint(self, endpoint: str) -> None:
        """Drop all entries for an endpoint (e.g. PE restarted after a fault:
        its code cache is gone, full frames must be re-sent)."""
        with self._lock:
            self._seen = {k for k in self._seen if k[0] != endpoint}

    def invalidate_digest(self, digest: str) -> None:
        """Drop all entries for one code digest, every endpoint: the digest
        was quarantined (sandbox refusal) and uninstalled fabric-wide, so
        any later send of those bytes must travel full — where the
        receiving verifier refuses it loudly instead of silently invoking
        a stale truncated reference."""
        with self._lock:
            self._seen = {k for k in self._seen if k[1] != digest}


@dataclass
class CachedExecutable:
    name: str
    digest: str
    fn: Callable[..., Any]  # compiled entry
    in_avals: tuple
    deps: tuple[str, ...]
    kind: int
    extras: dict[str, Any] = field(default_factory=dict)


class TargetCodeCache:
    """Digest-keyed executable cache + name registry on the target PE."""

    def __init__(self) -> None:
        self._by_digest: dict[str, CachedExecutable] = {}
        self._by_name: dict[str, CachedExecutable] = {}
        self._batched: dict[tuple[str, int], Callable[..., Any]] = {}
        self._lock = threading.Lock()
        self.stats = CacheStats()
        self.batched_compiles = 0

    def has_name(self, name: str) -> bool:
        with self._lock:
            return name in self._by_name

    def lookup(self, name: str) -> CachedExecutable | None:
        with self._lock:
            exe = self._by_name.get(name)
            if exe is not None:
                self.stats.hits += 1
            return exe

    def lookup_digest(self, digest: str) -> CachedExecutable | None:
        with self._lock:
            return self._by_digest.get(digest)

    def install(self, exe: CachedExecutable, jit_ms: float = 0.0) -> None:
        with self._lock:
            self._by_digest[exe.digest] = exe
            self._by_name[exe.name] = exe
            self.stats.misses += 1
            self.stats.jit_compiles += 1
            self.stats.jit_ms_total += jit_ms

    # batched executables: one per (digest, power-of-two padding bucket) ----
    def lookup_batched(self, digest: str, bucket: int) -> Callable[..., Any] | None:
        with self._lock:
            return self._batched.get((digest, bucket))

    def install_batched(self, digest: str, bucket: int, fn: Callable[..., Any]) -> None:
        with self._lock:
            self._batched[(digest, bucket)] = fn
            self.batched_compiles += 1

    def deregister(self, name: str) -> None:
        """ifunc de-registration discards the JIT'd code (Sec. III-C)."""
        with self._lock:
            exe = self._by_name.pop(name, None)
            if exe is not None:
                self._by_digest.pop(exe.digest, None)
                self._batched = {
                    k: v for k, v in self._batched.items() if k[0] != exe.digest
                }

    def forget_names(self) -> None:
        """Drop the Three-Chains registry but keep the digest-keyed JIT
        artifacts — the paper's two cache layers (Sec. V-A 'Lookup'): the
        TSI uncached benchmark forgets registrations so full frames travel
        and the install path runs, while LLVM's (here: XLA's) compiled
        code is still found by content digest, so re-JIT costs nothing."""
        with self._lock:
            self._by_name.clear()

    def clear(self) -> None:
        with self._lock:
            self._by_digest.clear()
            self._by_name.clear()
            self._batched.clear()
