"""Reliability policy: the knobs the exactly-once delivery layer runs on.

The paper's X-RDMA frames ride UCX's reliable RC transport; the simulated
fabric here is at-least-once *and* — with :meth:`Fabric.set_loss` armed —
lossy.  :class:`ReliabilityConfig` parameterizes the recovery machinery
spread across the PE layers:

* the **sender** (:class:`repro.core.pe.wire.WireLayer`) assigns per-peer
  sequence numbers, keeps the exact transmitted bytes of every unacked
  frame, and retransmits on a tick clock with exponential backoff
  (``rto_ticks``, ``backoff``); a frame retransmitted ``retransmit_budget``
  times without an ACK escalates its peer to *suspect*;
* the **receiver** (:class:`repro.core.pe.progress.ProgressEngine`) ingests
  in seq order (out-of-order frames held, duplicates dropped — exactly-once
  delivery into the lanes), piggybacks cumulative ACKs on every frame it
  sends back, and emits a standalone ACK frame after ``ack_delay`` idle
  ticks so a one-directional flow still completes;
* the **failure detector** (also in the progress engine) declares a
  *suspected* peer dead after ``max_misses`` further silent ticks, then
  clears every piece of state entangled with it — credits, sender-cache
  rows, retransmit queues — the way ``Cluster.restart_server`` does;
* **completion deadlines**: a :class:`repro.core.pe.cq.GatherFuture`
  submitted under reliability expires after ``future_deadline`` ticks, at
  which point the service layer resubmits it to a surviving owner or
  degrades it to a partial result with a per-position validity mask.

Everything defaults to *off*: ``ReliabilityConfig()`` is the pre-reliability
runtime, bit-for-bit (frames carry seq 0 / ack 0 and bypass all of the
above).  ``ReliabilityConfig.on()`` enables the layer with the defaults the
benchmarks use.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ReliabilityConfig:
    """Knobs for the reliable-delivery / failure-recovery layer.

    ``rto_ticks``          base retransmission timeout, in progress-engine
                           ticks (one tick = one ``poll`` of the PE).
    ``backoff``            exponential backoff factor: the (n+1)-th
                           retransmission waits ``rto_ticks * backoff**n``.
    ``retransmit_budget``  retransmissions per frame before the peer is
                           escalated to *suspect* (retransmission pauses).
    ``max_misses``         ticks a suspected peer may stay silent before the
                           failure detector declares it dead.
    ``ack_delay``          ticks a received frame may wait for a piggyback
                           opportunity before a standalone ACK is emitted.
    ``future_deadline``    ticks before an in-flight completion-queue future
                           expires and the service resubmits or degrades it.
    """

    enabled: bool = False
    rto_ticks: int = 4
    backoff: float = 2.0
    retransmit_budget: int = 5
    max_misses: int = 3
    ack_delay: int = 2
    future_deadline: int = 64

    @classmethod
    def on(cls, **kwargs) -> "ReliabilityConfig":
        """The enabled configuration (benchmark/test defaults)."""
        kwargs.setdefault("enabled", True)
        return cls(**kwargs)

    def rto_after(self, attempts: int) -> int:
        """Timeout (ticks) before retransmission number ``attempts + 1``."""
        return max(1, int(math.ceil(self.rto_ticks * self.backoff**attempts)))

    def recovery_horizon(self) -> int:
        """Worst-case ticks from a frame's first transmission to its peer
        being declared dead: every backoff interval, then the detector's
        silence window."""
        return (
            sum(self.rto_after(i) for i in range(self.retransmit_budget))
            + self.max_misses
            + self.ack_delay
        )

    def idle_grace(self) -> int:
        """Zero-progress polls a driver loop must tolerate before calling
        the cluster wedged: recovery is *supposed* to look idle between a
        backoff timer arming and firing."""
        return self.recovery_horizon() + 4
