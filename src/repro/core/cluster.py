"""In-process cluster of processing elements (hosts + DPUs) on one fabric.

Peer indexing convention: peers[0..n_servers-1] are the servers (DPU role),
peers[n_servers] is the client (host role).  This index space is what
X-RDMA action vectors use for ``dst``/``requester`` fields.

The scheduler is a deterministic single-threaded round-robin poll loop
(this container has one core; daemon-thread polling is supported by the
same PE.poll API but benchmarks use the scheduler for reproducibility).
"""

from __future__ import annotations

from typing import Callable

from .dataplane import DataPlaneConfig
from .pe import PE, Toolchain
from .propagate import PropagationConfig
from .reliability import ReliabilityConfig
from .transport import Capability, Fabric, WireModel
from .verify import SandboxConfig


class Cluster:
    def __init__(
        self,
        n_servers: int,
        wire: WireModel | str = "ideal",
        server_triple: str = "cpu-bf2",
        client_triple: str = "cpu-host",
        toolchain: Toolchain | None = None,
        hetero_wire: bool = False,
    ) -> None:
        self.fabric = Fabric(wire)
        # hetero_wire=True prices every fabric op with the *initiator's*
        # advertised capability profile (mixed thor_xeon + thor_bf2
        # accounting); default off keeps single-profile accounting
        # bit-identical to prior runs.
        self.fabric.hetero = hetero_wire
        self.toolchain = toolchain or Toolchain()
        self.n_servers = n_servers
        names = [f"server{i}" for i in range(n_servers)] + ["client"]
        self.servers = [
            PE(n, self.fabric, triple=server_triple, toolchain=self.toolchain, peers=names)
            for n in names[:-1]
        ]
        self.client = PE(
            "client", self.fabric, triple=client_triple, toolchain=self.toolchain, peers=names
        )
        # placement optimizers watching this cluster (register_placement):
        # restart_server tells them to drop cached plans routed to the
        # restarted PE.  Cluster-level default placement policy, settable
        # via tuned FlowProfiles (set_flow).
        self._placements: list = []
        self.placement_policy: str | None = None

    @property
    def client_index(self) -> int:
        return self.n_servers

    # ------------------------------------------------------------ placement
    def capabilities(self) -> "dict[str, Capability]":
        """Advertised platform/capability vector per live PE."""
        return dict(self.fabric.capabilities)

    def register_placement(self, optimizer) -> None:
        """Attach a placement optimizer whose cached plans must be
        invalidated when a PE restarts (idempotent)."""
        if optimizer not in self._placements:
            self._placements.append(optimizer)

    def placement(self):
        """The most recently registered placement optimizer, or ``None``."""
        return self._placements[-1] if self._placements else None

    def set_placement(self, policy: "str | None") -> None:
        """Cluster-wide default placement policy consumed by services when
        a call doesn't pin one: ``"pushdown"``, ``"pull"``, ``"auto"``
        (consult a placement optimizer), or ``None`` (service default)."""
        if policy is not None and policy not in ("pushdown", "pull", "auto"):
            raise ValueError(f"unknown placement policy {policy!r}")
        self.placement_policy = policy

    def set_batching(self, enabled: bool) -> None:
        """Flip every PE between the per-message and the batched runtime
        (coalesced sends + grouped polls)."""
        for pe in self.pes():
            pe.batching = enabled

    def set_dataplane(self, config: DataPlaneConfig | None) -> None:
        """Install one data-plane protocol selection (framed / zero-copy /
        rendezvous thresholds) on every PE; ``None`` restores the default
        all-framed plane."""
        cfg = config or DataPlaneConfig()
        for pe in self.pes():
            pe.dataplane = cfg

    def set_propagation(self, config: PropagationConfig | None) -> None:
        """Install one propagation policy (tree topology / fanout / ttl)
        on every PE, the way :meth:`set_dataplane` threads the data plane;
        ``None`` restores the default (binomial, DEFAULT_TTL)."""
        cfg = config or PropagationConfig()
        for pe in self.pes():
            pe.propagation = cfg

    def set_flow(
        self,
        lanes: bool | None = None,
        credit_window: int | None = None,
        poll_budget: int | None = ...,  # type: ignore[assignment]
        profile: "str | dict | None" = None,
    ) -> None:
        """Install progress-engine/flow-control knobs on every PE: control-
        before-data ``lanes``, the per-peer ``credit_window`` (payloads;
        0 disables), and the per-poll ``poll_budget`` (payloads; ``None``
        drains everything; pass it explicitly to change it — the default
        leaves it alone).

        ``profile`` loads a whole tuned knob set at once — a mapping (or a
        path to a JSON file) in the ``FlowProfile.as_dict()`` shape emitted
        by :mod:`repro.analysis.autotune` — applying batching, the data
        plane, the propagation tree, the flow knobs above, and tenant
        budgets in one shot.  Explicit keyword arguments win over the
        profile's values.  The profile travels as plain JSON so the core
        never imports the analysis layer.
        """
        if profile is not None:
            if isinstance(profile, str):
                import json

                with open(profile) as fp:
                    profile = json.load(fp)
            if "batching" in profile:
                self.set_batching(bool(profile["batching"]))
            if {"eager_max", "rndv_min", "zerocopy"} & profile.keys():
                self.set_dataplane(
                    DataPlaneConfig(
                        eager_max=int(profile.get("eager_max", 256)),
                        rndv_min=int(profile.get("rndv_min", 1 << 62)),
                        zerocopy=bool(profile.get("zerocopy", False)),
                    )
                )
            k_code = profile.get("k_code")
            if k_code is not None:
                self.set_propagation(
                    PropagationConfig()
                    if int(k_code) == 0
                    else PropagationConfig(topology="kary", k=int(k_code))
                )
            if lanes is None and "lanes" in profile:
                lanes = bool(profile["lanes"])
            if credit_window is None and "credit_window" in profile:
                credit_window = int(profile["credit_window"])
            if poll_budget is ... and "poll_budget" in profile:
                pb = profile["poll_budget"]
                poll_budget = None if pb is None else int(pb)
            if profile.get("tenant_budgets"):
                self.set_tenant_budgets(dict(profile["tenant_budgets"]))
            if "placement" in profile:
                self.set_placement(profile["placement"])
        for pe in self.pes():
            if lanes is not None:
                pe.lanes = lanes
            if credit_window is not None:
                pe.credit_window = credit_window
            if poll_budget is not ...:
                pe.poll_budget = poll_budget

    def set_tenant_budgets(self, budgets: "dict[str, int] | str | None") -> None:
        """Install one per-tenant outgoing-credit budget map on every PE's
        wire layer (tenant -> payloads in flight; 0/absent = unbudgeted);
        ``None`` clears all budgets — the untenanted runtime.  A string is
        a path to a JSON file holding the map (or a tuned profile dict
        with a ``tenant_budgets`` key)."""
        if isinstance(budgets, str):
            import json

            with open(budgets) as fp:
                loaded = json.load(fp)
            budgets = loaded.get("tenant_budgets", loaded) or {}
        budgets = dict(budgets or {})
        for pe in self.pes():
            pe.wire.tenant_budgets = dict(budgets)

    def set_reliability(self, config: ReliabilityConfig | None) -> None:
        """Install one reliability policy (seq/ack tracking, retransmit
        timers, failure detection) on every PE; ``None`` restores the
        default (disabled — the pre-reliability runtime, bit-for-bit)."""
        cfg = config or ReliabilityConfig()
        for pe in self.pes():
            pe.reliability = cfg

    def set_sandbox(self, config: SandboxConfig | None) -> None:
        """Install one safe-code-injection policy (install-time verifier +
        runtime quotas) on every PE, and wire quarantine propagation: a
        digest refused anywhere is uninstalled everywhere, every sender
        cache forgets it, and each PE degrades its own in-flight futures;
        ``None`` restores the default (disabled — the unverified runtime,
        bit-for-bit)."""
        cfg = config or SandboxConfig()
        for pe in self.pes():
            pe.sandbox = cfg
            # idempotent re-wiring: exactly one cluster listener per PE
            pe.verifier.on_quarantine = [self._quarantine_cluster_wide]

    def _quarantine_cluster_wide(self, digest: str, name: str) -> None:
        """One PE originated a quarantine: absorb it on every PE (local
        uninstall + CQ degradation + queue purge, no re-broadcast) and
        make every sender cache forget the digest, so no truncated frame
        referencing the banished code ever travels again."""
        for pe in self.pes():
            pe.sender_cache.invalidate_digest(digest)
            pe.verifier.absorb_quarantine(digest, name)

    def refusals(self) -> dict[str, int]:
        """Cluster-wide rollup of every PE's refusal counters (publish-path
        refusals, verifier refusals, sandbox quota refusals), per reason."""
        total: dict[str, int] = {}
        for pe in self.pes():
            for reason, n in pe.stats.refusals.items():
                total[reason] = total.get(reason, 0) + n
        return total

    def _recovery_grace(self) -> int:
        """Zero-progress rounds the scheduler must tolerate before calling
        the cluster dead: under reliability, a lost frame sits silent until
        its retransmit timer fires, so idleness up to the recovery horizon
        is recovery in progress, not a hang."""
        graces = [
            pe.reliability.idle_grace()
            for pe in self.alive_pes()
            if pe.reliability.enabled
        ]
        return max(graces, default=0)

    def pes(self) -> list[PE]:
        return [*self.servers, self.client]

    def drain_rounds(self, max_rounds: int = 100_000) -> int:
        """Poll every live PE until a full round makes no progress; returns
        the round count.  (Unlike :meth:`drain` this needs no idle-grace
        heuristics when reliability is off: propagation traffic is
        self-contained, so one zero-progress round means the fabric is
        empty.  Under reliability a lost frame is silent until its
        retransmit timer fires, so idle rounds up to the recovery horizon
        are tolerated before declaring the fabric drained.)"""
        rounds = 0
        idle = 0
        grace = self._recovery_grace()
        while rounds < max_rounds:
            rounds += 1
            if sum(pe.poll() for pe in self.alive_pes()) == 0:
                idle += 1
                if idle > grace:
                    break
            else:
                idle = 0
        return rounds

    def publish_and_cover(
        self,
        name: str,
        payload: bytes = b"",
        config: PropagationConfig | None = None,
        ttl: int | None = None,
        reparent: bool = True,
        max_rounds: int = 100_000,
    ) -> tuple[int, int, list[PE]]:
        """The fault-handling core every tree publish shares: publish from
        the client down the spanning tree, drain, and re-cover any alive
        server a dropped hop or dead mid-tree PE left without the code by
        a *direct* root publish (ttl=1; ``publish_to`` forgets the stale
        sender-cache row so the code travels again).  Returns ``(rounds,
        reparented, still_uncovered)`` — reporting layers
        (:func:`repro.sharding.collectives.xrdma_bcast`) and strict layers
        (:meth:`distribute_code`) decide what partial coverage means.
        """
        cfg = config or PropagationConfig()
        self.set_propagation(cfg)
        client = self.client
        hexd = client.resolve_source(name).digest.hex()
        alive = [pe for pe in self.servers if pe.endpoint.alive]

        def uncovered() -> list[PE]:
            return [
                pe for pe in alive if pe.target_cache.lookup_digest(hexd) is None
            ]

        client.publish_ifunc(name, payload, ttl=ttl, config=cfg)
        rounds = self.drain_rounds(max_rounds)
        reparented = 0
        if reparent:
            missing = uncovered()
            for pe in missing:
                client.publish_to(pe.name, name, payload, ttl=1)
                reparented += 1
            if missing:
                rounds += self.drain_rounds(max_rounds)
        return rounds, reparented, uncovered()

    def distribute_code(self, name: str, config: PropagationConfig | None = None) -> None:
        """Tree-publish an ifunc's code from the client to every alive
        server (code-only publish: install + re-publish, no invoke), then
        mark *every* sender's cache for the covered peers so the whole
        subsequent request stream — client launches and server-to-server
        FORWARDs alike — travels digest-only.  A degraded cluster
        distributes exactly as a healthy one minus its corpses
        (:meth:`publish_and_cover` re-parents orphaned subtrees); residual
        gaps are an error here, because a workload is about to send
        digest-only frames that an uncovered PE cannot decode.
        """
        _, _, still = self.publish_and_cover(name, b"", config=config)
        if still:  # direct publishes cannot be lost on this fabric
            raise TimeoutError(
                f"code distribution of {name!r} left "
                f"{[pe.name for pe in still]} uncovered"
            )
        hexd = self.client.resolve_source(name).digest.hex()
        alive = [pe for pe in self.servers if pe.endpoint.alive]
        for sender in self.alive_pes():
            for pe in alive:
                sender.sender_cache.mark(pe.name, hexd)

    def alive_pes(self) -> list[PE]:
        return [pe for pe in self.pes() if pe.endpoint.alive]

    # ------------------------------------------------------------- schedule
    def run_until(
        self,
        pred: Callable[[], bool],
        max_rounds: int = 1_000_000,
    ) -> int:
        """Round-robin poll all live PEs until ``pred()`` holds.

        Returns the number of scheduler rounds.  Raises TimeoutError if the
        cluster goes idle (no messages in flight) while ``pred`` is false —
        that means a message was lost (e.g. a PE died), which is the fault
        the runtime layer recovers from.
        """
        idle = 0
        idle_limit = max(2, self._recovery_grace())
        for rounds in range(max_rounds):
            if pred():
                return rounds
            progress = sum(pe.poll() for pe in self.alive_pes())
            if progress == 0:
                idle += 1
                if idle > idle_limit:
                    raise TimeoutError("cluster idle but predicate unsatisfied")
            else:
                idle = 0
        raise TimeoutError("max_rounds exceeded")

    def drain(self, max_rounds: int = 1_000_000) -> None:
        """Poll until no traffic remains in flight."""
        try:
            self.run_until(lambda: False, max_rounds=max_rounds)
        except TimeoutError:
            pass

    # ------------------------------------------------------- fault injection
    def kill_server(self, idx: int) -> None:
        self.fabric.kill(f"server{idx}")

    def restart_server(self, idx: int) -> PE:
        """Process restart: fresh endpoint, empty caches — and every other
        PE's sender-cache entries for this endpoint dropped, because the
        restarted process no longer holds any code a sender believes it
        sent.  Without the invalidation a sender would ship digest-only
        (truncated) frames the fresh PE cannot decode; with it, the next
        send pays the full code frame once and re-warms."""
        name = f"server{idx}"
        # PE() connects a fresh endpoint, displacing the dead one: fresh
        # inbox, no regions, empty caches — exactly a restarted process.
        pe = PE(
            name,
            self.fabric,
            triple=self.servers[idx].triple,
            toolchain=self.toolchain,
            peers=self.servers[idx].peers,
        )
        self.servers[idx] = pe
        for peer in self.pes():
            # drops sender-cache rows, reliability seq/retransmit state,
            # pairwise credits, and the publish dedup keys of the previous
            # life (a restarted process re-mints publish ids from zero, and
            # its fresh seq stream restarts at 1 — stale windows would
            # swallow both)
            peer.forget_peer_state(name)
        # the fresh PE re-advertised its capability vector under a new
        # epoch (PE.__init__); any placement plan priced against the dead
        # incarnation is garbage — drop it so the next plan() re-prices
        for optimizer in self._placements:
            optimizer.invalidate_peer(name)
        return pe
