"""In-process cluster of processing elements (hosts + DPUs) on one fabric.

Peer indexing convention: peers[0..n_servers-1] are the servers (DPU role),
peers[n_servers] is the client (host role).  This index space is what
X-RDMA action vectors use for ``dst``/``requester`` fields.

The scheduler is a deterministic single-threaded round-robin poll loop
(this container has one core; daemon-thread polling is supported by the
same PE.poll API but benchmarks use the scheduler for reproducibility).
"""

from __future__ import annotations

from typing import Callable, Sequence

from .dataplane import DataPlaneConfig
from .ifunc import PE, Toolchain
from .transport import Fabric, WireModel


class Cluster:
    def __init__(
        self,
        n_servers: int,
        wire: WireModel | str = "ideal",
        server_triple: str = "cpu-bf2",
        client_triple: str = "cpu-host",
        toolchain: Toolchain | None = None,
    ) -> None:
        self.fabric = Fabric(wire)
        self.toolchain = toolchain or Toolchain()
        self.n_servers = n_servers
        names = [f"server{i}" for i in range(n_servers)] + ["client"]
        self.servers = [
            PE(n, self.fabric, triple=server_triple, toolchain=self.toolchain, peers=names)
            for n in names[:-1]
        ]
        self.client = PE(
            "client", self.fabric, triple=client_triple, toolchain=self.toolchain, peers=names
        )

    @property
    def client_index(self) -> int:
        return self.n_servers

    def set_batching(self, enabled: bool) -> None:
        """Flip every PE between the per-message and the batched runtime
        (coalesced sends + grouped polls)."""
        for pe in self.pes():
            pe.batching = enabled

    def set_dataplane(self, config: DataPlaneConfig | None) -> None:
        """Install one data-plane protocol selection (framed / zero-copy /
        rendezvous thresholds) on every PE; ``None`` restores the default
        all-framed plane."""
        cfg = config or DataPlaneConfig()
        for pe in self.pes():
            pe.dataplane = cfg

    def pes(self) -> list[PE]:
        return [*self.servers, self.client]

    def alive_pes(self) -> list[PE]:
        return [pe for pe in self.pes() if pe.endpoint.alive]

    # ------------------------------------------------------------- schedule
    def run_until(
        self,
        pred: Callable[[], bool],
        max_rounds: int = 1_000_000,
    ) -> int:
        """Round-robin poll all live PEs until ``pred()`` holds.

        Returns the number of scheduler rounds.  Raises TimeoutError if the
        cluster goes idle (no messages in flight) while ``pred`` is false —
        that means a message was lost (e.g. a PE died), which is the fault
        the runtime layer recovers from.
        """
        idle = 0
        for rounds in range(max_rounds):
            if pred():
                return rounds
            progress = sum(pe.poll() for pe in self.alive_pes())
            if progress == 0:
                idle += 1
                if idle > 2:
                    raise TimeoutError("cluster idle but predicate unsatisfied")
            else:
                idle = 0
        raise TimeoutError("max_rounds exceeded")

    def drain(self, max_rounds: int = 1_000_000) -> None:
        """Poll until no traffic remains in flight."""
        try:
            self.run_until(lambda: False, max_rounds=max_rounds)
        except TimeoutError:
            pass

    # ------------------------------------------------------- fault injection
    def kill_server(self, idx: int) -> None:
        self.fabric.kill(f"server{idx}")

    def restart_server(self, idx: int) -> PE:
        """Process restart: fresh endpoint, empty caches — every sender's
        cache entry for this endpoint is now stale (tested by the runtime
        layer, which invalidates via SenderCache.invalidate_endpoint)."""
        name = f"server{idx}"
        # PE() connects a fresh endpoint, displacing the dead one: fresh
        # inbox, no regions, empty caches — exactly a restarted process.
        pe = PE(
            name,
            self.fabric,
            triple=self.servers[idx].triple,
            toolchain=self.toolchain,
            peers=self.servers[idx].peers,
        )
        self.servers[idx] = pe
        return pe
