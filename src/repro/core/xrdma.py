"""X-RDMA operations: Chaser, ReturnResult, TSI (paper Secs. IV-B/IV-C).

An X-RDMA operation is an ifunc whose arrival *executes user code next to
the data*, and whose code may re-inject itself (FORWARD), answer the
requester (RETURN via ReturnResult), or generate new code (SPAWN).  The
decision logic lives in the shipped code; see :mod:`repro.core.ifunc` for
the fixed action ABI.

All integer state is int32: tables up to 2^31 entries, which keeps the core
independent of the global ``jax_enable_x64`` flag (the LM framework must
stay bf16/f32-default).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .frame import FrameKind
from .ifunc import (
    ACTION_WIDTH,
    A_DONE,
    A_FORWARD,
    A_RETURN,
    A_SPAWN,
    IFunc,
)

I32 = jnp.int32
CHASER_PAYLOAD = 4  # [addr, depth, requester, slot]


def _vec(*slots) -> jax.Array:
    """Build a padded i32 action vector from (action, dst, plen, payload...).

    One stack+concatenate instead of a chained ``.at[i].set`` scatter loop:
    same result, ACTION_WIDTH-times fewer ops in every traced action graph.
    """
    vals = jnp.stack([jnp.asarray(s, I32) for s in slots])
    return jnp.concatenate([vals, jnp.zeros((ACTION_WIDTH - len(slots),), I32)])


# ------------------------------------------------------------------ Chaser
def chaser_entry(payload: jax.Array, shard: jax.Array, meta: jax.Array) -> jax.Array:
    """One X-RDMA Chaser hop (paper Sec. IV-C).

    Chase locally (``lax.while_loop`` — the paper's in-process recursive
    call) until the chase completes or the frontier leaves this shard; then
    RETURN the result to the requester or FORWARD *this same code* to the
    owner of the next entry.
    """
    addr0, depth0, requester, slot = payload[0], payload[1], payload[2], payload[3]
    shard_id, shard_size = meta[0], meta[1]
    base = shard_id * shard_size

    def cond(c):
        a, d = c
        return (d > 0) & (a // shard_size == shard_id)

    def body(c):
        a, d = c
        return shard[a - base], d - 1

    addr, depth = lax.while_loop(cond, body, (addr0, depth0))
    done = depth == 0
    ret = _vec(A_RETURN, requester, 2, slot, addr)
    fwd = _vec(A_FORWARD, addr // shard_size, 4, addr, depth, requester, slot)
    return jnp.where(done, ret, fwd)


def make_chaser(
    shard_size: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "chaser",
) -> IFunc:
    return IFunc.build(
        name=name,
        fn=chaser_entry,
        payload_aval=jax.ShapeDtypeStruct((CHASER_PAYLOAD,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((shard_size,), I32),
            jax.ShapeDtypeStruct((3,), I32),
        ),
        deps=("region:table_shard", "cap:shard_meta", "returns:return_result"),
        abi="xrdma",
        targets=targets,
        kind=kind,
    )


# ------------------------------------------------------------ ReturnResult
def return_result_entry(payload: jax.Array, results: jax.Array) -> jax.Array:
    """Write ``value`` into the requester's result slot and bump the
    completion counter (last element)."""
    slot, value = payload[0], payload[1]
    return results.at[slot].set(value).at[results.shape[0] - 1].add(1)


def make_return_result(
    max_slots: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
) -> IFunc:
    return IFunc.build(
        name="return_result",
        fn=return_result_entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        dep_avals=(jax.ShapeDtypeStruct((max_slots + 1,), I32),),
        deps=("region:results",),
        abi="update",
        targets=targets,
        kind=kind,
    )


# --------------------------------------------------------------------- TSI
def tsi_entry(payload: jax.Array, counter: jax.Array) -> jax.Array:
    """Target-Side Increment (paper Sec. IV-B): counter += payload[0]."""
    return counter + payload[0]


def make_tsi(
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "tsi",
) -> IFunc:
    return IFunc.build(
        name=name,
        fn=tsi_entry,
        payload_aval=jax.ShapeDtypeStruct((1,), I32),
        dep_avals=(jax.ShapeDtypeStruct((1,), I32),),
        deps=("region:counter",),
        abi="update",
        targets=targets,
        kind=kind,
    )


# ------------------------------------------------------------------- Spawn
def spawner_entry(payload: jax.Array) -> jax.Array:
    """Demo of 'injected code generating new code' (paper Sec. I): arrival
    spawns a TSI ifunc at peer ``payload[0]`` with increment ``payload[1]``."""
    return _vec(A_SPAWN, payload[0], 1, payload[1])


def make_spawner(
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
) -> IFunc:
    return IFunc.build(
        name="spawner",
        fn=spawner_entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        deps=("spawn:tsi",),
        abi="xrdma",
        targets=targets,
    )
