"""X-RDMA operations: Chaser, ReturnResult, TSI (paper Secs. IV-B/IV-C).

An X-RDMA operation is an ifunc whose arrival *executes user code next to
the data*, and whose code may re-inject itself (FORWARD), answer the
requester (RETURN via ReturnResult), or generate new code (SPAWN).  The
decision logic lives in the shipped code; see :mod:`repro.core.pe.exec` for
the fixed action ABI.

All integer state is int32: tables up to 2^31 entries, which keeps the core
independent of the global ``jax_enable_x64`` flag (the LM framework must
stay bf16/f32-default).
"""

from __future__ import annotations

import struct
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .dataplane import SlabLayout
from .frame import FrameKind
from .pe import (
    ACTION_WIDTH,
    A_DONE,
    A_FORWARD,
    A_NOP,
    A_PUBLISH,
    A_RETURN,
    A_SPAWN,
    IFunc,
)
from .transport import RegionWrite

I32 = jnp.int32
CHASER_PAYLOAD = 4  # [addr, depth, requester, slot]
GATHER_HDR = 3  # [requester, slot, epoch] routing header (PE.submit convention)


def _vec(*slots) -> jax.Array:
    """Build a padded i32 action vector from (action, dst, plen, payload...).

    One stack+concatenate instead of a chained ``.at[i].set`` scatter loop:
    same result, ACTION_WIDTH-times fewer ops in every traced action graph.
    """
    vals = jnp.stack([jnp.asarray(s, I32) for s in slots])
    return jnp.concatenate([vals, jnp.zeros((ACTION_WIDTH - len(slots),), I32)])


# ------------------------------------------------------------------ Chaser
def chaser_entry(payload: jax.Array, shard: jax.Array, meta: jax.Array) -> jax.Array:
    """One X-RDMA Chaser hop (paper Sec. IV-C).

    Chase locally (``lax.while_loop`` — the paper's in-process recursive
    call) until the chase completes or the frontier leaves this shard; then
    RETURN the result to the requester or FORWARD *this same code* to the
    owner of the next entry.
    """
    addr0, depth0, requester, slot = payload[0], payload[1], payload[2], payload[3]
    shard_id, shard_size = meta[0], meta[1]
    base = shard_id * shard_size

    def cond(c):
        a, d = c
        return (d > 0) & (a // shard_size == shard_id)

    def body(c):
        a, d = c
        return shard[a - base], d - 1

    addr, depth = lax.while_loop(cond, body, (addr0, depth0))
    done = depth == 0
    ret = _vec(A_RETURN, requester, 2, slot, addr)
    fwd = _vec(A_FORWARD, addr // shard_size, 4, addr, depth, requester, slot)
    return jnp.where(done, ret, fwd)


def make_chaser(
    shard_size: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "chaser",
) -> IFunc:
    return IFunc.build(
        name=name,
        fn=chaser_entry,
        payload_aval=jax.ShapeDtypeStruct((CHASER_PAYLOAD,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((shard_size,), I32),
            jax.ShapeDtypeStruct((3,), I32),
        ),
        deps=("region:table_shard", "cap:shard_meta", "returns:return_result"),
        abi="xrdma",
        targets=targets,
        kind=kind,
    )


# ------------------------------------------------------------ ReturnResult
def return_result_entry(payload: jax.Array, results: jax.Array) -> jax.Array:
    """Write ``value`` into the requester's result slot and bump the
    completion counter (last element)."""
    slot, value = payload[0], payload[1]
    return results.at[slot].set(value).at[results.shape[0] - 1].add(1)


def _chase_slab(max_slots: int, region: str = "results") -> SlabLayout:
    """Zero-copy layout of the chase result buffer: one i32 word per slot
    plus the completion counter at the end.  A RETURN payload ``[slot,
    value]`` becomes one 4-byte WRITE at ``slot*4`` whose doorbell
    FETCH_ADDs the counter word — the paper's 'final PUT' verbatim."""

    def plan(pay: np.ndarray) -> list[RegionWrite]:
        slot, value = int(pay[0]), int(pay[1])
        return [
            RegionWrite(
                region,
                slot * 4,
                struct.pack("<i", value),
                doorbell=(max_slots * 4, 1, "add"),
            )
        ]

    return SlabLayout(region=region, plan=plan)


def make_return_result(
    max_slots: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
) -> IFunc:
    return IFunc.build(
        name="return_result",
        fn=return_result_entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        dep_avals=(jax.ShapeDtypeStruct((max_slots + 1,), I32),),
        deps=("region:results",),
        abi="update",
        targets=targets,
        kind=kind,
        slab=_chase_slab(max_slots),
    )


# ----------------------------------------------------------------- Gather
def _take_rows(shard: jax.Array, keys: jax.Array, lo: jax.Array) -> jax.Array:
    """Masked-take local resolution: rows for keys inside [lo, lo+V_loc),
    zeros elsewhere (the reference semantics of kernels.embed_lookup)."""
    v_loc = shard.shape[0]
    loc = keys - lo
    inside = (loc >= 0) & (loc < v_loc)
    rows = jnp.take(shard, jnp.clip(loc, 0, v_loc - 1), axis=0)
    return jnp.where(inside[:, None], rows, jnp.zeros((), shard.dtype))


def make_gatherer(
    rows_per_shard: int,
    n_servers: int,
    n_keys: int,
    dim: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "gatherer",
    returns: str = "gather_return",
    pallas_tpu: bool = True,
) -> IFunc:
    """The X-RDMA Gather op: one hop of a sharded embedding/KV-row gather.

    Payload (completion-queue convention): ``[requester, slot, epoch,
    key0..key_{K-1}]`` with unused key positions padded to -1.  ``epoch``
    is the slot's generation tag: a late or re-delivered RETURN whose
    epoch no longer matches the slot's is dropped by the RETURN code, so
    slot recycling is safe under at-least-once delivery.  On arrival the
    shipped code

    * resolves the locally-owned subset of the keys against the shard
      region (Pallas ``embed_lookup`` in the TPU slice, masked-take
      reference elsewhere — both produce the identical rows),
    * FORWARDs the unresolved remainder to the owning PE(s), preserving
      each key's *position* so every partial RETURN scatters into the
      right rows of the requester's slot (non-owned positions travel as
      -1), and
    * RETURNs the resolved rows (bit-cast f32->i32, never converted) plus
      their positions and a count to the requester's completion queue.

    One action matrix of ``n_servers + 1`` rows covers every case: row
    ``s`` is the potential FORWARD to server ``s``, the last row the
    partial RETURN; unneeded rows are NOPs.  A request whose keys span
    ``m`` shards costs ``m`` RETURNs and at most ``m`` FORWARDs — network
    actions only on locality breaks, exactly the Chaser's contract.
    """
    K, D, S = n_keys, dim, n_servers
    if K > 31:
        raise ValueError("n_keys > 31 would overflow the i32 position bitmask")
    ret_plen = 3 + K + K * D  # [slot, epoch, nres, pos(K), rows(K*D)]
    width = 3 + ret_plen  # rectangular action matrix; FORWARD rows zero-pad

    def entry_with(resolve):
        def entry(payload: jax.Array, shard: jax.Array, meta: jax.Array) -> jax.Array:
            requester, slot, epoch = payload[0], payload[1], payload[2]
            keys = payload[GATHER_HDR:]
            shard_id, rows_per = meta[0], meta[1]
            lo = shard_id * rows_per
            loc = keys - lo
            real = keys >= 0
            mine = real & (loc >= 0) & (loc < rows_per)
            rows = resolve(shard, keys, lo)  # (K, D), zeros off-shard
            rows = jnp.where(mine[:, None], rows, jnp.zeros((), rows.dtype))
            irows = lax.bitcast_convert_type(
                rows.astype(jnp.float32), I32
            ).reshape(-1)
            pos = jnp.arange(K, dtype=I32)
            nres = jnp.sum(mine.astype(I32))
            ret = jnp.concatenate(
                [
                    jnp.stack(
                        [
                            jnp.where(nres > 0, A_RETURN, A_NOP).astype(I32),
                            requester.astype(I32),
                            jnp.asarray(ret_plen, I32),
                        ]
                    ),
                    jnp.stack([slot, epoch, nres]).astype(I32),
                    jnp.where(mine, pos, -1).astype(I32),
                    irows,
                ]
            )
            # one potential FORWARD row per peer shard (position-preserving)
            owner = jnp.where(real & ~mine, keys // rows_per, -1)
            zpad = jnp.zeros((K * D,), I32)
            fwd_rows = []
            for s in range(S):
                take = owner == s
                cnt = jnp.sum(take.astype(I32))
                fwd_rows.append(
                    jnp.concatenate(
                        [
                            jnp.stack(
                                [
                                    jnp.where(cnt > 0, A_FORWARD, A_NOP).astype(I32),
                                    jnp.asarray(s, I32),
                                    jnp.asarray(GATHER_HDR + K, I32),
                                ]
                            ),
                            jnp.stack([requester, slot, epoch]).astype(I32),
                            jnp.where(take, keys, -1).astype(I32),
                            zpad,
                        ]
                    )
                )
            return jnp.stack([*fwd_rows, ret])  # (S + 1, width)

        return entry

    fn_by_platform = None
    # the TPU slice carries the Pallas one-hot-MXU resolver when the shard
    # shape satisfies its blocking constraints; FatBitcode.build falls back
    # to the portable entry if the kernel cannot cross-lower from here
    if pallas_tpu and (rows_per_shard <= 512 or rows_per_shard % 512 == 0):
        try:
            from repro.kernels.embed_lookup.kernel import embed_lookup

            def pallas_resolve(shard, keys, lo):
                return embed_lookup(shard, keys, lo, bt=min(256, K))

            fn_by_platform = {"tpu": entry_with(pallas_resolve)}
        except Exception:
            fn_by_platform = None

    return IFunc.build(
        name=name,
        fn=entry_with(_take_rows),
        payload_aval=jax.ShapeDtypeStruct((GATHER_HDR + K,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((rows_per_shard, D), jnp.float32),
            jax.ShapeDtypeStruct((3,), I32),
        ),
        deps=("region:embed_shard", "cap:gather_meta", f"returns:{returns}"),
        abi="xrdma",
        targets=targets,
        kind=kind,
        fn_by_platform=fn_by_platform,
    )


def _gather_slab(n_keys: int, dim: int, region: str = "cq_results") -> SlabLayout:
    """Zero-copy layout of one completion-queue slot: row ``[posmask,
    epoch, data(K*D)]`` of i32 words.  A partial RETURN's resolved rows
    become contiguous-run WRITE segments at their position offsets; the
    doorbell ORs the arrived-position bits into ``posmask`` (idempotent
    under re-delivery, same as the framed fold) and the guard pins the
    slot's generation — a stale write for a retired gather is refused at
    the 'NIC' instead of corrupting the slot's next owner."""
    K, D = n_keys, dim
    stride = (2 + K * D) * 4  # slot row bytes

    def plan(pay: np.ndarray) -> list[RegionWrite]:
        slot, epoch = int(pay[0]), int(pay[1])
        pos = pay[3 : 3 + K]
        rows = pay[3 + K :].reshape(K, D)
        base = slot * stride
        guard = (base + 4, epoch)
        valid = np.flatnonzero(pos >= 0)
        if valid.size == 0:
            return []
        bits = int(np.bitwise_or.reduce(1 << (pos[valid].astype(np.int64))))
        # contiguous (index, position) runs -> one scatter segment each
        breaks = np.where(
            (np.diff(valid) != 1) | (np.diff(pos[valid]) != 1)
        )[0] + 1
        writes = []
        for run in np.split(valid, breaks):
            i0, i1 = int(run[0]), int(run[-1])
            writes.append(
                RegionWrite(
                    region,
                    base + (2 + int(pos[i0]) * D) * 4,
                    rows[i0 : i1 + 1].tobytes(),
                    guard=guard,
                )
            )
        # the doorbell rides the last segment: it fires only after every
        # data word of this partial landed (fenced WQE chain)
        last = writes[-1]
        writes[-1] = RegionWrite(
            last.region, last.offset, last.data,
            doorbell=(base, bits, "or"), guard=guard,
        )
        return writes

    return SlabLayout(region=region, plan=plan)


def make_gather_return(
    max_slots: int,
    n_keys: int,
    dim: int,
    region: str = "cq_results",
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "gather_return",
) -> IFunc:
    """Scatter one partial gather result into the requester's completion
    queue: rows land at their request positions (out-of-order safe, any
    interleaving of slots), and the slot's arrived-position *bitmask* ORs
    in the positions this partial carried.  The bitmask (not a counter)
    is what makes at-least-once delivery safe within a generation: a
    re-delivered partial ORs bits already set and scatters rows already
    written — exactly idempotent — so completion (popcount == expected)
    can never fire early off a duplicate.  A RETURN whose epoch does not
    match the slot's current generation is a late result for a *retired*
    gather — dropped whole, so a recycled slot can never be corrupted by
    stale traffic.  Update-ABI, so a burst of partial returns folds into
    the region in one masked-scan dispatch under the batched runtime.

    Region row layout: ``[posmask, epoch, data(K*D)]``."""
    K, D = n_keys, dim
    if K > 31:
        raise ValueError("n_keys > 31 would overflow the i32 position bitmask")

    def entry(payload: jax.Array, results: jax.Array) -> jax.Array:
        slot, epoch = payload[0], payload[1]  # payload[2] = nres (diagnostic)
        pos = payload[3 : 3 + K]
        rows = payload[3 + K :].reshape(K, D)
        cur = results[slot]
        live = cur[1] == epoch  # stale-generation RETURNs drop whole
        valid = pos >= 0
        bits = jnp.sum(
            jnp.where(valid, jnp.left_shift(jnp.int32(1), jnp.clip(pos, 0, 30)), 0)
        )
        safe = jnp.where(valid, pos, K)  # K = out of bounds -> dropped
        block = cur[2:].reshape(K, D).at[safe].set(rows, mode="drop")
        newrow = jnp.concatenate(
            [(cur[0] | bits)[None], cur[1][None], block.reshape(-1)]
        )
        return results.at[slot].set(jnp.where(live, newrow, cur))

    return IFunc.build(
        name=name,
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((3 + K + K * D,), I32),
        dep_avals=(jax.ShapeDtypeStruct((max_slots, 2 + K * D), I32),),
        deps=(f"region:{region}",),
        abi="update",
        targets=targets,
        kind=kind,
        slab=_gather_slab(n_keys, dim, region),
    )


# ----------------------------------------------------------------- Filter
FILTER_HDR = GATHER_HDR + 2  # [requester, slot, epoch, lo, thresh_bits]


def make_filter(
    rows_per_shard: int,
    n_servers: int,
    window: int,
    dim: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "filter",
    returns: str = "filter_return",
    pallas_tpu: bool = True,
) -> IFunc:
    """The DPU predicate-pushdown op: filter a contiguous row window *next
    to the shard* and RETURN only the survivors.

    Payload ``[requester, slot, epoch, lo, thresh_bits]``: scan the
    ``window`` rows at global offset ``lo`` (the service aligns windows
    inside one shard), keep rows whose first column exceeds the f32
    threshold (``thresh_bits`` travels bit-cast through the i32 payload),
    and emit ONE ragged RETURN row::

        [slot, epoch, evalmask, spos(W), rows(nsurv*D)]

    with ``plen = 3 + W + nsurv*D`` — the action row's self-describing
    ``plen`` means only the survivor rows cross the wire, which is the
    whole point of pushdown: wire payload bytes scale with selectivity,
    not with the window.  ``spos`` carries the survivors' window
    positions packed to the front (-1 beyond ``nsurv``); ``evalmask`` is
    the full window bitmask, so completion fires after one RETURN even
    when *nothing* survives.  Dropped positions read as zeros at the
    requester (CQ slots are zeroed at alloc), matching the masked oracle
    ``where(pred, rows, 0)``.

    Per-ISA slices via ``fn_by_platform`` (paper Fig. 3): the CPU/TPU
    slices resolve the window with a dynamic slice (Pallas ``embed_lookup``
    on TPU when the shard blocking allows), while the DPU (``cpu-bf2``)
    slice ships a masked-take body — the BF2's Arm cores prefer the
    branch-free gather over a strided slice.  Every slice computes
    identical survivors; only the lowering differs.
    """
    W, D, S = window, dim, n_servers
    if W > 31:
        raise ValueError("window > 31 would overflow the i32 position bitmask")
    evalmask = (1 << W) - 1
    ret_hdr = 3  # [slot, epoch, evalmask]
    width = 3 + ret_hdr + W + W * D  # max plen: every row survives

    def entry_with(resolve):
        def entry(payload: jax.Array, shard: jax.Array, meta: jax.Array) -> jax.Array:
            requester, slot, epoch = payload[0], payload[1], payload[2]
            lo = payload[3]
            thresh = lax.bitcast_convert_type(payload[4], jnp.float32)
            shard_id, rows_per = meta[0], meta[1]
            base = shard_id * rows_per
            rows = resolve(shard, lo, base)  # (W, D) f32 window
            passed = rows[:, 0] > thresh
            nsurv = jnp.sum(passed.astype(I32))
            # survivors packed to the front, original window order kept
            order = jnp.argsort(~passed, stable=True).astype(I32)
            packed = jnp.arange(W, dtype=I32) < nsurv
            spos = jnp.where(packed, order, -1)
            srows = jnp.where(packed[:, None], rows[order], 0.0)
            irows = lax.bitcast_convert_type(
                srows.astype(jnp.float32), I32
            ).reshape(-1)
            plen = ret_hdr + W + nsurv * D  # ragged: survivors only
            return jnp.concatenate(
                [
                    jnp.stack(
                        [jnp.asarray(A_RETURN, I32), requester.astype(I32), plen]
                    ),
                    jnp.stack([slot, epoch, jnp.asarray(evalmask, I32)]),
                    spos,
                    irows,
                ]
            )  # one self-describing action row of `width` i32 words

        return entry

    def sliced_resolve(shard, lo, base):
        return lax.dynamic_slice(shard, (lo - base, jnp.asarray(0, I32)), (W, D))

    def masked_take_resolve(shard, lo, base):
        return _take_rows(shard, lo + jnp.arange(W, dtype=I32), base)

    fn_by_platform: dict = {"cpu-bf2": entry_with(masked_take_resolve)}
    # the TPU slice carries the Pallas resolver under the same blocking
    # constraints as the Gatherer; FatBitcode.build falls back to the
    # portable sliced entry if the kernel cannot cross-lower from here
    if pallas_tpu and (rows_per_shard <= 512 or rows_per_shard % 512 == 0):
        try:
            from repro.kernels.embed_lookup.kernel import embed_lookup

            def pallas_resolve(shard, lo, base):
                keys = lo + jnp.arange(W, dtype=I32)
                return embed_lookup(shard, keys, base, bt=min(256, W))

            fn_by_platform["tpu"] = entry_with(pallas_resolve)
        except Exception:
            pass

    return IFunc.build(
        name=name,
        fn=entry_with(sliced_resolve),
        payload_aval=jax.ShapeDtypeStruct((FILTER_HDR,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((rows_per_shard, D), jnp.float32),
            jax.ShapeDtypeStruct((3,), I32),
        ),
        deps=("region:embed_shard", "cap:gather_meta", f"returns:{returns}"),
        abi="xrdma",
        targets=targets,
        kind=kind,
        fn_by_platform=fn_by_platform,
    )


def _filter_slab(window: int, dim: int, region: str = "cq_results") -> SlabLayout:
    """Zero-copy layout of a Filter RETURN over the gather CQ slot row
    ``[posmask, epoch, data(W*D)]``: survivor rows become contiguous-run
    WRITE segments at their window-position offsets and the doorbell ORs
    the *evalmask* (whole window observed) — so the chain stays
    proportional to survivors while completion still fires, even with an
    empty survivor set (doorbell-only write).  Ragged-aware: the payload
    the sender hands over carries ``3 + W + nsurv*D`` words."""
    W, D = window, dim
    stride = (2 + W * D) * 4  # slot row bytes

    def plan(pay: np.ndarray) -> list[RegionWrite]:
        slot, epoch, evalmask = int(pay[0]), int(pay[1]), int(pay[2])
        spos = pay[3 : 3 + W]
        nsurv = int(np.sum(spos >= 0))
        rows = pay[3 + W : 3 + W + nsurv * D].reshape(nsurv, D)
        base = slot * stride
        guard = (base + 4, epoch)
        writes = []
        if nsurv:
            pos = spos[:nsurv].astype(np.int64)
            # survivors are packed; split only on window-position gaps
            breaks = np.where(np.diff(pos) != 1)[0] + 1
            for run in np.split(np.arange(nsurv), breaks):
                i0, i1 = int(run[0]), int(run[-1])
                writes.append(
                    RegionWrite(
                        region,
                        base + (2 + int(pos[i0]) * D) * 4,
                        rows[i0 : i1 + 1].tobytes(),
                        guard=guard,
                    )
                )
        if writes:
            last = writes[-1]
            writes[-1] = RegionWrite(
                last.region, last.offset, last.data,
                doorbell=(base, evalmask, "or"), guard=guard,
            )
        else:
            # nothing survived: the doorbell alone completes the window
            writes.append(
                RegionWrite(
                    region, base, b"", doorbell=(base, evalmask, "or"), guard=guard
                )
            )
        return writes

    return SlabLayout(region=region, plan=plan)


def make_filter_return(
    max_slots: int,
    window: int,
    dim: int,
    region: str = "cq_results",
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "filter_return",
) -> IFunc:
    """Fold one Filter RETURN into the requester's completion queue.

    Same idempotent position-scatter discipline as ``gather_return`` —
    OR the arrived bits, scatter rows by position with ``mode="drop"``,
    drop stale-epoch returns whole — with two filter-specific twists.
    The bits come from the payload's ``evalmask`` word: the whole window
    was *observed* even where nothing survived (unobserved is different
    from empty), so one RETURN completes the window regardless of the
    survivor count.  And the payload is **ragged**: only ``nsurv`` rows
    travel behind the always-full ``spos`` vector, and the
    ``ragged:zeros`` dep tag tells the exec layer to zero-extend to the
    declared aval — safe because the ``-1`` sentinels in ``spos`` arrive
    intact and mask off exactly the zero-padded row slots.

    Region row layout: ``[posmask, epoch, data(W*D)]``."""
    W, D = window, dim
    if W > 31:
        raise ValueError("window > 31 would overflow the i32 position bitmask")

    def entry(payload: jax.Array, results: jax.Array) -> jax.Array:
        slot, epoch, evalmask = payload[0], payload[1], payload[2]
        spos = payload[3 : 3 + W]
        rows = payload[3 + W :].reshape(W, D)
        cur = results[slot]
        live = cur[1] == epoch  # stale-generation RETURNs drop whole
        valid = spos >= 0  # packed survivor prefix; -1 beyond nsurv
        bits = evalmask  # the whole window was observed
        safe = jnp.where(valid, spos, W)  # W = out of bounds -> dropped
        block = cur[2:].reshape(W, D).at[safe].set(rows, mode="drop")
        newrow = jnp.concatenate(
            [(cur[0] | bits)[None], cur[1][None], block.reshape(-1)]
        )
        return results.at[slot].set(jnp.where(live, newrow, cur))

    return IFunc.build(
        name=name,
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((3 + W + W * D,), I32),
        dep_avals=(jax.ShapeDtypeStruct((max_slots, 2 + W * D), I32),),
        deps=(f"region:{region}", "ragged:zeros"),
        abi="update",
        targets=targets,
        kind=kind,
        slab=_filter_slab(window, dim, region),
    )


# --------------------------------------------------------------------- TSI
def tsi_entry(payload: jax.Array, counter: jax.Array) -> jax.Array:
    """Target-Side Increment (paper Sec. IV-B): counter += payload[0]."""
    return counter + payload[0]


def make_tsi(
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "tsi",
) -> IFunc:
    return IFunc.build(
        name=name,
        fn=tsi_entry,
        payload_aval=jax.ShapeDtypeStruct((1,), I32),
        dep_avals=(jax.ShapeDtypeStruct((1,), I32),),
        deps=("region:counter",),
        abi="update",
        targets=targets,
        kind=kind,
    )


# ------------------------------------------------------------------ Reduce
def make_reducer(
    width: int,
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    kind: FrameKind = FrameKind.BITCODE,
    name: str = "reducer",
) -> IFunc:
    """The multi-hop X-RDMA reduction op (one node's step of
    :func:`repro.sharding.collectives.xrdma_reduce`).

    Propagate-ABI: every invocation folds one contribution into this PE's
    ``reduce_acc`` region — ``[count, acc(width)]`` — and emits at most one
    action row.  Payload ``[count, value(width)]``:

    * ``count == 0`` is the broadcast *seed* (delivered by the tree
      publish): fold this PE's own ``reduce_src`` contribution, count 1.
    * ``count > 0`` is a child subtree's partial: fold ``value``, count
      the subtree's nodes.

    When the fold's count reaches the subtree size in ``reduce_meta``
    (``[expected, parent, is_root]``), the completing invocation FORWARDs
    the folded partial — this same ifunc, code and all — to the tree
    parent; at the root it emits DONE with the cluster-wide result.  Under
    the batched runtime several children's partials fold in one masked
    ``lax.scan`` dispatch and only the row that completes the subtree
    carries the upward FORWARD — the scan's sequential carry is exactly
    the fold-before-forward the tree needs.

    At-least-once caveat: seed delivery is deduplicated by the publish
    layer, but a *duplicated child partial* would double-fold and overshoot
    ``expected`` — the count then never equals it and the reduction
    surfaces as an idle timeout (loud containment), matching the paper's
    reliable-connection transport assumption for RETURN traffic.
    """
    W = width

    def entry(
        payload: jax.Array, acc: jax.Array, src: jax.Array, meta: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        count, val = payload[0], payload[1:]
        seed = count == 0
        new_cnt = acc[0] + jnp.where(seed, jnp.asarray(1, I32), count)
        new_val = acc[1:] + jnp.where(seed, src, val)
        expected, parent, is_root = meta[0], meta[1], meta[2]
        done = new_cnt == expected
        action = jnp.where(
            done, jnp.where(is_root > 0, A_DONE, A_FORWARD), A_NOP
        ).astype(I32)
        dst = jnp.where(done & (is_root == 0), parent, 0).astype(I32)
        plen = jnp.where(done, 1 + W, 0).astype(I32)
        new_acc = jnp.concatenate([new_cnt[None], new_val])
        row = jnp.concatenate([jnp.stack([action, dst, plen]), new_acc])
        return new_acc, row

    return IFunc.build(
        name=name,
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((1 + W,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((1 + W,), I32),
            jax.ShapeDtypeStruct((W,), I32),
            jax.ShapeDtypeStruct((3,), I32),
        ),
        deps=("region:reduce_acc", "region:reduce_src", "cap:reduce_meta"),
        abi="propagate",
        targets=targets,
        kind=kind,
    )


# ------------------------------------------------------------------ Gossip
def make_gossiper(
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
    name: str = "gossiper",
) -> IFunc:
    """Injected code that re-publishes *itself* (paper Sec. I, literally).

    Payload ``[hops_left, value]``; deps ``region:gossip_log`` (``[visits,
    sum]``) and ``cap:gossip_meta`` (``[my_index, n_peers]``).  Each
    arrival logs itself locally and, while ``hops_left > 0``, emits
    ``A_PUBLISH`` to the next peer on the ring — the *code* decides where
    its next copy goes; the runtime only carries it.  Hop budget 1 per
    publish, so the tree layer never fans this out: the recursion is
    entirely the ifunc's own.
    """

    def entry(
        payload: jax.Array, log: jax.Array, meta: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        hops, value = payload[0], payload[1]
        me, n = meta[0], meta[1]
        new_log = jnp.stack([log[0] + 1, log[1] + value])
        nxt = jnp.where(me + 1 >= n, 0, me + 1)
        row = jnp.where(
            hops > 0,
            _vec(A_PUBLISH, nxt, 3, 1, hops - 1, value),
            _vec(A_NOP, 0, 0),
        )
        return new_log, row

    return IFunc.build(
        name=name,
        fn=entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        dep_avals=(
            jax.ShapeDtypeStruct((2,), I32),
            jax.ShapeDtypeStruct((2,), I32),
        ),
        deps=("region:gossip_log", "cap:gossip_meta"),
        abi="propagate",
        targets=targets,
    )


# ------------------------------------------------------------------- Spawn
def spawner_entry(payload: jax.Array) -> jax.Array:
    """Demo of 'injected code generating new code' (paper Sec. I): arrival
    spawns a TSI ifunc at peer ``payload[0]`` with increment ``payload[1]``."""
    return _vec(A_SPAWN, payload[0], 1, payload[1])


def make_spawner(
    targets: Sequence[str] = ("cpu-host", "cpu-bf2", "cpu-a64fx", "tpu-v5e"),
) -> IFunc:
    return IFunc.build(
        name="spawner",
        fn=spawner_entry,
        payload_aval=jax.ShapeDtypeStruct((2,), I32),
        deps=("spawn:tsi",),
        abi="xrdma",
        targets=targets,
    )
