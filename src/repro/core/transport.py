"""Simulated RDMA fabric: endpoints, one-sided PUT/GET, wire-time accounting.

The paper evaluates on 100 Gb/s InfiniBand (ConnectX-6 HCAs / BlueField-2
DPUs).  This container has one CPU core and no NIC, so the fabric here is an
in-process software RDMA: a PUT copies wire bytes into the target's receive
buffer (the target discovers delivery by MAGIC-polling, as in Sec. III-D); a
GET reads a registered memory region *without running any code on the target*
(one-sided semantics, the GBPC baseline relies on this).

Every operation is additionally *accounted* against a calibrated wire model
(:class:`WireModel`) so that benchmarks report a modeled wire time next to
the measured in-process time.  The models are calibrated from the paper's own
Tables I-III (two-point fit: cached 26 B frame and uncached 5185 B frame), so
modeled cached/uncached and DAPC/GBPC *ratios* are directly comparable with
the paper's.  Byte counts — the quantity the paper's caching argument is
about — are exact, not modeled.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np


# --------------------------------------------------------------------- wire
@dataclass(frozen=True)
class WireModel:
    """Latency/throughput model: ``t_us(n) = alpha_us + n / beta_Bus``.

    ``alpha_us``    per-message latency floor (doorbell, WQE, fabric hop).
    ``beta_Bus``    effective small-message payload bandwidth in bytes/us
                    (far below the 12.5 GB/s line rate of 100 Gb/s IB -
                    the paper's own numbers imply 2.1-3.2 B/ns).
    ``o_us``        per-message *throughput* cost for back-to-back messages
                    (message-rate benchmarks; pipelining makes o < alpha).

    Calibration (paper Tables I-VI), two-point fits:
      ookami     cached 26B @ 2.62us, uncached 5185B @ 5.02us, AM rate 1.32M/s
      thor_bf2   cached 26B @ 1.85us, uncached 5185B @ 3.45us, AM rate 0.974M/s
      thor_xeon  cached 26B @ 1.51us, uncached 5185B @ 3.58us, AM rate 6.754M/s
    """

    name: str
    alpha_us: float
    beta_Bus: float  # latency-regime bytes/us (single message in flight)
    o_us: float  # per-message throughput overhead (pipelined)
    beta_tput_Bus: float = 0.0  # throughput-regime bytes/us (pipelined)

    def latency_us(self, nbytes: int) -> float:
        return self.alpha_us + nbytes / self.beta_Bus

    def inverse_throughput_us(self, nbytes: int) -> float:
        beta = self.beta_tput_Bus or self.beta_Bus
        return self.o_us + nbytes / beta

    def rate_msg_per_s(self, nbytes: int) -> float:
        return 1e6 / self.inverse_throughput_us(nbytes)


WIRE_PROFILES: dict[str, WireModel] = {
    # latency fit:    beta = (5185-26)/(t_unc - t_cached); alpha = t_cached - 26/beta
    # throughput fit: beta_t = (5185-26)/(1/r_unc - 1/r_cached); o = 1/r_cached - 26/beta_t
    # (two-point fits straight from Tables I-VI; pipelining makes beta_t >> beta)
    "ookami": WireModel(
        "ookami", alpha_us=2.6079, beta_Bus=2149.6, o_us=0.5896, beta_tput_Bus=2762.0
    ),
    "thor_bf2": WireModel(
        "thor_bf2", alpha_us=1.8419, beta_Bus=3224.4, o_us=0.7546, beta_tput_Bus=3159.0
    ),
    "thor_xeon": WireModel(
        "thor_xeon", alpha_us=1.4996, beta_Bus=2492.3, o_us=0.1463, beta_tput_Bus=15041.0
    ),
    # zero-cost model for pure byte accounting
    "ideal": WireModel(
        "ideal", alpha_us=0.0, beta_Bus=float("inf"), o_us=0.0,
        beta_tput_Bus=float("inf"),
    ),
}


# ------------------------------------------------------------------ fabric
@dataclass
class TrafficStats:
    """Per-fabric aggregate accounting (resettable by benchmarks)."""

    puts: int = 0
    gets: int = 0
    put_bytes: int = 0
    get_bytes: int = 0
    modeled_us: float = 0.0  # serial wire-latency accounting
    modeled_tput_us: float = 0.0  # back-to-back (message-rate) accounting
    coalesced_frames: int = 0  # PUTs that carried >1 payload (multi-payload frames)
    coalesced_payloads: int = 0  # payloads that travelled inside those PUTs

    def reset(self) -> None:
        self.puts = self.gets = 0
        self.put_bytes = self.get_bytes = 0
        self.modeled_us = 0.0
        self.modeled_tput_us = 0.0
        self.coalesced_frames = 0
        self.coalesced_payloads = 0

    def as_dict(self) -> dict[str, float]:
        return {
            "puts": self.puts,
            "gets": self.gets,
            "put_bytes": self.put_bytes,
            "get_bytes": self.get_bytes,
            "modeled_us": round(self.modeled_us, 3),
            "modeled_tput_us": round(self.modeled_tput_us, 3),
            "coalesced_frames": self.coalesced_frames,
            "coalesced_payloads": self.coalesced_payloads,
        }


class EndpointDead(RuntimeError):
    """Raised on operations against a killed endpoint (fault injection)."""


class Endpoint:
    """One processing element's network identity: receive queue + regions.

    The receive queue models the ifunc message buffer the target polls; the
    regions dict models RDMA-registered memory exposed for one-sided GET/PUT
    (numpy arrays, addressable by (region_name, byte offset)).
    """

    def __init__(self, name: str) -> None:
        self.name = name
        self.inbox: deque[bytearray] = deque()
        self.regions: dict[str, np.ndarray] = {}
        self.alive = True
        self._lock = threading.Lock()

    # registered memory -----------------------------------------------------
    def register_region(self, name: str, arr: np.ndarray) -> None:
        self.regions[name] = arr

    def read_region(self, region: str, offset: int, nbytes: int) -> bytes:
        buf = self.regions[region].view(np.uint8).reshape(-1)
        return bytes(buf[offset : offset + nbytes])

    def write_region(self, region: str, offset: int, data: bytes) -> None:
        buf = self.regions[region].view(np.uint8).reshape(-1)
        buf[offset : offset + len(data)] = np.frombuffer(data, np.uint8)

    # receive side ----------------------------------------------------------
    def deliver(self, wire: bytes) -> None:
        with self._lock:
            self.inbox.append(bytearray(wire))

    def drain(self) -> Iterator[bytearray]:
        while True:
            with self._lock:
                if not self.inbox:
                    return
                yield self.inbox.popleft()


class Fabric:
    """The interconnect: owns endpoints, implements PUT/GET, accounts bytes."""

    def __init__(self, wire: WireModel | str = "ideal") -> None:
        self.wire = WIRE_PROFILES[wire] if isinstance(wire, str) else wire
        self.endpoints: dict[str, Endpoint] = {}
        self.stats = TrafficStats()
        self._lock = threading.Lock()

    def connect(self, name: str) -> Endpoint:
        ep = Endpoint(name)
        self.endpoints[name] = ep
        return ep

    def _target(self, dst: str) -> Endpoint:
        ep = self.endpoints[dst]
        if not ep.alive:
            raise EndpointDead(dst)
        return ep

    # one-sided ops ---------------------------------------------------------
    def put(self, src: str, dst: str, wire_bytes: bytes, n_payloads: int = 1) -> float:
        """One-sided PUT of a (possibly truncated, possibly coalesced) frame.

        Returns the modeled wire time in us.  The receiver is not notified;
        it discovers the message by polling (MAGIC sentinels).  A coalesced
        PUT (``n_payloads > 1``) is *one* wire message: one ``alpha_us`` /
        ``o_us`` charge for the summed bytes — exactly the amortization the
        batched runtime is after — and is counted in ``coalesced_frames`` so
        benchmarks can report it.
        """
        ep = self._target(dst)
        n = len(wire_bytes)
        t = self.wire.latency_us(n)
        with self._lock:
            self.stats.puts += 1
            self.stats.put_bytes += n
            self.stats.modeled_us += t
            self.stats.modeled_tput_us += self.wire.inverse_throughput_us(n)
            if n_payloads > 1:
                self.stats.coalesced_frames += 1
                self.stats.coalesced_payloads += n_payloads
        ep.deliver(wire_bytes)
        return t

    def get(self, src: str, dst: str, region: str, offset: int, nbytes: int) -> bytes:
        """One-sided GET: read target memory; no target-side code runs.

        Modeled as a full round trip (request + data), the cost structure of
        an RDMA READ: latency ~ 2*alpha + n/beta.
        """
        ep = self._target(dst)
        data = ep.read_region(region, offset, nbytes)
        t = 2 * self.wire.alpha_us + nbytes / self.wire.beta_Bus
        with self._lock:
            self.stats.gets += 1
            self.stats.get_bytes += nbytes
            self.stats.modeled_us += t
            self.stats.modeled_tput_us += t  # GETs are round-trips; no pipelining
        return data

    # fault injection ---------------------------------------------------------
    def kill(self, name: str) -> None:
        """Endpoint process death: queue drops, memory unreachable."""
        ep = self.endpoints[name]
        ep.alive = False
        ep.inbox.clear()

    def revive(self, name: str) -> Endpoint:
        """Restarted process: fresh endpoint state (all caches/regions gone)."""
        ep = Endpoint(name)
        self.endpoints[name] = ep
        return ep
