"""Simulated RDMA fabric: endpoints, one-sided PUT/GET, wire-time accounting.

The paper evaluates on 100 Gb/s InfiniBand (ConnectX-6 HCAs / BlueField-2
DPUs).  This container has one CPU core and no NIC, so the fabric here is an
in-process software RDMA: a PUT copies wire bytes into the target's receive
buffer (the target discovers delivery by MAGIC-polling, as in Sec. III-D); a
GET reads a registered memory region *without running any code on the target*
(one-sided semantics, the GBPC baseline relies on this).

Every operation is additionally *accounted* against a calibrated wire model
(:class:`WireModel`) so that benchmarks report a modeled wire time next to
the measured in-process time.  The models are calibrated from the paper's own
Tables I-III (two-point fit: cached 26 B frame and uncached 5185 B frame), so
modeled cached/uncached and DAPC/GBPC *ratios* are directly comparable with
the paper's.  Byte counts — the quantity the paper's caching argument is
about — are exact, not modeled.
"""

from __future__ import annotations

import struct
import threading
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Iterator, Sequence

import numpy as np


# --------------------------------------------------------------------- wire
@dataclass(frozen=True)
class WireModel:
    """Latency/throughput model: ``t_us(n) = alpha_us + n / beta_Bus``.

    ``alpha_us``    per-message latency floor (doorbell, WQE, fabric hop).
    ``beta_Bus``    effective small-message payload bandwidth in bytes/us
                    (far below the 12.5 GB/s line rate of 100 Gb/s IB -
                    the paper's own numbers imply 2.1-3.2 B/ns).
    ``o_us``        per-message *throughput* cost for back-to-back messages
                    (message-rate benchmarks; pipelining makes o < alpha).

    Calibration (paper Tables I-VI), two-point fits:
      ookami     cached 26B @ 2.62us, uncached 5185B @ 5.02us, AM rate 1.32M/s
      thor_bf2   cached 26B @ 1.85us, uncached 5185B @ 3.45us, AM rate 0.974M/s
      thor_xeon  cached 26B @ 1.51us, uncached 5185B @ 3.58us, AM rate 6.754M/s
    """

    name: str
    alpha_us: float
    beta_Bus: float  # latency-regime bytes/us (single message in flight)
    o_us: float  # per-message throughput overhead (pipelined)
    beta_tput_Bus: float = 0.0  # throughput-regime bytes/us (pipelined)

    def latency_us(self, nbytes: int) -> float:
        return self.alpha_us + nbytes / self.beta_Bus

    def inverse_throughput_us(self, nbytes: int) -> float:
        beta = self.beta_tput_Bus or self.beta_Bus
        return self.o_us + nbytes / beta

    def rate_msg_per_s(self, nbytes: int) -> float:
        return 1e6 / self.inverse_throughput_us(nbytes)


WIRE_PROFILES: dict[str, WireModel] = {
    # latency fit:    beta = (5185-26)/(t_unc - t_cached); alpha = t_cached - 26/beta
    # throughput fit: beta_t = (5185-26)/(1/r_unc - 1/r_cached); o = 1/r_cached - 26/beta_t
    # (two-point fits straight from Tables I-VI; pipelining makes beta_t >> beta)
    "ookami": WireModel(
        "ookami", alpha_us=2.6079, beta_Bus=2149.6, o_us=0.5896, beta_tput_Bus=2762.0
    ),
    "thor_bf2": WireModel(
        "thor_bf2", alpha_us=1.8419, beta_Bus=3224.4, o_us=0.7546, beta_tput_Bus=3159.0
    ),
    "thor_xeon": WireModel(
        "thor_xeon", alpha_us=1.4996, beta_Bus=2492.3, o_us=0.1463, beta_tput_Bus=15041.0
    ),
    # zero-cost model for pure byte accounting
    "ideal": WireModel(
        "ideal", alpha_us=0.0, beta_Bus=float("inf"), o_us=0.0,
        beta_tput_Bus=float("inf"),
    ),
}


# ------------------------------------------------------------- capabilities
#: Which calibrated wire profile a PE of a given toolchain triple fronts:
#: the host Xeon and the BlueField-2 DPU sit on the *same* 100 Gb/s link
#: but pay very different per-message costs (Tables I-VI), which is the
#: asymmetry the placement optimizer prices.
TRIPLE_WIRE: dict[str, str] = {
    "cpu-host": "thor_xeon",
    "cpu-a64fx": "ookami",
    "cpu-bf2": "thor_bf2",
    "tpu-v5e": "thor_xeon",
}

#: Memory-bandwidth class per triple — the DPU's weak Arm cores stream a
#: shard scan far slower than the host (the paper's BF2 caveat, Sec. V).
MEM_BW_CLASS: dict[str, str] = {
    "cpu-host": "ddr-host",
    "cpu-a64fx": "hbm",
    "cpu-bf2": "ddr-dpu",
    "tpu-v5e": "hbm",
}

#: Effective single-core streaming scan rate per class, bytes/us.  Modeled
#: (this container has one CPU core), calibrated to the qualitative gap the
#: paper reports: BF2 DDR ~half the host's effective rate, HBM far above.
MEM_BW_BUS: dict[str, float] = {
    "ddr-host": 16000.0,
    "ddr-dpu": 8000.0,
    "hbm": 60000.0,
}


@dataclass(frozen=True)
class Capability:
    """A PE's advertised platform/capability vector.

    Registered in the :class:`Fabric` when the PE connects and consumed by
    the placement layer (:mod:`repro.sharding.placement`): the wire
    coefficients are the PE's *own* calibrated profile (what its HCA pays
    to initiate a message), ``mem_bw_class`` prices operand scans executed
    next to the data.  ``epoch`` is the advertisement generation — bumped
    on every (re)advertise so cached placement plans can detect restarts.
    """

    isa: str  # toolchain triple, e.g. "cpu-bf2"
    platform: str  # jax lowering platform ("cpu" | "tpu")
    wire: str  # calibrated WireModel name (TRIPLE_WIRE)
    alpha_us: float
    beta_Bus: float
    o_us: float
    beta_tput_Bus: float
    mem_bw_class: str  # see MEM_BW_CLASS / MEM_BW_BUS
    epoch: int = 0

    @classmethod
    def for_triple(cls, triple: str, platform: str) -> "Capability":
        wire = TRIPLE_WIRE.get(triple, "thor_xeon")
        m = WIRE_PROFILES[wire]
        return cls(
            isa=triple,
            platform=platform,
            wire=wire,
            alpha_us=m.alpha_us,
            beta_Bus=m.beta_Bus,
            o_us=m.o_us,
            beta_tput_Bus=m.beta_tput_Bus or m.beta_Bus,
            mem_bw_class=MEM_BW_CLASS.get(triple, "ddr-host"),
        )

    def model(self) -> WireModel:
        return WireModel(
            self.wire, self.alpha_us, self.beta_Bus, self.o_us, self.beta_tput_Bus
        )

    @property
    def scan_Bus(self) -> float:
        """Effective streaming scan bandwidth, bytes/us."""
        return MEM_BW_BUS[self.mem_bw_class]

    def as_dict(self) -> dict:
        return {
            "isa": self.isa,
            "platform": self.platform,
            "wire": self.wire,
            "alpha_us": self.alpha_us,
            "beta_Bus": self.beta_Bus,
            "o_us": self.o_us,
            "beta_tput_Bus": self.beta_tput_Bus,
            "mem_bw_class": self.mem_bw_class,
            "epoch": self.epoch,
        }


# ------------------------------------------------------------------ fabric
#: Categories every wire byte falls into (``TrafficStats.by_kind``):
#: ``header`` frame headers + sentinels + batch sub-headers, ``payload``
#: actual ifunc payload bytes, ``code`` fat-bitcode + deps sections,
#: ``region`` one-sided data (RDMA READ/WRITE of registered memory,
#: including doorbell words).  Benchmarks report the framing tax directly
#: from this split instead of deriving it by hand.
BYTE_KINDS = ("header", "payload", "code", "region")


@dataclass
class TrafficStats:
    """Per-fabric aggregate accounting (resettable by benchmarks)."""

    puts: int = 0
    gets: int = 0
    put_bytes: int = 0
    get_bytes: int = 0
    modeled_us: float = 0.0  # serial wire-latency accounting
    modeled_tput_us: float = 0.0  # back-to-back (message-rate) accounting
    coalesced_frames: int = 0  # PUTs that carried >1 payload (multi-payload frames)
    coalesced_payloads: int = 0  # payloads that travelled inside those PUTs
    region_puts: int = 0  # one-sided RDMA WRITE batches into registered memory
    region_put_bytes: int = 0  # data + doorbell bytes those writes carried
    region_guard_drops: int = 0  # guarded writes dropped by a stale generation
    hop_frames: int = 0  # PUBLISH frames (propagation hop header on board)
    hop_bytes: int = 0  # wire bytes those publish frames carried
    credit_stalls: int = 0  # sends deferred by an exhausted per-peer window
    # --- per-tenant accounting (multi-tenant QoS; untenanted traffic is
    # not broken out — it is the difference against the aggregates) ---
    tenant_puts: dict[str, int] = field(default_factory=dict)
    tenant_put_bytes: dict[str, int] = field(default_factory=dict)
    tenant_stalls: dict[str, int] = field(default_factory=dict)  # budget stalls
    # --- injected loss (set_loss): sender-paid bytes that never arrived ---
    frames_lost: int = 0  # PUTs the loss model ate (bytes still accounted)
    lost_bytes: int = 0  # wire bytes those eaten PUTs carried
    region_writes_lost: int = 0  # one-sided slab writes the loss model ate
    by_kind: dict[str, int] = field(default_factory=dict)  # see BYTE_KINDS

    def reset(self) -> None:
        self.puts = self.gets = 0
        self.put_bytes = self.get_bytes = 0
        self.modeled_us = 0.0
        self.modeled_tput_us = 0.0
        self.coalesced_frames = 0
        self.coalesced_payloads = 0
        self.region_puts = self.region_put_bytes = 0
        self.region_guard_drops = 0
        self.hop_frames = self.hop_bytes = 0
        self.credit_stalls = 0
        self.frames_lost = self.lost_bytes = 0
        self.region_writes_lost = 0
        self.by_kind = {}
        self.tenant_puts = {}
        self.tenant_put_bytes = {}
        self.tenant_stalls = {}

    def add_kinds(self, kinds: dict[str, int] | None) -> None:
        for k, v in (kinds or {}).items():
            if v:
                self.by_kind[k] = self.by_kind.get(k, 0) + v

    @property
    def wire_bytes_by_kind(self) -> dict[str, int]:
        return {k: self.by_kind.get(k, 0) for k in BYTE_KINDS}

    def report_kwargs(self) -> dict:
        """Snapshot of the wire-side fields every per-run report shares —
        ChaseReport and GatherReport construct themselves from this one
        definition so the two benchmarks' accounting cannot drift."""
        return {
            "puts": self.puts,
            "gets": self.gets,
            "put_bytes": self.put_bytes,
            "get_bytes": self.get_bytes,
            "modeled_us": self.modeled_us,
            "coalesced_frames": self.coalesced_frames,
            "coalesced_payloads": self.coalesced_payloads,
            "region_puts": self.region_puts,
            "region_put_bytes": self.region_put_bytes,
            "hop_frames": self.hop_frames,
            "wire_bytes_by_kind": self.wire_bytes_by_kind,
        }

    def as_dict(self) -> dict[str, float]:
        return {
            "puts": self.puts,
            "gets": self.gets,
            "put_bytes": self.put_bytes,
            "get_bytes": self.get_bytes,
            "modeled_us": round(self.modeled_us, 3),
            "modeled_tput_us": round(self.modeled_tput_us, 3),
            "coalesced_frames": self.coalesced_frames,
            "coalesced_payloads": self.coalesced_payloads,
            "region_puts": self.region_puts,
            "region_put_bytes": self.region_put_bytes,
            "region_guard_drops": self.region_guard_drops,
            "hop_frames": self.hop_frames,
            "hop_bytes": self.hop_bytes,
            "credit_stalls": self.credit_stalls,
            "frames_lost": self.frames_lost,
            "lost_bytes": self.lost_bytes,
            "region_writes_lost": self.region_writes_lost,
            "wire_bytes_by_kind": self.wire_bytes_by_kind,
            "tenant_puts": dict(self.tenant_puts),
            "tenant_put_bytes": dict(self.tenant_put_bytes),
            "tenant_stalls": dict(self.tenant_stalls),
        }


class WireReportMixin:
    """Derived wire totals shared by the per-run report dataclasses (which
    carry the :meth:`TrafficStats.report_kwargs` field set)."""

    @property
    def wire_bytes(self) -> int:
        return self.put_bytes + self.get_bytes + self.region_put_bytes

    @property
    def network_ops(self) -> int:
        """Wire operations: PUTs + GETs + slab-write batches (what
        batching and the zero-copy plane amortize)."""
        return self.puts + self.gets + self.region_puts


@dataclass(frozen=True)
class RegionWrite:
    """One one-sided write into a peer's registered memory.

    ``doorbell`` — optional ``(byte_offset, value, op)`` with ``op`` in
    {"or", "add"}: after the data lands, the fabric atomically folds
    ``value`` into the int32 word at ``byte_offset`` of the same region
    (RDMA atomic FETCH_ADD / masked-CAS).  The receiver discovers
    completion by polling that word — no inbox, no frame, no dispatch.

    ``guard`` — optional ``(byte_offset, expected)``: the write applies
    only while the int32 word at ``byte_offset`` still equals
    ``expected``.  This models generation-tagged memory registration (a
    retired slot's rkey is invalidated): a stale write's bytes still
    cross the wire but the NIC refuses to apply them.
    """

    region: str
    offset: int
    data: bytes
    doorbell: tuple[int, int, str] | None = None
    guard: tuple[int, int] | None = None


class EndpointDead(RuntimeError):
    """Raised on operations against a killed endpoint (fault injection)."""


class WireBuf(bytearray):
    """A received wire buffer, tagged with the peer that PUT it.

    Behaves exactly like the ``bytearray`` the inbox always held (tests
    slice, corrupt, and re-deliver these), but carries ``src`` so the
    progress engine can return flow-control credits to the right sender
    when the buffer is finally processed.  Buffers delivered outside
    :meth:`Fabric.put` (tests re-injecting captured frames) carry an empty
    ``src`` and simply return no credit.
    """

    src: str = ""


class Endpoint:
    """One processing element's network identity: receive queue + regions.

    The receive queue models the ifunc message buffer the target polls; the
    regions dict models RDMA-registered memory exposed for one-sided GET/PUT
    (numpy arrays, addressable by (region_name, byte offset)).
    """

    def __init__(self, name: str) -> None:
        self.name = name
        self.inbox: deque[bytearray] = deque()
        self.regions: dict[str, np.ndarray] = {}
        self.region_ver: dict[str, int] = {}  # bumped on every (re)register/write
        self.alive = True
        self._lock = threading.Lock()

    # registered memory -----------------------------------------------------
    def register_region(self, name: str, arr: np.ndarray) -> None:
        # RDMA registration pins physical pages: a non-C-contiguous view
        # (transpose, stride slice) has no single pinnable extent, so it is
        # materialized contiguously at registration time — same rule as
        # ibv_reg_mr over a copy buffer.  Contiguous arrays register
        # in place (zero copy), preserving caller aliasing.
        self.regions[name] = np.ascontiguousarray(arr)
        self.region_ver[name] = self.region_ver.get(name, 0) + 1

    def touch_region(self, name: str) -> None:
        """Record that a region's bytes changed underneath its registration
        (local in-place mutation): device-resident mirrors must refresh."""
        self.region_ver[name] = self.region_ver.get(name, 0) + 1

    def unregister_region(self, name: str) -> None:
        """Drop a registration and its version bookkeeping (rkey invalidated)."""
        self.regions.pop(name, None)
        self.region_ver.pop(name, None)

    def read_region(self, region: str, offset: int, nbytes: int) -> bytes:
        buf = self.regions[region].view(np.uint8).reshape(-1)
        return bytes(buf[offset : offset + nbytes])

    def write_region(self, region: str, offset: int, data: bytes) -> None:
        buf = self.regions[region].view(np.uint8).reshape(-1)
        buf[offset : offset + len(data)] = np.frombuffer(data, np.uint8)
        self.touch_region(region)

    def read_region_i32(self, region: str, offset: int) -> int:
        return struct.unpack("<i", self.read_region(region, offset, 4))[0]

    # receive side ----------------------------------------------------------
    def deliver(self, wire: bytes, src: str = "") -> None:
        buf = WireBuf(wire)
        buf.src = src
        with self._lock:
            self.inbox.append(buf)

    def drain(self) -> Iterator[bytearray]:
        while True:
            with self._lock:
                if not self.inbox:
                    return
                yield self.inbox.popleft()


class Fabric:
    """The interconnect: owns endpoints, implements PUT/GET, accounts bytes."""

    def __init__(self, wire: WireModel | str = "ideal") -> None:
        self.wire = WIRE_PROFILES[wire] if isinstance(wire, str) else wire
        self.endpoints: dict[str, Endpoint] = {}
        self.stats = TrafficStats()
        # advertised platform/capability vectors (PE.__init__ advertises on
        # connect; kill/revive drop the entry until the restarted PE
        # re-advertises).  ``hetero=True`` makes the fabric price each
        # operation with the *initiator's* advertised wire profile — off by
        # default so existing single-profile accounting stays bit-identical.
        self.capabilities: dict[str, Capability] = {}
        self._cap_models: dict[str, WireModel] = {}
        self._cap_epoch = 0
        self.hetero = False
        # framed payloads in flight per (src, dst): bumped on put (by the
        # frame's packed payload count — credits are payload-denominated so
        # a coalesced burst is accounted at its true size), released as the
        # receiver's progress engine processes them.  This is the
        # receive-buffer occupancy a credit window bounds.
        self._credit_out: dict[tuple[str, str], int] = {}
        # per-tenant slice of that occupancy: a FIFO ledger of
        # [tenant, n_payloads] entries per (src, dst) link, plus the
        # aggregate per-(src, tenant) outstanding count a tenant budget
        # bounds.  Attribution on credit_return is FIFO — exact when the
        # receiver drains in order, approximate under lane reordering,
        # but conserved either way: a tenant's count only ever drains by
        # what it deposited.
        self._tenant_fifo: dict[tuple[str, str], deque] = {}
        self._tenant_out: dict[tuple[str, str], int] = {}
        self._lock = threading.Lock()
        # seeded Bernoulli loss injection (set_loss): 0.0 = lossless
        self._loss_rate = 0.0
        self._loss_rng: np.random.Generator | None = None
        # optional trace capture (analysis/trace.py): every hook in the
        # runtime reaches the recorder through this single attach point,
        # guarded by `is not None` — detached runs pay one attribute load
        self.tracer = None

    # loss injection ---------------------------------------------------------
    def set_loss(self, rate: float, seed: int = 0) -> None:
        """Arm (or disarm, ``rate=0``) seeded Bernoulli frame loss.

        Each framed PUT and each one-sided region write is independently
        dropped with probability ``rate`` *after* the sender pays for it
        (bytes and modeled time are accounted — the NIC sent them; the
        receiver just never sees them, and no receive credit is consumed).
        One mechanism shared by the chaos suites and
        ``benchmarks/reliability.py``; the seeded generator makes every
        loss schedule reproducible under the deterministic scheduler.
        """
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"loss rate {rate} outside [0, 1)")
        self._loss_rate = float(rate)
        self._loss_rng = np.random.default_rng(seed) if rate else None

    def _lose(self) -> bool:
        return (
            self._loss_rng is not None
            and float(self._loss_rng.random()) < self._loss_rate
        )

    def connect(self, name: str) -> Endpoint:
        ep = Endpoint(name)
        self.endpoints[name] = ep
        self._clear_credits(name)
        return ep

    # capability registry -----------------------------------------------------
    def advertise(self, name: str, cap: Capability) -> Capability:
        """Register (or refresh) ``name``'s capability vector.

        Every advertisement mints a fresh fabric-wide epoch so consumers
        (cached placement plans) can tell a restarted PE from the one they
        priced against.  Returns the epoch-stamped vector.
        """
        with self._lock:
            self._cap_epoch += 1
            cap = replace(cap, epoch=self._cap_epoch)
            self.capabilities[name] = cap
            self._cap_models[name] = cap.model()
        return cap

    def capability(self, name: str) -> Capability | None:
        return self.capabilities.get(name)

    def _model_for(self, src: str) -> WireModel:
        """Wire model pricing an operation initiated by ``src``: the
        initiator's advertised profile under ``hetero``, else the single
        fabric-wide profile (legacy accounting, bit-identical)."""
        if not self.hetero:
            return self.wire
        return self._cap_models.get(src, self.wire)

    # credit accounting ------------------------------------------------------
    def credit_outstanding(self, src: str, dst: str) -> int:
        """Payloads PUT by ``src`` that ``dst`` has not yet processed."""
        return self._credit_out.get((src, dst), 0)

    def tenant_outstanding(self, src: str, tenant: str) -> int:
        """Payloads PUT by ``src`` on ``tenant``'s behalf (any destination)
        not yet processed — what a per-tenant credit budget bounds."""
        return self._tenant_out.get((src, tenant), 0)

    def _tenant_credit(self, src: str, tenant: str, delta: int) -> None:
        # lock held by caller
        key = (src, tenant)
        left = self._tenant_out.get(key, 0) + delta
        if left > 0:
            self._tenant_out[key] = left
        else:
            self._tenant_out.pop(key, None)

    def _drain_tenant_fifo(self, key: tuple[str, str], n: int) -> None:
        # lock held by caller; attribute n retired payloads FIFO-first
        fifo = self._tenant_fifo.get(key)
        while n > 0 and fifo:
            entry = fifo[0]  # mutable [tenant, n_payloads]
            take = min(entry[1], n)
            entry[1] -= take
            n -= take
            self._tenant_credit(key[0], entry[0], -take)
            if entry[1] == 0:
                fifo.popleft()
        if fifo is not None and not fifo:
            self._tenant_fifo.pop(key, None)

    def credit_return(self, src: str, dst: str, n: int = 1) -> None:
        """Release ``n`` receive credits from ``dst`` back to ``src``
        (called by the receiver's progress engine as frames retire)."""
        if not src:
            return
        with self._lock:
            key = (src, dst)
            left = self._credit_out.get(key, 0) - n
            if left > 0:
                self._credit_out[key] = left
            else:
                self._credit_out.pop(key, None)
            self._drain_tenant_fifo(key, n)

    def _release_tenant_fifo(self, key: tuple[str, str]) -> None:
        # lock held by caller; give every ledgered payload on this link
        # back to its tenant (the frames themselves are gone)
        for tenant, count in self._tenant_fifo.pop(key, ()):
            self._tenant_credit(key[0], tenant, -count)

    def _clear_credits(self, name: str) -> None:
        """Drop all credit state involving ``name`` (its frames are gone —
        a dead inbox drops them, a fresh endpoint starts empty — so a
        sender's window against it must not stay consumed forever)."""
        with self._lock:
            for key in [k for k in self._credit_out if name in k]:
                self._credit_out.pop(key, None)
            for key in [k for k in self._tenant_fifo if name in k]:
                self._release_tenant_fifo(key)

    def clear_peer_credits(self, a: str, b: str) -> None:
        """Drop credit state between one pair of peers, both directions —
        what a PE that just declared ``b`` dead clears, without touching
        other senders' windows against ``b`` (each PE's failure detector
        makes its own call)."""
        with self._lock:
            self._credit_out.pop((a, b), None)
            self._credit_out.pop((b, a), None)
            self._release_tenant_fifo((a, b))
            self._release_tenant_fifo((b, a))

    def _target(self, dst: str) -> Endpoint:
        ep = self.endpoints[dst]
        if not ep.alive:
            raise EndpointDead(dst)
        return ep

    # one-sided ops ---------------------------------------------------------
    def put(
        self,
        src: str,
        dst: str,
        wire_bytes: bytes,
        n_payloads: int = 1,
        kinds: dict[str, int] | None = None,
        hop: bool = False,
        tenant: str | None = None,
    ) -> float:
        """One-sided PUT of a (possibly truncated, possibly coalesced) frame.

        Returns the modeled wire time in us.  The receiver is not notified;
        it discovers the message by polling (MAGIC sentinels).  A coalesced
        PUT (``n_payloads > 1``) is *one* wire message: one ``alpha_us`` /
        ``o_us`` charge for the summed bytes — exactly the amortization the
        batched runtime is after — and is counted in ``coalesced_frames`` so
        benchmarks can report it.  ``kinds`` attributes the bytes across
        :data:`BYTE_KINDS` (omitted = all counted as payload).  ``hop``
        marks a propagation PUBLISH frame (hop header on board) so tree
        multicasts are visible in the fabric accounting.  ``tenant`` charges
        the frame's payloads against that tenant's credit ledger (and its
        per-tenant traffic counters) — multi-tenant QoS accounting.
        """
        ep = self._target(dst)
        n = len(wire_bytes)
        model = self._model_for(src)
        t = model.latency_us(n)
        with self._lock:
            self.stats.puts += 1
            self.stats.put_bytes += n
            self.stats.modeled_us += t
            self.stats.modeled_tput_us += model.inverse_throughput_us(n)
            self.stats.add_kinds(kinds if kinds is not None else {"payload": n})
            if n_payloads > 1:
                self.stats.coalesced_frames += 1
                self.stats.coalesced_payloads += n_payloads
            if hop:
                self.stats.hop_frames += 1
                self.stats.hop_bytes += n
            if tenant is not None:
                tp = self.stats.tenant_puts
                tp[tenant] = tp.get(tenant, 0) + 1
                tb = self.stats.tenant_put_bytes
                tb[tenant] = tb.get(tenant, 0) + n
            lost = self._lose()
            if lost:
                # the sender paid for the bytes but they never land: no
                # delivery, no receive-buffer occupancy, no credit consumed
                self.stats.frames_lost += 1
                self.stats.lost_bytes += n
            if self.tracer is not None:
                ev = {"src": src, "dst": dst, "n": n, "p": n_payloads}
                if kinds is not None:
                    ev["by"] = kinds
                if hop:
                    ev["hop"] = True
                if tenant is not None:
                    ev["tn"] = tenant
                if lost:
                    ev["lost"] = True
                self.tracer.emit("put", **ev)
            if lost:
                return t
            if n_payloads:
                self._credit_out[(src, dst)] = (
                    self._credit_out.get((src, dst), 0) + n_payloads
                )
                if tenant is not None:
                    self._tenant_fifo.setdefault((src, dst), deque()).append(
                        [tenant, n_payloads]
                    )
                    self._tenant_credit(src, tenant, n_payloads)
        ep.deliver(wire_bytes, src=src)
        return t

    def put_region(
        self,
        src: str,
        dst: str,
        region: str,
        offset: int,
        data: bytes,
        *,
        doorbell: tuple[int, int, str] | None = None,
        guard: tuple[int, int] | None = None,
    ) -> float:
        """One-sided RDMA WRITE into ``dst``'s registered region.

        No frame, no inbox, no receiver dispatch: the bytes land in memory
        and the optional ``doorbell`` word is bumped atomically so the
        receiver discovers completion by polling memory (the paper's
        pointer chase 'returns its result with a final PUT').  See
        :class:`RegionWrite` for doorbell/guard semantics.
        """
        return self.put_region_multi(
            src,
            dst,
            [RegionWrite(region, offset, data, doorbell=doorbell, guard=guard)],
        )

    def put_region_multi(self, src: str, dst: str, writes: Sequence[RegionWrite]) -> float:
        """A doorbell-batched chain of one-sided writes to one peer.

        Models a posted WQE chain: the first segment pays the full
        ``alpha_us`` latency, each further segment only the pipelined
        per-message overhead ``o_us``, and all data bytes share the wire at
        ``beta_Bus``.  Each write's guard is checked independently — a
        stale-generation write is dropped at the 'NIC' without disturbing
        its chain-mates — and each doorbell folds in only after its own
        data landed.
        """
        if not writes:
            return 0.0
        ep = self._target(dst)
        nbytes = sum(len(w.data) for w in writes) + 4 * sum(
            1 for w in writes if w.doorbell is not None
        )
        model = self._model_for(src)
        t = model.latency_us(nbytes) + (len(writes) - 1) * model.o_us
        with self._lock:
            self.stats.region_puts += 1
            self.stats.region_put_bytes += nbytes
            self.stats.modeled_us += t
            self.stats.modeled_tput_us += (
                len(writes) - 1
            ) * model.o_us + model.inverse_throughput_us(nbytes)
            self.stats.add_kinds({"region": nbytes})
            lw0 = self.stats.region_writes_lost
            gd0 = self.stats.region_guard_drops
            lost = False
            for w in writes:
                if lost or self._lose():
                    # a lost WQE segment takes the rest of the chain with
                    # it: QP delivery is in order, so the fenced doorbell
                    # on the last segment never fires over a gap — a
                    # half-landed partial stays invisible until resubmit
                    lost = True
                    self.stats.region_writes_lost += 1
                    continue
                if w.guard is not None:
                    g_off, g_want = w.guard
                    if ep.read_region_i32(w.region, g_off) != g_want:
                        self.stats.region_guard_drops += 1
                        continue
                if w.data:
                    ep.write_region(w.region, w.offset, w.data)
                if w.doorbell is not None:
                    d_off, d_val, d_op = w.doorbell
                    cur = ep.read_region_i32(w.region, d_off)
                    new = (cur | d_val) if d_op == "or" else (cur + d_val)
                    ep.write_region(w.region, d_off, struct.pack("<i", new))
            if self.tracer is not None:
                ev = {"src": src, "dst": dst, "n": nbytes, "w": len(writes)}
                lw = self.stats.region_writes_lost - lw0
                gd = self.stats.region_guard_drops - gd0
                if lw:
                    ev["lw"] = lw
                if gd:
                    ev["gd"] = gd
                self.tracer.emit("rput", **ev)
        return t

    def get(self, src: str, dst: str, region: str, offset: int, nbytes: int) -> bytes:
        """One-sided GET: read target memory; no target-side code runs.

        Modeled as a full round trip (request + data), the cost structure of
        an RDMA READ: latency ~ 2*alpha + n/beta.
        """
        ep = self._target(dst)
        data = ep.read_region(region, offset, nbytes)
        model = self._model_for(src)
        t = 2 * model.alpha_us + nbytes / model.beta_Bus
        with self._lock:
            self.stats.gets += 1
            self.stats.get_bytes += nbytes
            self.stats.modeled_us += t
            self.stats.modeled_tput_us += t  # GETs are round-trips; no pipelining
            self.stats.add_kinds({"region": nbytes})
            if self.tracer is not None:
                self.tracer.emit("get", src=src, dst=dst, n=nbytes, region=region)
        return data

    # fault injection ---------------------------------------------------------
    def kill(self, name: str) -> None:
        """Endpoint process death: queue drops, memory unreachable."""
        ep = self.endpoints[name]
        ep.alive = False
        ep.inbox.clear()
        self.capabilities.pop(name, None)
        self._cap_models.pop(name, None)
        self._clear_credits(name)

    def revive(self, name: str) -> Endpoint:
        """Restarted process: fresh endpoint state (all caches/regions gone).

        The capability vector does NOT survive: the revived process must
        re-advertise (PE.__init__ does) before hetero pricing or placement
        sees it again."""
        ep = Endpoint(name)
        self.endpoints[name] = ep
        self.capabilities.pop(name, None)
        self._cap_models.pop(name, None)
        self._clear_credits(name)
        return ep
