"""Three-Chains core: code+data movement over a (simulated) RDMA fabric.

Public API re-exports.  Layering:

  transport  — fabric, endpoints, one-sided PUT/GET, wire models
  frame      — message frames + truncation protocol (Figs. 2/3) + hop headers
  bitcode    — fat-bitcode archives over jax.export blobs (Sec. III-C)
  cache      — SenderCache / TargetCodeCache (Sec. III-D, Fig. 4)
  propagate  — spanning-tree multicast shapes + completion model (Sec. I)
  pe         — the layered PE runtime: source / wire / codecache / exec /
               progress layers + CompletionQueue + the PE facade
               (re-exported by the stable `ifunc` module)
  reliability — exactly-once delivery config: seq/ack windows, retransmit
               timers, failure detection knobs
  verify     — safe code injection: install-time bitcode verifier +
               runtime resource sandbox (capability stamps, quotas,
               cluster-wide quarantine)
  xrdma      — Chaser / ReturnResult / TSI / Gatherer / Reducer / Gossiper
  cluster    — in-process cluster + deterministic scheduler
  pointer_chase — DAPC miniapp + GBPC baseline (Secs. IV-C/D)
"""

from .bitcode import BitcodeSlice, FatBitcode, local_triple, platform_of
from .cache import CacheStats, SenderCache, TargetCodeCache
from .cluster import Cluster
from .dataplane import DataPlaneConfig, SlabLayout
from .frame import (
    CorruptFrame,
    Frame,
    FrameFlags,
    FrameKind,
    HopHeader,
    MAGIC,
    coalesce,
    delivery_complete,
    pack_hop,
    peek_header,
    split_hop,
    split_payloads,
    unpack,
    unpack_hop,
)
from .frame import ProtocolError
from .pe import (
    ACTION_WIDTH,
    A_DONE,
    A_FORWARD,
    A_NOP,
    A_PUBLISH,
    A_RETURN,
    A_SPAWN,
    CompletionQueue,
    GatherFuture,
    IFunc,
    ISAMismatch,
    PE,
    PEStats,
    ProgressEngine,
    Toolchain,
    WireLayer,
)
from .pointer_chase import ChaseReport, PointerChaseApp, chase_ref, make_chain
from .reliability import ReliabilityConfig
from .propagate import (
    PropagationConfig,
    subtree_sizes,
    tree_children,
    tree_children_map,
    tree_completion_us,
    tree_depth,
    tree_parent,
)
from .verify import (
    CapabilityStamp,
    SandboxConfig,
    SandboxViolation,
    Verifier,
)
from .transport import (
    Capability,
    Endpoint,
    EndpointDead,
    Fabric,
    MEM_BW_BUS,
    MEM_BW_CLASS,
    RegionWrite,
    TRIPLE_WIRE,
    WIRE_PROFILES,
    WireModel,
)
from .xrdma import (
    make_chaser,
    make_filter,
    make_filter_return,
    make_gather_return,
    make_gatherer,
    make_gossiper,
    make_reducer,
    make_return_result,
    make_spawner,
    make_tsi,
)

__all__ = [
    "ACTION_WIDTH",
    "A_DONE",
    "A_FORWARD",
    "A_NOP",
    "A_PUBLISH",
    "A_RETURN",
    "A_SPAWN",
    "BitcodeSlice",
    "CacheStats",
    "Capability",
    "CapabilityStamp",
    "ChaseReport",
    "Cluster",
    "CompletionQueue",
    "CorruptFrame",
    "DataPlaneConfig",
    "Endpoint",
    "EndpointDead",
    "Fabric",
    "FatBitcode",
    "Frame",
    "FrameFlags",
    "FrameKind",
    "GatherFuture",
    "HopHeader",
    "IFunc",
    "ISAMismatch",
    "MAGIC",
    "MEM_BW_BUS",
    "MEM_BW_CLASS",
    "PE",
    "PEStats",
    "PointerChaseApp",
    "ProgressEngine",
    "PropagationConfig",
    "ProtocolError",
    "RegionWrite",
    "ReliabilityConfig",
    "SandboxConfig",
    "SandboxViolation",
    "SenderCache",
    "SlabLayout",
    "TRIPLE_WIRE",
    "TargetCodeCache",
    "Toolchain",
    "Verifier",
    "WIRE_PROFILES",
    "WireLayer",
    "WireModel",
    "chase_ref",
    "coalesce",
    "delivery_complete",
    "local_triple",
    "make_chain",
    "make_chaser",
    "make_filter",
    "make_filter_return",
    "make_gather_return",
    "make_gatherer",
    "make_gossiper",
    "make_reducer",
    "make_return_result",
    "make_spawner",
    "make_tsi",
    "pack_hop",
    "peek_header",
    "platform_of",
    "split_hop",
    "split_payloads",
    "subtree_sizes",
    "tree_children",
    "tree_children_map",
    "tree_completion_us",
    "tree_depth",
    "tree_parent",
    "unpack",
    "unpack_hop",
]
