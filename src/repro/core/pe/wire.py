"""Wire layer: frame egress — batching queues, coalesced flush, rendezvous
staging, and per-peer credit-based flow control.

This layer owns everything between "the runtime decided to send a frame"
and "bytes hit the fabric": sequence numbering, the per-destination send
queues the batched runtime coalesces at :meth:`WireLayer.flush`, the
sender-cache truncation decision (code travels once per peer), the
rendezvous staging ring, and the credit window.

Credit-based flow control (the progress-engine half lives in
:mod:`repro.core.pe.progress`): each framed PUT consumes one receive
credit at the destination; when ``credit_window`` is set and the window is
exhausted, further *data* frames queue locally in FIFO order instead of
flooding a slow peer's receive buffer.  Credits return when the receiver's
progress engine processes the frames, and the sender's next
:meth:`pump` (called from its own poll/flush) drains the queue.  Control
frames — PUBLISH hops and rendezvous descriptors — never consume credits:
they are small, latency-critical, and starving them behind bulk data is
exactly the priority inversion the lane/credit design removes.

Multi-tenant QoS (:attr:`WireLayer.tenant_budgets`): a frame tagged with a
tenant additionally charges that tenant's slice of the sender's outgoing
occupancy (the fabric's per-tenant ledger).  A tenant over its budget
stalls *its own* frames in a per-(destination, tenant) queue — other
tenants' frames to the same peer keep flowing, which is the isolation
property.  Stalled frames are unsequenced (seqs are assigned at transmit
time), so cross-tenant reordering at one destination is invisible to the
reliability layer's per-peer streams.  EXPRESS-flagged frames still
consume credits and budgets — the flag only buys drain priority at the
receiver (see :mod:`repro.core.pe.progress`), never window exemption.
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Callable

import numpy as np

from ..frame import Frame, FrameFlags, FrameKind, coalesce, pack_rndv, rndv_region
from ..reliability import ReliabilityConfig
from ..transport import EndpointDead, Fabric, RegionWrite

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from ..cache import SenderCache
    from ..transport import Endpoint
    from .source import IFunc

# rendezvous staging ring depth: outstanding staged RETURN payloads per PE
# before the oldest registration is reclaimed (bounds pinned memory the way
# a real transport bounds its rendezvous buffer pool)
RNDV_STAGING_DEPTH = 1024


def is_control(kind: int, flags: int) -> bool:
    """The lane classification both ends of the wire agree on: PUBLISH hop
    frames, rendezvous descriptors, and ACKs are control traffic (small,
    latency-critical); everything else — ifunc payloads, RETURN data,
    AMs — is bulk data."""
    return bool(flags & FrameFlags.HOP) or kind in (FrameKind.RNDV, FrameKind.ACK)


class WireLayer:
    """Frame egress for one PE: queues, credits, coalescing, staging."""

    def __init__(
        self,
        name: str,
        fabric: Fabric,
        endpoint: "Endpoint",
        sender_cache: "SenderCache",
        stats,
        peers: list[str],
    ) -> None:
        self.name = name
        self.fabric = fabric
        self.endpoint = endpoint
        self.sender_cache = sender_cache
        self.stats = stats  # the PE's PEStats (shared across layers)
        self.peers = peers  # shared list reference (facade owns it)
        self.batching = False  # batched runtime: queue sends for flush()
        self.caching_enabled = True  # benchmark switch: uncached mode
        self.credit_window = 0  # 0 = flow control off (unlimited window)
        # tenant -> outgoing-payload budget (0/absent = unbudgeted); the
        # per-tenant carve-out of the receive-window occupancy
        self.tenant_budgets: dict[str, int] = {}
        self._seq = 0
        self._sendq: dict[str, list[Frame]] = {}  # per-destination pending frames
        self._regionq: dict[str, list[RegionWrite]] = {}  # pending one-sided writes
        # frames awaiting credits, one FIFO lane per (dst, tenant) so a
        # stalled tenant never heads-of-line-blocks its neighbours
        self._creditq: dict[tuple[str, str | None], deque[Frame]] = {}
        self._rndv_tokens: deque[str] = deque()  # staged rendezvous regions (ring)
        self._rndv_seq = 0
        # --- reliability (sender half; receiver half in progress.py) ---
        self.reliability = ReliabilityConfig()  # disabled by default
        # cumulative-ack provider: the progress engine's per-source ingest
        # high-water mark, stamped into every outgoing frame (piggyback)
        self.ack_provider: Callable[[str], int] | None = None
        # escalation hook: peer exhausted its retransmit budget -> suspect
        self.on_suspect: Callable[[str], None] | None = None
        self._tick = 0  # mirror of the progress engine's tick clock
        self._peer_seq: dict[str, int] = {}  # next seq to assign, per peer
        # per-peer retransmit queue, seq order.  Entries are mutable lists
        # [seq, wire_bytes, n_payloads, kinds, hop, control, due, attempts]:
        # the EXACT first-transmit bytes are kept and resent verbatim, so a
        # retransmitted code-carrying frame is not wrongly truncated by the
        # sender-cache entry its first flight created.
        self._unacked: dict[str, deque[list]] = {}
        self._suspect: set[str] = set()  # budget-exhausted peers (paused)
        self._acked_sent: dict[str, int] = {}  # highest ack stamped per peer

    # --- sequencing -------------------------------------------------------
    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # --- egress -----------------------------------------------------------
    def put_frame(self, dst: str, frame: Frame) -> int:
        """PUT a frame now, or queue it for the next :meth:`flush`.

        Returns wire bytes sent, or 0 when the frame was queued (the wire
        size of a queued frame is only known after coalescing).
        """
        if self.batching:
            self._sendq.setdefault(dst, []).append(frame)
            return 0
        return self.put_now(dst, frame)

    def put_now(self, dst: str, frame: Frame) -> int:
        """PUT one frame, honouring the credit window and tenant budget.

        Control frames (hop headers, rendezvous descriptors) always
        transmit; a data frame beyond the peer window or its tenant's
        budget — or behind earlier stalled frames of the same (dst,
        tenant) lane, so per-lane FIFO order holds — queues locally and
        travels on a later :meth:`pump`.  Returns wire bytes sent (0 when
        credit-queued).
        """
        if not is_control(int(frame.kind), int(frame.flags)):
            lane = (dst, frame.tenant)
            window_full = bool(self.credit_window) and not self._credit_ok(dst)
            budget_full = not self._tenant_ok(frame.tenant)
            if self._creditq.get(lane) or window_full or budget_full:
                self._creditq.setdefault(lane, deque()).append(frame)
                self.stats.credit_stalls += 1
                self.fabric.stats.credit_stalls += 1
                if budget_full:
                    ts = self.fabric.stats.tenant_stalls
                    ts[frame.tenant] = ts.get(frame.tenant, 0) + 1
                    self.stats.bump_tenant("stalls", frame.tenant)
                tracer = getattr(self.fabric, "tracer", None)
                if tracer is not None:
                    ev = {"src": self.name, "dst": dst}
                    if frame.tenant is not None:
                        ev["tn"] = frame.tenant
                    if budget_full:
                        ev["budget"] = True
                    tracer.emit("stall", **ev)
                return 0
        return self._transmit(dst, frame)

    def _credit_ok(self, dst: str) -> bool:
        return self.fabric.credit_outstanding(self.name, dst) < self.credit_window

    def _tenant_ok(self, tenant: str | None) -> bool:
        if tenant is None:
            return True
        budget = self.tenant_budgets.get(tenant, 0)
        if not budget:
            return True
        return self.fabric.tenant_outstanding(self.name, tenant) < budget

    def _transmit(self, dst: str, frame: Frame) -> int:
        if frame.kind in (FrameKind.ACTIVE_MESSAGE, FrameKind.RNDV):
            cached = True  # AM / rendezvous descriptors never carry code
        else:
            cached = self.caching_enabled and self.sender_cache.check_and_add(
                dst, frame.digest.hex(), len(frame.code)
            )
        rel = self.reliability
        tracked = rel.enabled and dst != self.name
        if tracked:
            # per-peer stream: one seq space per (src, dst), in-order
            # ingest at the receiver (the per-QP ordering of a real RC
            # transport); the piggybacked ack rides for free in the header
            seq = self._peer_seq.get(dst, 0) + 1
            self._peer_seq[dst] = seq
            frame.seq = seq & 0xFFFFFFFF
            frame.ack = self._ack_for(dst)
        wire = frame.wire_bytes(cached=cached)
        kinds = frame.kind_breakdown(cached)
        hop = bool(frame.flags & FrameFlags.HOP)
        self.stats.sends += 1
        if not cached and frame.code:
            self.stats.code_sends += 1
        if tracked:
            self._unacked.setdefault(dst, deque()).append([
                frame.seq, wire, frame.n_payloads, kinds, hop,
                is_control(int(frame.kind), int(frame.flags)),
                self._tick + rel.rto_after(0), 0, frame.tenant,
            ])
        if frame.tenant is not None:
            self.stats.bump_tenant("sends", frame.tenant)
        tracer = getattr(self.fabric, "tracer", None)
        if tracer is not None:
            ev = {
                "src": self.name, "dst": dst, "n": len(wire),
                "p": frame.n_payloads, "kind": int(frame.kind),
                "name": frame.name, "pb": kinds.get("payload", 0),
                "cb": kinds.get("code", 0), "cached": cached,
            }
            if hop:
                ev["hop"] = True
            if frame.tenant is not None:
                ev["tn"] = frame.tenant
            if tracked:
                ev["seq"] = frame.seq
            tracer.emit("send", **ev)
        try:
            self.fabric.put(
                self.name, dst, wire, n_payloads=frame.n_payloads,
                kinds=kinds, hop=hop, tenant=frame.tenant,
            )
        except EndpointDead:
            if not tracked:
                raise
            # under reliability a synchronous dead-endpoint PUT is just a
            # lost frame: it stays on the retransmit queue and the failure
            # detector — not the caller — attributes the death
            self.stats.sends_to_dead += 1
        return len(wire)

    # --- reliability: sender half -----------------------------------------
    def _ack_for(self, dst: str) -> int:
        if self.ack_provider is None:
            return 0
        ack = int(self.ack_provider(dst))
        if ack > self._acked_sent.get(dst, 0):
            self._acked_sent[dst] = ack
        return ack

    def acked_sent(self, peer: str) -> int:
        """Highest cumulative ack this PE has stamped toward ``peer``."""
        return self._acked_sent.get(peer, 0)

    def on_ack(self, peer: str, ack: int) -> None:
        """Retire every unacked frame to ``peer`` with seq <= ``ack``
        (cumulative ACK, piggybacked or standalone)."""
        q = self._unacked.get(peer)
        if not q:
            return
        while q and q[0][0] <= ack:
            q.popleft()
            self.stats.frames_acked += 1
        if not q:
            del self._unacked[peer]

    def peer_alive(self, peer: str) -> None:
        """Any frame from ``peer`` is a sign of life: clear suspicion and
        re-arm its retransmit timers from now."""
        if peer not in self._suspect:
            return
        self._suspect.discard(peer)
        for e in self._unacked.get(peer, ()):
            e[6] = self._tick + self.reliability.rto_after(0)
            e[7] = 0

    def on_tick(self, tick: int) -> int:
        """Drive the retransmit clock one tick: resend every due unacked
        frame (control frames first) with exponential backoff; a frame out
        of budget escalates its peer to *suspect* via :attr:`on_suspect`
        and pauses that peer's retransmissions.  Returns frames resent."""
        self._tick = tick
        rel = self.reliability
        if not rel.enabled:
            return 0
        resent = 0
        for dst in list(self._unacked):
            if dst in self._suspect:
                continue
            q = self._unacked[dst]
            due = [e for e in q if e[6] <= tick]
            if not due:
                continue
            due.sort(key=lambda e: (not e[5], e[0]))  # control first, then seq
            for e in due:
                if e[7] >= rel.retransmit_budget:
                    self._suspect.add(dst)
                    self.stats.peers_suspected += 1
                    if self.on_suspect is not None:
                        self.on_suspect(dst)
                    break
                e[7] += 1
                e[6] = tick + rel.rto_after(e[7])
                self.stats.retransmits += 1
                resent += 1
                tracer = getattr(self.fabric, "tracer", None)
                if tracer is not None:
                    tracer.emit(
                        "retx", src=self.name, dst=dst, seq=e[0], n=len(e[1])
                    )
                try:
                    # the exact bytes of the first flight — same truncation,
                    # same seq, same (now possibly stale, harmlessly lower)
                    # piggybacked ack; the tenant pays for its own
                    # retransmissions (they occupy the same receive buffer)
                    self.fabric.put(
                        self.name, dst, e[1], n_payloads=e[2],
                        kinds=e[3], hop=e[4], tenant=e[8],
                    )
                except EndpointDead:
                    self.stats.sends_to_dead += 1
        return resent

    def send_ack(self, dst: str, ack: int) -> None:
        """Emit one standalone cumulative-ACK frame (header-only, never
        sequenced or retransmitted — ACKs are not acked; a lost one is
        covered by the next piggyback or the sender's retransmit)."""
        frame = Frame(kind=FrameKind.ACK, name="", payload=b"", ack=ack)
        if ack > self._acked_sent.get(dst, 0):
            self._acked_sent[dst] = ack
        wire = frame.wire_bytes(cached=True)
        self.stats.acks_sent += 1
        tracer = getattr(self.fabric, "tracer", None)
        if tracer is not None:
            tracer.emit("ack", src=self.name, dst=dst, ack=ack)
        try:
            # n_payloads=0: an ACK occupies no receive-buffer credit and is
            # consumed at ingest without ever entering a lane
            self.fabric.put(
                self.name, dst, wire, n_payloads=0, kinds={"header": len(wire)}
            )
        except EndpointDead:
            pass  # the detector owns death attribution

    def suspects(self) -> set[str]:
        return set(self._suspect)

    def unacked_frames(self, peer: str | None = None) -> int:
        if peer is not None:
            return len(self._unacked.get(peer, ()))
        return sum(len(q) for q in self._unacked.values())

    def forget_peer(self, peer: str) -> None:
        """Drop every piece of sender-side reliability and queue state for
        ``peer`` (declared dead or restarted): its retransmit queue, its
        seq stream, its credit-stalled frames, its suspicion."""
        dropped = len(self._unacked.pop(peer, ()))
        self.stats.unacked_dropped += dropped
        for lane in [k for k in self._creditq if k[0] == peer]:
            self.stats.credit_dropped += len(self._creditq.pop(lane))
        self._peer_seq.pop(peer, None)
        self._acked_sent.pop(peer, None)
        self._suspect.discard(peer)

    def drop_queued_digest(self, digest: bytes) -> int:
        """Purge every not-yet-transmitted frame carrying ``digest`` from
        the batching send queues and the credit-stall lanes: the digest
        was quarantined, and a queued frame must not carry banished code
        (or a digest-only reference to it) onto the fabric after the
        uninstall.  Returns the number of frames dropped."""
        dropped = 0
        for dst, frames in list(self._sendq.items()):
            kept = [f for f in frames if f.digest != digest]
            dropped += len(frames) - len(kept)
            if kept:
                self._sendq[dst] = kept
            else:
                del self._sendq[dst]
        for lane, q in list(self._creditq.items()):
            kept_q = deque(f for f in q if f.digest != digest)
            dropped += len(q) - len(kept_q)
            if kept_q:
                self._creditq[lane] = kept_q
            else:
                del self._creditq[lane]
        return dropped

    def pump(self) -> int:
        """Transmit credit-stalled frames whose window (and tenant budget)
        reopened; returns the number sent.  Lanes drain independently —
        one tenant's backlog never gates another's.  A destination that
        died while frames were queued loses exactly its own lanes (the
        fabric's loss model — those frames were in flight), counted in
        ``stats.credit_dropped``."""
        sent = 0
        for lane in list(self._creditq):
            dst, tenant = lane
            q = self._creditq[lane]
            while (
                q
                and (not self.credit_window or self._credit_ok(dst))
                and self._tenant_ok(tenant)
            ):
                frame = q.popleft()
                try:
                    self._transmit(dst, frame)
                    sent += 1
                except EndpointDead:
                    self.stats.credit_dropped += 1 + len(q)
                    q.clear()
            if not q:
                del self._creditq[lane]
        return sent

    def queued_credit_frames(
        self, dst: str | None = None, tenant: str | None = None
    ) -> int:
        if dst is not None:
            return sum(
                len(q)
                for lane, q in self._creditq.items()
                if lane[0] == dst and (tenant is None or lane[1] == tenant)
            )
        if tenant is not None:
            return sum(
                len(q) for lane, q in self._creditq.items() if lane[1] == tenant
            )
        return sum(len(q) for q in self._creditq.values())

    # --- one-sided writes -------------------------------------------------
    def put_region(self, dst: str, writes: list[RegionWrite]) -> None:
        """Issue (or, under batching, queue) a slab-write burst to one peer."""
        if self.batching:
            self._regionq.setdefault(dst, []).extend(writes)
        else:
            try:
                self.fabric.put_region_multi(self.name, dst, writes)
            except EndpointDead:
                if not self.reliability.enabled:
                    raise
                # one-sided writes have no retransmit queue (the data lived
                # in the dispatch that produced it): the requester's CQ
                # deadline recovers — resubmit or degrade with a mask
                self.stats.region_write_failures += 1

    # --- batched flush ----------------------------------------------------
    def flush(self) -> int:
        """Emit every queued frame and one-sided write burst.

        A burst of same-type frames to one peer travels as a single
        coalesced PUT (one ``alpha_us``, summed bytes); a burst of queued
        zero-copy slab writes to one peer travels as a single doorbell-
        batched WQE chain (one ``alpha_us``, one ``o_us`` per extra
        segment).  A failing destination (e.g. a killed endpoint) loses
        only its own traffic — every other destination's queue is still
        delivered, then the first error is re-raised.  Returns the number
        of wire operations issued.
        """
        puts = self.pump()
        queued, self._sendq = self._sendq, {}
        regionq, self._regionq = self._regionq, {}
        errors: list[Exception] = []
        for dst, frames in queued.items():
            # group by ifunc type AND payload size (AM payloads are caller-
            # defined and xrdma plen varies, so same-name frames can be
            # ragged — those travel as separate coalesced PUTs), preserving
            # first-seen order.  PUBLISH hop frames never coalesce: each
            # carries its own per-edge path header.  EXPRESS and tenant are
            # part of the key: a coalesced frame has one lane class and one
            # budget to charge, so mixed-QoS bursts travel separately.
            groups: dict[tuple[int, str, bytes, int, int, str | None], list[Frame]] = {}
            for f in frames:
                key = (
                    int(f.kind), f.name, f.digest, len(f.payload),
                    int(f.flags) & (FrameFlags.HOP | FrameFlags.EXPRESS),
                    f.tenant,
                )
                groups.setdefault(key, []).append(f)
            for key, members in groups.items():
                batch = [coalesce(members)] if not key[4] & FrameFlags.HOP else members
                for frame in batch:
                    try:
                        if self.put_now(dst, frame):
                            puts += 1
                    except Exception as e:  # noqa: BLE001 - deliver the rest first
                        errors.append(e)
        for dst, writes in regionq.items():
            try:
                self.fabric.put_region_multi(self.name, dst, writes)
                puts += 1
            except EndpointDead as e:
                if self.reliability.enabled:
                    self.stats.region_write_failures += 1
                else:
                    errors.append(e)
            except Exception as e:  # noqa: BLE001 - deliver the rest first
                errors.append(e)
        if puts:
            self.stats.flushes += 1
        if errors:
            raise errors[0]
        return puts

    # --- rendezvous staging (sender side) ---------------------------------
    def rndv_send(self, dst: str, ifn: "IFunc", pay: np.ndarray) -> None:
        """Rendezvous RETURN: stage the payload in a source-registered
        region and frame only the 16-byte descriptor; the requester pulls
        the data with a one-sided GET (cost ``2*alpha + n/beta``, correct
        when the payload dwarfs ``2*alpha``)."""
        token = self._rndv_seq
        self._rndv_seq += 1
        staging = rndv_region(self.name, token)
        # explicit copy: `pay` may be a view into a whole batched action
        # matrix, and registering the view would pin that matrix in the
        # staging ring long after the dispatch that produced it
        data = np.array(pay, np.int32)
        self.endpoint.register_region(staging, data)
        self._rndv_tokens.append(staging)
        while len(self._rndv_tokens) > RNDV_STAGING_DEPTH:
            self.endpoint.unregister_region(self._rndv_tokens.popleft())
        desc = pack_rndv(self.peers.index(self.name), token, data.nbytes)
        self.put_frame(
            dst,
            Frame(kind=FrameKind.RNDV, name=ifn.name, payload=desc, seq=self.next_seq()),
        )

    def fetch_rndv(self, src: str, token: int, nbytes: int) -> bytes:
        """Pull one staged rendezvous payload from ``src`` (receiver side)."""
        return self.fabric.get(self.name, src, rndv_region(src, token), 0, nbytes)
