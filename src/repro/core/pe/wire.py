"""Wire layer: frame egress — batching queues, coalesced flush, rendezvous
staging, and per-peer credit-based flow control.

This layer owns everything between "the runtime decided to send a frame"
and "bytes hit the fabric": sequence numbering, the per-destination send
queues the batched runtime coalesces at :meth:`WireLayer.flush`, the
sender-cache truncation decision (code travels once per peer), the
rendezvous staging ring, and the credit window.

Credit-based flow control (the progress-engine half lives in
:mod:`repro.core.pe.progress`): each framed PUT consumes one receive
credit at the destination; when ``credit_window`` is set and the window is
exhausted, further *data* frames queue locally in FIFO order instead of
flooding a slow peer's receive buffer.  Credits return when the receiver's
progress engine processes the frames, and the sender's next
:meth:`pump` (called from its own poll/flush) drains the queue.  Control
frames — PUBLISH hops and rendezvous descriptors — never consume credits:
they are small, latency-critical, and starving them behind bulk data is
exactly the priority inversion the lane/credit design removes.
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING

import numpy as np

from ..frame import Frame, FrameFlags, FrameKind, coalesce, pack_rndv, rndv_region
from ..transport import EndpointDead, Fabric, RegionWrite

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from ..cache import SenderCache
    from ..transport import Endpoint
    from .source import IFunc

# rendezvous staging ring depth: outstanding staged RETURN payloads per PE
# before the oldest registration is reclaimed (bounds pinned memory the way
# a real transport bounds its rendezvous buffer pool)
RNDV_STAGING_DEPTH = 1024


def is_control(kind: int, flags: int) -> bool:
    """The lane classification both ends of the wire agree on: PUBLISH hop
    frames and rendezvous descriptors are control traffic (small, latency-
    critical); everything else — ifunc payloads, RETURN data, AMs — is
    bulk data."""
    return bool(flags & FrameFlags.HOP) or kind == FrameKind.RNDV


class WireLayer:
    """Frame egress for one PE: queues, credits, coalescing, staging."""

    def __init__(
        self,
        name: str,
        fabric: Fabric,
        endpoint: "Endpoint",
        sender_cache: "SenderCache",
        stats,
        peers: list[str],
    ) -> None:
        self.name = name
        self.fabric = fabric
        self.endpoint = endpoint
        self.sender_cache = sender_cache
        self.stats = stats  # the PE's PEStats (shared across layers)
        self.peers = peers  # shared list reference (facade owns it)
        self.batching = False  # batched runtime: queue sends for flush()
        self.caching_enabled = True  # benchmark switch: uncached mode
        self.credit_window = 0  # 0 = flow control off (unlimited window)
        self._seq = 0
        self._sendq: dict[str, list[Frame]] = {}  # per-destination pending frames
        self._regionq: dict[str, list[RegionWrite]] = {}  # pending one-sided writes
        self._creditq: dict[str, deque[Frame]] = {}  # frames awaiting credits
        self._rndv_tokens: deque[str] = deque()  # staged rendezvous regions (ring)
        self._rndv_seq = 0

    # --- sequencing -------------------------------------------------------
    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # --- egress -----------------------------------------------------------
    def put_frame(self, dst: str, frame: Frame) -> int:
        """PUT a frame now, or queue it for the next :meth:`flush`.

        Returns wire bytes sent, or 0 when the frame was queued (the wire
        size of a queued frame is only known after coalescing).
        """
        if self.batching:
            self._sendq.setdefault(dst, []).append(frame)
            return 0
        return self.put_now(dst, frame)

    def put_now(self, dst: str, frame: Frame) -> int:
        """PUT one frame, honouring the credit window.

        Control frames (hop headers, rendezvous descriptors) always
        transmit; a data frame beyond the window — or behind earlier
        stalled frames, so per-destination FIFO order holds — queues
        locally and travels on a later :meth:`pump`.  Returns wire bytes
        sent (0 when credit-queued).
        """
        if not is_control(int(frame.kind), int(frame.flags)) and self.credit_window:
            stalled = self._creditq.get(dst)
            if stalled or not self._credit_ok(dst):
                self._creditq.setdefault(dst, deque()).append(frame)
                self.stats.credit_stalls += 1
                self.fabric.stats.credit_stalls += 1
                return 0
        return self._transmit(dst, frame)

    def _credit_ok(self, dst: str) -> bool:
        return self.fabric.credit_outstanding(self.name, dst) < self.credit_window

    def _transmit(self, dst: str, frame: Frame) -> int:
        if frame.kind in (FrameKind.ACTIVE_MESSAGE, FrameKind.RNDV):
            cached = True  # AM / rendezvous descriptors never carry code
        else:
            cached = self.caching_enabled and self.sender_cache.check_and_add(
                dst, frame.digest.hex(), len(frame.code)
            )
        wire = frame.wire_bytes(cached=cached)
        self.stats.sends += 1
        if not cached and frame.code:
            self.stats.code_sends += 1
        self.fabric.put(
            self.name,
            dst,
            wire,
            n_payloads=frame.n_payloads,
            kinds=frame.kind_breakdown(cached),
            hop=bool(frame.flags & FrameFlags.HOP),
        )
        return len(wire)

    def pump(self) -> int:
        """Transmit credit-stalled frames whose window reopened; returns
        the number sent.  A destination that died while frames were queued
        loses exactly its own queue (the fabric's loss model — those
        frames were in flight), counted in ``stats.credit_dropped``."""
        sent = 0
        for dst in list(self._creditq):
            q = self._creditq[dst]
            while q and self._credit_ok(dst):
                frame = q.popleft()
                try:
                    self._transmit(dst, frame)
                    sent += 1
                except EndpointDead:
                    self.stats.credit_dropped += 1 + len(q)
                    q.clear()
            if not q:
                del self._creditq[dst]
        return sent

    def queued_credit_frames(self, dst: str | None = None) -> int:
        if dst is not None:
            return len(self._creditq.get(dst, ()))
        return sum(len(q) for q in self._creditq.values())

    # --- one-sided writes -------------------------------------------------
    def put_region(self, dst: str, writes: list[RegionWrite]) -> None:
        """Issue (or, under batching, queue) a slab-write burst to one peer."""
        if self.batching:
            self._regionq.setdefault(dst, []).extend(writes)
        else:
            self.fabric.put_region_multi(self.name, dst, writes)

    # --- batched flush ----------------------------------------------------
    def flush(self) -> int:
        """Emit every queued frame and one-sided write burst.

        A burst of same-type frames to one peer travels as a single
        coalesced PUT (one ``alpha_us``, summed bytes); a burst of queued
        zero-copy slab writes to one peer travels as a single doorbell-
        batched WQE chain (one ``alpha_us``, one ``o_us`` per extra
        segment).  A failing destination (e.g. a killed endpoint) loses
        only its own traffic — every other destination's queue is still
        delivered, then the first error is re-raised.  Returns the number
        of wire operations issued.
        """
        puts = self.pump()
        queued, self._sendq = self._sendq, {}
        regionq, self._regionq = self._regionq, {}
        errors: list[Exception] = []
        for dst, frames in queued.items():
            # group by ifunc type AND payload size (AM payloads are caller-
            # defined and xrdma plen varies, so same-name frames can be
            # ragged — those travel as separate coalesced PUTs), preserving
            # first-seen order.  PUBLISH hop frames never coalesce: each
            # carries its own per-edge path header.
            groups: dict[tuple[int, str, bytes, int, int], list[Frame]] = {}
            for f in frames:
                key = (
                    int(f.kind), f.name, f.digest, len(f.payload),
                    int(f.flags) & FrameFlags.HOP,
                )
                groups.setdefault(key, []).append(f)
            for key, members in groups.items():
                batch = [coalesce(members)] if not key[4] else members
                for frame in batch:
                    try:
                        if self.put_now(dst, frame):
                            puts += 1
                    except Exception as e:  # noqa: BLE001 - deliver the rest first
                        errors.append(e)
        for dst, writes in regionq.items():
            try:
                self.fabric.put_region_multi(self.name, dst, writes)
                puts += 1
            except Exception as e:  # noqa: BLE001 - deliver the rest first
                errors.append(e)
        if puts:
            self.stats.flushes += 1
        if errors:
            raise errors[0]
        return puts

    # --- rendezvous staging (sender side) ---------------------------------
    def rndv_send(self, dst: str, ifn: "IFunc", pay: np.ndarray) -> None:
        """Rendezvous RETURN: stage the payload in a source-registered
        region and frame only the 16-byte descriptor; the requester pulls
        the data with a one-sided GET (cost ``2*alpha + n/beta``, correct
        when the payload dwarfs ``2*alpha``)."""
        token = self._rndv_seq
        self._rndv_seq += 1
        staging = rndv_region(self.name, token)
        # explicit copy: `pay` may be a view into a whole batched action
        # matrix, and registering the view would pin that matrix in the
        # staging ring long after the dispatch that produced it
        data = np.array(pay, np.int32)
        self.endpoint.register_region(staging, data)
        self._rndv_tokens.append(staging)
        while len(self._rndv_tokens) > RNDV_STAGING_DEPTH:
            self.endpoint.unregister_region(self._rndv_tokens.popleft())
        desc = pack_rndv(self.peers.index(self.name), token, data.nbytes)
        self.put_frame(
            dst,
            Frame(kind=FrameKind.RNDV, name=ifn.name, payload=desc, seq=self.next_seq()),
        )

    def fetch_rndv(self, src: str, token: int, nbytes: int) -> bytes:
        """Pull one staged rendezvous payload from ``src`` (receiver side)."""
        return self.fabric.get(self.name, src, rndv_region(src, token), 0, nbytes)
