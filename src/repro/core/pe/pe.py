"""The PE facade: one processing element, wired from the four runtime layers.

``PE`` composes (and owns the state shared by) the layered runtime:

* :class:`repro.core.pe.wire.WireLayer` — frame egress, batching queues,
  coalesced flush, rendezvous staging, per-peer credit windows.
* :class:`repro.core.pe.codecache.CodeCacheLayer` — install arriving code,
  digest validation, bucketed batched executables.
* :class:`repro.core.pe.exec.ExecLayer` — invoke, the masked-scan update
  ABI, action application.
* :class:`repro.core.pe.progress.ProgressEngine` — the poll loop: priority
  lanes, per-poll budget, credit return.

The facade itself keeps the *policy* the layers are parameterized by —
source registry, dataplane protocol selection, propagation topology,
capability/region linking — plus the source-side API (``send_ifunc``,
``publish_ifunc``, ``submit``).  Everything here is re-exported through
:mod:`repro.core.ifunc`, whose import surface is guaranteed stable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import numpy as np

from ..bitcode import platform_of
from ..cache import CachedExecutable, SenderCache, TargetCodeCache
from ..dataplane import DataPlaneConfig
from ..frame import Frame, FrameFlags, FrameKind, HopHeader, ProtocolError, pack_hop
from ..propagate import PropagationConfig, tree_children
from ..reliability import ReliabilityConfig
from ..transport import Capability, EndpointDead, Fabric
from ..verify import SandboxConfig, Verifier
from .codecache import CodeCacheLayer
from .cq import CompletionQueue, GatherFuture
from .exec import ExecLayer
from .progress import ProgressEngine
from .source import IFunc, Toolchain
from .wire import WireLayer


@dataclass
class PEStats:
    msgs: int = 0
    ifunc_installs: int = 0
    invokes: int = 0  # XLA dispatches (a batched dispatch counts once)
    batched_invokes: int = 0  # dispatches that retired >1 payload
    invoked_payloads: int = 0  # payloads retired across all dispatches
    forwards: int = 0
    returns: int = 0
    spawns: int = 0
    sends: int = 0  # frames this PE PUT on the wire (any kind)
    code_sends: int = 0  # of those, frames that carried code bytes
    zerocopy_returns: int = 0  # RETURNs that went one-sided (no frame/dispatch)
    rndv_returns: int = 0  # RETURNs that went descriptor + GET
    am_handled: int = 0
    flushes: int = 0
    # --- credit-based flow control (wire layer) ---
    credit_stalls: int = 0  # sends deferred because the peer window was full
    credit_dropped: int = 0  # stalled frames dropped when their peer died
    # --- recursive propagation (PUBLISH hops) ---
    publishes: int = 0  # hop frames sent (root fan-out + re-publishes)
    publish_handled: int = 0  # publishes accepted (installed/invoked) here
    publish_dupes: int = 0  # re-delivered publishes dropped by the dedup key
    publish_stopped_ttl: int = 0  # had children but no hop budget left
    publish_send_failures: int = 0  # child endpoint dead at re-publish time
    # --- reliability layer (sender: wire.py / receiver: progress.py) ---
    retransmits: int = 0  # unacked frames resent after an rto expiry
    frames_acked: int = 0  # unacked frames retired by a cumulative ack
    acks_sent: int = 0  # standalone ACK frames emitted (piggybacks are free)
    acks_received: int = 0  # standalone ACK frames consumed at ingest
    dup_frames_dropped: int = 0  # duplicate deliveries dropped at the seq gate
    frames_held_ooo: int = 0  # out-of-order arrivals parked for a gap
    peers_suspected: int = 0  # retransmit budget exhausted -> suspect
    peers_declared_dead: int = 0  # suspects the failure detector gave up on
    sends_to_dead: int = 0  # PUTs absorbed against a dead endpoint
    unacked_dropped: int = 0  # retransmit-queue frames dropped with a dead peer
    region_write_failures: int = 0  # one-sided bursts absorbed against a dead peer
    rndv_dead_pulls: int = 0  # rendezvous pulls whose source died pre-GET
    jit_ms_total: float = 0.0
    # --- multi-tenant QoS (wire layer) ---
    tenant_sends: dict = field(default_factory=dict)  # frames sent, per tenant
    tenant_stalls: dict = field(default_factory=dict)  # budget stalls, per tenant
    # --- unified refusal accounting (publish path + verifier + quotas) ---
    # reason -> count; reasons: publish_ttl / publish_cycle / publish_digest
    # (the PR 4 publish-path refusals), verify_quarantined / verify_ops /
    # verify_region / verify_action / verify_ttl (install-time verifier),
    # quota_payload / quota_invokes / quota_actions / quota_fanout (runtime
    # sandbox), quarantine_drop (queued frames purged on quarantine)
    refusals: dict = field(default_factory=dict)

    def refuse(self, reason: str, n: int = 1) -> None:
        self.refusals[reason] = self.refusals.get(reason, 0) + n

    # legacy spellings of the PR 4 publish-path counters, now keys in the
    # unified dict (read-only: writers must go through refuse())
    @property
    def publish_refused_ttl(self) -> int:
        return self.refusals.get("publish_ttl", 0)

    @property
    def publish_refused_cycle(self) -> int:
        return self.refusals.get("publish_cycle", 0)

    @property
    def publish_refused_digest(self) -> int:
        return self.refusals.get("publish_digest", 0)

    def bump_tenant(self, which: str, tenant: str, n: int = 1) -> None:
        d = self.tenant_sends if which == "sends" else self.tenant_stalls
        d[tenant] = d.get(tenant, 0) + n

    def as_dict(self) -> dict[str, float]:
        d = self.__dict__.copy()
        d["jit_ms_total"] = round(self.jit_ms_total, 3)
        d["tenant_sends"] = dict(self.tenant_sends)
        d["tenant_stalls"] = dict(self.tenant_stalls)
        d["refusals"] = dict(self.refusals)
        return d


class PE:
    """A processing element: endpoint + layered ifunc runtime + local state.

    ``triple`` models the ISA/uarch (hosts are ``cpu-host`` Xeons, DPUs are
    ``cpu-bf2`` BlueField Arm cores, A64FX nodes ``cpu-a64fx``); on this
    container all execute on the CPU backend, but triple *mismatch logic* is
    real: binary ifuncs require an exact triple, fat-bitcode falls back by
    platform and re-optimizes locally (Sec. III-C).

    Runtime knobs (all default to the pre-layered behaviour):

    * ``batching`` — coalesced sends + grouped single-dispatch polls.
    * ``caching_enabled`` — sender-cache code truncation (benchmark switch).
    * ``credit_window`` — per-peer send window (in payloads); 0 disables
      flow control.
    * ``lanes`` — control-before-data drain priority in the progress engine.
    * ``poll_budget`` — max *payloads* processed per poll (a coalesced
      frame counts as its packed payload count and is consumed partially
      when it exceeds the remainder); ``None`` drains all.
    """

    def __init__(
        self,
        name: str,
        fabric: Fabric,
        triple: str = "cpu-host",
        toolchain: Toolchain | None = None,
        peers: Sequence[str] = (),
    ) -> None:
        platform_of(triple)  # validate
        self.name = name
        self.triple = triple
        self.fabric = fabric
        self.endpoint = fabric.connect(name)
        # advertise the platform/capability vector at connect time — the
        # placement layer and hetero wire pricing read it from the fabric;
        # a restarted PE re-advertises here with a fresh epoch
        self.capability = fabric.advertise(
            name, Capability.for_triple(triple, platform_of(triple))
        )
        self.toolchain = toolchain
        self.peers: list[str] = list(peers)
        self.target_cache = TargetCodeCache()
        self.sender_cache = SenderCache()
        self.source_registry: dict[str, IFunc] = {}
        self.am_table: dict[str, Callable[["PE", bytes], None]] = {}
        self.caps: dict[str, np.ndarray] = {}
        self.completed: list[np.ndarray] = []
        self.stats = PEStats()
        self.dataplane = DataPlaneConfig()  # protocol selection (default: framed)
        self.propagation = PropagationConfig()  # tree multicast policy
        self._region_dev: dict[str, tuple[int, jax.Array]] = {}
        self._pub_seq = 0  # publish ids minted by this PE as a tree root
        # completion queues draining into this PE (quarantine sweeps them)
        self.completion_queues: list[CompletionQueue] = []
        # --- the layers (constructed over the shared state above) ---
        self.verifier = Verifier(name, self.stats)
        self.verifier.local_cleanup = self._quarantine_cleanup
        self.wire = WireLayer(
            name, fabric, self.endpoint, self.sender_cache, self.stats, self.peers
        )
        self.codecache = CodeCacheLayer(
            name, triple, self.target_cache, self.stats, self.verifier
        )
        self.execl = ExecLayer(self, self.codecache, self.stats, self.verifier)
        self.progress = ProgressEngine(
            self, self.wire, self.codecache, self.execl, self.stats
        )
        # reliability cross-wiring: the wire layer piggybacks the progress
        # engine's cumulative acks, and budget exhaustion feeds the
        # progress engine's failure detector
        self.wire.ack_provider = self.progress.cum_for
        self.wire.on_suspect = self._on_peer_suspect
        self.on_peer_dead_callbacks: list[Callable[[str], None]] = []

    # --- runtime knobs (delegated to the owning layer) ---------------------
    @property
    def batching(self) -> bool:
        """Batched runtime: coalesced sends + grouped polls (wire layer)."""
        return self.wire.batching

    @batching.setter
    def batching(self, enabled: bool) -> None:
        self.wire.batching = enabled

    @property
    def caching_enabled(self) -> bool:
        """Sender-cache truncation on/off (benchmark switch, wire layer)."""
        return self.wire.caching_enabled

    @caching_enabled.setter
    def caching_enabled(self, enabled: bool) -> None:
        self.wire.caching_enabled = enabled

    @property
    def credit_window(self) -> int:
        """Per-peer credit window for data frames; 0 = flow control off."""
        return self.wire.credit_window

    @credit_window.setter
    def credit_window(self, window: int) -> None:
        self.wire.credit_window = int(window)

    @property
    def lanes(self) -> bool:
        """Control-before-data drain priority (progress engine)."""
        return self.progress.lanes

    @lanes.setter
    def lanes(self, enabled: bool) -> None:
        self.progress.lanes = enabled

    @property
    def poll_budget(self) -> int | None:
        """Payloads processed per poll (coalesced frames count as their
        packed payload count); ``None`` drains everything."""
        return self.progress.budget

    @poll_budget.setter
    def poll_budget(self, budget: int | None) -> None:
        self.progress.budget = budget

    @property
    def reliability(self) -> ReliabilityConfig:
        """The reliable-delivery / failure-recovery policy (see
        :class:`repro.core.reliability.ReliabilityConfig`); the default
        (disabled) config is the pre-reliability runtime bit-for-bit."""
        return self.wire.reliability

    @reliability.setter
    def reliability(self, config: ReliabilityConfig | None) -> None:
        cfg = config or ReliabilityConfig()
        self.wire.reliability = cfg
        self.progress.detector.monitor.max_misses = cfg.max_misses

    @property
    def sandbox(self) -> SandboxConfig:
        """The safe-code-injection policy (see
        :class:`repro.core.verify.SandboxConfig`); the default (disabled)
        config is the unverified runtime bit-for-bit."""
        return self.verifier.config

    @sandbox.setter
    def sandbox(self, config: SandboxConfig | None) -> None:
        self.verifier.config = config or SandboxConfig()

    # --- failure handling ---------------------------------------------------
    def _on_peer_suspect(self, peer: str) -> None:
        self.progress.detector.suspect(peer, self.progress.tick)

    def on_peer_dead(self, peer: str) -> None:
        """The failure detector declared ``peer`` dead: clear every piece
        of state entangled with it, exactly the invalidation
        :meth:`repro.core.cluster.Cluster.restart_server` performs —
        retransmit/credit queues, seq streams, sender-cache rows, publish
        dedup for its root index, fabric credits — then notify listeners
        (e.g. a service that must degrade or resubmit its futures)."""
        self.stats.peers_declared_dead += 1
        self.forget_peer_state(peer, forgive=False)
        for cb in list(self.on_peer_dead_callbacks):
            cb(peer)

    def forget_peer_state(self, peer: str, forgive: bool = True) -> None:
        """Drop all per-peer runtime state for ``peer`` (both wire and
        progress halves).  ``forgive=True`` additionally clears the
        failure detector's verdict — the restart case, where the peer's
        next life must start with a clean slate."""
        self.wire.forget_peer(peer)
        self.progress.forget_src(peer)
        self.sender_cache.invalidate_endpoint(peer)
        if peer in self.peers:
            self.forget_publisher(self.peer_index(peer))
        self.fabric.clear_peer_credits(self.name, peer)
        if forgive:
            self.progress.detector.forgive(peer)

    def _quarantine_cleanup(self, digest: str, name: str) -> None:
        """Local teardown for one quarantined digest (the verifier's
        ``local_cleanup`` hook): uninstall the compiled executable, forget
        every sender-cache truncation belief, purge queued frames still
        carrying the digest, and degrade in-flight CQ futures waiting on
        it via the validity-mask path instead of letting them hang."""
        exe = self.target_cache.lookup_digest(digest)
        if exe is not None:
            self.target_cache.deregister(exe.name)
        elif name:
            held = self.target_cache.lookup(name)
            if held is not None and held.digest == digest:
                self.target_cache.deregister(name)
        self.sender_cache.invalidate_digest(digest)
        dropped = self.wire.drop_queued_digest(bytes.fromhex(digest))
        if dropped:
            self.stats.refuse("quarantine_drop", dropped)
        for cq in self.completion_queues:
            for fut in list(cq._inflight.values()):
                if fut.code_digest == digest:
                    fut.poison()

    # --- local state ------------------------------------------------------
    def register_region(self, name: str, arr: np.ndarray) -> None:
        self.endpoint.register_region(name, arr)

    def region(self, name: str) -> np.ndarray:
        return self.endpoint.regions[name]

    def region_device(self, name: str) -> jax.Array:
        """Device-resident view of a region, cached until the region is
        rewritten (read-mostly shards stay resident, like RDMA-registered
        memory staying pinned).  Versioning lives on the endpoint so that
        *remote* one-sided writes (zero-copy RETURNs landing in a slab)
        also invalidate the device mirror — otherwise a framed fold could
        read a stale snapshot and overwrite bytes the fabric just wrote."""
        ver = self.endpoint.region_ver.get(name, 0)
        hit = self._region_dev.get(name)
        if hit is not None and hit[0] == ver:
            return hit[1]
        dev = jax.device_put(self.endpoint.regions[name])
        self._region_dev[name] = (ver, dev)
        return dev

    def write_region(self, name: str, value: np.ndarray) -> None:
        np.copyto(self.endpoint.regions[name], value)
        self.endpoint.touch_region(name)

    def register_cap(self, name: str, arr: np.ndarray) -> None:
        self.caps[name] = np.asarray(arr)

    # --- source side --------------------------------------------------------
    def register_source(self, ifunc: IFunc) -> IFunc:
        self.source_registry[ifunc.name] = ifunc
        return ifunc

    def resolve_source(self, name: str) -> IFunc:
        got = self.source_registry.get(name)
        if got is None:
            if self.toolchain is None:
                raise ProtocolError(f"{self.name}: no source artifact for {name!r}")
            got = self.register_source(self.toolchain.lookup(name))
        return got

    # stable alias: pre-layering callers reached the private spelling
    _resolve_source = resolve_source

    def send_ifunc(
        self,
        dst: str,
        name: str,
        payload: np.ndarray | bytes,
        *,
        express: bool = False,
        tenant: str | None = None,
    ) -> int:
        """Create and PUT an ifunc message; returns wire bytes sent.

        ``express`` flags the frame for control-lane drain priority at the
        receiver (it still consumes credits); ``tenant`` charges the frame
        against that tenant's credit budget and traffic counters."""
        ifunc = self.resolve_source(name)
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        frame = ifunc.make_frame(pay, seq=self.wire.next_seq())
        if express:
            frame.flags = int(frame.flags) | int(FrameFlags.EXPRESS)
        frame.tenant = tenant
        return self.wire.put_frame(dst, frame)

    def send_am(self, dst: str, name: str, payload: np.ndarray | bytes) -> int:
        """Active Message baseline: payload-only frame, handler pre-deployed."""
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        frame = Frame(
            kind=FrameKind.ACTIVE_MESSAGE, name=name, payload=pay,
            seq=self.wire.next_seq(),
        )
        return self.wire.put_frame(dst, frame)

    def peer_index(self, name: str) -> int:
        """This cluster's dense peer index for ``name`` (the index space
        X-RDMA action vectors use for ``dst``/``requester``)."""
        return self.peers.index(name)

    # --- recursive propagation: source side ---------------------------------
    def publish_ifunc(
        self,
        name: str,
        payload: np.ndarray | bytes = b"",
        *,
        ttl: int | None = None,
        config: PropagationConfig | None = None,
    ) -> list[str]:
        """Publish an ifunc down this PE's spanning tree (paper Sec. I:
        code that "recursively propagate[s] itself to other remote
        machines").

        Sends one PUBLISH hop frame to each of this PE's *tree children*
        only — O(log n) for the binomial default — and every child that
        installs the code re-publishes it to its own children, so coverage
        reaches all n peers without the root sending n frames.  An empty
        ``payload`` is a pure code distribution (install + re-publish, no
        invoke); a non-empty payload is invoked at every covered PE (the
        broadcast the multi-hop collectives build on).  Returns the peer
        names actually sent to.
        """
        cfg = config or self.propagation
        ifunc = self.resolve_source(name)
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        me = self.peer_index(self.name)
        self._pub_seq += 1
        hop = HopHeader(
            ttl=ttl if ttl is not None else cfg.ttl,
            root=me,
            pub_id=self._pub_seq,
            path=(me,),
            k=cfg.k_code,
        )
        return self.publish_to_children(
            hop, ifunc.kind, name, pay, ifunc.code_bytes, ifunc.deps, ifunc.digest
        )

    def forget_publisher(self, root: int) -> None:
        """Drop publish-dedup state for one root peer index (see
        :meth:`repro.core.pe.progress.ProgressEngine.forget_publisher`)."""
        self.progress.forget_publisher(root)

    def publish_to(
        self,
        dst: str,
        name: str,
        payload: np.ndarray | bytes = b"",
        *,
        ttl: int = 1,
    ) -> None:
        """Publish directly to one named peer (no tree fan-out at this end;
        the receiver still re-publishes if ``ttl`` allows).  This is the
        re-parenting primitive: when a mid-tree PE dies, the root re-covers
        the orphaned subtree by publishing straight to its survivors."""
        ifunc = self.resolve_source(name)
        # a direct publish exists because the normal delivery is in doubt —
        # drop our cache belief so the code travels again (a dropped hop
        # upstream may have warmed this entry without the bytes ever landing)
        self.sender_cache.forget(dst, ifunc.digest.hex())
        pay = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        me = self.peer_index(self.name)
        self._pub_seq += 1
        hop = HopHeader(
            ttl=ttl, root=me, pub_id=self._pub_seq, path=(me,),
            k=self.propagation.k_code,
        )
        self.send_publish(
            dst, hop, ifunc.kind, name, pay, ifunc.code_bytes, ifunc.deps,
            ifunc.digest,
        )

    def publish_to_children(
        self,
        hop: HopHeader,
        kind: FrameKind,
        name: str,
        inner: bytes,
        code: bytes,
        deps: tuple[str, ...],
        digest: bytes,
    ) -> list[str]:
        """Send one hop frame per tree child; a dead child loses only its
        own subtree's frame (counted), the rest of the fan-out proceeds."""
        me = self.peer_index(self.name)
        sent: list[str] = []
        for child in tree_children(hop.k, hop.root, me, len(self.peers)):
            dst = self.peers[child]
            try:
                self.send_publish(dst, hop, kind, name, inner, code, deps, digest)
                sent.append(dst)
            except EndpointDead:
                self.stats.publish_send_failures += 1
                # the PUT never landed: roll back the cache entry the send
                # just added, or a later re-publish would wrongly truncate
                self.sender_cache.forget(dst, digest.hex())
        return sent

    def send_publish(
        self,
        dst: str,
        hop: HopHeader,
        kind: FrameKind,
        name: str,
        inner: bytes,
        code: bytes,
        deps: tuple[str, ...],
        digest: bytes,
    ) -> None:
        frame = Frame(
            kind=kind,
            name=name,
            payload=pack_hop(hop) + inner,
            code=code,
            deps=deps,
            digest=digest,
            seq=self.wire.next_seq(),
            flags=FrameFlags.HOP,
        )
        self.stats.publishes += 1
        # publishes bypass the batching send queue even when batching is on:
        # hop frames never coalesce (per-edge path headers), and a dead
        # child must surface EndpointDead HERE — synchronously — so the
        # fan-out's per-child containment and sender-cache rollback apply
        # identically on both runtimes (a queued send would defer the error
        # to flush() and skip both).
        self.wire.put_now(dst, frame)

    # --- completion-tracked submissions -------------------------------------
    def submit(
        self,
        dst: str,
        name: str,
        body: np.ndarray,
        queue: CompletionQueue,
        expected: int,
        *,
        express: bool = False,
        tenant: str | None = None,
        slot_quota: int = 0,
    ) -> GatherFuture | None:
        """Submit a completion-tracked X-RDMA op and return its future —
        or ``None`` (would-block) when every completion-queue slot is in
        flight, so a saturated queue backpressures admission instead of
        raising mid-batch.

        Multi-tenant QoS: ``tenant`` tags the request's frames with the
        budget they charge, ``express`` requests control-lane drain
        priority, and ``slot_quota`` caps how many CQ slots this tenant
        may hold concurrently (the same would-block ``None`` contract as
        global saturation, so per-tenant admission control composes with
        the existing backpressure loop).

        The completion-queue wire convention: the runtime prepends the
        routing header ``[requester, slot, epoch]`` to the caller's
        ``body``, so every shipped op under this protocol sees
        ``payload[0]`` = the requester's peer index, ``payload[1]`` = the
        slot its RETURNs must target, and ``payload[2]`` = the slot's
        generation tag (RETURN code drops stale generations, making slot
        recycling safe under at-least-once delivery).  ``expected`` is how
        many result units (e.g. resolved rows) must arrive — possibly via
        several out-of-order RETURNs from different PEs — before the
        future reads done.
        """
        alloc = queue.try_alloc(tag=tenant, quota=slot_quota)
        if alloc is None:
            return None
        slot, epoch = alloc
        hdr = np.array([self.peer_index(self.name), slot, epoch], np.int32)
        payload = np.concatenate([hdr, np.asarray(body, np.int32)])
        rel = self.reliability
        fut = GatherFuture(
            queue=queue, slot=slot, expected=int(expected),
            submit_tick=queue.ticks,
            deadline=rel.future_deadline if rel.enabled else 0,
            code_digest=self.resolve_source(name).digest.hex(),
        )
        queue._inflight[slot] = fut
        try:
            self.send_ifunc(dst, name, payload, express=express, tenant=tenant)
        except Exception:
            fut.cancel()  # a failed send must not leak the slot
            raise
        return fut

    # --- progress ----------------------------------------------------------
    def poll(self, max_msgs: int | None = None) -> int:
        """Drive the progress engine one step (see
        :meth:`repro.core.pe.progress.ProgressEngine.poll`)."""
        return self.progress.poll(max_msgs)

    def flush(self) -> int:
        """Emit every queued frame and one-sided write burst (see
        :meth:`repro.core.pe.wire.WireLayer.flush`)."""
        return self.wire.flush()

    # --- action sinks (called by the exec layer) ----------------------------
    def forward_ifunc(self, dst: str, exe: CachedExecutable, pay: np.ndarray) -> None:
        """FORWARD: re-inject *this same ifunc*, code and all, to ``dst``."""
        frame = Frame(
            kind=FrameKind(exe.kind),
            name=exe.name,
            payload=pay.tobytes(),
            code=exe.extras["code"],
            deps=exe.deps,
            digest=bytes.fromhex(exe.digest),
            seq=self.wire.next_seq(),
        )
        self.wire.put_frame(dst, frame)

    def return_payload(self, dst: str, target: str, pay: np.ndarray) -> None:
        """Ship one RETURN payload under the data plane's protocol selection.

        ``framed`` re-injects the RETURN ifunc (PR 1 path, coalescable);
        ``zerocopy`` writes the payload one-sidedly into the requester's
        registered slab per the ifunc's :class:`SlabLayout` and bumps the
        doorbell — no frame, no requester-side dispatch; ``rendezvous``
        stages the payload locally and frames only a 16-byte descriptor
        the requester GETs against.
        """
        ifn = self.resolve_source(target)
        cached = self.caching_enabled and self.sender_cache.has(dst, ifn.digest.hex())
        proto = self.dataplane.select(
            int(pay.nbytes), slab=ifn.slab is not None, code_cached=cached
        )
        tracer = getattr(self.fabric, "tracer", None)
        if tracer is not None:
            # `zc` is what a zero-copy write burst of this RETURN would
            # carry (data + doorbell words), -1 when the ifunc has no slab
            # — the counterfactual the autotuner's protocol re-selection
            # needs even when the live run framed it
            if ifn.slab is not None:
                plan = ifn.slab.plan(np.ascontiguousarray(pay, np.int32))
                zc = sum(len(w.data) for w in plan) + 4 * sum(
                    1 for w in plan if w.doorbell is not None
                )
            else:
                zc = -1
            tracer.emit(
                "ret", src=self.name, dst=dst, name=target,
                n=int(pay.nbytes), zc=zc, cached=cached, proto=proto,
            )
        if proto == "zerocopy":
            self.stats.zerocopy_returns += 1
            writes = ifn.slab.plan(np.ascontiguousarray(pay, np.int32))
            self.wire.put_region(dst, writes)
        elif proto == "rendezvous":
            self.stats.rndv_returns += 1
            self.wire.rndv_send(dst, ifn, pay)
        else:
            self.send_ifunc(dst, target, pay)

    def publish_self(self, dst: str, exe: CachedExecutable, pay: np.ndarray) -> None:
        """A_PUBLISH: shipped code re-publishing *itself* — ``pay[0]`` is
        the hop budget it grants, the rest travels as the published
        payload; the paper's "recursively propagate itself" emitted by the
        code, not the runtime."""
        self.verifier.check_publish_ttl(exe, int(pay[0]))
        me = self.peer_index(self.name)
        self._pub_seq += 1
        hop = HopHeader(
            ttl=int(pay[0]),
            root=me,
            pub_id=self._pub_seq,
            path=(me,),
            k=self.propagation.k_code,
        )
        try:
            self.send_publish(
                dst,
                hop,
                FrameKind(exe.kind),
                exe.name,
                np.ascontiguousarray(pay[1:]).tobytes(),
                exe.extras.get("code", b""),
                exe.deps,
                bytes.fromhex(exe.digest),
            )
        except EndpointDead:
            self.stats.publish_send_failures += 1
