"""Source layer: ifunc handles and the toolchain artifact registry.

Source side, an :class:`IFunc` couples an entry function (a pure JAX
function) with its fat-bitcode archive (``jax.export`` blobs for every
toolchain target, Sec. III-C) and its dependency list (Sec. III-C
``.deps``).  Nothing here touches the wire: frames are *built* by
:meth:`IFunc.make_frame` and moved by the wire layer
(:mod:`repro.core.pe.wire`).

Dependency tags (the wire ``DEPS`` list, Sec. III-C):

* ``abi:<update|xrdma|propagate|pure>`` — invoke convention (see
  :mod:`repro.core.pe.exec` for the action protocol).
* ``region:<name>`` — link the PE's registered memory region as an argument.
* ``cap:<name>``    — link a host capability (small constant array, e.g.
  shard metadata) as an argument.
* ``returns:<ifunc>`` / ``spawn:<ifunc>`` — ifunc types this code may emit;
  resolved through the PE's source registry / toolchain at action time.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax

from ..bitcode import DEFAULT_TOOLCHAIN_TARGETS, FatBitcode
from ..dataplane import SlabLayout
from ..frame import Frame, FrameKind


@dataclass
class IFunc:
    """Source-side handle: name + fat-bitcode + deps (paper Fig. 1 register)."""

    name: str
    fat: FatBitcode
    deps: tuple[str, ...]
    abi: str
    payload_aval: jax.ShapeDtypeStruct
    kind: FrameKind = FrameKind.BITCODE
    # Optional zero-copy layout for RETURN-type ifuncs: lets a sender map
    # this ifunc's payload onto one-sided slab writes instead of a frame.
    # Sender-side only — never travels on the wire, never affects digest.
    slab: SlabLayout | None = None

    @property
    def code_bytes(self) -> bytes:
        return self.fat.to_bytes()

    @property
    def digest(self) -> bytes:
        return hashlib.sha256(self.code_bytes).digest()

    @classmethod
    def build(
        cls,
        name: str,
        fn: Callable[..., Any],
        payload_aval: jax.ShapeDtypeStruct,
        dep_avals: Sequence[jax.ShapeDtypeStruct] = (),
        deps: Sequence[str] = (),
        abi: str = "pure",
        targets: Sequence[str] = DEFAULT_TOOLCHAIN_TARGETS,
        kind: FrameKind = FrameKind.BITCODE,
        fn_by_platform=None,
        slab: SlabLayout | None = None,
    ) -> "IFunc":
        """Run the Three-Chains toolchain: cross-compile ``fn`` for every
        target triple into a fat-bitcode archive.

        ``kind=BINARY`` models Sec. III-B: the archive holds exactly one
        slice (the source machine's own triple) and the target will refuse
        a triple mismatch instead of re-lowering.  ``fn_by_platform``
        optionally swaps the entry per platform (see FatBitcode.build).
        """
        if kind == FrameKind.BINARY and len(targets) != 1:
            raise ValueError("binary ifuncs are single-triple by definition")
        fat = FatBitcode.build(
            fn, (payload_aval, *dep_avals), targets=targets,
            fn_by_platform=fn_by_platform,
        )
        wire_deps = (f"abi:{abi}", *deps)
        return cls(
            name=name,
            fat=fat,
            deps=wire_deps,
            abi=abi,
            payload_aval=payload_aval,
            kind=kind,
            slab=slab,
        )

    def make_frame(self, payload: bytes, seq: int = 0) -> Frame:
        return Frame(
            kind=self.kind,
            name=self.name,
            payload=payload,
            code=self.code_bytes,
            deps=self.deps,
            digest=self.digest,
            seq=seq,
        )


class Toolchain:
    """The shared filesystem of toolchain artifacts (paper Fig. 1: generated
    files 'placed in a directory that can be located by Three-Chains').

    Any PE may *register as a sender* from here — that is how a server that
    received a Chaser can emit a ReturnResult it never received over the
    wire, just as the paper's SPMD app binaries can register any ifunc
    library present on their local disk.  What is NOT pre-deployed is the
    target-side executable: code still travels in frames and installs via
    the cache protocol.
    """

    def __init__(self) -> None:
        self._artifacts: dict[str, IFunc] = {}

    def publish(self, ifunc: IFunc) -> IFunc:
        self._artifacts[ifunc.name] = ifunc
        return ifunc

    def lookup(self, name: str) -> IFunc:
        return self._artifacts[name]

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._artifacts))
