"""Progress engine: the poll loop that drives a PE forward.

This is the paper's 'UCX ifunc polling function' grown into an explicit
runtime layer (HAM keeps its messaging progress separate from execution
for the same reason): one place that ingests arrived wire buffers, decides
*what to work on next*, routes frames to the code-cache / execution
layers, and returns flow-control credits to senders as receive buffers
retire.

Two scheduling features beyond the flat FIFO drain:

* **Priority lanes** (``lanes=True``): arrivals are classified at ingest —
  PUBLISH hop frames and rendezvous descriptors into the *control* lane,
  everything else (ifunc payloads, bulk RETURN data, AMs) into the *data*
  lane — and the control lane drains first.  Under overload a code
  distribution no longer queues behind thousands of bulk RETURNs
  (benchmarks/overload.py measures exactly this inversion).
* **Poll budget** (``budget=N``): at most N *payloads* are processed per
  poll — a coalesced frame counts as its packed payload count, and a frame
  bigger than the remaining budget is consumed partially (the engine
  remembers its offset), so one giant burst cannot blow through the bound.
  The remainder stays queued in the engine's lanes (receive buffers still
  held, so their credits stay consumed — which is what makes the
  sender-side window in :mod:`repro.core.pe.wire` an honest backpressure
  signal).  ``budget=None`` (default) drains everything, which is
  bit-compatible with the pre-layered runtime.

Credits: every framed PUT consumed one receive credit at this endpoint;
the engine returns it to the sender the moment the frame is taken for
processing.  The engine also pumps this PE's own credit-stalled sends at
the end of every poll, so a reopened window is used without waiting for
an unrelated flush.
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING

from ..cache import CachedExecutable
from ..frame import (
    CorruptFrame,
    FrameFlags,
    FrameKind,
    ProtocolError,
    peek_header,
    split_hop,
    split_payloads,
    unpack,
    unpack_rndv,
    uvarint_decode,
)
from ..liveness import HeartbeatMonitor
from ..propagate import tree_children
from ..transport import EndpointDead
from .codecache import ISAMismatch
from .wire import is_control

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from .codecache import CodeCacheLayer
    from .exec import ExecLayer
    from .wire import WireLayer


class FailureDetector:
    """Suspect-gated peer-death detection on the progress-engine tick.

    This folds :class:`repro.core.liveness.HeartbeatMonitor` into the
    poll loop: the tick counter is the clock (``interval_s=1`` tick), every
    ingested frame from a peer is its heartbeat, and — the gate — only
    peers the wire layer escalated to *suspect* (retransmit budget
    exhausted) are eligible to be declared dead after ``max_misses`` silent
    ticks.  A healthy-but-quiet peer is never a failure: with nothing
    unacked there is no evidence against it, so the monitor's timeout alone
    must not kill it.  ``declare_dead`` is the bypass for *definitive*
    evidence (a one-sided GET against freed memory).
    """

    def __init__(self, max_misses: int = 3) -> None:
        self.monitor = HeartbeatMonitor(interval_s=1.0, max_misses=max_misses)
        self.suspects: set[str] = set()

    @property
    def dead(self) -> set[str]:
        return self.monitor.dead

    def alive(self, name: str, tick: int) -> None:
        self.monitor.beat(name, now=float(tick))
        self.suspects.discard(name)

    def suspect(self, name: str, tick: int) -> None:
        self.suspects.add(name)
        self.monitor.last_seen.setdefault(name, float(tick))

    def declare_dead(self, name: str) -> bool:
        """Immediate death on definitive evidence; True if newly dead."""
        newly = name not in self.monitor.dead
        self.monitor.dead.add(name)
        self.suspects.add(name)
        return newly

    def check(self, tick: int) -> set[str]:
        """Peers newly declared dead at ``tick`` (suspects only)."""
        newly = self.monitor.check(now=float(tick))
        for name in list(newly):
            if name not in self.suspects:
                self.monitor.dead.discard(name)  # quiet, not suspect: spare
                newly.discard(name)
        return newly

    def forgive(self, name: str) -> None:
        """Forget a peer entirely (it restarted with a fresh identity)."""
        self.monitor.dead.discard(name)
        self.monitor.last_seen.pop(name, None)
        self.suspects.discard(name)


class ProgressEngine:
    """Poll-driven scheduler for one PE: lanes, budget, credits, routing."""

    def __init__(self, rt, wire: "WireLayer", codecache: "CodeCacheLayer",
                 execl: "ExecLayer", stats) -> None:
        self.rt = rt
        self.wire = wire
        self.codecache = codecache
        self.execl = execl
        self.stats = stats  # the PE's PEStats (shared across layers)
        self.lanes = False  # control-before-data drain priority
        self.budget: int | None = None  # payloads processed per poll (None = all)
        # lane entries are mutable [src, buf, consumed_payloads]: a frame
        # bigger than the remaining budget is consumed in pieces, and the
        # offset of the first unprocessed payload rides with the buffer
        self._control: deque[list] = deque()
        self._data: deque[list] = deque()
        self._seen_pubs: set[tuple[bytes, int, int]] = set()  # publish dedup
        # --- reliability (receiver half; sender half in wire.py) ---
        self.tick = 0  # the tick clock: one per poll while reliability is on
        self.detector = FailureDetector()
        # per-source receive state [cum, held]: ``cum`` the contiguous
        # ingest high-water mark (everything <= cum entered the lanes
        # exactly once, in order), ``held`` the out-of-order frames parked
        # until the gap before them fills
        self._recv: dict[str, list] = {}
        self._ack_owed: dict[str, int] = {}  # src -> tick the debt started
        # buffers consumed at the seq gate since the last poll returned
        # (dups dropped, ACKs absorbed, OOO frames parked): link progress
        # the idle detectors must see even though no lane entry resulted
        self._gate_progress = 0
        # publish dedup keys waiting to retire: (src, seq, key) retired
        # once the ack for seq has actually been stamped toward src
        self._pub_log: deque[tuple[str, int, tuple]] = deque()

    # --- lane bookkeeping --------------------------------------------------
    def _ingest(self) -> int:
        """Move arrived wire buffers from the endpoint inbox into the
        engine's lanes, classifying control vs data at ingest (a header
        peek, no full parse).  With lanes disabled everything lands in the
        data lane in arrival order — the flat FIFO of the old runtime.

        With reliability on, ingest is also the seq gate: frames from each
        source enter the lanes in seq order exactly once — duplicates
        (retransmits that raced the ack) are dropped here with their
        credits returned, out-of-order frames are held until the gap
        before them fills, ACK frames are consumed without ever entering a
        lane, and every sequenced frame's piggybacked ack retires the wire
        layer's retransmit state.  Returns buffers drained (held and
        dropped ones included: a duplicate arriving IS link progress)."""
        rel = self.wire.reliability
        n = 0
        for buf in self.rt.endpoint.drain():
            src = getattr(buf, "src", "")
            raw = bytes(buf)
            n += 1
            if not (rel.enabled and src and src != self.rt.name):
                self._admit_lane(src, raw)
                continue
            try:
                hdr = peek_header(raw)
            except CorruptFrame:
                hdr = None  # the error surfaces when the frame is processed
            if hdr is None:
                self._admit_lane(src, raw)
                continue
            self.wire.peer_alive(src)
            self.detector.alive(src, self.tick)
            if hdr.ack:
                self.wire.on_ack(src, hdr.ack)
            if hdr.kind == FrameKind.ACK:
                self.stats.acks_received += 1
                self._gate_progress += 1
                continue  # header-only: no payload, no credit, no lane
            if hdr.seq == 0:
                self._admit_lane(src, raw)  # unsequenced (pre-reliability)
                continue
            st = self._recv.setdefault(src, [0, {}])
            if hdr.seq <= st[0] or hdr.seq in st[1]:
                # duplicate delivery: drop before it can re-invoke, return
                # the receive credit its PUT consumed, re-owe the ack (ours
                # may have been the loss that caused the retransmit)
                self.stats.dup_frames_dropped += 1
                self._gate_progress += 1
                self.rt.fabric.credit_return(
                    src, self.rt.name, self._payloads_in(raw)
                )
                self._owe_ack(src)
                continue
            if hdr.seq > st[0] + 1:
                st[1][hdr.seq] = raw  # out of order: hold for the gap
                self.stats.frames_held_ooo += 1
                self._gate_progress += 1
                continue
            st[0] = hdr.seq
            self._admit_lane(src, raw)
            while st[0] + 1 in st[1]:  # release now-contiguous held frames
                st[0] += 1
                self._admit_lane(src, st[1].pop(st[0]))
            self._owe_ack(src)
        return n

    def _admit_lane(self, src: str, raw: bytes) -> None:
        lane = self._control if self.lanes and self._is_control(raw) else self._data
        lane.append([src, raw, 0])

    def _owe_ack(self, src: str) -> None:
        self._ack_owed.setdefault(src, self.tick)

    def cum_for(self, src: str) -> int:
        """Cumulative ingest high-water mark for ``src`` — what the wire
        layer piggybacks as the ack on every frame sent back to it."""
        st = self._recv.get(src)
        return st[0] if st is not None else 0

    def _is_control(self, raw: bytes) -> bool:
        """Control-lane admission: hop frames, rendezvous descriptors, and
        EXPRESS-flagged tenant frames — but only when they are
        *self-contained*.  A digest-only frame whose code this PE does not
        hold yet, or a descriptor for an uninstalled ifunc, depends on an
        earlier code-carrying data frame; promoting it past that frame
        would turn the sender-cache truncation protocol's in-order
        assumption into a spurious stale-cache refusal, so those stay in
        FIFO order with the data lane.  EXPRESS is a receive-side drain
        priority only: the frames still consumed credits at the sender
        (see :mod:`repro.core.pe.wire`)."""
        try:
            hdr = peek_header(raw)
        except CorruptFrame:
            return False  # the error surfaces when the frame is processed
        if hdr is None:
            return False
        if is_control(int(hdr.kind), int(hdr.flags)):
            if hdr.flags & FrameFlags.HOP:
                has_code = len(raw) >= hdr.full_total and hdr.code_len > 0
                return has_code or (
                    self.codecache.cache.lookup_digest(hdr.digest.hex()) is not None
                )
            # rendezvous descriptors never carry code: the exe must be resident
            return self.codecache.cache.has_name(hdr.name)
        if hdr.flags & FrameFlags.EXPRESS:
            # an express tenant frame drains ahead of bulk data when it is
            # self-contained (code on board or already resident)
            has_code = len(raw) >= hdr.full_total and hdr.code_len > 0
            return has_code or (
                self.codecache.cache.lookup_digest(hdr.digest.hex()) is not None
            )
        return False

    def pending(self) -> int:
        """Frames held in the engine's lanes (ingested, not yet processed)."""
        return len(self._control) + len(self._data)

    def forget_publisher(self, root: int) -> None:
        """Drop publish-dedup state for one root peer index.  A restarted
        peer re-mints pub_ids from zero; without this, its fresh publishes
        of already-seen code collide with the stale (digest, root, pub_id)
        keys recorded for its previous life and are silently dropped as
        duplicates — exactly-once would quietly become at-most-zero."""
        self._seen_pubs = {k for k in self._seen_pubs if k[1] != root}

    def _front(self) -> deque | None:
        """The lane to serve next: control drains before data."""
        if self._control:
            return self._control
        if self._data:
            return self._data
        return None

    def _take(self) -> list | None:
        """Pop the next whole frame to process — control lane first — and
        return its receive credits to the sender (the buffer is consumed)."""
        lane = self._front()
        if lane is None:
            return None
        entry = lane.popleft()
        self.rt.fabric.credit_return(
            entry[0], self.rt.name, self._payloads_in(entry[1]) - entry[2]
        )
        return entry

    @staticmethod
    def _payloads_in(buf: bytes) -> int:
        """Payload units one wire buffer carries (1, or a BATCH frame's
        packed count) — the currency the poll budget is denominated in.
        Malformed frames count as 1; their error surfaces at processing."""
        try:
            hdr = peek_header(buf)
        except CorruptFrame:
            return 1
        if hdr is None or not hdr.flags & FrameFlags.BATCH:
            return 1
        try:
            return max(1, uvarint_decode(buf, hdr.header_len)[0])
        except (CorruptFrame, IndexError):
            return 1

    # --- the poll loop -----------------------------------------------------
    def poll(self, max_msgs: int | None = None) -> int:
        """Drain the endpoint buffer, installing and invoking arrivals.

        With :attr:`WireLayer.batching` on, the drained frames are grouped
        by code digest, each group's payloads are decoded into one
        ``(B, ...)`` block and retired by a single batched XLA dispatch,
        and everything the dispatches emitted is flushed as coalesced
        per-destination PUTs.  Returns a progress count: frames processed
        plus credit-stalled sends pumped.
        """
        budget = max_msgs if max_msgs is not None else self.budget
        rel = self.wire.reliability
        if rel.enabled:
            self.tick += 1
        if self.wire.batching:
            processed = self._poll_batched(budget)
        else:
            processed = self._poll_single(budget)
        processed += self.wire.pump()
        if rel.enabled:
            processed += self._reliability_tick()
            processed += self._gate_progress
            self._gate_progress = 0
        return processed

    def _reliability_tick(self) -> int:
        """The per-poll reliability work: drive the sender's retransmit
        clock, flush overdue standalone ACKs, retire publish-dedup keys
        whose seq window is now cumulatively acked, and run the failure
        detector.  Returns a progress count (retransmits + acks + deaths —
        recovery activity must read as progress to the idle detectors)."""
        rel = self.wire.reliability
        n = self.wire.on_tick(self.tick)
        for src, since in list(self._ack_owed.items()):
            cum = self.cum_for(src)
            if cum <= self.wire.acked_sent(src):
                del self._ack_owed[src]  # a piggyback already covered it
                continue
            if self.tick - since >= rel.ack_delay:
                self.wire.send_ack(src, cum)
                del self._ack_owed[src]
                n += 1
        # bounded publish-dedup memory: once the ack for a key's carrying
        # frame has been stamped toward its sender, every future replay of
        # that frame dies at the seq gate before reaching the publish
        # handler — the key has no work left to do
        while self._pub_log:
            src, seq, key = self._pub_log[0]
            if seq > self.wire.acked_sent(src):
                break
            self._seen_pubs.discard(key)
            self._pub_log.popleft()
        for name in self.detector.check(self.tick):
            self.rt.on_peer_dead(name)
            n += 1
        return n

    def forget_src(self, src: str) -> None:
        """Drop receiver-side reliability state for one peer (declared
        dead or restarted): its seq stream restarts from zero with its
        next life, so held fragments and the old high-water mark are
        meaningless — keeping them would silently swallow the fresh
        stream's first frames as duplicates."""
        self._recv.pop(src, None)
        self._ack_owed.pop(src, None)
        if self._pub_log:
            self._pub_log = deque(e for e in self._pub_log if e[0] != src)

    def _poll_single(self, budget: int | None) -> int:
        """Per-message mode: handle frames one at a time, FIFO within each
        lane.  The first bad frame raises immediately (the old runtime's
        blast radius); the rest stays queued for the next poll."""
        self._ingest()
        n = used = 0
        while budget is None or used < budget:
            # re-ingest when the lanes run dry: a handler's sends may
            # deliver to this very endpoint (self-directed frames), and
            # the old drain loop picked those up within the same poll
            if not self.pending() and self._ingest() == 0:
                break
            entry = self._take()
            if entry is None:
                break
            # entry[2] is nonzero when a previous *batched* poll consumed
            # the frame partially and the mode switched: resume from the
            # recorded offset or the retired payloads would invoke twice
            consumed = self._payloads_in(entry[1]) - entry[2]
            used += consumed
            self.execute_frame(entry[1], start=entry[2], src=entry[0])
            n += 1
            self.stats.msgs += 1
            tracer = getattr(self.rt.fabric, "tracer", None)
            if tracer is not None:
                tracer.emit(
                    "frame", src=entry[0], dst=self.rt.name, p=consumed, done=True
                )
        if n:
            tracer = getattr(self.rt.fabric, "tracer", None)
            if tracer is not None:
                tracer.emit("poll", src=self.rt.name, tick=self.tick, p=used)
        return n

    def _poll_batched(self, budget: int | None) -> int:
        """Batched mode: take up to ``budget`` payloads (control lane
        first, big coalesced frames consumed partially), handle control/AM
        inline, group data payloads by code digest, and retire each group
        in ONE batched XLA dispatch; then flush the coalesced output burst
        even if a frame was bad."""
        self._ingest()
        taken: list[tuple[bytes, int, int | None, str]] = []  # (buf, start, stop, src)
        used = 0
        tracer = getattr(self.rt.fabric, "tracer", None)
        while budget is None or used < budget:
            lane = self._front()
            if lane is None:
                break
            src, raw, start = lane[0]
            n_pay = self._payloads_in(raw)
            remaining = n_pay - start
            take = remaining if budget is None else min(remaining, budget - used)
            if take <= 0:
                break
            used += take
            # credits are payload-denominated: return exactly what this
            # poll consumed, whether or not the frame is finished
            self.rt.fabric.credit_return(src, self.rt.name, take)
            done = start + take >= n_pay
            if tracer is not None:
                tracer.emit(
                    "frame", src=src, dst=self.rt.name, p=take, done=done
                )
            if done:
                taken.append((raw, start, None, src))
                lane.popleft()
                self.stats.msgs += 1
            else:
                # partial consumption: remember the offset, keep the buffer
                # at the lane head for the next poll
                taken.append((raw, start, start + take, src))
                lane[0][2] = start + take
        if taken and tracer is not None:
            tracer.emit("poll", src=self.rt.name, tick=self.tick, p=used)
        if taken:
            try:
                self._execute_batch(taken)
            finally:
                self.wire.flush()  # emitted actions travel even if a frame was bad
        return len(taken)

    # --- frame routing -----------------------------------------------------
    def execute_frame(self, buf: bytes, start: int = 0, src: str = "") -> None:
        """Route one wire buffer: publish hop, AM, rendezvous descriptor,
        or plain ifunc frame (install if needed, invoke per payload).
        ``start`` skips payloads a previous (budgeted, batched) poll
        already retired from this same frame; ``src`` is the sending peer
        when known (reliability bookkeeping)."""
        hdr = peek_header(buf)
        if hdr is None:
            raise ProtocolError("short frame")
        if hdr.flags & FrameFlags.HOP:
            self._handle_publish(buf, hdr, src)
            return
        if hdr.kind == FrameKind.ACTIVE_MESSAGE:
            self._handle_am(unpack(buf, has_code=False), start)
            return
        if hdr.kind == FrameKind.RNDV:
            frame = unpack(buf, has_code=False)
            for desc in split_payloads(frame)[start:]:
                exe, data = self._rndv_pull(frame.name, desc)
                if exe is None:
                    continue  # source died before the pull (detector fed)
                self.execl.invoke(exe, data)
            return
        # ifunc path: does this wire carry code? (sender truncates iff it
        # believes we have it; len tells the truth, the registry must agree)
        exe, frame = self.codecache.resolve_exe(buf, hdr)
        for pay in split_payloads(frame)[start:]:
            self.execl.invoke(exe, pay)

    def _execute_batch(self, bufs: list[tuple[bytes, int, int | None]]) -> None:
        """Group frames by code digest and invoke each group once.

        Each entry is ``(buf, start, stop)``: the payload slice the budget
        admitted this poll (``(buf, 0, None)`` = the whole frame).  A frame
        that fails to resolve (stale sender cache after a restart) or a
        group that fails to invoke (corrupt payload block) must not take
        the rest of the batch down with it: every healthy frame/group is
        still processed, then the first error is re-raised — the same
        blast radius as the per-message path.
        """
        groups: dict[bytes, tuple[CachedExecutable, list[bytes]]] = {}
        errors: list[Exception] = []
        for buf, start, stop, src in bufs:
            try:
                hdr = peek_header(buf)
                if hdr is None:
                    raise ProtocolError("short frame")
                if hdr.flags & FrameFlags.HOP:
                    # publishes are install-dominated and rare (one per PE
                    # per code distribution): handled inline, re-publishes
                    # ride the post-poll flush as everything else does
                    self._handle_publish(buf, hdr, src)
                    continue
                if hdr.kind == FrameKind.ACTIVE_MESSAGE:
                    self._handle_am(unpack(buf, has_code=False), start, stop)
                    continue
                if hdr.kind == FrameKind.RNDV:
                    # pull each staged payload, then fold it into the same
                    # digest group as any framed payloads of the same ifunc:
                    # rendezvous and eager arrivals retire in ONE dispatch
                    frame = unpack(buf, has_code=False)
                    for desc in split_payloads(frame)[start:stop]:
                        exe, data = self._rndv_pull(frame.name, desc)
                        if exe is None:
                            continue  # source died before the pull
                        entry = groups.setdefault(bytes.fromhex(exe.digest), (exe, []))
                        entry[1].append(data)
                    continue
                exe, frame = self.codecache.resolve_exe(buf, hdr)
                entry = groups.setdefault(hdr.digest, (exe, []))
                entry[1].extend(split_payloads(frame)[start:stop])
            except (ProtocolError, ValueError, ISAMismatch, EndpointDead) as e:
                errors.append(e)
        for exe, pays in groups.values():
            try:
                self.execl.invoke_batch(exe, pays)
            except Exception as e:  # noqa: BLE001 - process remaining groups
                errors.append(e)
        if errors:
            raise errors[0]

    # --- handlers ----------------------------------------------------------
    def _handle_am(self, frame, start: int = 0, stop: int | None = None) -> None:
        handler = self.rt.am_table.get(frame.name)
        if handler is None:
            raise ProtocolError(f"{self.rt.name}: no AM handler {frame.name!r}")
        for pay in split_payloads(frame)[start:stop]:
            self.stats.am_handled += 1
            handler(self.rt, pay)

    def _rndv_pull(self, name: str, desc: bytes):
        """Resolve a rendezvous descriptor: GET the staged payload from the
        source's staging region; returns ``(exe, data)``.  The executable
        must already be cached — descriptors cannot carry code (the sender
        only selects rendezvous for cache-warm peers), so a miss here means
        a stale sender cache.  Under reliability, a source that died
        between staging and the pull returns ``(None, None)`` after feeding
        the failure detector (kill-mid-rendezvous: the CQ deadline recovers
        the requester, nothing is left pinned here)."""
        src_idx, token, nbytes = unpack_rndv(desc)  # CorruptFrame if malformed
        exe = self.codecache.cache.lookup(name)
        if exe is None:
            raise ProtocolError(
                f"{self.rt.name}: rendezvous descriptor for unregistered ifunc "
                f"{name!r} (stale sender cache — was this PE restarted?)"
            )
        if not 0 <= src_idx < len(self.rt.peers):
            raise ProtocolError(
                f"{self.rt.name}: rendezvous src index {src_idx} out of range"
            )
        src = self.rt.peers[src_idx]
        try:
            data = self.wire.fetch_rndv(src, token, nbytes)
        except EndpointDead:
            if not self.wire.reliability.enabled:
                raise  # pre-reliability containment: loud at the caller
            # definitive evidence — the staging memory died with its
            # process; skip the detector's silence window entirely
            self.stats.rndv_dead_pulls += 1
            if self.detector.declare_dead(src):
                self.rt.on_peer_dead(src)
            return None, None
        except KeyError:
            # staging ring evicted the region, or the source restarted with
            # fresh (empty) registered memory — loud but contained, like the
            # framed path's stale-sender-cache refusal
            raise ProtocolError(
                f"{self.rt.name}: rendezvous staging region for token {token} "
                f"gone at {src!r} (evicted or source restarted)"
            ) from None
        return exe, data

    def _handle_publish(self, buf: bytes, hdr, src: str = "") -> None:
        """One PUBLISH hop: validate -> install -> invoke -> re-publish.

        The validation ladder runs *before* anything is installed or
        invoked, in blast-radius order (Kourtis et al.: injected code must
        be validated at every hop, not only at the origin):

        1. poisoned code — the code section's sha256 must equal the header
           digest; a mismatch is refused loudly and, crucially, is NOT
           re-published, so a poisoned frame cannot ride the tree.
        2. duplicate — (code digest, root, pub_id) already handled here:
           dropped silently (the fabric is at-least-once; re-delivery is
           normal, and the drop is what makes a forwarding loop starve).
        3. ttl expired — a frame arriving with no hop budget left was
           forwarded by a peer that should have stopped: refused loudly.
        4. cycle — this PE's own index on the visited path: refused loudly
           (the path digest was already verified by the hop parser).

        An accepted publish installs the code, invokes the payload (if the
        publish carries one — a bare publish is pure code distribution),
        and re-publishes code + payload to its tree children with one hop
        spent and itself appended to the path.  Warm children receive
        digest-only frames: the SenderCache truncation applies to hop
        frames exactly as to point-to-point sends.
        """
        has_code = len(buf) >= hdr.full_total and hdr.code_len > 0
        frame = unpack(buf, has_code=has_code)
        if frame.flags & FrameFlags.BATCH:
            raise ProtocolError(f"{self.rt.name}: publish frames never coalesce")
        hop, inner = split_hop(frame.payload)  # CorruptFrame on tampering
        me = self.rt.peer_index(self.rt.name)
        if has_code:
            self.codecache.validate_publish_code(frame, hdr)
        key = (hdr.digest, hop.root, hop.pub_id)
        if key in self._seen_pubs:
            self.stats.publish_dupes += 1
            return
        if hop.ttl <= 0:
            self.stats.refuse("publish_ttl")
            raise ProtocolError(
                f"{self.rt.name}: publish of {hdr.name!r} arrived with expired "
                f"ttl (path {hop.path})"
            )
        if me in hop.path:
            self.stats.refuse("publish_cycle")
            raise ProtocolError(
                f"{self.rt.name}: publish of {hdr.name!r} would cycle — own "
                f"index {me} already on path {hop.path}"
            )
        # the admitting hop's ttl clamps the verifier's capability stamp:
        # code delivered with budget t may never re-mint a tree deeper than t
        if has_code:
            exe = self.codecache.install(frame, admitted_ttl=hop.ttl)
        else:
            exe = self.codecache.resolve_publish_exe(hdr, admitted_ttl=hop.ttl)
        self._seen_pubs.add(key)
        if src and hdr.seq and self.wire.reliability.enabled:
            # queued for retirement once this frame's seq is cumulatively
            # acked toward src (bounded dedup memory under long gossip:
            # replays after that die at the ingest seq gate instead)
            self._pub_log.append((src, hdr.seq, key))
        self.stats.publish_handled += 1
        if inner:
            self.execl.invoke(exe, inner)
        children = tree_children(hop.k, hop.root, me, len(self.rt.peers))
        if not children:
            return
        if hop.ttl < 2:
            self.stats.publish_stopped_ttl += 1
            return
        code = frame.code if has_code else exe.extras.get("code", b"")
        self.rt.publish_to_children(
            hop.child_hop(me),
            FrameKind(exe.kind),
            exe.name,
            inner,
            code,
            exe.deps,
            bytes.fromhex(exe.digest),
        )
