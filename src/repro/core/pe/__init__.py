"""Layered PE runtime — the processing-element side of Three-Chains.

Layering (each module imports downward only; the facade composes them):

  source     — IFunc handles + Toolchain artifact registry (source side)
  wire       — frame egress: batching queues, coalesced flush, rendezvous
               staging, per-peer credit-based flow control
  codecache  — install/digest-validate arriving code, bucketed batched
               executables over the TargetCodeCache
  exec       — invoke + masked-scan update ABI + the X-RDMA action protocol
  progress   — the ProgressEngine poll loop: priority lanes, per-poll
               budget, credit return
  cq         — completion queues + futures for overlapped submissions
  pe         — the thin PE facade wiring the layers together

:mod:`repro.core.ifunc` re-exports everything here; that import surface is
guaranteed stable (``from repro.core.ifunc import PE, ...`` keeps working).
"""

from .codecache import CodeCacheLayer, ISAMismatch
from .cq import CompletionQueue, GatherFuture
from .exec import (
    ACTION_WIDTH,
    A_DONE,
    A_FORWARD,
    A_NOP,
    A_PUBLISH,
    A_RETURN,
    A_SPAWN,
    ExecLayer,
    dep_named,
    region_arg_pos,
)
from .pe import PE, PEStats
from .progress import ProgressEngine
from .source import IFunc, Toolchain
from .wire import RNDV_STAGING_DEPTH, WireLayer, is_control

__all__ = [
    "ACTION_WIDTH",
    "A_DONE",
    "A_FORWARD",
    "A_NOP",
    "A_PUBLISH",
    "A_RETURN",
    "A_SPAWN",
    "CodeCacheLayer",
    "CompletionQueue",
    "ExecLayer",
    "GatherFuture",
    "IFunc",
    "ISAMismatch",
    "PE",
    "PEStats",
    "ProgressEngine",
    "RNDV_STAGING_DEPTH",
    "Toolchain",
    "WireLayer",
    "dep_named",
    "is_control",
    "region_arg_pos",
]
