"""Completion queues: client-side tracking for overlapped X-RDMA ops.

The paper's ifuncs complete by writing into requester memory the requester
polls (ReturnResult + a counter).  This layer generalizes that to *many
overlapped operations* with epoch-tagged slot recycling; see
:class:`CompletionQueue` for the full protocol.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from .pe import PE


class CompletionQueue:
    """Client-side completion queue for in-flight X-RDMA submissions.

    The paper's ifuncs complete by writing into requester memory the
    requester polls (ReturnResult + a counter).  This layer generalizes
    that to *many overlapped operations*: a results region laid out as
    ``(max_slots, 2 + width)`` int32 rows — ``row[0]`` is the slot's
    arrived-position bitmask (popcount = distinct results arrived, so a
    re-delivered partial RETURN ORs in bits it already set and can never
    complete a slot early), ``row[1]`` its generation tag (epoch),
    ``row[2:]`` its data block — plus a free-list of slots and a future
    per in-flight submission.  RETURN ifuncs
    (e.g. :func:`repro.core.xrdma.make_gather_return`) scatter into a
    slot's block and bump its counter; because each RETURN names its slot,
    completions may arrive *out of order* and interleaved across many
    in-flight gathers, and retire through the batched update-ABI fold in
    one XLA dispatch per poll.  Each allocation bumps the slot's epoch and
    stamps it into every frame of that submission, so a late or
    re-delivered RETURN for a *retired* gather mismatches the recycled
    slot's generation and is dropped by the RETURN code — at-least-once
    delivery cannot corrupt a successor request.  Completion is
    poll-driven: nothing blocks, :meth:`GatherFuture.done` just reads the
    counter the next poll wrote.

    ``shape`` is the logical shape of one slot's data block (e.g.
    ``(n_keys, dim)`` for a gather); ``dtype`` its logical element type —
    the wire/region representation is always int32 (bit-cast, never
    converted, so float rows survive bit-identically).

    The results region doubles as the zero-copy data plane's registered
    slab: under ``DataPlaneConfig.zero_copy`` the remote PE WRITEs partial
    rows straight into the slot's data words and the fabric ORs the
    arrived-position bits into ``row[0]`` as the doorbell, guarded by the
    generation word ``row[1]`` — so ``done()``/``result()`` poll the same
    memory whether results arrived framed, one-sided, or mixed.

    Slot exhaustion is an *admission* signal, not an error:
    :meth:`try_alloc` returns ``None`` when no slot is free (the
    would-block contract :meth:`repro.core.pe.pe.PE.submit` exposes), so a
    saturated queue backpressures new submissions without disturbing the
    in-flight ones.  :meth:`_alloc` keeps the raising contract for callers
    that treat exhaustion as a bug.
    """

    def __init__(
        self,
        pe: "PE",
        shape: tuple[int, ...],
        dtype=np.int32,
        max_slots: int = 64,
        region: str = "cq_results",
    ) -> None:
        self.pe = pe
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)
        assert self.dtype.itemsize == 4, "slot blocks are int32-word addressed"
        self.width = int(np.prod(self.shape))
        self.max_slots = max_slots
        self.region = region
        pe.register_region(region, np.zeros((max_slots, 2 + self.width), np.int32))
        # the owning PE tracks its queues so a sandbox quarantine can
        # degrade the in-flight futures of a banished digest (older stub
        # PEs in unit tests may lack the registry)
        queues = getattr(pe, "completion_queues", None)
        if queues is not None:
            queues.append(self)
        self._free: deque[int] = deque(range(max_slots))
        self._inflight: dict[int, "GatherFuture"] = {}
        # per-tag (tenant) slot occupancy, for quota-bounded admission:
        # how many in-flight slots each tag currently holds
        self._tag_inflight: dict[str, int] = {}
        self._slot_tag: dict[int, str] = {}
        # deadline clock: advanced by the driving scheduler (one per service
        # tick); futures submitted under reliability expire against it
        self.ticks = 0

    def advance(self, n: int = 1) -> None:
        """Advance the deadline clock (the scheduler's tick, not wall time)."""
        self.ticks += n

    def expired(self) -> list["GatherFuture"]:
        """In-flight futures past their deadline and still incomplete —
        the set the service layer must resubmit or degrade."""
        return [f for f in list(self._inflight.values()) if f.expired()]

    # -- slot lifecycle ----------------------------------------------------
    def try_alloc(
        self, tag: str | None = None, quota: int = 0
    ) -> tuple[int, int] | None:
        """Take a free slot and advance its generation; -> (slot, epoch),
        or ``None`` when every slot is in flight (would-block).

        ``tag``/``quota`` add per-tenant admission control: with a quota
        set, a tag already holding ``quota`` in-flight slots is refused
        (the same would-block ``None``) even while global slots remain —
        one tenant cannot monopolize the completion queue."""
        if tag is not None and quota > 0 and self._tag_inflight.get(tag, 0) >= quota:
            return None
        if not self._free:
            return None
        slot = self._free.popleft()
        if tag is not None:
            self._tag_inflight[tag] = self._tag_inflight.get(tag, 0) + 1
            self._slot_tag[slot] = tag
        arr = self.pe.region(self.region)
        epoch = int(arr[slot, 1]) + 1
        arr[slot, 0] = 0
        arr[slot, 1] = epoch
        arr[slot, 2:] = 0
        # re-register so the device-resident copy the RETURN fold reads is
        # refreshed with the new generation tag
        self.pe.register_region(self.region, arr)
        tracer = getattr(getattr(self.pe, "fabric", None), "tracer", None)
        if tracer is not None:
            ev = {"src": getattr(self.pe, "name", ""), "slot": slot, "epoch": epoch}
            if tag is not None:
                ev["tn"] = tag
            tracer.emit("cq_alloc", **ev)
        return slot, epoch

    def _alloc(self) -> tuple[int, int]:
        """Raising variant of :meth:`try_alloc` (legacy contract)."""
        got = self.try_alloc()
        if got is None:
            raise RuntimeError(
                f"completion queue full ({self.max_slots} slots in flight); "
                "poll and retire futures before submitting more"
            )
        return got

    def _release(self, slot: int) -> None:
        # count/data cleared on next alloc; the epoch stays, so RETURNs
        # still in flight for the retired generation mismatch and drop
        self._inflight.pop(slot, None)
        tag = self._slot_tag.pop(slot, None)
        if tag is not None:
            left = self._tag_inflight.get(tag, 0) - 1
            if left > 0:
                self._tag_inflight[tag] = left
            else:
                self._tag_inflight.pop(tag, None)
        self._free.append(slot)
        tracer = getattr(getattr(self.pe, "fabric", None), "tracer", None)
        if tracer is not None:
            tracer.emit("cq_free", src=getattr(self.pe, "name", ""), slot=slot)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def tag_inflight(self, tag: str) -> int:
        """In-flight slots currently held by ``tag`` (tenant occupancy)."""
        return self._tag_inflight.get(tag, 0)

    def _count(self, slot: int) -> int:
        """Distinct results arrived: popcount of the position bitmask."""
        return bin(int(self.pe.region(self.region)[slot, 0]) & 0xFFFFFFFF).count("1")

    def _data(self, slot: int) -> np.ndarray:
        raw = self.pe.region(self.region)[slot, 2:]
        return raw.view(self.dtype).reshape(self.shape)

    def completed(self) -> list["GatherFuture"]:
        """Every in-flight future whose results have fully arrived."""
        return [f for f in list(self._inflight.values()) if f.done()]


@dataclass
class GatherFuture:
    """Poll-driven handle for one completion-queue submission.

    ``done()`` becomes true once ``expected`` result units have been
    RETURNed into the slot (out-of-order, possibly from several PEs);
    ``result()`` copies the slot's data block out and recycles the slot.
    ``cancel()`` abandons an in-flight submission (failed send, lost
    frame) and recycles the slot — the epoch guard makes that safe even
    if the abandoned gather's RETURNs later arrive.  ``meta`` is caller
    scratch (e.g. the original un-padded key batch).

    Reliability additions: ``submit_tick``/``deadline`` arm expiry against
    the queue's tick clock (``deadline=0`` never expires — the
    pre-reliability contract); ``attempts`` counts service-level
    resubmissions of the same logical request; :meth:`valid_mask` /
    :meth:`result_partial` expose the per-position arrival bitmask so a
    gather whose owner died can degrade to a partial result instead of
    hanging — each position is marked valid iff its RETURN actually
    landed.
    """

    queue: CompletionQueue
    slot: int
    expected: int
    meta: Any = None
    submit_tick: int = 0
    deadline: int = 0  # ticks before expiry; 0 = no deadline
    attempts: int = 0  # service-level resubmissions so far
    code_digest: str = ""  # digest of the submitted ifunc (quarantine sweep)
    poisoned: bool = False  # the submitted code was quarantined mid-flight
    _released: bool = False

    def poison(self) -> None:
        """Mark this future's code quarantined: it reads as expired from
        now on, so the service's recovery sweep degrades it through
        :meth:`result_partial` (partial rows + validity mask) instead of
        waiting for RETURNs that are never coming."""
        self.poisoned = True

    def expired(self) -> bool:
        """Past the deadline with results still missing — or poisoned by a
        sandbox quarantine (never true for a completed or released
        future; absent both, no deadline armed means no expiry)."""
        if self._released or self.done():
            return False
        if self.poisoned:
            return True
        return (
            self.deadline > 0
            and self.queue.ticks - self.submit_tick >= self.deadline
        )

    def valid_mask(self) -> np.ndarray:
        """Per-position arrival mask: ``mask[i]`` is True iff result unit
        ``i`` has been RETURNed into the slot."""
        bits = int(self.queue.pe.region(self.queue.region)[self.slot, 0])
        return np.array(
            [(bits >> i) & 1 == 1 for i in range(self.expected)], bool
        )

    def result_partial(self, release: bool = True) -> "tuple[np.ndarray, np.ndarray]":
        """Degraded completion: whatever arrived, plus the validity mask.
        Positions with ``mask[i] == False`` hold zeros (their owner died
        or their RETURN was lost past recovery) — the loud, attributed
        alternative to hanging forever."""
        if self._released:
            raise RuntimeError("future already consumed")
        mask = self.valid_mask()
        out = self.queue._data(self.slot).copy()
        if release:
            self._released = True
            self.queue._release(self.slot)
        return out, mask

    def done(self) -> bool:
        return not self._released and self.queue._count(self.slot) >= self.expected

    def result(self, release: bool = True) -> np.ndarray:
        if self._released:
            raise RuntimeError("future already consumed")
        if not self.done():
            raise RuntimeError(
                f"slot {self.slot} incomplete: "
                f"{self.queue._count(self.slot)}/{self.expected} results arrived"
            )
        out = self.queue._data(self.slot).copy()
        if release:
            self._released = True
            self.queue._release(self.slot)
        return out

    def cancel(self) -> None:
        """Abandon this submission and recycle its slot (idempotent)."""
        if not self._released:
            self._released = True
            self.queue._release(self.slot)
