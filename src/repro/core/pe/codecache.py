"""Code-cache layer: install arriving code, validate digests, and build
the batched (bucketed) executables the batched runtime dispatches.

Target side of Sec. III-C/D: extract the triple's slice from a fat-bitcode
archive -> (ORC-)JIT -> digest cache, with the name registry deciding
whether a truncated (digest-only) frame is acceptable and the digest
deciding whether a name's code is *current*.  The batched renderings —
``vmap``/``lax.map`` for value ABIs, the masked ``lax.scan`` fold for
update/propagate ABIs — are cached per (digest, power-of-two bucket) in
the same :class:`repro.core.cache.TargetCodeCache`.
"""

from __future__ import annotations

import hashlib
import time

import jax
import jax.numpy as jnp
from jax import lax

from ..bitcode import FatBitcode
from ..cache import CachedExecutable, TargetCodeCache
from ..frame import Frame, FrameKind, ProtocolError
from .exec import A_NOP, region_arg_pos


class ISAMismatch(RuntimeError):
    """Binary ifunc landed on a PE whose triple it was not compiled for."""


class CodeCacheLayer:
    """Install/resolve/batch-compile for one PE's target code cache."""

    def __init__(
        self, name: str, triple: str, cache: TargetCodeCache, stats, verifier=None
    ) -> None:
        self.name = name
        self.triple = triple
        self.cache = cache
        self.stats = stats  # the PE's PEStats (shared across layers)
        self.verifier = verifier  # the PE's Verifier (None in bare tests)

    def _gate(self, name, digest_hex, deps, exported, admitted_ttl=None) -> None:
        """Run the install-time verifier over one code-cache ingress.  A
        stamped digest is a dict hit (the warm path the benchmark pins at
        zero cost); a quarantined or failing one raises SandboxViolation
        before the code becomes resolvable."""
        ver = self.verifier
        if ver is not None and ver.config.enabled:
            ver.admit(name, digest_hex, deps, exported, admitted_ttl)

    # --- install ----------------------------------------------------------
    def install(
        self, frame: Frame, admitted_ttl: int | None = None
    ) -> CachedExecutable:
        """Extract slice -> verify -> (ORC-)JIT -> digest cache (Sec.
        III-C/D).  ``admitted_ttl`` is the admitting PUBLISH hop's
        remaining budget, clamped into the capability stamp's re-mint
        ceiling.

        A digest hit skips compilation entirely (ORC-JIT's internal symbol
        cache, which the paper observed makes re-JIT of already-seen code
        free) — only the name registration is new."""
        hit = self.cache.lookup_digest(frame.digest.hex())
        if hit is not None:
            self._gate(
                frame.name, hit.digest, frame.deps or hit.deps,
                hit.extras.get("exported"), admitted_ttl,
            )
            exe = CachedExecutable(
                name=frame.name,
                digest=hit.digest,
                fn=hit.fn,
                in_avals=hit.in_avals,
                deps=frame.deps or hit.deps,
                kind=int(frame.kind),
                extras=dict(hit.extras),
            )
            self.cache.install(exe, jit_ms=0.0)
            self.stats.ifunc_installs += 1
            return exe

        fat = FatBitcode.from_bytes(frame.code)
        if frame.kind == FrameKind.BINARY:
            # binary code is ISA/uarch-specific: exact triple or bust
            if self.triple not in fat.slices:
                raise ISAMismatch(
                    f"binary ifunc {frame.name!r} built for {fat.triples()} "
                    f"cannot run on {self.triple!r} (Sec. III-B problem; "
                    f"ship bitcode instead)"
                )
            blob = fat.slices[self.triple]
        else:
            blob = fat.extract(self.triple).blob
        exported = jax.export.deserialize(blob)
        # verify between deserialize and compile: a refused slice must not
        # cost this PE an XLA compilation (the compile itself is a resource)
        self._gate(frame.name, frame.digest.hex(), frame.deps, exported, admitted_ttl)
        t0 = time.perf_counter()
        compiled = jax.jit(exported.call).lower(*exported.in_avals).compile()
        jit_ms = (time.perf_counter() - t0) * 1e3
        abi = "pure"
        for d in frame.deps:
            if d.startswith("abi:"):
                abi = d.split(":", 1)[1]
        exe = CachedExecutable(
            name=frame.name,
            digest=frame.digest.hex(),
            fn=compiled,
            in_avals=tuple(exported.in_avals),
            deps=frame.deps,
            kind=int(frame.kind),
            extras={"code": frame.code, "abi": abi, "exported": exported},
        )
        self.cache.install(exe, jit_ms=jit_ms)
        self.stats.ifunc_installs += 1
        self.stats.jit_ms_total += jit_ms
        return exe

    # --- resolve ----------------------------------------------------------
    def resolve_exe(self, buf: bytes, hdr) -> tuple[CachedExecutable, Frame]:
        """Find (or install) the executable a frame refers to; returns it
        with the frame unpacked exactly once (code-carrying frames are
        multi-KB, a second parse is a second copy).

        The name registry decides whether a truncated frame is acceptable;
        the digest decides whether the name's code is *current* — a frame
        carrying new code under a known name (republished ifunc) installs
        and supersedes, it never silently runs the stale executable.
        """
        from ..frame import unpack

        has_code = len(buf) >= hdr.full_total and hdr.code_len > 0
        frame = unpack(buf, has_code=has_code)
        if not self.cache.has_name(hdr.name):
            if not has_code:
                raise ProtocolError(
                    f"{self.name}: truncated frame for unregistered ifunc "
                    f"{hdr.name!r} (stale sender cache — was this PE restarted?)"
                )
            return self.install(frame), frame
        exe = self.cache.lookup(hdr.name)
        assert exe is not None
        if exe.digest != hdr.digest.hex():
            if has_code:
                return self.install(frame), frame
            hit = self.cache.lookup_digest(hdr.digest.hex())
            if hit is None:
                raise ProtocolError(
                    f"{self.name}: truncated frame for {hdr.name!r} with "
                    f"unknown code digest (stale sender cache)"
                )
            exe = hit
        # warm-path gate: quarantine refusal or stamp dict hit; a digest
        # never seen by an (enabled-later) verifier is admitted here
        self._gate(exe.name, exe.digest, exe.deps, exe.extras.get("exported"))
        return exe, frame

    def validate_publish_code(self, frame: Frame, hdr) -> None:
        """Poisoned-code gate: a code-carrying publish whose code section
        does not hash to the header digest is refused loudly (and the
        caller must not re-publish it down the tree)."""
        if hashlib.sha256(frame.code).digest() != frame.digest:
            self.stats.refuse("publish_digest")
            raise ProtocolError(
                f"{self.name}: publish of {hdr.name!r} carries code that does "
                f"not match its digest (poisoned code refused, not re-published)"
            )

    def resolve_publish_exe(
        self, hdr, admitted_ttl: int | None = None
    ) -> CachedExecutable:
        """Resolve a digest-only (truncated) publish: the code must already
        be digest-cached here, or the sender's cache belief was stale."""
        exe = self.cache.lookup(hdr.name)
        if exe is None or exe.digest != hdr.digest.hex():
            hit = self.cache.lookup_digest(hdr.digest.hex())
            if hit is None:
                raise ProtocolError(
                    f"{self.name}: digest-only publish for unknown code "
                    f"{hdr.name!r} (stale sender cache — was this PE "
                    f"restarted?)"
                )
            exe = CachedExecutable(
                name=hdr.name,
                digest=hit.digest,
                fn=hit.fn,
                in_avals=hit.in_avals,
                deps=hit.deps,
                kind=int(hdr.kind),
                extras=dict(hit.extras),
            )
            self._gate(
                exe.name, exe.digest, exe.deps,
                exe.extras.get("exported"), admitted_ttl,
            )
            self.cache.install(exe, jit_ms=0.0)
            self.stats.ifunc_installs += 1
        else:
            self._gate(
                exe.name, exe.digest, exe.deps,
                exe.extras.get("exported"), admitted_ttl,
            )
        return exe

    # --- batched executables ----------------------------------------------
    @staticmethod
    def bucket(n: int) -> int:
        """Power-of-two padding bucket: bounds batched recompiles to log2."""
        return 1 << max(0, n - 1).bit_length()

    def batched_executable(self, exe: CachedExecutable, bucket: int):
        """The vmapped rendering of an installed ifunc, cached per
        (digest, bucket) in the target code cache.

        ``jax.vmap`` over a deserialized export blob needs a batching rule
        for ``call_exported``; where the installed JAX version lacks one,
        the fallback is ``lax.map`` — sequential semantics inside ONE fused
        XLA dispatch, which is the quantity being amortized.  update-ABI
        code folds payloads into the region carry with a masked ``lax.scan``
        (exact sequential semantics, one dispatch, one region write).
        """
        hit = self.cache.lookup_batched(exe.digest, bucket)
        if hit is not None:
            return hit
        exported = exe.extras["exported"]
        call = exported.call
        abi = exe.extras.get("abi", "pure")
        pay_aval = exe.in_avals[0]
        block_aval = jax.ShapeDtypeStruct((bucket, *pay_aval.shape), pay_aval.dtype)
        dep_avals = tuple(exe.in_avals[1:])
        t0 = time.perf_counter()
        if abi in ("update", "propagate"):
            # entry(payload, ..region.., ...) -> new_region (update) or
            # (new_region, actions) (propagate), folded as a scan carry;
            # padded rows are masked out so the fold is exact — a masked
            # propagate row contributes neither to the region nor an action
            # (its row is overwritten with NOPs).
            valid_aval = jax.ShapeDtypeStruct((bucket,), jnp.bool_)
            rpos = region_arg_pos(exe)

            def folded(pays, valid, region, *extra):
                def step(r, pv):
                    p, v = pv
                    dep_args = list(extra)
                    dep_args.insert(rpos, r)
                    if abi == "propagate":
                        nr, acts = call(p, *dep_args)
                        nops = jnp.zeros_like(acts).at[..., 0].set(A_NOP)
                        return jnp.where(v, nr, r), jnp.where(v, acts, nops)
                    return jnp.where(v, call(p, *dep_args), r), None

                carry, ys = lax.scan(step, region, (pays, valid))
                return (carry, ys) if abi == "propagate" else carry

            extra_avals = [a for i, a in enumerate(dep_avals) if i != rpos]
            compiled = (
                jax.jit(folded)
                .lower(block_aval, valid_aval, dep_avals[rpos], *extra_avals)
                .compile()
            )
        else:
            def vmapped(pays, *deps):
                return jax.vmap(call, in_axes=(0, *([None] * len(dep_avals))))(
                    pays, *deps
                )

            def mapped(pays, *deps):
                return lax.map(lambda p: call(p, *deps), pays)

            compiled = None
            for impl in (vmapped, mapped):
                try:
                    compiled = jax.jit(impl).lower(block_aval, *dep_avals).compile()
                    break
                except NotImplementedError:
                    continue
            assert compiled is not None
        self.stats.jit_ms_total += (time.perf_counter() - t0) * 1e3
        self.cache.install_batched(exe.digest, bucket, compiled)
        return compiled
