"""Execution layer: invoke installed executables and apply the fixed
X-RDMA action protocol their results encode.

ABI — how the runtime and injected code meet
--------------------------------------------
The paper's ifunc entry is ``main(payload, payload_size, target_ptr)`` and
may call UCX itself (via remote dynamic linking) to recursively re-inject
itself.  An XLA executable cannot call back into the transport mid-flight,
so the TPU-idiomatic rendering keeps the *decision logic in the shipped
code* and leaves only a fixed, function-agnostic action protocol in the
runtime (the moral equivalent of the UCX API the paper's ifuncs link
against):

* ``update`` ABI — ``entry(payload, region) -> new_region``.  The runtime
  stores the result back into the named memory region (TSI's counter).
* ``xrdma`` ABI — ``entry(payload, *linked_deps) -> i64[ACTION_WIDTH]``
  action vector::

      [action, dst, plen, p0 .. p7]

  ``action``: 0 DONE | 1 FORWARD (re-inject *this same ifunc*, code and
  all, to peer ``dst`` with payload ``p[:plen]``) | 2 RETURN (send the
  ifunc named by the ``returns:`` dep to ``dst``) | 3 SPAWN (send the
  ifunc named by the ``spawn:`` dep — "generate new code") | 4 NOP
  (no action; skipped by the runtime) | 5 PUBLISH (re-publish *this same
  ifunc* to peer ``dst`` under a fresh propagation hop header — ``p0`` is
  the hop ttl, ``p[1:plen]`` the published payload; this is how shipped
  code recursively propagates itself, Sec. I).
* ``propagate`` ABI — ``entry(payload, region, *deps) -> (new_region,
  actions)``: one entry both folds into its linked region (like
  ``update``) *and* emits action rows (like ``xrdma``).  Under the
  batched runtime the region fold is the same masked ``lax.scan`` as
  ``update`` — which is exactly what a tree reduction needs: child
  partials fold into the accumulator in one dispatch, and the row whose
  fold completes the subtree emits the upward FORWARD.

  An xrdma entry may instead return an ``(R, W)`` i32 *matrix* of action
  rows; the runtime applies the rows in order.  ``W`` only has to satisfy
  ``W >= 3 + plen`` for every row — rows are self-describing via their
  ``plen`` field, so one rectangular matrix carries ragged payloads.  NOP
  rows are how statically-shaped shipped code emits a *variable* number
  of actions.

  Local recursion — the paper's "ifunc calls itself recursively" when the
  next pointer is local — happens *inside* the shipped code as a
  ``lax.while_loop``: the blob chases until the frontier leaves its shard,
  then emits FORWARD.  One network action per locality break, exactly the
  paper's DAPC behaviour.

The layer is transport-blind: every action that must travel (FORWARD,
RETURN, SPAWN, PUBLISH) is handed to the runtime facade (the ``actions``
collaborator), which owns protocol selection and the wire layer.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

import numpy as np

from ..cache import CachedExecutable
from ..frame import ProtocolError
from .. import verify as _verify_codes

if TYPE_CHECKING:  # pragma: no cover - annotations only
    from .codecache import CodeCacheLayer

ACTION_WIDTH = 11  # [action, dst, plen, p0..p7]
A_DONE, A_FORWARD, A_RETURN, A_SPAWN, A_NOP, A_PUBLISH = 0, 1, 2, 3, 4, 5

# core/verify.py mirrors these codes (importing this package there would
# cycle through the pe facade); keep the two in lockstep
assert (A_DONE, A_FORWARD, A_RETURN, A_SPAWN, A_NOP, A_PUBLISH) == (
    _verify_codes.A_DONE, _verify_codes.A_FORWARD, _verify_codes.A_RETURN,
    _verify_codes.A_SPAWN, _verify_codes.A_NOP, _verify_codes.A_PUBLISH,
)


# --------------------------------------------------------- dep-list helpers
def dep_named(exe: CachedExecutable, tag: str) -> str | None:
    """First ``tag:<value>`` entry on the executable's dep list, if any."""
    for d in exe.deps:
        t, _, val = d.partition(":")
        if t == tag:
            return val
    return None


def region_arg_pos(exe: CachedExecutable) -> int:
    """Position of the (single) region among the linked dep arguments."""
    pos = 0
    for d in exe.deps:
        tag, _, _ = d.partition(":")
        if tag == "region":
            return pos
        if tag == "cap":
            pos += 1
    raise AssertionError("update ABI requires a region dep")


class ExecLayer:
    """Invoke + action application for one PE.

    ``rt`` is the runtime facade (:class:`repro.core.pe.pe.PE`): it links
    dep arguments (regions as device-resident mirrors, capabilities),
    stores update-ABI results back, collects DONE payloads, and carries
    the travelling actions to the wire.
    """

    def __init__(self, rt, codecache: "CodeCacheLayer", stats, verifier=None) -> None:
        self.rt = rt
        self.codecache = codecache
        self.stats = stats  # the PE's PEStats (shared across layers)
        self.verifier = verifier  # the PE's sandbox ledger (None in bare tests)

    # --- payload/dep decoding ---------------------------------------------
    @staticmethod
    def _pad_ragged(aval, payload: bytes) -> bytes:
        """Zero-extend a ragged payload to the entry's declared aval.

        An xrdma action row's self-describing ``plen`` lets the *send* side
        ship only the meaningful prefix (e.g. a Filter RETURN carrying just
        the survivor rows).  The executable's input shape is static, so an
        entry that declares the ``ragged:`` dep tag opts into receiver-side
        zero-padding — its semantics must not depend on the padded tail
        (the Filter fold scatters by position and drops ``-1`` slots).  A
        payload *longer* than the declared aval is still a protocol error.
        """
        want = int(np.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
        if len(payload) > want:
            raise ProtocolError(
                f"ragged payload of {len(payload)} B exceeds declared {want} B"
            )
        if len(payload) < want:
            payload = bytes(payload) + b"\0" * (want - len(payload))
        return payload

    @staticmethod
    def decode_payload(exe: CachedExecutable, payload: bytes) -> np.ndarray:
        aval = exe.in_avals[0]
        if dep_named(exe, "ragged") is not None:
            payload = ExecLayer._pad_ragged(aval, payload)
        arr = np.frombuffer(payload, dtype=aval.dtype)
        return arr.reshape(aval.shape)

    @staticmethod
    def decode_payload_block(
        exe: CachedExecutable, pays: list[bytes], bucket: int
    ) -> np.ndarray:
        """Decode N same-type payloads into a ``(bucket, ...)`` block.

        Padding rows repeat the last real payload: a real payload is known
        to terminate (e.g. a Chaser's ``while_loop`` bound), so edge-repeat
        padding can never hang where zero-padding might; padded outputs are
        simply discarded.
        """
        aval = exe.in_avals[0]
        if dep_named(exe, "ragged") is not None:
            pays = [ExecLayer._pad_ragged(aval, p) for p in pays]
        arr = np.frombuffer(b"".join(pays), dtype=aval.dtype)
        arr = arr.reshape((len(pays), *aval.shape))
        if bucket > len(pays):
            arr = np.concatenate([arr, np.repeat(arr[-1:], bucket - len(pays), axis=0)])
        return arr

    def _dep_args(self, exe: CachedExecutable) -> list[Any]:
        args: list[Any] = []
        for d in exe.deps:
            tag, _, val = d.partition(":")
            if tag == "region":
                args.append(self.rt.region_device(val))
            elif tag == "cap":
                args.append(self.rt.caps[val])
        return args

    # --- invoke -------------------------------------------------------------
    def invoke(self, exe: CachedExecutable, payload: bytes) -> None:
        ver = self.verifier
        if ver is not None and ver.config.enabled:
            # retire-time quota charge, before the dispatch: code over its
            # payload/invoke budget is refused + quarantined, never run
            ver.charge_invoke(exe, [len(payload)])
        self.stats.invokes += 1
        self.stats.invoked_payloads += 1
        pay = self.decode_payload(exe, payload)
        args = self._dep_args(exe)
        out = exe.fn(pay, *args)
        abi = exe.extras.get("abi", "pure")
        if abi == "update":
            region = dep_named(exe, "region")
            assert region is not None, "update ABI requires a region dep"
            self.rt.write_region(region, np.asarray(out))
        elif abi == "propagate":
            region = dep_named(exe, "region")
            assert region is not None, "propagate ABI requires a region dep"
            new_region, actions = out
            self.rt.write_region(region, np.asarray(new_region))
            self.apply_actions(exe, np.asarray(actions))
        elif abi == "xrdma":
            self.apply_actions(exe, np.asarray(out))
        else:  # pure
            self.rt.completed.append(np.asarray(out))

    def invoke_batch(self, exe: CachedExecutable, pays: list[bytes]) -> None:
        """Retire N same-ifunc payloads in one XLA dispatch."""
        if len(pays) == 1:  # the per-message executable is already compiled
            self.invoke(exe, pays[0])
            return
        ver = self.verifier
        if ver is not None and ver.config.enabled:
            ver.charge_invoke(exe, [len(p) for p in pays])
        n = len(pays)
        bucket = self.codecache.bucket(n)
        block = self.decode_payload_block(exe, pays, bucket)
        fn = self.codecache.batched_executable(exe, bucket)
        args = self._dep_args(exe)
        abi = exe.extras.get("abi", "pure")
        self.stats.invokes += 1
        self.stats.batched_invokes += 1
        self.stats.invoked_payloads += n
        if abi in ("update", "propagate"):
            region = dep_named(exe, "region")
            assert region is not None, f"{abi} ABI requires a region dep"
            valid = np.arange(bucket) < n
            rpos = region_arg_pos(exe)
            extra = [a for i, a in enumerate(args) if i != rpos]
            out = fn(block, valid, args[rpos], *extra)
            if abi == "propagate":
                out, acts = out
                self.rt.write_region(region, np.asarray(out))
                # padded rows were masked to NOPs inside the scan; applying
                # the real rows in payload order preserves the sequential
                # semantics (the row that completes a fold emits the action)
                for per_payload in np.asarray(acts)[:n]:
                    self.apply_actions(exe, per_payload)
            else:
                self.rt.write_region(region, np.asarray(out))
        elif abi == "xrdma":
            actions = np.asarray(fn(block, *args))[:n]
            for per_payload in actions:
                self.apply_actions(exe, per_payload)
        else:  # pure
            outs = np.asarray(fn(block, *args))[:n]
            self.rt.completed.extend(outs)

    # --- action application ---------------------------------------------------
    def apply_actions(self, exe: CachedExecutable, out: np.ndarray) -> None:
        """Apply what an xrdma entry returned: one action vector, or an
        (R, W) matrix of action rows applied in order (see module docstring)."""
        if out.ndim == 2:
            for row in out:
                self.apply_action(exe, row)
        else:
            self.apply_action(exe, out)

    def apply_action(self, exe: CachedExecutable, action: np.ndarray) -> None:
        """The fixed X-RDMA action protocol (see module docstring)."""
        code = int(action[0])
        dst_idx = int(action[1])
        plen = int(action[2])
        pay = np.ascontiguousarray(action[3 : 3 + plen])
        if code == A_NOP:
            return
        ver = self.verifier
        if ver is not None and ver.config.enabled:
            # capability-stamp action whitelist + cumulative action/fan-out
            # quotas; a refused row quarantines the digest before dispatch
            ver.charge_action(exe, code)
        if code == A_DONE:
            self.rt.completed.append(pay)
            return
        dst = self.rt.peers[dst_idx]
        if code == A_FORWARD:
            self.stats.forwards += 1
            self.rt.forward_ifunc(dst, exe, pay)
        elif code == A_RETURN:
            self.stats.returns += 1
            target = dep_named(exe, "returns")
            assert target is not None, "RETURN requires a returns: dep"
            self.rt.return_payload(dst, target, pay)
        elif code == A_SPAWN:
            self.stats.spawns += 1
            target = dep_named(exe, "spawn")
            assert target is not None, "SPAWN requires a spawn: dep"
            self.rt.send_ifunc(dst, target, pay)
        elif code == A_PUBLISH:
            # shipped code re-publishing *itself*: p0 is the hop budget it
            # grants, the rest travels as the published payload — the
            # paper's "recursively propagate itself" emitted by the code,
            # not the runtime
            self.rt.publish_self(dst, exe, pay)
        else:
            raise ProtocolError(f"bad action code {code}")
