"""Safe code injection: install-time bitcode verifier + runtime sandbox.

The paper's headline capability — remotely injected code that recursively
propagates itself — is exactly what a multi-tenant fabric cannot extend
on trust.  This module is the eBPF-shaped answer (Kourtis et al.,
PAPERS.md): *verify before install, bound at run time*.

Install-time (:meth:`Verifier.admit`): every code slice entering
:meth:`repro.core.cache.TargetCodeCache.install` — direct install,
SenderCache ship, or PUBLISH hop — is checked against its declared ABI
before it becomes resolvable:

* **op budget** — the StableHLO module's SSA-op count must fit
  ``SandboxConfig.max_ops`` (a compile bomb is refused before XLA sees it);
* **region whitelist** — the ``region:``/``cap:`` names in the slice's
  dep list must fall inside ``SandboxConfig.allowed_regions`` (empty =
  any *declared* region; ``rndv/``-prefixed transport staging regions are
  always refused — shipped code never touches the rendezvous ring);
* **action derivation** — the ``A_*`` rows the slice may emit are derived
  from its ABI (``returns:``/``spawn:`` deps gate ``A_RETURN``/``A_SPAWN``)
  and intersected with ``SandboxConfig.allowed_actions``;
* **ttl ceiling** — the capability stamp records
  ``min(config.max_publish_ttl, admitting hop's ttl)``, so hostile code
  cannot re-mint deeper publish trees than it was admitted with.

The result is a :class:`CapabilityStamp` keyed by code digest, cached
per-PE: warm-tree digest-only hops hit the stamp dict and pay nothing
(the benchmark's ``verify_overhead_pct`` pins this at 0).

Run-time (:meth:`Verifier.charge_invoke` / :meth:`Verifier.charge_action`
/ :meth:`Verifier.check_publish_ttl`): per-digest cumulative quotas —
payload bytes ingested, invoke ticks, action rows, publish fan-out —
enforced at retire time with the PR 4 poison pattern: loud
:class:`SandboxViolation`, a per-reason bump in ``PEStats.refusals``, and
the offending digest **quarantined** — uninstalled everywhere, sender
caches told to forget, queued frames dropped, in-flight CQ futures
degraded via the validity-mask path rather than hung.

``SandboxConfig`` threads like :class:`repro.core.reliability.ReliabilityConfig`:
frozen, ``enabled=False`` by default, and the disabled path is bit-for-bit
the prior runtime (every hook exits on one attribute read).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from time import perf_counter
from typing import Callable

from .frame import ProtocolError
from .propagate import DEFAULT_TTL

# Action row codes, mirrored from repro.core.pe.exec (importing the pe
# package here would cycle: pe.pe facade <- verify <- pe.exec).  The exec
# layer asserts this mirror at import time.
A_DONE, A_FORWARD, A_RETURN, A_SPAWN, A_NOP, A_PUBLISH = range(6)
ALL_ACTIONS = (A_DONE, A_FORWARD, A_RETURN, A_SPAWN, A_NOP, A_PUBLISH)
_ACTION_NAMES = {
    A_DONE: "A_DONE", A_FORWARD: "A_FORWARD", A_RETURN: "A_RETURN",
    A_SPAWN: "A_SPAWN", A_NOP: "A_NOP", A_PUBLISH: "A_PUBLISH",
}

#: transport rendezvous staging regions — never grantable to shipped code
RNDV_PREFIX = "rndv/"


class SandboxViolation(ProtocolError):
    """A code slice failed verification or blew a runtime quota.

    Subclasses :class:`repro.core.frame.ProtocolError` so the progress
    engine's per-frame containment (one poisoned frame never takes its
    batch siblings down) applies unchanged."""


@dataclass(frozen=True)
class SandboxConfig:
    """Per-PE (per tenant-class, via the router's strictest-merge) sandbox
    policy.  Frozen + off by default: with ``enabled=False`` every
    enforcement hook is a single attribute read and the runtime is
    bit-for-bit the unsandboxed one.

    Quota fields use ``0`` = unlimited.  ``allowed_regions`` empty means
    "any region the ABI declares" — the install check then only refuses
    the always-forbidden ``rndv/`` staging names — while a non-empty
    tuple is a hard whitelist over both ``region:`` and ``cap:`` deps.
    """

    enabled: bool = False
    # --- install-time verifier ---
    max_ops: int = 4096  # StableHLO SSA ops per slice (0 = unlimited)
    max_publish_ttl: int = DEFAULT_TTL  # ttl ceiling shipped code may re-mint
    allowed_regions: tuple = ()  # () = any ABI-declared region/cap
    allowed_actions: tuple = ALL_ACTIONS  # A_* codes grantable at all
    # --- run-time quotas (per code digest; 0 = unlimited) ---
    max_invoke_payload_bytes: int = 0  # largest single payload accepted
    max_payload_bytes: int = 0  # cumulative payload bytes ingested
    max_invokes: int = 0  # cumulative invoke ticks consumed
    max_actions: int = 0  # cumulative action rows emitted
    max_publish_fanout: int = 0  # cumulative A_PUBLISH rows emitted

    @classmethod
    def on(cls, **kwargs) -> "SandboxConfig":
        """Enabled config in one call: ``SandboxConfig.on(max_invokes=8)``."""
        kwargs.setdefault("enabled", True)
        return cls(**kwargs)

    @classmethod
    def strictest(cls, configs: "list[SandboxConfig]") -> "SandboxConfig":
        """Fold many tenant-class policies into the one policy the fabric
        can enforce (frames carry no tenant attribution below the router,
        so per-PE enforcement takes the conservative envelope): quotas
        take the tightest non-zero bound, action whitelists intersect,
        and region whitelists union **only when every class restricts**
        (one unrestricted class means declared-region semantics stand)."""
        if not configs:
            return cls()

        def tight(vals: "list[int]") -> int:
            nz = [v for v in vals if v]
            return min(nz) if nz else 0

        actions: set = set(ALL_ACTIONS)
        for c in configs:
            actions &= set(c.allowed_actions)
        if all(c.allowed_regions for c in configs):
            regions = tuple(sorted({r for c in configs for r in c.allowed_regions}))
        else:
            regions = ()
        return cls(
            enabled=any(c.enabled for c in configs),
            max_ops=tight([c.max_ops for c in configs]),
            max_publish_ttl=min(c.max_publish_ttl for c in configs),
            allowed_regions=regions,
            allowed_actions=tuple(sorted(actions)),
            max_invoke_payload_bytes=tight(
                [c.max_invoke_payload_bytes for c in configs]
            ),
            max_payload_bytes=tight([c.max_payload_bytes for c in configs]),
            max_invokes=tight([c.max_invokes for c in configs]),
            max_actions=tight([c.max_actions for c in configs]),
            max_publish_fanout=tight([c.max_publish_fanout for c in configs]),
        )


@dataclass
class CapabilityStamp:
    """What one verified code digest is allowed to do on this PE.  Minted
    once at cold install; every later resolve of the same digest —
    including warm-tree digest-only PUBLISH hops — is a dict hit."""

    digest: str  # sha256 hex of the fat-bitcode slice
    ops: int  # StableHLO SSA-op count measured at admission
    regions: frozenset  # region/cap names the ABI grants
    actions: frozenset  # A_* codes this code may emit
    max_ttl: int  # deepest publish tree it may re-mint
    verify_ms: float = 0.0  # cold verification cost (informational)


@dataclass
class UsageLedger:
    """Cumulative runtime consumption of one digest on one PE."""

    invokes: int = 0
    payload_bytes: int = 0
    actions: int = 0
    publishes: int = 0


def count_ops(exported) -> int:
    """StableHLO SSA-op count of one exported slice: the number of
    ``name = op`` bindings in the serialized module text.  This is the
    instruction-budget metric — deterministic, cheap (text scan), and
    measured on the *traced* code before XLA compiles anything."""
    if exported is None:
        return 0
    return exported.mlir_module().count(" = ")


class Verifier:
    """Per-PE verifier + sandbox ledger.

    The layers call four hooks: :meth:`admit` at every code-cache ingress
    (install / resolve / publish-resolve), :meth:`charge_invoke` and
    :meth:`charge_action` from the exec layer at retire time, and
    :meth:`check_publish_ttl` when locally-running code mints a new
    publish tree.  All four exit immediately when the config is disabled.
    """

    def __init__(self, name: str, stats) -> None:
        self.name = name
        self.stats = stats  # the PE's PEStats (refusal counters)
        self.config = SandboxConfig()
        self.stamps: dict[str, CapabilityStamp] = {}
        self.usage: dict[str, UsageLedger] = {}
        self.quarantined: set[str] = set()
        # local teardown (uninstall + CQ poison + queue purge), set by the
        # owning PE; fired on every quarantine, local or absorbed
        self.local_cleanup: Callable[[str, str], None] | None = None
        # cluster-wide listeners (sender-cache forget + absorb on peers),
        # fired only by the PE that *originates* the quarantine
        self.on_quarantine: list = []
        # accounting for the benchmark's warm/cold split
        self.verifies = 0  # cold verifications performed
        self.stamp_hits = 0  # warm stamp-cache reuses
        self.verify_ms_total = 0.0

    @property
    def enabled(self) -> bool:
        return self.config.enabled

    # ------------------------------------------------------------ refusals
    def _refuse(self, reason: str, msg: str) -> None:
        self.stats.refuse(reason)
        raise SandboxViolation(f"{self.name}: {msg}")

    # ------------------------------------------------------------ admission
    def admit(
        self,
        name: str,
        digest: str,
        deps: tuple,
        exported=None,
        admitted_ttl: int | None = None,
    ) -> CapabilityStamp:
        """Gate one code-cache ingress.  Quarantined digests are refused
        outright; a stamped digest is a dict hit (the warm path); anything
        else is cold-verified against the config and stamped.

        ``admitted_ttl`` is the admitting PUBLISH hop's remaining ttl —
        the stamp's re-mint ceiling is clamped under it, so code can never
        grow a deeper tree than the one that delivered it."""
        if digest in self.quarantined:
            self._refuse(
                "verify_quarantined", f"{name} [{digest[:12]}] is quarantined"
            )
        stamp = self.stamps.get(digest)
        if stamp is not None:
            self.stamp_hits += 1
            return stamp
        t0 = perf_counter()
        cfg = self.config
        ops = count_ops(exported) if cfg.max_ops else 0
        if cfg.max_ops and ops > cfg.max_ops:
            self.quarantine(digest, name)
            self._refuse(
                "verify_ops",
                f"{name} [{digest[:12]}] has {ops} ops > budget {cfg.max_ops}",
            )
        regions = frozenset(
            d.split(":", 1)[1]
            for d in deps
            if d.startswith(("region:", "cap:"))
        )
        for r in sorted(regions):
            if r.startswith(RNDV_PREFIX) or (
                cfg.allowed_regions and r not in cfg.allowed_regions
            ):
                self.quarantine(digest, name)
                self._refuse(
                    "verify_region",
                    f"{name} [{digest[:12]}] declares region {r!r} "
                    f"outside its whitelist",
                )
        actions = {A_DONE, A_NOP, A_FORWARD, A_PUBLISH}
        if any(d.startswith("returns:") for d in deps):
            actions.add(A_RETURN)
        if any(d.startswith("spawn:") for d in deps):
            actions.add(A_SPAWN)
        actions &= set(cfg.allowed_actions)
        max_ttl = cfg.max_publish_ttl
        if admitted_ttl is not None:
            max_ttl = min(max_ttl, int(admitted_ttl))
        ms = (perf_counter() - t0) * 1e3
        stamp = CapabilityStamp(
            digest=digest, ops=ops, regions=regions,
            actions=frozenset(actions), max_ttl=max_ttl, verify_ms=ms,
        )
        self.stamps[digest] = stamp
        self.verifies += 1
        self.verify_ms_total += ms
        return stamp

    # ------------------------------------------------------- runtime quotas
    def _ledger(self, digest: str) -> UsageLedger:
        led = self.usage.get(digest)
        if led is None:
            led = self.usage[digest] = UsageLedger()
        return led

    def charge_invoke(self, exe, nbytes_list: "list[int]") -> None:
        """Charge one retire-time dispatch (``len(nbytes_list)`` payloads)
        against the digest's invoke-tick and payload-byte quotas.  Runs
        *before* the dispatch: code over budget never executes again."""
        cfg = self.config
        if not cfg.enabled:
            return
        digest = exe.digest
        if digest in self.quarantined:
            self._refuse(
                "verify_quarantined",
                f"{exe.name} [{digest[:12]}] invoked while quarantined",
            )
        led = self._ledger(digest)
        if cfg.max_invoke_payload_bytes:
            worst = max(nbytes_list, default=0)
            if worst > cfg.max_invoke_payload_bytes:
                self.quarantine(digest, exe.name)
                self._refuse(
                    "quota_payload",
                    f"{exe.name} payload {worst}B > per-invoke cap "
                    f"{cfg.max_invoke_payload_bytes}B",
                )
        total = sum(nbytes_list)
        if cfg.max_payload_bytes and led.payload_bytes + total > cfg.max_payload_bytes:
            self.quarantine(digest, exe.name)
            self._refuse(
                "quota_payload",
                f"{exe.name} cumulative payload {led.payload_bytes + total}B "
                f"> quota {cfg.max_payload_bytes}B",
            )
        n = len(nbytes_list)
        if cfg.max_invokes and led.invokes + n > cfg.max_invokes:
            self.quarantine(digest, exe.name)
            self._refuse(
                "quota_invokes",
                f"{exe.name} invoke ticks {led.invokes + n} "
                f"> quota {cfg.max_invokes}",
            )
        led.invokes += n
        led.payload_bytes += total

    def charge_action(self, exe, code: int) -> None:
        """Charge one emitted action row against the digest's capability
        stamp (which ``A_*`` rows it may emit at all) and its cumulative
        action / publish-fanout quotas."""
        cfg = self.config
        if not cfg.enabled:
            return
        digest = exe.digest
        if digest in self.quarantined:
            self._refuse(
                "verify_quarantined",
                f"{exe.name} [{digest[:12]}] acting while quarantined",
            )
        stamp = self.stamps.get(digest)
        if stamp is not None and code not in stamp.actions:
            self.quarantine(digest, exe.name)
            self._refuse(
                "verify_action",
                f"{exe.name} emitted {_ACTION_NAMES.get(code, code)} "
                f"outside its capability stamp",
            )
        led = self._ledger(digest)
        led.actions += 1
        if cfg.max_actions and led.actions > cfg.max_actions:
            self.quarantine(digest, exe.name)
            self._refuse(
                "quota_actions",
                f"{exe.name} emitted {led.actions} action rows "
                f"> quota {cfg.max_actions}",
            )
        if code == A_PUBLISH:
            led.publishes += 1
            if cfg.max_publish_fanout and led.publishes > cfg.max_publish_fanout:
                self.quarantine(digest, exe.name)
                self._refuse(
                    "quota_fanout",
                    f"{exe.name} published {led.publishes} times "
                    f"> fan-out quota {cfg.max_publish_fanout}",
                )

    def check_publish_ttl(self, exe, granted_ttl: int) -> None:
        """Refuse a locally-minted publish whose granted ttl exceeds the
        code's stamped ceiling — hostile code cannot re-mint a deeper
        propagation tree than the hop that admitted it."""
        cfg = self.config
        if not cfg.enabled:
            return
        stamp = self.stamps.get(exe.digest)
        ceiling = stamp.max_ttl if stamp is not None else cfg.max_publish_ttl
        if granted_ttl > ceiling:
            self.quarantine(exe.digest, exe.name)
            self._refuse(
                "verify_ttl",
                f"{exe.name} re-minted publish ttl {granted_ttl} "
                f"> stamped ceiling {ceiling}",
            )

    # ------------------------------------------------------------ quarantine
    def quarantine(self, digest: str, name: str = "") -> None:
        """Originate a quarantine: local teardown, then tell the cluster
        (listeners invalidate sender caches and absorb on every peer)."""
        if digest in self.quarantined:
            return
        self._absorb(digest, name)
        for cb in list(self.on_quarantine):
            cb(digest, name)

    def absorb_quarantine(self, digest: str, name: str = "") -> None:
        """Apply a quarantine decided elsewhere: local teardown only —
        never re-fires the cluster listeners (no broadcast recursion)."""
        if digest in self.quarantined:
            return
        self._absorb(digest, name)

    def _absorb(self, digest: str, name: str) -> None:
        self.quarantined.add(digest)
        self.stamps.pop(digest, None)
        if self.local_cleanup is not None:
            self.local_cleanup(digest, name)
