"""Protocol-selecting data plane: eager / zero-copy / rendezvous RETURNs.

The paper's X-RDMA operations win precisely because bulk data moves
one-sidedly while only *control* travels as injected code (Sec. V: the
pointer chase returns its result with a final PUT).  The framed runtime
ships every RETURN payload inside a header-carrying PUT the receiver must
poll, decode, and re-dispatch — a framing-and-requeue tax that dominates
when the payload is rows, not control words.  This module is the UCX-style
protocol selection (short/eager/rendezvous) that removes it:

``framed``      the RETURN payload travels inside a (coalescable) frame and
                is applied by a requester-side dispatch.  Right for small
                payloads: one ``alpha`` covers a whole coalesced burst.
                Modeled cost: ``alpha + (hdr + n)/beta`` per frame.
``zerocopy``    eager one-sided: the remote PE WRITEs partial rows straight
                into the requester's registered completion slab and bumps a
                doorbell word; the requester discovers completion by polling
                memory, and the requester-side dispatch disappears.
                Modeled cost: ``alpha + (n + 4)/beta`` — no header, no code,
                no requeue.
``rendezvous``  a 16-byte descriptor travels framed; the requester pulls the
                payload with a one-sided GET from a source-registered
                staging region.  Modeled cost: ``alpha + (hdr+16)/beta +
                2*alpha + n/beta`` — the extra round trip amortizes to
                nothing once the payload dwarfs ``2*alpha``, and the eager
                path's receive-side bounce copy (which the wire model does
                not charge, but real NICs do) is avoided entirely.

Selection is sender-side, per RETURN, from the payload size and this
config — the same decision table UCX evaluates per message.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List

import numpy as np

from .frame import RNDV_DESC_NBYTES
from .transport import RegionWrite, WireModel

#: Eager/zero-copy boundary: RETURN payloads at or below this many bytes
#: stay framed (one coalesced alpha covers many of them); above it the
#: payload is written one-sidedly into the completion slab.
DEFAULT_EAGER_MAX = 256

#: Framed-eager/rendezvous boundary, calibrated by benchmarks/wire_model.py:
#: the crossover where a receive-side bounce copy at memcpy bandwidth costs
#: more than the rendezvous round trip (~2*alpha*copy_bandwidth, tens of KB
#: on every calibrated profile — the same order as UCX's default).
DEFAULT_RNDV_MIN = 32 * 1024

#: Receive-side copy bandwidth (bytes/us) charged against eager delivery in
#: the crossover model only: an eager unexpected message lands in a bounce
#: buffer and must be copied out; rendezvous and zero-copy land in place.
COPY_BUS = 10_000.0


@dataclass(frozen=True)
class SlabLayout:
    """Sender-side recipe for the zero-copy path: maps one RETURN action
    payload onto one-sided writes into the requester's registered slab —
    data segments at their slot/position offsets, a doorbell word the
    requester polls, and a generation guard that drops stale writes.

    Built next to the RETURN ifunc's codegen (``make_gather_return`` /
    ``make_return_result``), the single place that knows the slab's row
    layout; the PE runtime stays protocol-generic.
    """

    region: str
    plan: Callable[[np.ndarray], List[RegionWrite]]


@dataclass(frozen=True)
class DataPlaneConfig:
    """Per-PE protocol-selection thresholds (all sizes in payload bytes).

    The default is the pure framed plane (both fast paths disabled), which
    is bit-compatible with the pre-dataplane runtime — benchmarks A/B the
    three modes explicitly via the constructors below.
    """

    eager_max: int = DEFAULT_EAGER_MAX
    rndv_min: int = 1 << 62  # rendezvous disabled unless opted in
    zerocopy: bool = False

    @classmethod
    def framed(cls) -> "DataPlaneConfig":
        """Everything travels in frames (the PR 1 runtime, the A/B base)."""
        return cls(eager_max=1 << 62, rndv_min=1 << 62, zerocopy=False)

    @classmethod
    def zero_copy(cls, eager_max: int = DEFAULT_EAGER_MAX) -> "DataPlaneConfig":
        """Eager frames below ``eager_max``, one-sided slab WRITEs above."""
        return cls(eager_max=eager_max, rndv_min=1 << 62, zerocopy=True)

    @classmethod
    def rendezvous(cls, rndv_min: int = DEFAULT_RNDV_MIN) -> "DataPlaneConfig":
        """Eager frames below ``rndv_min``, descriptor+GET at/above it."""
        return cls(eager_max=1 << 62, rndv_min=rndv_min, zerocopy=False)

    def select(self, nbytes: int, *, slab: bool, code_cached: bool) -> str:
        """Pick the protocol for one RETURN of ``nbytes`` payload bytes.

        ``slab`` — the RETURN type declares a registered-slab layout, so a
        one-sided write knows where the bytes go.  ``code_cached`` — the
        requester already holds the RETURN ifunc's executable; rendezvous
        descriptors cannot carry code, so first contact always goes framed.
        """
        if self.zerocopy and slab and nbytes > self.eager_max:
            return "zerocopy"
        if nbytes >= self.rndv_min and code_cached:
            return "rendezvous"
        return "framed"


# ------------------------------------------------------- modeled cost table
def framed_us(wire: WireModel, nbytes: int, hdr: int = 64, copy: bool = True) -> float:
    """Eager framed delivery of one ``nbytes`` payload: wire latency plus
    (optionally) the receive-side bounce copy real NICs pay for unexpected
    eager messages."""
    t = wire.latency_us(hdr + nbytes)
    if copy:
        t += nbytes / COPY_BUS
    return t


def zerocopy_us(wire: WireModel, nbytes: int) -> float:
    """One-sided WRITE + 4-byte doorbell, landing in place (no copy)."""
    return wire.latency_us(nbytes + 4)


def rendezvous_us(wire: WireModel, nbytes: int, hdr: int = 64) -> float:
    """Framed 16-byte descriptor + one GET round trip, landing in place."""
    return wire.latency_us(hdr + RNDV_DESC_NBYTES) + 2 * wire.alpha_us + nbytes / wire.beta_Bus


def eager_rndv_crossover(wire: WireModel, hdr: int = 64, max_bytes: int = 1 << 22) -> int:
    """Smallest payload size where rendezvous beats framed eager delivery
    (doubling + bisection over the monotone cost difference)."""
    lo, hi = 1, 1
    while hi < max_bytes and framed_us(wire, hi, hdr) <= rendezvous_us(wire, hi, hdr):
        lo, hi = hi, hi * 2
    if hi >= max_bytes:
        return max_bytes
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if framed_us(wire, mid, hdr) <= rendezvous_us(wire, mid, hdr):
            lo = mid
        else:
            hi = mid
    return hi
